"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any JAX initialization; tests and
benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def mesh_kwargs(n_axes: int) -> dict:
    """jax >= 0.5 takes axis_types=(AxisType.Auto, ...); older jax has neither
    the enum nor the kwarg — explicit-sharding mode simply doesn't exist there,
    so omitting it is the exact equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod" axis.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    The "pod" axis composes with "data" for FSDP/DP (gradients cross the slower
    inter-pod links exactly once per step); "model" carries TP/EP/SP.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"), **mesh_kwargs(2))
