import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes, record memory/cost/collective artifacts for §Roofline.

THE TWO LINES ABOVE MUST STAY FIRST — jax locks the device count on first init,
and only the dry-run wants 512 placeholder devices (tests/benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Each cell writes reports/dryrun/<mesh>/<arch>__<shape>.json; failures are bugs.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, input_specs, skip_reason
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rf
from repro.models import transformer as tf
from repro.parallel.sharding import (
    param_shardings, batch_shardings, cache_shardings,
)
from repro.train.optim import TrainConfig
from repro.train.step import make_train_step, make_prefill, make_serve_step, \
    abstract_opt_state

DEFAULT_MICROBATCHES = {"train_4k": 8}


def opt_shardings(cfg, mesh, abstract_opt, psh):
    """Optimizer state shardings: mu/nu mirror params; scalars replicated."""
    del cfg   # uniform *_shardings signature; mirrors the param shardings
    out = {"mu": psh, "nu": psh,
           "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    if "ef" in abstract_opt:
        out["ef"] = psh
    return out


def lower_cell(arch: str, shape: str, mesh, mesh_name: str,
               microbatches: int | None = None, perf_variant: str = "baseline"):
    """Lower + compile one cell; returns (compiled, RooflineReport).

    perf_variant="opt" switches on the §Perf levers (activation sharding
    constraints, bf16 pre-cast before the layer scan, cast-free attention);
    "baseline" is the paper-faithful configuration."""
    import dataclasses

    cfg = get_config(arch)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if perf_variant == "opt":
        cfg = dataclasses.replace(
            cfg, shard_activations=True, dp_axes=dp, tp_axis="model",
            precast_params=True, cast_free_attention=True)
    elif perf_variant == "opt-noact":   # bisect: levers minus act constraints
        cfg = dataclasses.replace(
            cfg, precast_params=True, cast_free_attention=True)
    elif perf_variant == "opt-actonly":  # bisect: act constraints only
        cfg = dataclasses.replace(
            cfg, shard_activations=True, dp_axes=dp, tp_axis="model")
    elif perf_variant == "opt-dp":       # pure DP: "model" joins the batch axes
        cfg = dataclasses.replace(
            cfg, shard_activations=True, dp_axes=dp + ("model",), tp_axis="",
            precast_params=True, cast_free_attention=True)
    elif perf_variant == "opt-dots":     # opt + save-matmuls remat policy
        cfg = dataclasses.replace(
            cfg, shard_activations=True, dp_axes=dp, tp_axis="model",
            precast_params=True, cast_free_attention=True,
            remat_policy="dots")
    spec = SHAPES[shape]
    tp_enabled = perf_variant != "opt-dp"  # opt-dots keeps TP
    batch_extra = ("model",) if perf_variant == "opt-dp" else ()
    reason = skip_reason(cfg, shape)
    if reason:
        raise ValueError(f"SKIP {arch} x {shape}: {reason}")
    specs = input_specs(cfg, shape)
    ap = tf.abstract_params(cfg)
    psh = param_shardings(cfg, mesh, ap, tp_enabled=tp_enabled)
    n_dev = mesh.devices.size
    t0 = time.time()

    if spec.kind == "train":
        tcfg = TrainConfig(
            microbatches=microbatches or DEFAULT_MICROBATCHES.get(shape, 1))
        aos = abstract_opt_state(cfg, tcfg, ap)
        osh = opt_shardings(cfg, mesh, aos, psh)
        bsh = batch_shardings(mesh, specs["batch"], extra_axes=batch_extra)
        fn = make_train_step(cfg, tcfg)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(psh, osh, bsh), donate_argnums=(0, 1)
            ).lower(ap, aos, specs["batch"])
            compiled = lowered.compile()
    elif spec.kind == "prefill":
        bsh = batch_shardings(mesh, specs["batch"], extra_axes=batch_extra)
        fn = make_prefill(cfg, specs["cache_len"])
        with mesh:
            lowered = jax.jit(fn, in_shardings=(psh, bsh)).lower(ap, specs["batch"])
            compiled = lowered.compile()
    else:  # decode
        csh = cache_shardings(cfg, mesh, specs["cache"])
        tsh = batch_shardings(mesh, {"t": specs["tokens"]},
                              extra_axes=batch_extra)["t"]
        fn = make_serve_step(cfg)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(psh, csh, tsh), donate_argnums=(1,)
            ).lower(ap, specs["cache"], specs["tokens"])
            compiled = lowered.compile()

    dt = time.time() - t0
    rep = rf.report_from_artifacts(
        arch, shape, mesh_name, n_dev, compiled, cfg, spec,
        notes=f"compile={dt:.1f}s variant={perf_variant}")
    return compiled, rep


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             microbatches: int | None = None,
             perf_variant: str = "baseline") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if perf_variant == "baseline" else f"__{perf_variant}"
    path = os.path.join(out_dir, f"{arch}__{shape}{suffix}.json")
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape)
    if reason:
        result = {"arch": arch, "shape": shape, "mesh": mesh_name,
                  "status": "skip", "reason": reason}
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        print(f"SKIP  {arch:24s} {shape:12s} {reason}")
        return result
    try:
        compiled, rep = lower_cell(arch, shape, mesh, mesh_name, microbatches,
                                   perf_variant)
        result = {"status": "ok", **rep.to_json()}
        ma = compiled.memory_analysis()
        result["memory_analysis"] = {
            "argument_size_in_bytes": int(ma.argument_size_in_bytes),
            "output_size_in_bytes": int(ma.output_size_in_bytes),
            "temp_size_in_bytes": int(ma.temp_size_in_bytes),
            "alias_size_in_bytes": int(ma.alias_size_in_bytes),
        }
        print(f"OK    {arch:24s} {shape:12s} mesh={mesh_name} "
              f"flops={rep.hlo_flops:.3g} bytes={rep.hlo_bytes:.3g} "
              f"coll={rep.coll_bytes_raw:.3g} rho={rep.rho:.1f} "
              f"temp={rep.temp_bytes/2**30:.2f}GiB "
              f"bottleneck={rep.bottleneck} "
              f"roofline={rep.roofline_fraction():.3f} [{rep.notes}]")
    except Exception as e:
        result = {"arch": arch, "shape": shape, "mesh": mesh_name,
                  "status": "fail", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
        print(f"FAIL  {arch:24s} {shape:12s} {type(e).__name__}: {e}")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 = 512 chips (default: one 16x16 pod)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--perf", action="store_true",
                    help="enable the §Perf optimization levers (variant 'opt')")
    ap.add_argument("--variant", default=None,
                    choices=("baseline", "opt", "opt-noact", "opt-actonly", "opt-dp",
                             "opt-dots"),
                    help="explicit perf variant (overrides --perf)")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    out_dir = os.path.join(args.out, mesh_name)
    variant = args.variant or ("opt" if args.perf else "baseline")
    results = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                results.append(run_cell(arch, shape, args.multi_pod, out_dir,
                                        args.microbatches, variant))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all)")
        results.append(run_cell(args.arch, args.shape, args.multi_pod, out_dir,
                                args.microbatches, variant))
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skip" for r in results)
    n_fail = sum(r.get("status") == "fail" for r in results)
    print(f"\ndryrun[{mesh_name}]: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
