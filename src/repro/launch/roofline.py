"""Roofline extraction from compiled dry-run artifacts (§Roofline).

Three terms per (arch x shape x mesh), all in seconds PER DEVICE (XLA reports the
partitioned per-device module):

  compute    = MODEL_FLOPS/device / peak            (197 TFLOP/s bf16, TPU v5e)
  memory     = HLO_bytes_accessed * rho / HBM_bw    (819 GB/s)
  collective = collective_bytes * rho / link_bw     (~50 GB/s/link ICI)

MEASURED CAVEAT (validated in tests/test_roofline.py): XLA's HloCostAnalysis
counts while-loop bodies ONCE, so scanned structures (layer scan, microbatch
scan, KV-chunk scan) are undercounted by their trip counts. Correction: the
analytic model FLOPs are exact and the scanned bodies are homogeneous, so

  rho = max(1, MODEL_FLOPS/device / HLO_flops)

rescales bytes and collective traffic by the same trip-count factor that the
flops were undercounted by. For unrolled programs rho ~= 1 and the raw HLO
numbers stand (the tests assert this on an unrolled config).

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference) plus the explicit
attention term (2·B·S²·H·hd per layer forward, window-bounded for SWA, zero for
attention-free) — 6ND alone misses attention entirely, which matters at 32k+.

collective_bytes is NOT in cost_analysis: we parse the optimized HLO text and
sum RESULT-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (operand types are not inlined in optimized HLO
text), with ring-algorithm multipliers: all-reduce moves ~2x its payload per
device, the others ~1x.
"""

from __future__ import annotations

import dataclasses
import json
import re

TPU_PEAK_FLOPS = 197e12
TPU_HBM_BPS = 819e9
ICI_LINK_BPS = 50e9
HBM_BYTES = 16 * (1 << 30)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-algorithm traffic per device, as a multiple of the result payload
_KIND_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute)\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind result-shape bytes (with ring multipliers) from optimized HLO."""
    raw = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_shape, kind = m.group(1), m.group(2).replace("-start", "")
        total = sum(_shape_bytes(t, d) for t, d in _SHAPE_RE.findall(result_shape))
        raw[kind] += total * _KIND_MULT[kind]
        counts[kind] += 1
    return {"bytes": raw, "counts": counts, "total_bytes": sum(raw.values())}


def analytic_model_flops(cfg, shape_spec) -> float:
    """MODEL_FLOPS (global, all devices): 6ND/2ND + explicit attention term."""
    n = cfg.active_param_count()
    b, s = shape_spec.global_batch, shape_spec.seq_len
    if shape_spec.kind == "train":
        base = 6.0 * n * b * s
        attn = 3.0 * _attn_fwd_flops(cfg, b, s)      # fwd + ~2x bwd
    elif shape_spec.kind == "prefill":
        base = 2.0 * n * b * s
        attn = _attn_fwd_flops(cfg, b, s)
    else:  # decode: one token per sequence against an s-token cache
        base = 2.0 * n * b
        attn = _attn_decode_flops(cfg, b, s)
    return base + attn


def _attn_fwd_flops(cfg, b: int, s: int) -> float:
    if not getattr(cfg, "has_attention", False):
        return 0.0
    h, hd, L = cfg.n_heads, cfg.head_dim, cfg.n_layers
    if cfg.family == "hybrid":
        L = len(range(cfg.attn_every - 1, cfg.n_layers, cfg.attn_every)) \
            if cfg.attn_every else 0
    span = min(s, cfg.sliding_window) if cfg.sliding_window else s
    # QK^T + AV, causal-halved: 2 * (2 * b * s * span/2 * h * hd)
    return 2.0 * b * s * span * h * hd * L


def _attn_decode_flops(cfg, b: int, s: int) -> float:
    if not getattr(cfg, "has_attention", False):
        return 0.0
    h, hd, L = cfg.n_heads, cfg.head_dim, cfg.n_layers
    if cfg.family == "hybrid":
        L = len(range(cfg.attn_every - 1, cfg.n_layers, cfg.attn_every)) \
            if cfg.attn_every else 0
    span = min(s, cfg.sliding_window) if cfg.sliding_window else s
    return 4.0 * b * span * h * hd * L


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # raw artifacts (per device, loop bodies counted once)
    hlo_flops: float
    hlo_bytes: float
    coll_bytes_raw: float
    coll_detail: dict
    # analytic + correction
    analytic_flops_global: float
    rho: float = 1.0
    # terms (seconds, per device)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    # memory analysis (per device)
    temp_bytes: float = 0.0
    arg_bytes: float = 0.0
    fits_hbm: bool = False
    notes: str = ""

    def finalize(self):
        per_dev = self.analytic_flops_global / self.n_devices
        self.rho = max(1.0, per_dev / self.hlo_flops) if self.hlo_flops else 1.0
        self.t_compute = per_dev / TPU_PEAK_FLOPS
        self.t_memory = self.hlo_bytes * self.rho / TPU_HBM_BPS
        self.t_collective = self.coll_bytes_raw * self.rho / ICI_LINK_BPS
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (per_dev / (self.hlo_flops * self.rho)
                             if self.hlo_flops else 0.0)
        self.fits_hbm = (self.temp_bytes + self.arg_bytes) <= HBM_BYTES
        return self

    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the step under perfect overlap:
        compute_term / max(all terms). 1.0 = at the roofline."""
        m = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / m if m else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["roofline_fraction"] = self.roofline_fraction()
        return d


def report_from_artifacts(arch: str, shape: str, mesh_name: str, n_devices: int,
                          compiled, cfg, shape_spec,
                          notes: str = "") -> RooflineReport:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    try:
        ma = compiled.memory_analysis()
        temp = float(getattr(ma, "temp_size_in_bytes", 0))
        args = float(getattr(ma, "argument_size_in_bytes", 0))
    except Exception:   # pragma: no cover
        temp, args = 0.0, 0.0
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes_raw=float(coll["total_bytes"]), coll_detail=coll,
        analytic_flops_global=analytic_model_flops(cfg, shape_spec),
        temp_bytes=temp, arg_bytes=args, notes=notes,
    )
    return rep.finalize()


def save_report(report: RooflineReport, path: str):
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
