"""Serving launcher: batched prefill + decode with continuous batching-lite.

Requests are prompts of uneven length; the scheduler right-pads them into the
static prefill shape (a production system would bucket), runs one jitted prefill,
then decodes greedily with the jitted serve_step until every sequence emits EOS
or hits max_new_tokens. Finished sequences keep decoding dead tokens until the
batch drains (static shapes), which is exactly what continuous batching replaces
— the scheduler refills finished slots from the queue between decode bursts.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.parallel.sharding import param_shardings
from repro.train.step import make_prefill, make_serve_step


@dataclasses.dataclass
class ServeStats:
    prompts: int = 0
    generated_tokens: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.decode_s if self.decode_s else 0.0


def serve_batch(cfg, prompts: list, *, max_new_tokens: int = 16,
                cache_len: int = 256, eos_id: int | None = None,
                pad_id: int = 0, mesh=None, params=None,
                seed: int = 0) -> tuple:
    """Generate greedily for a batch of token-id prompts. Returns
    (list of generated id lists, ServeStats).

    Prompts are right-padded with ``pad_id`` to the longest prompt's length;
    the true lengths are threaded into prefill so each sequence's first
    generated token is predicted from its own last real token, never from
    padding. ``eos_id`` is opt-in (default: no early stop) — it no longer
    collides with the pad id by both defaulting to 0.

    Known limitation: the prefill cache still holds K/V (or recurrent state)
    for the pad positions of shorter prompts, and decode appends after the
    padded length, so tokens after the first can still attend to pads. Fixing
    that needs per-sequence cache positions + pad masking in decode (proper
    continuous batching) — production systems bucket by length instead."""
    mesh = mesh or make_host_mesh()
    b = len(prompts)
    max_len = max(len(p) for p in prompts)
    lengths = np.array([len(p) for p in prompts], np.int32)
    toks = np.full((b, max_len), pad_id, np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p          # right-pad (static prefill shape)

    if params is None:
        key = jax.random.PRNGKey(seed)
        ap = tf.abstract_params(cfg)
        psh = param_shardings(cfg, mesh, ap)
        with mesh:
            params = jax.jit(lambda k: tf.init_params(k, cfg),
                             out_shardings=psh)(key)

    prefill_fn = jax.jit(make_prefill(cfg, cache_len))
    step_fn = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    stats = ServeStats(prompts=b)

    with mesh:
        t0 = time.time()
        logits, cache = prefill_fn(
            params, {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lengths)})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        stats.prefill_s = time.time() - t0

        outs = [[int(nxt[i, 0])] for i in range(b)]
        done = np.array([eos_id is not None and outs[i][-1] == eos_id
                         for i in range(b)])
        t0 = time.time()
        for _ in range(max_new_tokens - 1):
            nxt, cache = step_fn(params, cache, nxt)
            arr = np.asarray(nxt)
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(arr[i, 0]))
                    done[i] = eos_id is not None and arr[i, 0] == eos_id
            if done.all():
                break
        stats.decode_s = time.time() - t0
    stats.generated_tokens = sum(len(o) for o in outs)
    return outs, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            rng.integers(4, args.prompt_len)).tolist()
               for _ in range(args.batch)]
    outs, stats = serve_batch(cfg, prompts, max_new_tokens=args.max_new_tokens,
                              cache_len=args.cache_len)
    for i, o in enumerate(outs):
        print(f"[serve] seq {i}: {len(o)} tokens -> {o[:12]}...")
    print(f"[serve] prefill {stats.prefill_s*1e3:.0f}ms, "
          f"{stats.tokens_per_s:.1f} tok/s decode")


if __name__ == "__main__":
    main()
