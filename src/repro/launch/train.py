"""Training launcher: real execution at any scale the runtime owns.

On this CPU container it trains smoke/~100M configs for real (examples/train_lm.py
drives it); on TPU pods the same entry point runs the full configs — the only
difference is the mesh passed in.

Fault-tolerance wiring (all unit-tested):
  * CheckpointManager: periodic + SIGTERM-triggered saves, keep-k GC.
  * resume: restores params/opt/step and fast-forwards the data iterator (the
    pipeline is stateless-indexable, so resume is exact).
  * elastic restart: restore onto a different mesh via shardings.
  * straggler watchdog: per-step wall-time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged with their step index (on real fleets
    this feeds the scheduler's replacement policy; here it is observability).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import make_batch_iterator
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tf
from repro.parallel.sharding import param_shardings, batch_shardings
from repro.ckpt.checkpoint import CheckpointManager
from repro.train.optim import TrainConfig
from repro.train.step import make_train_step, init_opt_state


@dataclasses.dataclass
class RunStats:
    steps: int = 0
    last_loss: float = float("nan")
    stragglers: int = 0
    resumed_from: int | None = None


def train_loop(cfg, tcfg: TrainConfig, *, mesh=None, batch_size: int = 8,
               seq_len: int = 128, steps: int = 50, ckpt_dir: str | None = None,
               ckpt_every: int = 20, straggler_factor: float = 3.0,
               log_every: int = 10, seed: int = 0,
               _step_hook=None) -> RunStats:
    """``_step_hook(step)`` is a test seam: called inside the timed region of
    every step (used to inject artificial stragglers)."""
    mesh = mesh or make_host_mesh()
    stats = RunStats()

    key = jax.random.PRNGKey(seed)
    ap = tf.abstract_params(cfg)
    psh = param_shardings(cfg, mesh, ap)
    with mesh:
        params = jax.jit(
            lambda k: tf.init_params(k, cfg), out_shardings=psh)(key)
    opt_state = init_opt_state(cfg, tcfg, params)

    start_step = 0
    mgr = CheckpointManager(ckpt_dir, every_steps=ckpt_every) if ckpt_dir else None
    if mgr is not None:
        restored = mgr.restore_or_none({"params": params, "opt": opt_state})
        if restored is not None:
            (state, start_step) = restored
            params, opt_state = state["params"], state["opt"]
            stats.resumed_from = start_step
            print(f"[train] resumed from step {start_step}")

    sample_batch = next(make_batch_iterator(cfg, batch_size, seq_len, seed))[1]
    bsh = batch_shardings(mesh, jax.eval_shape(lambda: sample_batch))
    it = make_batch_iterator(cfg, batch_size, seq_len, seed,
                             start_index=start_step, shardings=bsh)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    ewma = None
    with mesh:
        for step in range(start_step, steps):
            _, batch = next(it)
            t0 = time.time()
            if _step_hook is not None:
                _step_hook(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            # steps 0-1 are compile/layout-dominated (first call + donated-buffer
            # relayout); the watchdog arms after that warmup
            if step - start_step >= 2:
                if ewma is not None and dt > straggler_factor * ewma:
                    stats.stragglers += 1
                    print(f"[train] straggler: step {step} took {dt:.2f}s "
                          f"(ewma {ewma:.2f}s)")
                else:
                    ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if step % log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            stats.steps = step + 1
            stats.last_loss = loss
            if mgr is not None and mgr.should_save_now(step + 1):
                mgr.save(step + 1, {"params": params, "opt": opt_state})
                if mgr.preempted:
                    print("[train] preempted; checkpoint saved, exiting")
                    break
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=("none", "int8"),
                    default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(learning_rate=args.lr, microbatches=args.microbatches,
                       grad_compression=args.grad_compression,
                       total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))
    mesh = make_host_mesh(args.model_parallel)
    stats = train_loop(cfg, tcfg, mesh=mesh, batch_size=args.batch_size,
                       seq_len=args.seq_len, steps=args.steps,
                       ckpt_dir=args.ckpt_dir)
    print(f"[train] done: {stats}")


if __name__ == "__main__":
    main()
