from repro.serve.kv_planner import KVPlan, plan_kv_cache, kv_cache_bytes
from repro.serve.spgemm_service import (
    AdmissionError, SpGEMMFuture, SpGEMMService, SpGEMMRequest,
    SpGEMMResponse, ServiceStats, plan_key,
)

__all__ = [
    "KVPlan", "plan_kv_cache", "kv_cache_bytes",
    "AdmissionError", "SpGEMMFuture", "SpGEMMService", "SpGEMMRequest",
    "SpGEMMResponse", "ServiceStats", "plan_key",
]
