from repro.serve.kv_planner import KVPlan, plan_kv_cache, kv_cache_bytes

__all__ = ["KVPlan", "plan_kv_cache", "kv_cache_bytes"]
