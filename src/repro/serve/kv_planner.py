"""KV-cache placement planner: the paper's DP + Algorithm 4, applied to serving.

A decode step is SpGEMM-shaped: the query (A) and output (C) are tiny and
streamed; the KV cache (B) is the big, repeatedly-gathered operand. The paper's
decision tree maps directly:

  whole_fast  — cache fits HBM alongside weights: keep it resident (all decode
                shapes except extreme contexts land here).
  dp          — cache fits only if something else moves: pin the cache (B) in
                HBM, demote optimizer/aux state to host (the paper's
                "place only B fast").
  chunk1      — cache exceeds HBM: keep Q/O + weights resident (A,C fast),
                stream KV chunks from host DRAM through an HBM staging buffer
                (copy cost = cache_bytes per step -> only viable when the
                per-step compute amortizes PCIe, i.e. huge batches) — the
                capacity-scaling mode the paper built chunking for.

The planner returns the decision + the modeled per-token overhead so serving
code (and tests) can assert the policy, mirroring core/planner.py.
"""

from __future__ import annotations

import dataclasses

from repro.core.memory_model import MemorySystem, TPU_V5E_HOST
from repro.models.config import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class KVPlan:
    algorithm: str          # "whole_fast" | "dp" | "chunk_stream"
    cache_bytes: float
    weights_bytes: float
    hbm_bytes: float
    chunk_bytes: float      # staging chunk for chunk_stream (0 otherwise)
    per_step_copy_s: float  # modeled extra copy time per decode step


def kv_cache_bytes(cfg: ModelConfig, batch: int, cache_len: int) -> float:
    """Exact bytes of the decode cache pytree (KV or SSM state)."""
    cache = tf.init_cache(cfg, batch, cache_len, abstract=True)
    total = 0
    for leaf in _leaves(cache):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return float(total)


def _leaves(tree):
    import jax

    return jax.tree.leaves(tree)


def plan_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  n_devices: int = 1, weights_dtype_bytes: int = 2,
                  aux_bytes: float = 0.0,
                  system: MemorySystem = TPU_V5E_HOST,
                  staging_fraction: float = 0.25) -> KVPlan:
    """Decide where the cache lives for a decode deployment.

    ``n_devices`` divides both weights and cache (their sharded footprints);
    ``aux_bytes`` is other demotable state sharing HBM."""
    hbm = system.fast.capacity_bytes
    weights = cfg.param_count() * weights_dtype_bytes / n_devices
    cache = kv_cache_bytes(cfg, batch, cache_len) / n_devices
    if weights + cache + aux_bytes <= hbm:
        return KVPlan("whole_fast", cache, weights, hbm, 0.0, 0.0)
    if weights + cache <= hbm:
        # demote aux (paper's DP: the irregular operand keeps the fast memory)
        return KVPlan("dp", cache, weights, hbm, 0.0, 0.0)
    # stream the cache through a staging buffer (Chunk1: A/C resident)
    chunk = max(hbm - weights, hbm * staging_fraction) * staging_fraction
    per_step = system.copy_time(cache)   # every step touches the whole cache
    return KVPlan("chunk_stream", cache, weights, hbm, chunk, per_step)
