"""Batched SpGEMM serving: queue (A, B) requests, bucket by padded geometry,
execute each bucket through one compiled vmapped-scan program.

The paper's chunked algorithms (Deveci et al., 1804.00695) exist to serve big
multiplies from a small fast memory; the symmetric serving scenario — many
*small* multiplies behind one endpoint — is instead dominated by per-multiply
setup (Nagasaka & Azad, 1804.01698): replanning, repadding, and above all
recompilation. ``SpGEMMService`` amortizes all three:

  * each request gets a per-instance :class:`GeometryEnvelope` for its plan,
    **quantized** (nnz caps rounded up to a quantum, row-nnz bounds to powers
    of two) so near-identical geometries collapse into one *bucket*;
  * each bucket owns one ``(envelope, plan)`` executable per microbatch
    width drawn from a bounded **power-of-two width ladder** ({1, 2, 4, ...,
    ``max_batch``}): full flushes run at ``max_batch``, and a short flush
    tail runs at the smallest ladder width that fits instead of re-executing
    ``batch[0]`` up to ``max_batch`` times — at most ``log2(max_batch) + 1``
    compiles per bucket, no retrace on repeat traffic at any seen width;
  * a **retrace budget** caps the number of distinct buckets: once
    exhausted, new geometries fold into a compatible existing bucket (growing
    its envelope) instead of compiling program #budget+1;
  * ``backend`` selects the bucket executable: the vmapped ``lax.scan``
    cores (default), the Pallas ranged-SpGEMM kernel with explicit
    double-buffered chunk prefetch (``backend="pallas"``), the CSR-native
    sparse-output kernel (``backend="sparse"``, fast-memory footprint scaling
    with ``nnz(C)``), its hash-probe variant (``backend="hash"``, workspace
    scaling with the densest output row), or ``backend="auto"`` — each
    bucket resolves to the accumulator whose planner byte model is smallest
    under *that bucket's* envelope, so one service can serve dense-output
    buckets on the slab kernel and wide-sparse buckets on hash;
  * responses report per-request latency, the executed (padded) microbatch
    width, and the modeled fast<->slow :class:`ChunkStats` copy traffic at
    the envelope-padded staged sizes.

``benchmarks/spgemm_serving.py`` measures the resulting throughput against
naive per-instance dispatch.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from repro.core import backend_registry
from repro.core.chunk_stream import TRACE_COUNTS, chunked_spgemm_batched
from repro.core.chunking import ChunkStats, instance_envelope
from repro.core.planner import ChunkPlan, plan_knl
from repro.sparse.csr import CSR, GeometryEnvelope


def plan_key(plan: ChunkPlan) -> tuple:
    """The compile-relevant identity of a plan (cost fields excluded)."""
    return (plan.algorithm, tuple(plan.p_ac), tuple(plan.p_b))


@dataclasses.dataclass(frozen=True)
class SpGEMMRequest:
    req_id: int
    A: CSR
    B: CSR
    submit_s: float          # perf_counter timestamp at submit


@dataclasses.dataclass
class SpGEMMResponse:
    req_id: int
    C: CSR                   # assembled result for this request
    latency_s: float         # submit -> bucket results materialized
    exec_s: float            # wall time of this request's bucket execution
    bucket_key: tuple        # (GeometryEnvelope, plan_key)
    batch_size: int          # true requests in the executed microbatch
    padded_batch: int        # ladder width the microbatch was padded to
    stats: ChunkStats        # modeled copy traffic at envelope-padded sizes


@dataclasses.dataclass
class _Bucket:
    envelope: GeometryEnvelope
    plan: ChunkPlan
    queue: list              # pending SpGEMMRequest
    compiles: int = 0        # new traces of the batched core while executing
    executions: int = 0      # microbatches run
    served: int = 0          # requests completed
    widths_used: set = dataclasses.field(default_factory=set)

    @property
    def key(self) -> tuple:
        return (self.envelope, plan_key(self.plan))


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    served: int = 0
    buckets_created: int = 0
    budget_merges: int = 0     # geometries folded into an existing bucket
    budget_overflows: int = 0  # no compatible bucket; budget exceeded anyway
    dominated_hits: int = 0    # requests absorbed by a larger existing bucket
    compiles: int = 0          # total batched-core traces across all buckets
    exec_s: float = 0.0        # total bucket execution wall time
    padded_requests: int = 0   # padding slots executed (flush-tail waste)


class SpGEMMService:
    """Queue-and-flush SpGEMM endpoint over ``chunked_spgemm_batched``.

    ``plan`` pins one ChunkPlan for every request (all requests must share its
    row geometry); without it, each request is planned by ``plan_knl`` against
    ``fast_limit_bytes``. ``quantum`` controls envelope quantization (bigger =
    fewer buckets, more padding waste), ``max_batch`` the largest microbatch
    width (short flush tails drop to the smallest power-of-two ladder width
    that fits, bounding both padding waste and per-bucket compiles),
    ``retrace_budget`` the maximum number of distinct compiled buckets, and
    ``backend`` the executor every bucket runs: any registered spec with a
    batched entry (``backend_registry.batched_backends()``) or ``"auto"``,
    which resolves per bucket from the planner byte models. ``block_size``
    opts the block-level symbolic phase into every submit-time envelope
    (defaulted from the spec for block backends like ``"bsr"``; set it
    explicitly under ``"auto"`` to let buckets resolve to a block backend).
    """

    def __init__(self, plan: ChunkPlan | None = None, *,
                 fast_limit_bytes: float | None = None,
                 quantum: int = 32, max_batch: int = 4,
                 retrace_budget: int = 8, backend: str = "scan",
                 block_size: int | None = None):
        if plan is None and fast_limit_bytes is None:
            raise ValueError("need a fixed plan or fast_limit_bytes to plan by")
        if max_batch < 1 or quantum < 1 or retrace_budget < 1:
            raise ValueError("quantum, max_batch, retrace_budget must be >= 1")
        spec = None if backend == "auto" else backend_registry.get(backend)
        if spec is not None and not spec.supports_batched:
            raise ValueError(
                f"backend {backend!r} does not support batched execution")
        if block_size is None and spec is not None and spec.needs_block_caps:
            block_size = spec.block_size
        self._plan = plan
        self._fast_limit = fast_limit_bytes
        self.quantum = quantum
        self.max_batch = max_batch
        self.retrace_budget = retrace_budget
        self.backend = backend
        self.block_size = block_size
        # bounded microbatch width ladder: powers of two below max_batch plus
        # max_batch itself ({1, 2, 4, ..., max_batch})
        self.widths = sorted(
            {1 << i for i in range(max_batch.bit_length())
             if (1 << i) < max_batch} | {max_batch}
        )
        self._buckets: dict = {}         # key -> _Bucket
        self._next_id = 0
        self.stats = ServiceStats()

    # -- request path -------------------------------------------------------

    def _plan_for(self, A: CSR, B: CSR) -> ChunkPlan:
        if self._plan is not None:
            return self._plan
        return plan_knl(A, B, fast_limit_bytes=self._fast_limit)

    def _resolve_bucket(self, env: GeometryEnvelope, plan: ChunkPlan) -> _Bucket:
        key = (env, plan_key(plan))
        bucket = self._buckets.get(key)
        if bucket is not None:
            return bucket
        # a bigger already-compiled bucket serves this geometry for free
        for b in self._buckets.values():
            if plan_key(b.plan) == plan_key(plan) and b.envelope.dominates(env):
                self.stats.dominated_hits += 1
                return b
        if len(self._buckets) < self.retrace_budget:
            bucket = _Bucket(envelope=env, plan=plan, queue=[])
            self._buckets[bucket.key] = bucket
            self.stats.buckets_created += 1
            return bucket
        # budget exhausted: grow a compatible bucket's envelope instead of
        # compiling another program (its next flush retraces once, then the
        # merged geometry is stable)
        candidates = [
            b for b in self._buckets.values()
            if plan_key(b.plan) == plan_key(plan)
            and b.envelope.a_shape == env.a_shape
            and b.envelope.b_shape == env.b_shape
            and b.envelope.dtype == env.dtype
        ]
        if candidates:
            host = max(candidates, key=lambda b: b.served + len(b.queue))
            del self._buckets[host.key]
            host.envelope = host.envelope.union(env).quantized(self.quantum)
            other = self._buckets.get(host.key)
            if other is not None:
                # the grown envelope landed exactly on another bucket: fold
                # the host's queue into it rather than clobbering either
                other.queue.extend(host.queue)
                host = other
            else:
                self._buckets[host.key] = host
            self.stats.budget_merges += 1
            return host
        # nothing compatible (different shapes/plan): must exceed the budget
        bucket = _Bucket(envelope=env, plan=plan, queue=[])
        self._buckets[bucket.key] = bucket
        self.stats.buckets_created += 1
        self.stats.budget_overflows += 1
        return bucket

    def submit(self, A: CSR, B: CSR) -> int:
        """Queue one C = A x B request; returns its request id."""
        plan = self._plan_for(A, B)
        env = instance_envelope(
            A, B, plan, block_size=self.block_size).quantized(self.quantum)
        bucket = self._resolve_bucket(env, plan)
        req = SpGEMMRequest(self._next_id, A, B, time.perf_counter())
        self._next_id += 1
        bucket.queue.append(req)
        self.stats.submitted += 1
        return req.req_id

    @property
    def pending(self) -> int:
        return sum(len(b.queue) for b in self._buckets.values())

    @property
    def n_buckets(self) -> int:
        return len(self._buckets)

    def bucket_summaries(self) -> list:
        """(envelope, algorithm, compiles, executions, served, widths_used)
        per bucket."""
        return [
            (b.envelope, b.plan.algorithm, b.compiles, b.executions, b.served,
             frozenset(b.widths_used))
            for b in self._buckets.values()
        ]

    # -- execution path -----------------------------------------------------

    def _execute_bucket(self, bucket: _Bucket) -> list:
        """Drain one bucket in ladder-width microbatches; returns responses."""
        backend = self.backend
        if backend == "auto":
            # per-bucket resolution: the envelope is the geometry, so the
            # accumulator choice is stable across the bucket's lifetime
            # (until a budget merge grows the envelope — then it re-resolves)
            from repro.core.planner import select_accumulator_backend

            backend = select_accumulator_backend(bucket.plan, bucket.envelope)
        # the spec's trace-key template names the counter the compile
        # accounting below watches — no per-backend suffix table to maintain
        counter = backend_registry.get(backend).trace_key_batched.format(
            alg=bucket.plan.algorithm)
        responses = []
        while bucket.queue:
            batch = bucket.queue[: self.max_batch]
            del bucket.queue[: len(batch)]
            # pad to the smallest ladder width that fits (repeating the first
            # request; padded slots' outputs are discarded): a 1-request flush
            # tail executes 1 multiply, not max_batch, while the bounded
            # ladder keeps the retrace count at O(log max_batch) per bucket
            width = next(w for w in self.widths if w >= len(batch))
            padded = batch + [batch[0]] * (width - len(batch))
            bucket.widths_used.add(width)
            traces0 = TRACE_COUNTS[counter]
            t0 = time.perf_counter()
            # validate_caps=False: every request's exact instance envelope
            # was computed at submit time and its bucket envelope dominates
            # it by construction (domination check, union growth, quantize-
            # only-up), so the batched path's per-instance symbolic re-
            # expansion would be pure overhead on the hot path
            Cs, stats = chunked_spgemm_batched(
                [r.A for r in padded], [r.B for r in padded],
                bucket.plan, envelope=bucket.envelope, backend=backend,
                validate_caps=False,
            )
            jax.block_until_ready([(C.indptr, C.indices, C.data) for C in Cs])
            t1 = time.perf_counter()
            new_traces = TRACE_COUNTS[counter] - traces0
            bucket.compiles += new_traces
            bucket.executions += 1
            self.stats.compiles += new_traces
            self.stats.exec_s += t1 - t0
            self.stats.padded_requests += width - len(batch)
            for req, C in zip(batch, Cs[: len(batch)]):
                responses.append(SpGEMMResponse(
                    req_id=req.req_id, C=C,
                    latency_s=t1 - req.submit_s, exec_s=t1 - t0,
                    bucket_key=bucket.key, batch_size=len(batch),
                    padded_batch=width, stats=stats,
                ))
            bucket.served += len(batch)
            self.stats.served += len(batch)
        return responses

    def flush(self) -> list:
        """Execute every queued request; responses ordered by request id."""
        responses = []
        for bucket in list(self._buckets.values()):
            responses.extend(self._execute_bucket(bucket))
        responses.sort(key=lambda r: r.req_id)
        return responses
