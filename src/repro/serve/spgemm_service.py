"""Continuous-batching SpGEMM serving: async submit/poll over bucketed
compiled executables, with admission control and a bounded executable cache.

The paper's chunked algorithms (Deveci et al., 1804.00695) exist to serve big
multiplies from a small fast memory; the symmetric serving scenario — many
*small* multiplies behind one endpoint — is instead dominated by per-multiply
setup (Nagasaka & Azad, 1804.01698): replanning, repadding, and above all
recompilation. ``SpGEMMService`` amortizes all three:

  * each request gets a per-instance :class:`GeometryEnvelope` for its plan,
    **quantized** (nnz caps rounded up to a quantum, row-nnz bounds to powers
    of two) so near-identical geometries collapse into one *bucket*;
  * each bucket owns one ``(envelope, plan)`` executable per microbatch
    width drawn from a bounded **width ladder** (powers of two up to
    ``max_batch`` by default; with ``learn_tail_widths`` recurring flush-tail
    sizes earn exact widths, trading one extra compile for zero padding on
    that tail thereafter);
  * ``submit`` is **async**: it returns an :class:`SpGEMMFuture` (an ``int``
    subclass carrying the request id) immediately; :meth:`poll` flushes any
    bucket whose queue reached a full microbatch or whose oldest request
    exceeds the per-request latency SLO (``slo_s``), :meth:`drain` flushes
    everything. Due buckets execute **oldest-deadline-first**, not dict
    order;
  * **admission control**: ``max_pending`` bounds total queued requests;
    over the bound, ``admission="shed"`` raises :class:`AdmissionError` and
    ``admission="flush"`` drains the oldest-deadline bucket to make room;
  * the **retrace budget** is a real working-set bound: beyond
    ``retrace_budget`` distinct buckets, an idle bucket (empty queue, not
    flushed for ``eviction_hysteresis`` bucket-executions) is **evicted** —
    and because every bucket owns its jitted cores
    (``BackendSpec.make_batched_cores``), eviction genuinely frees the
    compiled executables; a re-arriving geometry *refaults* (recompiles
    once). With eviction disabled (``eviction_hysteresis=None``, the
    default) new geometries fold into a compatible bucket exactly as
    before;
  * responses split **compile time from execution time**: the first flush
    at a new (bucket, width) warms the executable on an envelope-shaped
    all-sentinel batch (``compile_s`` — an upper bound that includes one
    envelope-shaped execution), so ``exec_s``/``latency_s`` are never
    polluted by cold traces, and flush tails pad with the same empty
    sentinel instances instead of re-multiplying a live request;
  * the staged C-accumulator buffers are **donated** into the jitted cores
    (``donate_buffers``), letting XLA write results into the staging
    allocation on the warm path.

``benchmarks/spgemm_serving.py`` measures the resulting throughput against
naive per-instance dispatch; ``docs/serving.md`` documents the bucket
lifecycle (create -> dominate -> merge -> evict -> refault) and the knobs.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import time

import numpy as np

import jax

from repro.core import backend_registry
from repro.core.chunk_stream import TRACE_COUNTS, chunked_spgemm_batched
from repro.core.chunking import ChunkStats, instance_envelope
from repro.core.planner import (
    ChunkPlan, plan_knl, replan_for_latency, select_accumulator_backend,
)
from repro.sparse.csr import CSR, GeometryEnvelope, csr_from_scipy_like


def plan_key(plan: ChunkPlan) -> tuple:
    """The compile-relevant identity of a plan (cost fields excluded)."""
    return (plan.algorithm, tuple(plan.p_ac), tuple(plan.p_b))


class AdmissionError(RuntimeError):
    """Raised by ``submit`` when the service is over ``max_pending`` and
    admission control is set to shed."""


class SpGEMMFuture(int):
    """Async handle returned by :meth:`SpGEMMService.submit`.

    Subclasses ``int`` (the value is the request id), so callers that sort,
    hash, or compare submit results against ``SpGEMMResponse.req_id`` keep
    working unchanged. ``done()`` reports whether the request's bucket has
    executed; ``result()`` returns the response, draining the service first
    if the request is still queued (drain, not a targeted flush: a budget
    merge may have moved the request between buckets)."""

    def __new__(cls, req_id: int, service: "SpGEMMService"):
        self = super().__new__(cls, req_id)
        self._service = service
        self._response = None
        return self

    def done(self) -> bool:
        return self._response is not None

    def result(self) -> "SpGEMMResponse":
        if self._response is None:
            self._service.drain()
        if self._response is None:
            raise RuntimeError(
                f"request {int(self)} not completed by drain (was it shed?)")
        return self._response


@dataclasses.dataclass(frozen=True)
class SpGEMMRequest:
    req_id: int
    A: CSR
    B: CSR
    submit_s: float          # perf_counter timestamp at submit
    future: SpGEMMFuture | None = dataclasses.field(default=None, compare=False)


@dataclasses.dataclass
class SpGEMMResponse:
    req_id: int
    C: CSR                   # assembled result for this request
    latency_s: float         # submit -> bucket results materialized
    exec_s: float            # wall time of this request's bucket execution
    compile_s: float         # cold-trace time paid by this microbatch (0 warm)
    bucket_key: tuple        # (GeometryEnvelope, plan_key)
    batch_size: int          # true requests in the executed microbatch
    padded_batch: int        # ladder width the microbatch was padded to
    stats: ChunkStats        # modeled copy traffic at envelope-padded sizes


@dataclasses.dataclass
class _Bucket:
    envelope: GeometryEnvelope
    plan: ChunkPlan
    queue: list              # pending SpGEMMRequest
    compiles: int = 0        # new traces of the batched core while executing
    executions: int = 0      # microbatches run
    served: int = 0          # requests completed
    widths_used: set = dataclasses.field(default_factory=set)
    backend: str | None = None       # resolved executor (None until first run)
    cores: dict | None = None        # bucket-owned jitted core set
    compiled_widths: set = dataclasses.field(default_factory=set)
    last_used: int = 0               # service tick of last submit/flush
    sentinel: tuple | None = None    # cached envelope-shaped empty (A, B)
    exec_ewma: float | None = None   # per-request execution seconds, smoothed

    @property
    def key(self) -> tuple:
        return (self.envelope, plan_key(self.plan))

    def invalidate_executables(self) -> None:
        """Drop everything keyed to the old envelope (after a merge or
        replan): the cores (freeing their compiled programs), the warmed
        widths, the cached sentinel, and the resolved backend (the byte-model
        argmin may flip under the grown envelope)."""
        self.cores = None
        self.compiled_widths = set()
        self.sentinel = None
        self.backend = None


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    served: int = 0
    buckets_created: int = 0
    budget_merges: int = 0     # geometries folded into an existing bucket
    budget_overflows: int = 0  # no compatible bucket; budget exceeded anyway
    dominated_hits: int = 0    # requests absorbed by a larger existing bucket
    compiles: int = 0          # total batched-core traces across all buckets
    exec_s: float = 0.0        # total bucket execution wall time (warm only)
    compile_s: float = 0.0     # total cold-trace wall time (sentinel warmups)
    padded_requests: int = 0   # padding slots executed (flush-tail waste)
    dominated_padding_bytes: int = 0  # staged-byte waste of dominated hits
    evictions: int = 0         # idle buckets dropped to admit a new geometry
    refaults: int = 0          # evicted geometries that came back (recompiled)
    shed: int = 0              # submits rejected by admission control
    admission_flushes: int = 0  # forced flushes to stay under max_pending
    slo_flushes: int = 0       # poll() flushes triggered by the latency SLO
    replans: int = 0           # buckets re-planned from observed latency
    learned_widths: int = 0    # ladder widths added from the tail distribution


class SpGEMMService:
    """Continuous-batching SpGEMM endpoint over ``chunked_spgemm_batched``.

    ``plan`` pins one ChunkPlan for every request (all requests must share its
    row geometry); without it, each request is planned by ``plan_knl`` against
    ``fast_limit_bytes``. ``quantum`` controls envelope quantization (bigger =
    fewer buckets, more padding waste), ``max_batch`` the largest microbatch
    width, ``retrace_budget`` the maximum number of distinct compiled buckets,
    and ``backend`` the executor every bucket runs: any registered spec with a
    batched entry (``backend_registry.batched_backends()``) or ``"auto"``,
    which resolves per bucket from the planner byte models. ``block_size``
    opts the block-level symbolic phase into every submit-time envelope
    (defaulted from the spec for block backends like ``"bsr"``; set it
    explicitly under ``"auto"`` to let buckets resolve to a block backend).

    Serving knobs (all optional; defaults preserve the synchronous
    queue+flush behavior):

    * ``slo_s`` — per-request latency SLO: :meth:`poll` flushes a bucket
      whose oldest request has waited longer.
    * ``max_pending``/``admission`` — bound on total queued requests;
      ``"shed"`` raises :class:`AdmissionError`, ``"flush"`` drains the
      oldest-deadline bucket to make room.
    * ``eviction_hysteresis`` — enables cold-bucket eviction: with the
      budget full, a bucket that is idle (empty queue) and has not been
      touched for this many bucket-executions may be evicted to admit a new
      geometry. ``None`` (default) disables eviction (budget merges only).
    * ``donate_buffers`` — donate the staged C-accumulator stacks into the
      bucket-owned jitted cores (safe: the service allocates them fresh per
      flush; outputs alias the donated buffers).
    * ``learn_tail_widths`` — add a flush-tail size seen
      ``tail_learn_threshold`` times to the width ladder (one extra compile,
      zero padding for that tail thereafter).
    * ``adapt_quantum`` — per-(shapes, dtype, plan) families adapt their
      envelope quantum from observed traffic: churny families (mostly bucket
      misses) coarsen up to ``8 * quantum``, stable families (mostly hits)
      tighten down to ``quantum / 4``.
    """

    _ENV_MEMO_CAP = 256          # submit-path envelope memo entries (strong refs)
    _ADAPT_WINDOW = 16           # submits per family between quantum adjusts

    def __init__(self, plan: ChunkPlan | None = None, *,
                 fast_limit_bytes: float | None = None,
                 quantum: int = 32, max_batch: int = 4,
                 retrace_budget: int = 8, backend: str = "scan",
                 block_size: int | None = None,
                 slo_s: float | None = None,
                 max_pending: int | None = None,
                 admission: str = "shed",
                 eviction_hysteresis: int | None = None,
                 donate_buffers: bool = True,
                 learn_tail_widths: bool = False,
                 tail_learn_threshold: int = 3,
                 adapt_quantum: bool = False):
        if plan is None and fast_limit_bytes is None:
            raise ValueError("need a fixed plan or fast_limit_bytes to plan by")
        if max_batch < 1 or quantum < 1 or retrace_budget < 1:
            raise ValueError("quantum, max_batch, retrace_budget must be >= 1")
        if admission not in ("shed", "flush"):
            raise ValueError("admission must be 'shed' or 'flush'")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        if eviction_hysteresis is not None and eviction_hysteresis < 0:
            raise ValueError("eviction_hysteresis must be >= 0 (or None)")
        spec = None if backend == "auto" else backend_registry.get(backend)
        if spec is not None and not spec.supports_batched:
            raise ValueError(
                f"backend {backend!r} does not support batched execution")
        if block_size is None and spec is not None and spec.needs_block_caps:
            block_size = spec.block_size
        self._plan = plan
        self._fast_limit = fast_limit_bytes
        self.quantum = quantum
        self.max_batch = max_batch
        self.retrace_budget = retrace_budget
        self.backend = backend
        self.block_size = block_size
        self.slo_s = slo_s
        self.max_pending = max_pending
        self.admission = admission
        self.eviction_hysteresis = eviction_hysteresis
        self.donate_buffers = donate_buffers
        self.learn_tail_widths = learn_tail_widths
        self.tail_learn_threshold = tail_learn_threshold
        self.adapt_quantum = adapt_quantum
        # bounded microbatch width ladder: powers of two below max_batch plus
        # max_batch itself ({1, 2, 4, ..., max_batch}); learn_tail_widths may
        # insert observed tail sizes later
        self.widths = sorted(
            {1 << i for i in range(max_batch.bit_length())
             if (1 << i) < max_batch} | {max_batch}
        )
        self._buckets: dict = {}         # key -> _Bucket
        self._next_id = 0
        self._tick = 0                   # bucket-execution counter (LRU clock)
        self._evicted_keys: dict = {}    # bucket key -> eviction tick (bounded)
        self._ready: list = []           # responses produced outside poll/drain
        self._tail_counts: collections.Counter = collections.Counter()
        self._env_memo: collections.OrderedDict = collections.OrderedDict()
        self._family_quanta: dict = {}   # family -> adapted quantum
        self._family_traffic: dict = {}  # family -> [events, misses]
        self._plan_overrides: dict = {}  # plan_key -> replanned ChunkPlan
        self.stats = ServiceStats()

    # -- request path -------------------------------------------------------

    def _plan_for(self, A: CSR, B: CSR) -> ChunkPlan:
        plan = (self._plan if self._plan is not None
                else plan_knl(A, B, fast_limit_bytes=self._fast_limit))
        # follow latency-replan overrides (chained after repeated replans)
        seen: set = set()
        while True:
            key = plan_key(plan)
            override = self._plan_overrides.get(key)
            if override is None or key in seen:
                return plan
            seen.add(key)
            plan = override

    def _instance_env(self, A: CSR, B: CSR, plan: ChunkPlan) -> GeometryEnvelope:
        """Unquantized instance envelope, memoized by operand identity.

        ``instance_envelope`` runs the host-side symbolic expansion
        (``strip_output_caps``) — the dominant submit-path cost on warm
        traffic, which typically resubmits the *same* CSR objects. The memo
        is a bounded LRU keyed by ``(id(A), id(B), plan, block_size)`` with
        the operands themselves stored for an identity re-check (so a
        recycled ``id`` can never alias a stale envelope); the strong refs
        it holds are bounded by ``_ENV_MEMO_CAP``."""
        key = (id(A), id(B), plan_key(plan), self.block_size)
        hit = self._env_memo.get(key)
        if hit is not None and hit[0] is A and hit[1] is B:
            self._env_memo.move_to_end(key)
            return hit[2]
        env = instance_envelope(A, B, plan, block_size=self.block_size)
        self._env_memo[key] = (A, B, env)
        if len(self._env_memo) > self._ENV_MEMO_CAP:
            self._env_memo.popitem(last=False)
        return env

    def _family_quantum(self, family: tuple) -> int:
        if not self.adapt_quantum:
            return self.quantum
        return self._family_quanta.get(family, self.quantum)

    def _adapt_family(self, family: tuple, outcome: str) -> None:
        """Adapt a family's quantum from its observed hit/miss mix: mostly
        misses (new buckets, merges) means the geometry churns — coarsen so
        more of it collapses together; mostly hits means it is stable —
        tighten to shave padding. Bounded to [quantum/4, 8*quantum]."""
        if not self.adapt_quantum:
            return
        rec = self._family_traffic.setdefault(family, [0, 0])
        rec[0] += 1
        if outcome != "hit":
            rec[1] += 1
        if rec[0] < self._ADAPT_WINDOW:
            return
        events, misses = rec
        q = self._family_quanta.get(family, self.quantum)
        if misses * 2 > events:
            q = min(q * 2, self.quantum * 8)
        elif misses * 8 < events:
            q = max(q // 2, max(1, self.quantum // 4))
        self._family_quanta[family] = q
        self._family_traffic[family] = [0, 0]

    def _create_bucket(self, env: GeometryEnvelope, plan: ChunkPlan) -> _Bucket:
        bucket = _Bucket(envelope=env, plan=plan, queue=[],
                         last_used=self._tick)
        self._buckets[bucket.key] = bucket
        self.stats.buckets_created += 1
        if bucket.key in self._evicted_keys:
            del self._evicted_keys[bucket.key]
            self.stats.refaults += 1
        return bucket

    def _try_evict(self) -> bool:
        """Evict the least-recently-used idle bucket, if eviction is enabled
        and some bucket has been idle past the hysteresis. Returns whether a
        slot was freed. Only empty-queue buckets are candidates (evicting
        queued work would drop requests), and the hysteresis keeps a bucket
        that *just* flushed from bouncing out the moment a new geometry
        arrives."""
        if self.eviction_hysteresis is None:
            return False
        candidates = [
            b for b in self._buckets.values()
            if not b.queue
            and (self._tick - b.last_used) >= self.eviction_hysteresis
        ]
        if not candidates:
            return False
        victim = min(candidates, key=lambda b: b.last_used)
        del self._buckets[victim.key]
        # bounded evicted-key memory, oldest forgotten first: enough to
        # recognize refaults without growing with the geometry universe
        self._evicted_keys[victim.key] = self._tick
        cap = max(8 * self.retrace_budget, 64)
        while len(self._evicted_keys) > cap:
            self._evicted_keys.pop(next(iter(self._evicted_keys)))
        self.stats.evictions += 1
        return True

    def _resolve_bucket(self, env: GeometryEnvelope,
                        plan: ChunkPlan) -> tuple:
        """Find or make the bucket serving ``env``; returns
        ``(bucket, outcome)`` with outcome in {"hit", "create", "merge",
        "overflow"} (feeding quantum adaptation)."""
        key = (env, plan_key(plan))
        bucket = self._buckets.get(key)
        if bucket is not None:
            return bucket, "hit"
        # a bigger already-compiled bucket serves this geometry for free —
        # pick the *tightest* dominator (minimal staged padding), not the
        # first in dict order, and account the padding the hit still costs
        dominators = [
            b for b in self._buckets.values()
            if plan_key(b.plan) == plan_key(plan) and b.envelope.dominates(env)
        ]
        if dominators:
            best = min(dominators, key=lambda b: b.envelope.staged_nbytes())
            self.stats.dominated_hits += 1
            self.stats.dominated_padding_bytes += (
                best.envelope.staged_nbytes() - env.staged_nbytes())
            return best, "hit"
        if len(self._buckets) < self.retrace_budget or self._try_evict():
            return self._create_bucket(env, plan), "create"
        # budget exhausted and nothing evictable: grow a compatible bucket's
        # envelope instead of compiling another program (its next flush
        # retraces once, then the merged geometry is stable)
        candidates = [
            b for b in self._buckets.values()
            if plan_key(b.plan) == plan_key(plan)
            and b.envelope.a_shape == env.a_shape
            and b.envelope.b_shape == env.b_shape
            and b.envelope.dtype == env.dtype
        ]
        if candidates:
            host = max(candidates, key=lambda b: b.served + len(b.queue))
            del self._buckets[host.key]
            host.envelope = host.envelope.union(env).quantized(self.quantum)
            host.invalidate_executables()
            other = self._buckets.get(host.key)
            if other is not None:
                # the grown envelope landed exactly on another bucket: fold
                # the host's queue into it rather than clobbering either
                other.queue.extend(host.queue)
                host = other
            else:
                self._buckets[host.key] = host
            self.stats.budget_merges += 1
            return host, "merge"
        # nothing compatible (different shapes/plan): must exceed the budget
        bucket = self._create_bucket(env, plan)
        self.stats.budget_overflows += 1
        return bucket, "overflow"

    def _admit(self) -> None:
        if self.max_pending is None or self.pending < self.max_pending:
            return
        if self.admission == "shed":
            self.stats.shed += 1
            raise AdmissionError(
                f"{self.pending} requests pending >= max_pending="
                f"{self.max_pending} (admission='shed')")
        # admission == "flush": drain the oldest-deadline bucket to make
        # room; its responses surface through the futures and the next
        # poll/drain return
        queued = [b for b in self._buckets.values() if b.queue]
        oldest = min(queued, key=lambda b: b.queue[0].submit_s)
        self._ready.extend(self._execute_bucket(oldest))
        self.stats.admission_flushes += 1

    def submit(self, A: CSR, B: CSR) -> SpGEMMFuture:
        """Queue one C = A x B request; returns its future (an ``int``
        subclass carrying the request id). Raises :class:`AdmissionError`
        when over ``max_pending`` with ``admission="shed"``."""
        self._admit()
        plan = self._plan_for(A, B)
        raw = self._instance_env(A, B, plan)
        family = (raw.a_shape, raw.b_shape, raw.dtype, plan_key(plan))
        env = raw.quantized(self._family_quantum(family))
        bucket, outcome = self._resolve_bucket(env, plan)
        self._adapt_family(family, outcome)
        future = SpGEMMFuture(self._next_id, self)
        req = SpGEMMRequest(self._next_id, A, B, time.perf_counter(),
                            future=future)
        self._next_id += 1
        bucket.queue.append(req)
        bucket.last_used = self._tick
        self.stats.submitted += 1
        return future

    @property
    def pending(self) -> int:
        return sum(len(b.queue) for b in self._buckets.values())

    @property
    def n_buckets(self) -> int:
        return len(self._buckets)

    def bucket_summaries(self) -> list:
        """(envelope, algorithm, compiles, executions, served, widths_used)
        per bucket."""
        return [
            (b.envelope, b.plan.algorithm, b.compiles, b.executions, b.served,
             frozenset(b.widths_used))
            for b in self._buckets.values()
        ]

    # -- execution path -----------------------------------------------------

    def _sentinel_pair(self, bucket: _Bucket) -> tuple:
        """Envelope-shaped empty (A, B) instances: the padding filler for
        flush tails and the warmup batch for cold executables. An empty
        instance is dominated by every envelope, stages to the envelope's
        exact compiled shapes, and multiplies to nothing — so padded slots
        do no real multiply work and can never collide with a live request's
        donated buffers."""
        if bucket.sentinel is None:
            env = bucket.envelope

            def empty(shape: tuple) -> CSR:
                return csr_from_scipy_like(
                    np.zeros(shape[0] + 1, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.dtype(env.dtype)), shape,
                    dtype=np.dtype(env.dtype))

            bucket.sentinel = (empty(env.a_shape), empty(env.b_shape))
        return bucket.sentinel

    def _resolve_backend(self, bucket: _Bucket) -> backend_registry.BackendSpec:
        if bucket.backend is None:
            # per-bucket resolution: the envelope is the geometry, so the
            # accumulator choice is stable across the bucket's lifetime
            # (until a budget merge grows the envelope — the merge
            # invalidates the resolution along with the executables)
            bucket.backend = (
                select_accumulator_backend(bucket.plan, bucket.envelope)
                if self.backend == "auto" else self.backend)
        spec = backend_registry.get(bucket.backend)
        if bucket.cores is None and spec.make_batched_cores is not None:
            # the bucket is the sole owner of its compiled programs, so
            # evicting it (or invalidating after a merge) really frees them
            bucket.cores = spec.make_batched_cores(donate=self.donate_buffers)
        return spec

    def _run_batch(self, bucket: _Bucket, As: list, Bs: list) -> tuple:
        Cs, stats = chunked_spgemm_batched(
            As, Bs, bucket.plan, envelope=bucket.envelope,
            backend=bucket.backend, validate_caps=False, cores=bucket.cores,
        )
        jax.block_until_ready([(C.indptr, C.indices, C.data) for C in Cs])
        return Cs, stats

    def _execute_bucket(self, bucket: _Bucket) -> list:
        """Drain one bucket in ladder-width microbatches; returns responses."""
        spec = self._resolve_backend(bucket)
        # the spec's trace-key template names the counter the compile
        # accounting below watches — no per-backend suffix table to maintain
        counter = spec.trace_key_batched.format(alg=bucket.plan.algorithm)
        responses = []
        while bucket.queue:
            batch = bucket.queue[: self.max_batch]
            del bucket.queue[: len(batch)]
            size = len(batch)
            # a recurring flush tail earns its own exact ladder width: one
            # extra compile, zero padding for that tail size thereafter
            if self.learn_tail_widths and size not in self.widths:
                self._tail_counts[size] += 1
                if self._tail_counts[size] >= self.tail_learn_threshold:
                    bisect.insort(self.widths, size)
                    self.stats.learned_widths += 1
            # pad to the smallest ladder width that fits, with envelope-
            # shaped empty sentinel instances (padded slots multiply nothing
            # and their outputs are never materialized into responses)
            width = next(w for w in self.widths if w >= size)
            if width > size:
                A0, B0 = self._sentinel_pair(bucket)
                As = [r.A for r in batch] + [A0] * (width - size)
                Bs = [r.B for r in batch] + [B0] * (width - size)
            else:
                As = [r.A for r in batch]
                Bs = [r.B for r in batch]
            bucket.widths_used.add(width)
            traces0 = TRACE_COUNTS[counter]
            # validate_caps=False throughout: every request's exact instance
            # envelope was computed at submit time and its bucket envelope
            # dominates it by construction (domination check, union growth,
            # quantize-only-up), so the batched path's per-instance symbolic
            # re-expansion would be pure overhead on the hot path
            compile_s = 0.0
            if width not in bucket.compiled_widths:
                # warm the executable on an all-sentinel batch first, so the
                # cold trace (and one envelope-shaped execution — compile_s
                # is an honest upper bound, not a pure-trace time) never
                # pollutes the real batch's exec_s/latency_s
                A0, B0 = self._sentinel_pair(bucket)
                t0 = time.perf_counter()
                self._run_batch(bucket, [A0] * width, [B0] * width)
                compile_s = time.perf_counter() - t0
                bucket.compiled_widths.add(width)
                self.stats.compile_s += compile_s
            t0 = time.perf_counter()
            Cs, stats = self._run_batch(bucket, As, Bs)
            t1 = time.perf_counter()
            exec_s = t1 - t0
            new_traces = TRACE_COUNTS[counter] - traces0
            bucket.compiles += new_traces
            bucket.executions += 1
            self._tick += 1
            bucket.last_used = self._tick
            ewma = exec_s / size
            bucket.exec_ewma = (ewma if bucket.exec_ewma is None
                                else 0.5 * bucket.exec_ewma + 0.5 * ewma)
            self.stats.compiles += new_traces
            self.stats.exec_s += exec_s
            self.stats.padded_requests += width - size
            for req, C in zip(batch, Cs[:size]):
                resp = SpGEMMResponse(
                    req_id=req.req_id, C=C,
                    latency_s=t1 - req.submit_s, exec_s=exec_s,
                    compile_s=compile_s,
                    bucket_key=bucket.key, batch_size=size,
                    padded_batch=width, stats=stats,
                )
                if req.future is not None:
                    req.future._response = resp
                responses.append(resp)
            bucket.served += size
            self.stats.served += size
        return responses

    def _take_ready(self) -> list:
        out, self._ready = self._ready, []
        return out

    def _due_buckets(self) -> list:
        """Buckets with something to run, oldest queued request first — the
        priority order every flush walks (oldest-deadline-first, not dict
        insertion order)."""
        queued = [b for b in self._buckets.values() if b.queue]
        return sorted(queued, key=lambda b: b.queue[0].submit_s)

    def poll(self) -> list:
        """Flush every *due* bucket: queue reached a full microbatch, or the
        oldest request has waited past ``slo_s``. Due buckets run
        oldest-deadline-first and responses return in execution order
        (plus any responses an admission flush produced since the last
        poll/drain)."""
        now = time.perf_counter()
        responses = self._take_ready()
        for bucket in self._due_buckets():
            if len(bucket.queue) >= self.max_batch:
                responses.extend(self._execute_bucket(bucket))
            elif (self.slo_s is not None
                    and now - bucket.queue[0].submit_s > self.slo_s):
                self.stats.slo_flushes += 1
                responses.extend(self._execute_bucket(bucket))
        return responses

    def drain(self) -> list:
        """Execute every queued request (oldest-deadline bucket first);
        responses ordered by request id."""
        responses = self._take_ready()
        for bucket in self._due_buckets():
            responses.extend(self._execute_bucket(bucket))
        responses.sort(key=lambda r: r.req_id)
        return responses

    def flush(self) -> list:
        """Synchronous alias of :meth:`drain` (the original queue+flush API)."""
        return self.drain()

    # -- feedback path ------------------------------------------------------

    def replan_lagging_buckets(self, slo_s: float | None = None) -> int:
        """Feed observed per-bucket latency back into planning: any bucket
        whose smoothed per-request execution time exceeds the SLO is
        re-planned with a coarser streamed-B partition
        (``planner.replan_for_latency`` — fewer, larger chunks, fewer kernel
        launches), its executables dropped, and its queued requests re-routed
        through the new plan (their envelopes are rebuilt: the chunk bounds
        changed). The override sticks: future submits that would have used
        the old plan get the replanned one. Returns the number of buckets
        re-planned."""
        slo = self.slo_s if slo_s is None else slo_s
        if slo is None:
            raise ValueError("replan_lagging_buckets needs slo_s (argument "
                             "or service-level)")
        replanned = 0
        for bucket in list(self._buckets.values()):
            if (bucket.exec_ewma is None or bucket.exec_ewma <= slo
                    or bucket.plan.n_b <= 1):
                continue
            new_plan = replan_for_latency(bucket.plan)
            if plan_key(new_plan) == plan_key(bucket.plan):
                continue
            self._plan_overrides[plan_key(bucket.plan)] = new_plan
            del self._buckets[bucket.key]
            self.stats.replans += 1
            replanned += 1
            for req in bucket.queue:
                raw = self._instance_env(req.A, req.B, new_plan)
                family = (raw.a_shape, raw.b_shape, raw.dtype,
                          plan_key(new_plan))
                env = raw.quantized(self._family_quantum(family))
                target, _ = self._resolve_bucket(env, new_plan)
                target.queue.append(req)
        return replanned
