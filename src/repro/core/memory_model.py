"""Two-level memory cost model (paper §3) with machine presets.

This container is CPU-only, so the paper's absolute GFLOP/s cannot be re-measured.
What *can* be reproduced exactly are the paper's decisions and relative effects, all
of which flow from a small analytic model of each memory level:

  time(op) = bytes_streamed / bandwidth  +  discrete_accesses * latency

per level, where the number of *discrete* accesses to B is derived from a reuse-
distance (LRU stack distance) simulation of KKMEM's access trace (repro.core.locality).
The presets below carry the paper's hardware constants; TPU_V5E carries the roofline
constants mandated for §Roofline.

Calibration targets from the paper that this model reproduces (validated in
tests/test_memory_model.py and benchmarks/):
  * KNL: HBM/DDR differ ~5x in bandwidth, ~equal latency -> bandwidth-bound cases
    (R x A, low delta) benefit from HBM; latency term never dominates.
  * P100: host-pinned differs in BOTH bandwidth (~20x) and latency (~5x) -> B_Pin
    placements collapse 7x-29x (Table 3); chunking becomes essential.
"""

from __future__ import annotations

import dataclasses

GiB = float(1 << 30)


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    name: str
    capacity_bytes: float
    bandwidth_Bps: float     # streaming bandwidth, bytes/s
    latency_s: float         # per discrete (non-prefetched) access
    granularity_bytes: int = 64   # transfer granularity (cache line / sector)
    concurrency: float = 64.0     # outstanding requests that overlap latency
    random_eff: float = 1.0       # fraction of stream bandwidth achieved by
                                  # scattered granule-sized reads (DRAM row-buffer
                                  # misses; MCDRAM's extra banks fare better)
    # (Little's law: a many-threaded KNL or a GPU HBM hides per-access latency
    # behind hundreds of in-flight misses; a host-pinned NVLink path does not —
    # this is exactly the bandwidth-vs-latency asymmetry the paper studies.)

    def stream_time(self, nbytes: float) -> float:
        return nbytes / self.bandwidth_Bps

    def access_time(self, n_accesses: float, bytes_per_access: float) -> float:
        """Discrete-access cost: every miss moves whole transfer granules and
        pays latency diluted by the level's sustainable concurrency. Only the
        FIRST granule of each access pays the scattered-read penalty; the rest
        of the row streams sequentially — the prefetch amortization of paper
        §3.1 (dense B rows approach stream bandwidth)."""
        lines = max(1.0, bytes_per_access / self.granularity_bytes)
        first = self.granularity_bytes / (self.bandwidth_Bps * self.random_eff)
        rest = (lines - 1.0) * self.granularity_bytes / self.bandwidth_Bps
        lat_term = self.latency_s / self.concurrency
        return n_accesses * (first + rest + lat_term)


@dataclasses.dataclass(frozen=True)
class MemorySystem:
    """A fast + slow memory pair with an explicit copy engine between them."""

    name: str
    fast: MemoryLevel
    slow: MemoryLevel
    copy_bandwidth_Bps: float   # fast<->slow copy engine (DMA / memcpy) bandwidth
    flops_peak: float           # peak FLOP/s of the compute attached to this memory
    spgemm_core_rate: float = 0.0
    # Sustained FLOP/s through the scalar accumulator pipeline (hash inserts,
    # index arithmetic) — SpGEMM never runs at vector peak. The paper's measured
    # ceilings: ~5 GFLOP/s on KNL (Fig 3/4, Table 2), ~23 GFLOP/s on P100
    # (Fig 6/7). This cap is what closes the DDR/HBM gap at high delta (Table 2).

    def copy_time(self, nbytes: float) -> float:
        return nbytes / self.copy_bandwidth_Bps

    def level(self, space: str) -> MemoryLevel:
        if space == "fast":
            return self.fast
        if space == "slow":
            return self.slow
        raise ValueError(f"space must be 'fast'|'slow', got {space!r}")


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

# Intel Xeon Phi 7250 (paper §3.2): 16 GB MCDRAM ~460 GB/s, 96 GB DDR4 ~90 GB/s.
# Latencies are comparable (MCDRAM slightly *higher*, ~150ns vs ~130ns) and both
# are hidden behind 256 hardware threads' outstanding misses — on KNL the levels
# differ in BANDWIDTH only, the paper's central premise for this machine.
KNL = MemorySystem(
    name="knl",
    fast=MemoryLevel("HBM(MCDRAM)", 16 * GiB, 460e9, 150e-9, concurrency=256,
                     random_eff=0.6),
    slow=MemoryLevel("DDR4", 96 * GiB, 90e9, 130e-9, concurrency=256,
                     random_eff=0.25),
    copy_bandwidth_Bps=90e9,   # copies bottlenecked by the DDR side
    flops_peak=3.0e12,         # ~3 TFLOP/s DP
    spgemm_core_rate=5.5e9,    # paper Fig 3/4 ceiling
)

# NVIDIA P100 + POWER8 over NVLink v1 (paper §3.3): 16 GB HBM2 ~732 GB/s ~400ns
# with thousands of warps in flight; host-pinned over NVLink ~32 GB/s at ~1.5us
# with FEW outstanding transactions — both bandwidth AND latency differ, the
# asymmetry that makes chunking essential on this machine (paper conclusion).
P100 = MemorySystem(
    name="p100",
    fast=MemoryLevel("HBM2", 16 * GiB, 732e9, 400e-9, concurrency=2048,
                     random_eff=0.8),
    slow=MemoryLevel("HostPinned(NVLink)", 512 * GiB, 32e9, 1500e-9,
                     concurrency=32),
    copy_bandwidth_Bps=32e9,
    flops_peak=4.7e12,         # DP
    spgemm_core_rate=25e9,     # paper Fig 6/7 ceiling
)

# TPU v5e chip (the §Roofline constants mandated by the task):
#   197 TFLOP/s bf16; 819 GB/s HBM (16 GiB); VMEM ~128 MiB at ~22 TB/s, ~ns latency.
# fast=VMEM, slow=HBM: the on-chip two-level pair the Pallas kernels chunk across.
TPU_V5E = MemorySystem(
    name="tpu_v5e",
    fast=MemoryLevel("VMEM", 128 * (1 << 20), 22e12, 30e-9, granularity_bytes=512),
    slow=MemoryLevel("HBM", 16 * GiB, 819e9, 600e-9, granularity_bytes=512),
    copy_bandwidth_Bps=819e9,
    flops_peak=197e12,
)

# TPU v5e chip <-> host DRAM (capacity level used for 500k-token KV offload).
TPU_V5E_HOST = MemorySystem(
    name="tpu_v5e_host",
    fast=MemoryLevel("HBM", 16 * GiB, 819e9, 600e-9, granularity_bytes=512),
    slow=MemoryLevel("HostDRAM(PCIe)", 512 * GiB, 16e9, 2000e-9, granularity_bytes=512),
    copy_bandwidth_Bps=16e9,
    flops_peak=197e12,
)

ICI_LINK_Bps = 50e9          # ~50 GB/s per ICI link (roofline collective term)
TPU_HBM_Bps = 819e9
TPU_PEAK_FLOPS = 197e12

MACHINES = {"knl": KNL, "p100": P100, "tpu_v5e": TPU_V5E, "tpu_v5e_host": TPU_V5E_HOST}


# ---------------------------------------------------------------------------
# SpGEMM cost: the paper's access-pattern analysis (§3.1) in closed form
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpGEMMCost:
    """Per-operand time decomposition of one C = A x B under a placement."""

    t_A: float
    t_B: float
    t_C: float
    t_compute: float
    t_copy: float = 0.0

    @property
    def total(self) -> float:
        # A/C streaming overlaps poorly with B gathers in KKMEM (single pass), so
        # the model sums operand terms; compute overlaps with memory on both machines
        # (OoO cores / warps), so total = max(memory, compute) + copies.
        return max(self.t_A + self.t_B + self.t_C, self.t_compute) + self.t_copy

    def gflops(self, flops: float) -> float:
        return flops / self.total / 1e9


def spgemm_cost(system: MemorySystem, *, bytes_A: float, bytes_B: float, bytes_C: float,
                flops: float, b_row_reads: float, b_row_bytes: float,
                b_miss_fraction: float, place_A: str = "slow", place_B: str = "slow",
                place_C: str = "slow", copy_bytes: float = 0.0) -> SpGEMMCost:
    """Cost of one KKMEM numeric phase.

    The paper's access analysis (§3.1): A is streamed once; C written once; B is
    gathered row-by-row ``b_row_reads`` times of which ``b_miss_fraction`` miss the
    cache hierarchy and go to the memory level holding B (reuse-distance simulation
    provides the fraction — repro.core.locality).
    """
    del bytes_B   # B traffic is the gather term: b_row_reads x b_row_bytes misses
    lA, lB, lC = (system.level(place_A), system.level(place_B), system.level(place_C))
    t_A = lA.stream_time(bytes_A)
    t_C = lC.stream_time(bytes_C)
    misses = b_row_reads * b_miss_fraction
    t_B = lB.access_time(misses, b_row_bytes)
    rate = system.spgemm_core_rate or system.flops_peak
    t_compute = flops / rate
    t_copy = system.copy_time(copy_bytes) if copy_bytes else 0.0
    return SpGEMMCost(t_A=t_A, t_B=t_B, t_C=t_C, t_compute=t_compute, t_copy=t_copy)
