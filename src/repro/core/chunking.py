"""Chunked SpGEMM executors: the paper's Algorithms 1 (KNL), 2 (Chunk1), 3 (Chunk2).

All three share the ranged fused-multiply-add kernel (repro.core.kkmem.spgemm_ranged):
a row-partition of B induces a column-partition of A that is realized by *skipping*
(masking) out-of-range A columns, never by physically repartitioning A.

Static-shape discipline: every B chunk is padded to the largest chunk's nnz and every
A/C row-strip to the largest strip, so each algorithm traces the jitted kernel exactly
once regardless of the partition count.

Executors return (C, ChunkStats); ChunkStats carries the *actual* fast<->slow traffic
(what `copy2Fast`/`copy2Slow` would have moved), which tests compare against the
planner's modeled copy cost, and which the benchmarks feed into the memory cost model
to reproduce the paper's figures.

This module holds the host-driven loop executors (the oracle path) and the
dispatcher; the device-resident single-trace scan executors live in
repro.core.chunk_stream and are the default backend of ``chunked_spgemm``.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.kkmem import spgemm, spgemm_ranged
from repro.core.planner import ChunkPlan
from repro.core.symbolic import strip_output_caps
from repro.sparse.csr import (
    CSR, GeometryEnvelope, csr_pad_to, csr_select_rows_host,
)


@dataclasses.dataclass
class ChunkStats:
    algorithm: str
    n_ac: int
    n_b: int
    copy_in_bytes: float = 0.0   # slow -> fast
    copy_out_bytes: float = 0.0  # fast -> slow
    kernel_calls: int = 0
    # ordered per-copy event logs (one entry per staged transfer, in issue
    # order). The loop executors append as they go; the scan executors compute
    # the identical sequence from the plan (a traced scan cannot mutate Python
    # state), so loop-vs-scan stats can be compared event-for-event.
    per_copy_in: list = dataclasses.field(default_factory=list)
    per_copy_out: list = dataclasses.field(default_factory=list)

    @property
    def copy_bytes(self) -> float:
        return self.copy_in_bytes + self.copy_out_bytes

    def add_in(self, nbytes: float) -> None:
        self.copy_in_bytes += nbytes
        self.per_copy_in.append(float(nbytes))

    def add_out(self, nbytes: float) -> None:
        self.copy_out_bytes += nbytes
        self.per_copy_out.append(float(nbytes))


def _partition_caps(m: CSR, bounds: tuple) -> tuple:
    """(nnz cap, row cap) of the largest piece of a contiguous row partition."""
    ptr = np.asarray(m.indptr)
    cap = max(int(ptr[e] - ptr[s]) for s, e in zip(bounds[:-1], bounds[1:]))
    rows = max(e - s for s, e in zip(bounds[:-1], bounds[1:]))
    return max(cap, 1), rows


def b_chunks(B: CSR, p_b: tuple, envelope: GeometryEnvelope | None = None):
    """Row chunks of B, uniformly padded (rows and nnz) so jit traces once.

    Without an envelope the caps come from this instance's largest chunk (the
    single-problem case); with one, every chunk is padded to the envelope's
    ``chunk_nnz_cap``/``chunk_rows``/``b_max_row_nnz`` so chunks from
    *different* instances stack into one batch."""
    if envelope is None:
        cap, rows = _partition_caps(B, p_b)
        mrn = B.max_row_nnz
    else:
        cap, rows = envelope.chunk_nnz_cap, envelope.chunk_rows
        mrn = envelope.b_max_row_nnz
    return [
        csr_pad_to(csr_select_rows_host(B, s, e, pad_to=cap),
                   rows=rows, max_row_nnz=mrn)
        for s, e in zip(p_b[:-1], p_b[1:])
    ]


def a_strips(A: CSR, p_ac: tuple, envelope: GeometryEnvelope | None = None):
    """Row strips of A, uniformly padded (rows and nnz); with an envelope the
    caps are the batch-wide ``strip_nnz_cap``/``strip_rows``/``a_max_row_nnz``."""
    if envelope is None:
        cap, rows = _partition_caps(A, p_ac)
        mrn = A.max_row_nnz
    else:
        cap, rows = envelope.strip_nnz_cap, envelope.strip_rows
        mrn = envelope.a_max_row_nnz
    return [
        csr_pad_to(csr_select_rows_host(A, s, e, pad_to=cap),
                   rows=rows, max_row_nnz=mrn)
        for s, e in zip(p_ac[:-1], p_ac[1:])
    ]


def instance_envelope(A: CSR, B: CSR, plan: ChunkPlan,
                      c_pad: int | None = None,
                      caps=None, block_size: int | None = None) -> GeometryEnvelope:
    """The padded geometry one (A, B) instance needs under ``plan``.

    The symbolic phase (repro.core.symbolic) runs once here: its output caps
    (whole-C capacity, densest C row, largest-strip capacity) are folded into
    the envelope so sparse-output executables are compile-keyed on the output
    structure too. ``c_pad`` only overrides the *capacity* field; the
    structural bounds stay exact. This is deliberate even when ``c_pad`` is
    given (which used to skip the symbolic phase entirely): an envelope is a
    compile key, and two instances must get equal envelopes regardless of
    which caller built them — callers that already ran the symbolic phase
    pass its ``StripOutputCaps`` as ``caps`` to avoid the repeat expansion.

    ``block_size`` opts into the *block*-level symbolic phase
    (``repro.core.symbolic.bsr_plan_caps``): the envelope additionally
    carries ``bsr_caps``, making block-structured backends (``"bsr"``)
    dispatchable and priceable by the planner under this envelope. It is
    opt-in because the block analysis is another host pass and block
    backends only ever win on block-structured operands."""
    if caps is None:
        caps = strip_output_caps(A, B, plan.p_ac)
    if c_pad is None:
        c_pad = caps.c_pad
    bsr_caps = ()
    if block_size is not None:
        from repro.core.symbolic import bsr_plan_caps

        bsr_caps = bsr_plan_caps(A, B, plan, block_size).as_tuple()
    chunk_cap, chunk_rows = _partition_caps(B, plan.p_b)
    strip_cap, strip_rows = _partition_caps(A, plan.p_ac)
    return GeometryEnvelope(
        a_shape=A.shape, b_shape=B.shape,
        a_nnz_cap=A.nnz_pad, a_max_row_nnz=A.max_row_nnz,
        b_max_row_nnz=B.max_row_nnz,
        chunk_rows=chunk_rows, chunk_nnz_cap=chunk_cap,
        strip_rows=strip_rows, strip_nnz_cap=strip_cap,
        c_pad=int(c_pad), dtype=str(A.dtype),
        c_nnz_cap=caps.c_nnz_cap, c_max_row_nnz=caps.c_max_row_nnz,
        bsr_caps=bsr_caps,
    )


def batch_envelope(As, Bs, plan: ChunkPlan, c_pad: int | None = None,
                   caps_list=None, block_size: int | None = None) -> GeometryEnvelope:
    """Union of per-instance envelopes: the smallest shared padded geometry a
    heterogeneous batch can be repadded to (``c_pad`` overrides the symbolic
    default for every instance when given). Callers that already ran the
    symbolic phase per instance pass its ``StripOutputCaps`` as ``caps_list``
    to avoid repeating the expansions; ``block_size`` folds block caps into
    every instance envelope (see :func:`instance_envelope`) so the union is
    block-capped too."""
    As, Bs = list(As), list(Bs)
    if caps_list is None:
        caps_list = [None] * len(As)
    return GeometryEnvelope.batch(
        instance_envelope(A, B, plan, c_pad=c_pad, caps=caps,
                          block_size=block_size)
        for (A, B), caps in zip(zip(As, Bs), caps_list)
    )


def _empty_like_c(n_rows: int, n_cols: int, c_pad: int, dtype) -> CSR:
    return CSR(
        indptr=jnp.zeros(n_rows + 1, jnp.int32),
        indices=jnp.zeros(c_pad, jnp.int32),
        data=jnp.zeros(c_pad, dtype),
        shape=(n_rows, n_cols),
        max_row_nnz=0,
    )


def _assemble(strips, p_ac: tuple, n_cols: int) -> CSR:
    """Concatenate per-strip C results (host) into one CSR over all rows."""
    ptrs, idxs, vals = [], [], []
    base = 0
    for (s, e), c in zip(zip(p_ac[:-1], p_ac[1:]), strips):
        ptr = np.asarray(c.indptr)[: e - s + 1]
        nnz = int(ptr[-1])
        ptrs.append(ptr[:-1] + base)
        idxs.append(np.asarray(c.indices)[:nnz])
        vals.append(np.asarray(c.data)[:nnz])
        base += nnz
    indptr = np.concatenate(ptrs + [[base]])
    from repro.sparse.csr import csr_from_scipy_like

    return csr_from_scipy_like(indptr, np.concatenate(idxs), np.concatenate(vals),
                               (p_ac[-1] - p_ac[0], n_cols))


# ---------------------------------------------------------------------------
# Algorithm 1: KNL chunking — A, C in slow memory; stream B chunks through fast
# ---------------------------------------------------------------------------


def chunk_knl(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    stats = ChunkStats("knl", 1, plan.n_b)
    chunks = b_chunks(B, plan.p_b)
    C = _empty_like_c(A.n_rows, B.n_cols, c_pad, A.dtype)
    for (r0, r1), Bc in zip(zip(plan.p_b[:-1], plan.p_b[1:]), chunks):
        stats.add_in(Bc.nbytes())                       # copy2Fast(B, B_rp)
        C = spgemm_ranged(A, Bc, r0, r1, C, c_pad)      # kkmem(A, FastB, C, B_rp)
        stats.kernel_calls += 1
    return C, stats


# ---------------------------------------------------------------------------
# Algorithms 2 & 3: GPU chunking — 2-D partitions, two streaming orders
# ---------------------------------------------------------------------------


def chunk_gpu1(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    """Alg. 2 — A,C strips stationary in fast memory; B chunks streamed (inner)."""
    stats = ChunkStats("chunk1", plan.n_ac, plan.n_b)
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    out = []
    for (a0, a1), Ai in zip(zip(plan.p_ac[:-1], plan.p_ac[1:]), strips):
        stats.add_in(Ai.nbytes())                        # FA = copy2Fast(A)
        stats.add_in((a1 - a0 + 1) * 4)                  # FC row pointers only
        Ci = _empty_like_c(Ai.n_rows, B.n_cols, c_pad, A.dtype)
        for (r0, r1), Bc in zip(zip(plan.p_b[:-1], plan.p_b[1:]), chunks):
            stats.add_in(Bc.nbytes())                    # FB = copy2Fast(B)
            Ci = spgemm_ranged(Ai, Bc, r0, r1, Ci, c_pad)
            stats.kernel_calls += 1
        stats.add_out(Ci.nbytes())                       # copy2Slow(FC)
        out.append(Ci)
    return _assemble(out, plan.p_ac, B.n_cols), stats


def chunk_gpu2(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    """Alg. 3 — B chunk stationary in fast memory; A,C strips streamed (inner)."""
    stats = ChunkStats("chunk2", plan.n_ac, plan.n_b)
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    partials = [
        _empty_like_c(s.n_rows, B.n_cols, c_pad, A.dtype) for s in strips
    ]
    n_b = plan.n_b
    for jb, ((r0, r1), Bc) in enumerate(zip(zip(plan.p_b[:-1], plan.p_b[1:]), chunks)):
        stats.add_in(Bc.nbytes())                        # FB = copy2Fast(B)
        for ia, Ai in enumerate(strips):
            stats.add_in(Ai.nbytes())                    # FA = copy2Fast(A)
            if jb > 0:
                stats.add_in(partials[ia].nbytes())            # FC partial back in
            partials[ia] = spgemm_ranged(Ai, Bc, r0, r1, partials[ia], c_pad)
            stats.kernel_calls += 1
            if jb < n_b - 1:
                stats.add_out(partials[ia].nbytes())           # partial out
        if jb == n_b - 1:
            for ia in range(len(strips)):
                stats.add_out(partials[ia].nbytes())           # final copy2Slow
    return _assemble(partials, plan.p_ac, B.n_cols), stats


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def default_c_pad(A: CSR, B: CSR, plan: ChunkPlan) -> int:
    """Exact symbolic capacity of the largest row strip (whole C for 1-strip
    plans). One global symbolic expansion (repro.core.symbolic), numerically
    identical to running the symbolic phase per strip."""
    return strip_output_caps(A, B, plan.p_ac).c_pad


def chunked_spgemm(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int | None = None,
                   backend: str = "scan", block_size: int | None = None):
    """Execute a ChunkPlan. ``c_pad`` defaults to the exact symbolic capacity of the
    largest row strip (whole C for 1-strip plans).

    ``backend`` names a registered :class:`repro.core.backend_registry.
    BackendSpec` (``"loop"``, ``"scan"``, ``"pallas"``, ``"sparse"``,
    ``"hash"``, ``"bsr"``, ...) or ``"auto"``, which lets the planner pick
    the accumulator backend whose peak-resident byte model is smallest under
    this instance's envelope (``planner.select_accumulator_backend``). The
    dispatch is entirely registry-driven: the spec supplies the
    per-algorithm executor, and its capability flags decide what the
    dispatcher stages — ``needs_output_caps`` backends receive the symbolic
    phase's ``StripOutputCaps`` (one expansion amortized across the default
    ``c_pad``, the auto resolve, and the executor's overflow check).

    ``block_size`` opts the *block* symbolic phase into the envelope: under
    ``backend="auto"`` the planner can then price (and select) block
    backends like ``"bsr"``; under an explicit block backend it overrides
    that backend's default block edge. See ``docs/backends.md``.
    """
    from repro.core import backend_registry

    spec = None if backend == "auto" else backend_registry.get(backend)
    # one symbolic expansion serves the default c_pad, the auto resolve, and
    # the caps-consuming executors' overflow checks (the symbolic module's
    # amortize-the-host-pass contract)
    caps = None
    if c_pad is None or backend == "auto" or (spec is not None
                                              and spec.needs_output_caps):
        caps = strip_output_caps(A, B, plan.p_ac)
    if c_pad is None:
        c_pad = caps.c_pad
    if plan.algorithm == "whole_fast":
        stats = ChunkStats("whole_fast", 1, 1)
        stats.add_in(A.nbytes() + B.nbytes())
        C = spgemm(A, B, c_pad)
        stats.add_out(C.nbytes())
        stats.kernel_calls = 1
        return C, stats
    if backend == "auto":
        from repro.core.planner import select_accumulator_backend

        env = instance_envelope(A, B, plan, c_pad=c_pad, caps=caps,
                                block_size=block_size)
        spec = backend_registry.get(select_accumulator_backend(plan, env))
    fn = spec.executors.get(plan.algorithm)
    if fn is None:
        raise ValueError(f"unknown algorithm {plan.algorithm!r}")
    kwargs = {}
    if spec.needs_output_caps:
        kwargs["caps"] = caps
    if block_size is not None and spec.needs_block_caps:
        kwargs["block_size"] = block_size
    return fn(A, B, plan, c_pad, **kwargs)
