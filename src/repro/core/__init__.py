"""repro.core — the paper's primary contribution.

KKMEM two-phase SpGEMM + selective data placement (DP) + chunked multilevel-memory
algorithms (Algs 1-4) + locality/reuse analysis + the two-level memory cost model.
"""
from repro.core.memory_model import (
    MemoryLevel, MemorySystem, KNL, P100, TPU_V5E, TPU_V5E_HOST, SpGEMMCost,
    spgemm_cost, MACHINES,
)
from repro.core.kkmem import (
    SpGEMMWorkspace, spgemm, spgemm_ranged, spgemm_full, spgemm_symbolic_host,
    spgemm_dense_oracle,
)
from repro.core.locality import LocalityStats, analyze, miss_table, stack_distances
from repro.core.placement import (
    Placement, ALL_FAST, ALL_SLOW, DP, dp_recommendation, placement_cost, place,
)
from repro.core.planner import (
    ChunkPlan, plan_chunks, plan_knl, binary_search_partition, partition_cost,
    row_bytes_csr, staged_chunk_bytes, staged_row_bytes,
)
from repro.core.chunking import (
    ChunkStats, chunk_knl, chunk_gpu1, chunk_gpu2, chunked_spgemm,
    instance_envelope, batch_envelope,
)
from repro.core.chunk_stream import (
    chunk_knl_scan, chunk_gpu1_scan, chunk_gpu2_scan,
    chunk_knl_pallas, chunk_gpu1_pallas, chunk_gpu2_pallas,
    chunked_spgemm_batched,
)
from repro.core.triangle import count_triangles, count_triangles_dense

__all__ = [
    "MemoryLevel", "MemorySystem", "KNL", "P100", "TPU_V5E", "TPU_V5E_HOST",
    "SpGEMMCost", "spgemm_cost", "MACHINES",
    "SpGEMMWorkspace", "spgemm", "spgemm_ranged", "spgemm_full",
    "spgemm_symbolic_host", "spgemm_dense_oracle",
    "LocalityStats", "analyze", "miss_table", "stack_distances",
    "Placement", "ALL_FAST", "ALL_SLOW", "DP", "dp_recommendation",
    "placement_cost", "place",
    "ChunkPlan", "plan_chunks", "plan_knl", "binary_search_partition",
    "partition_cost", "row_bytes_csr", "staged_chunk_bytes", "staged_row_bytes",
    "ChunkStats", "chunk_knl", "chunk_gpu1", "chunk_gpu2", "chunked_spgemm",
    "instance_envelope", "batch_envelope",
    "chunk_knl_scan", "chunk_gpu1_scan", "chunk_gpu2_scan",
    "chunk_knl_pallas", "chunk_gpu1_pallas", "chunk_gpu2_pallas",
    "chunked_spgemm_batched",
    "count_triangles", "count_triangles_dense",
]
