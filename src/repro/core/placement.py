"""Selective data placement (paper §3.2.1, Table 3).

For ``C = A x B``: A is streamed (read once), C is streamed (written once), the
accumulators are cache-resident; only B is gathered irregularly. So when the fast
memory cannot hold the whole problem, placing **only B fast** recovers most of the
fast-memory performance — *iff* B fits ("This method, DP, only works when B fits
into HBM").

On real TPU hardware placement is realized with ``jax.device_put`` +
``memory_kind`` shardings (HBM vs pinned_host); on this CPU container the placement
is recorded and its performance evaluated through the memory cost model, while the
functional result is (trivially) identical — asserted in tests.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core.memory_model import MemorySystem, SpGEMMCost, spgemm_cost
from repro.core.locality import LocalityStats, analyze
from repro.sparse.csr import CSR

SPACES = ("fast", "slow")


@dataclasses.dataclass(frozen=True)
class Placement:
    """Memory space per operand of C = A x B."""

    A: str = "slow"
    B: str = "slow"
    C: str = "slow"

    def __post_init__(self):
        for k in ("A", "B", "C"):
            if getattr(self, k) not in SPACES:
                raise ValueError(f"{k} space must be one of {SPACES}")

    def fast_bytes(self, bytes_A: float, bytes_B: float, bytes_C: float) -> float:
        return (
            (bytes_A if self.A == "fast" else 0.0)
            + (bytes_B if self.B == "fast" else 0.0)
            + (bytes_C if self.C == "fast" else 0.0)
        )


ALL_FAST = Placement("fast", "fast", "fast")
ALL_SLOW = Placement("slow", "slow", "slow")
DP = Placement("slow", "fast", "slow")  # the paper's recommendation


def dp_recommendation(system: MemorySystem, bytes_A: float, bytes_B: float,
                      bytes_C: float, reserve_fraction: float = 0.0) -> Placement:
    """The paper's DP policy: everything fast if it fits; else B fast if *it* fits;
    else everything slow (chunking territory — see repro.core.planner)."""
    cap = system.fast.capacity_bytes * (1.0 - reserve_fraction)
    if bytes_A + bytes_B + bytes_C <= cap:
        return ALL_FAST
    if bytes_B <= cap:
        return DP
    return ALL_SLOW


def paper_scale_cache(A: CSR, B: CSR, C_bytes: float = 0.0) -> float:
    """On-core cache capacity, scaled to the benchmark problem.

    The paper runs 1-32 GB problems against ~34 MB of on-core cache — a
    problem:cache ratio of ~70x at the small end. Our CPU-scale problems keep
    the paper's *structure* but not its size, so the modeled cache keeps the
    paper's ratio instead of an absolute capacity — otherwise every toy B is
    cache-resident and no memory-mode effect can exist."""
    total = A.nbytes() + B.nbytes() + float(C_bytes)
    return max(2 << 10, total / 70.0)


def placement_cost(system: MemorySystem, placement: Placement, A: CSR, B: CSR,
                   C_bytes: float, flops: float,
                   locality: LocalityStats | None = None,
                   cache_bytes: float | None = None) -> SpGEMMCost:
    """Modeled cost of one multiplication under ``placement`` (Table 3 analogue)."""
    st = locality or analyze(A, B)
    if cache_bytes is None:
        cache_bytes = paper_scale_cache(A, B, C_bytes)
    nnz_a = float(A.indptr[-1]) if not isinstance(A.indptr, jax.core.Tracer) else A.nnz_pad
    return spgemm_cost(
        system,
        bytes_A=A.nbytes(),
        bytes_B=B.nbytes(),
        bytes_C=C_bytes,
        flops=flops,
        b_row_reads=float(nnz_a),
        b_row_bytes=st.avg_b_row_bytes,
        b_miss_fraction=st.miss_fraction_bytes(cache_bytes),
        place_A=placement.A,
        place_B=placement.B,
        place_C=placement.C,
    )


def place(operand, space: str, system_name: str = "tpu_v5e"):
    """Physically place an operand pytree in a memory space.

    On TPU runtimes, 'slow' maps to ``pinned_host`` memory kind and 'fast' to device
    HBM. On backends without memory kinds (this CPU container) placement is a no-op
    transfer and the cost is tracked analytically.
    """
    del system_name   # parity with the cost APIs; physical placement is kind-based
    if space not in SPACES:
        raise ValueError(f"space must be one of {SPACES}")
    try:
        dev = jax.devices()[0]
        kind = "device" if space == "fast" else "pinned_host"
        sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
        return jax.device_put(operand, sharding)
    except (ValueError, RuntimeError, NotImplementedError):
        return jax.device_put(operand)
