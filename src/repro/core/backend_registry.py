"""Backend registry: one `BackendSpec` per chunked-SpGEMM backend.

Before this module, adding a backend meant a wiring pass across the whole
stack: an `if/elif` arm in ``chunked_spgemm``, another in
``chunked_spgemm_batched``, a hand-built ``_*_CORES_BATCHED`` dict, a
trace-suffix entry in ``SpGEMMService``, a byte model hooked into the
planner's accumulator tuple, and hand-maintained backend lists in the
conformance suite and CI smoke lanes. The registry collapses all of that
into **one registration call**: a backend ships its kernel module plus a
:class:`BackendSpec`, and the dispatchers (``chunked_spgemm``,
``chunked_spgemm_batched``, ``SpGEMMService``), the planner's ``auto``
resolve, the conformance matrix, and the bench/CI lane lists all derive
from ``specs()`` / ``all_backends()``.

The spec is deliberately thin — callables, templates, and capability
flags — so the registry stays import-light: this module imports nothing
from the rest of the package at module scope. Registrations live at the
bottom of ``repro.core.chunk_stream`` (the module that owns the executor
cores); :func:`ensure_registered` imports it on first use, which keeps
``import repro.core.backend_registry`` free of JAX work.

Contracts a spec must honor (enforced by the conformance suite's
registry-completeness test):

* ``executors`` maps every plan algorithm (``knl``/``chunk1``/``chunk2``)
  to an unbatched executor ``fn(A, B, plan, c_pad, ...) -> (C, ChunkStats)``.
  Executors with ``needs_output_caps`` additionally receive the symbolic
  phase's ``StripOutputCaps`` as ``caps=`` (the dispatcher amortizes the
  host expansion).
* ``run_batched(As, Bs, plan, envelope, *, caps_list, validate_caps)``
  runs the whole microbatch under a shared
  :class:`~repro.sparse.csr.GeometryEnvelope`; ``None`` means the backend
  is unbatched-only (the host-loop oracle).
* ``trace_key`` / ``trace_key_batched`` are ``"{alg}"``-templates naming
  the backend's ``TRACE_COUNTS`` keys — the compile-accounting contract
  the serving layer and the exact trace-count tests pin.
* ``byte_model(plan, envelope) -> BackendFastModel`` is the planner-side
  peak-resident model ``backend="auto"`` argmins over; accumulator
  backends (``is_accumulator``) must provide one. A model may return an
  infinite ``fast_bytes_needed`` when the envelope lacks the fields it
  prices (the BSR model without block caps), which excludes the backend
  from that resolve without special-casing the planner.
* ``needs_block_caps`` marks backends whose compile geometry is the
  envelope's ``bsr_caps`` block bounds; the dispatchers build/require
  block-capped envelopes for them, using ``block_size`` as the default
  block edge.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

ALGORITHMS = ("knl", "chunk1", "chunk2")


@dataclasses.dataclass(frozen=True)
class OpFlow:
    """Per-operand copy-event model: the ordered byte sizes of every
    slow->fast (or fast->slow) copy one pallas operand performs across the
    whole grid. ``key`` names the logical operand (several CSR field
    operands may share one key — their per-event bytes then sum into the
    single ``ChunkStats`` event the executor logs)."""

    key: str
    events: tuple     # ordered per-copy byte sizes, one float per copy event


@dataclasses.dataclass(frozen=True)
class ExpectedTraffic:
    """A backend's declared data-movement model for one staged core:
    the per-operand copy-event lists the traced jaxpr must reproduce
    *exactly* (``analysis/traffic.py`` checks equality, not domination),
    plus the ``ChunkStats``-granularity event lists the executors report
    (same-key operand flows merged event-wise). ``stats_exempt`` names a
    documented reason the stats tie is skipped (e.g. the BSR executor's
    per-pair host staging loop, which the pipeline-model stats
    intentionally idealize); the per-operand flow check still applies."""

    in_ops: tuple                  # tuple[OpFlow, ...], slow->fast
    out_ops: tuple                 # tuple[OpFlow, ...], fast->slow
    stats_in: tuple = ()           # ChunkStats.per_copy_in the executor logs
    stats_out: tuple = ()          # ChunkStats.per_copy_out the executor logs
    stats_exempt: str | None = None


@dataclasses.dataclass(frozen=True)
class TraceTarget:
    """An abstract-traceable handle on one backend core: ``fn(*args)`` must
    trace under ``jax.make_jaxpr`` without device execution (statics already
    bound into ``fn``). This is what a spec's ``audit_trace`` builds and what
    every ``repro.analysis`` pass consumes — the registry-level audit
    capability, kept here so the analysis package and the executor module
    never import each other."""

    fn: Callable
    args: tuple
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Everything the dispatch/planning/serving layers need to run a backend."""

    name: str
    executors: Mapping[str, Callable]           # algorithm -> unbatched executor
    run_batched: Callable | None = None         # batched entry; None = unbatched-only
    byte_model: Callable | None = None          # (plan, envelope) -> BackendFastModel
    trace_key: str | None = None                # "{alg}"-template, unbatched cores
    trace_key_batched: str | None = None        # "{alg}"-template, batched cores
    needs_output_caps: bool = False             # executor takes caps=StripOutputCaps
    needs_block_caps: bool = False              # envelope must carry bsr_caps
    is_accumulator: bool = False                # participates in backend="auto"
    block_size: int | None = None               # default block edge (block backends)
    # mask capability: the backend can fuse an output mask into its merge —
    # ``run_masked(A, B, mask, plan, c_pad, caps=...) -> (C, ChunkStats)``
    # computes ``(A x B) ∘ mask`` with the mask applied *inside* the kernel
    # (no unmasked C ever materialized). None = unmasked-only; the fused
    # triangle-counting path (repro.core.triangle) resolves through this.
    run_masked: Callable | None = None
    # audit capability: (A, B, plan, c_pad, envelope) -> TraceTarget staging
    # the backend's jitted core exactly as the executors would, so the static
    # verifier (repro.analysis) can abstract-trace it. None = not auditable
    # (the host-loop oracle has no jitted core).
    audit_trace: Callable | None = None
    # traffic capability: (A, B, plan, c_pad, envelope, meta) -> ExpectedTraffic,
    # the per-copy-event byte model `analysis/traffic.py` holds the traced
    # jaxpr to (exact equality). `meta` is the TraceTarget.meta of the
    # matching audit_trace — the statics (scalar-prefetch tables, chunk
    # counts) both sides were staged from. None = flow equality not checked
    # (the scan backend is device-resident: its stats are a replay oracle by
    # design, with no per-chunk pallas copies to reconcile).
    traffic_model: Callable | None = None
    # executable-cache capability: ``(donate=False) -> dict`` building a
    # FRESH set of jitted batched cores (same keying the module-level cores
    # use), passed back into ``run_batched(..., cores=...)``. Module-level
    # cores live in module-global jit caches — dropping a serving bucket
    # would never free its executables. A per-bucket core set makes the
    # bucket the sole owner of its compiled programs, so evicting the bucket
    # really frees them (and a refault really recompiles). ``donate=True``
    # additionally donates the staged batch buffers (the C accumulator
    # stacks) into the cores. None = backend has no batched cores to scope.
    make_batched_cores: Callable | None = None

    @property
    def supports_batched(self) -> bool:
        return self.run_batched is not None

    @property
    def supports_audit(self) -> bool:
        return self.audit_trace is not None

    @property
    def supports_traffic(self) -> bool:
        return self.traffic_model is not None

    @property
    def supports_mask(self) -> bool:
        return self.run_masked is not None


_REGISTRY: dict[str, BackendSpec] = {}


def register(spec: BackendSpec) -> BackendSpec:
    """Register a backend. Name collisions fail loudly — a duplicate
    registration is always a wiring bug (double import paths)."""
    if spec.name in _REGISTRY:
        raise ValueError(f"backend {spec.name!r} already registered")
    if spec.name == "auto":
        raise ValueError("'auto' is the dispatch mode, not a registrable backend")
    missing = [alg for alg in ALGORITHMS if alg not in spec.executors]
    if missing:
        raise ValueError(f"backend {spec.name!r} missing executors for {missing}")
    if spec.is_accumulator and spec.byte_model is None:
        raise ValueError(
            f"accumulator backend {spec.name!r} needs a planner byte model")
    # the trace-key contract: keys are per-algorithm, so a template without
    # the "{alg}" slot would collapse all three algorithms onto one counter
    # and silently break the serving layer's compile accounting. Fail at
    # import, where the registration lives, not at first format().
    for field in ("trace_key", "trace_key_batched"):
        template = getattr(spec, field)
        if template is not None and "{alg}" not in template:
            raise ValueError(
                f"backend {spec.name!r}: {field}={template!r} must contain "
                "the '{alg}' placeholder (one TRACE_COUNTS key per algorithm)")
    if spec.traffic_model is not None and spec.audit_trace is None:
        raise ValueError(
            f"backend {spec.name!r} registers a traffic_model without an "
            "audit_trace: the flow-equality analysis has no traced jaxpr "
            "to hold the model to")
    if spec.needs_block_caps and spec.block_size is None:
        raise ValueError(
            f"backend {spec.name!r} needs_block_caps but registers no "
            "block_size: the dispatchers could not build its default "
            "block-capped envelope")
    _REGISTRY[spec.name] = spec
    return spec


def ensure_registered() -> None:
    """Import the module that owns the executor cores (and therefore the
    registrations). Idempotent: module bodies run once."""
    import repro.core.chunk_stream  # noqa: F401  (registrations at module bottom)


def get(name: str) -> BackendSpec:
    """Resolve a backend name; unknown names raise the dispatcher's
    canonical error."""
    ensure_registered()
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(f"unknown backend {name!r}")
    return spec


def specs() -> tuple:
    """All registered specs, in registration order (the order is the
    planner's tie-break priority for accumulators)."""
    ensure_registered()
    return tuple(_REGISTRY.values())


def all_backends() -> tuple:
    """Registered backend names, registration order (excludes ``auto``)."""
    return tuple(s.name for s in specs())


def batched_backends() -> tuple:
    """Names of backends with a batched entry point."""
    return tuple(s.name for s in specs() if s.supports_batched)


def accumulator_specs() -> tuple:
    """Specs participating in the planner's ``auto`` resolve, priority order."""
    return tuple(s for s in specs() if s.is_accumulator)


def masked_backends() -> tuple:
    """Names of backends that can fuse an output mask into their kernel."""
    return tuple(s.name for s in specs() if s.supports_mask)
