"""Chunk planning: the paper's Algorithm 4 decision heuristic + binary-search
row partitioner.

Copy-cost model (paper §3.3.1):
  Chunk1 (A,C stationary, stream B):  cost1 = |A| + |C| + |B| * ||P_AC||
  Chunk2 (B stationary, stream A,C):  cost2 = |B| + |A| * ||P_B|| + |C| * (||P_B|| - 1)

Heuristic (Alg. 4): give 75% of fast memory to the operand streamed in the OUTER
loop (stationary), >=25% to the inner streamed operand so compute stays utilized;
prefer whole-residency when an operand set fits; otherwise minimize modeled copy
cost over both loop orders.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.memory_model import MemorySystem
from repro.sparse.csr import CSR


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Everything the chunk executors need, all host-static."""

    algorithm: str            # "whole_fast" | "knl" | "chunk1" | "chunk2"
    p_ac: tuple               # row boundaries of the A/C partition, len = n_ac + 1
    p_b: tuple                # row boundaries of the B partition,   len = n_b + 1
    copy_bytes: float         # modeled total fast<->slow traffic
    fast_bytes_needed: float  # peak fast-memory footprint

    @property
    def n_ac(self) -> int:
        return len(self.p_ac) - 1

    @property
    def n_b(self) -> int:
        return len(self.p_b) - 1

    def b_ranges(self) -> tuple:
        """(r0s, r1s) of the B partition as int32 arrays — scan per-step inputs."""
        b = np.asarray(self.p_b, np.int32)
        return b[:-1], b[1:]


def row_bytes_csr(m: CSR, value_bytes: int = 8, index_bytes: int = 4) -> np.ndarray:
    """Per-row byte footprint (values + column indices; indptr amortized)."""
    lens = np.asarray(m.indptr[1:]) - np.asarray(m.indptr[:-1])
    return lens * (value_bytes + index_bytes)


def binary_search_partition(row_bytes: np.ndarray, target_bytes: float) -> tuple:
    """Paper's BinarySearch: split rows into contiguous chunks each <= target bytes.

    Uses searchsorted over the prefix-sum (true binary search, O(p log n)). A single
    row larger than the target gets its own chunk (cannot split a row).
    """
    n = int(row_bytes.size)
    if n == 0:
        return (0,)
    prefix = np.concatenate([[0.0], np.cumsum(row_bytes, dtype=np.float64)])
    bounds = [0]
    while bounds[-1] < n:
        lo = bounds[-1]
        # furthest row end with cumulative bytes <= prefix[lo] + target
        hi = int(np.searchsorted(prefix, prefix[lo] + target_bytes, side="right") - 1)
        hi = max(hi, lo + 1)  # always make progress (oversized single row)
        bounds.append(min(hi, n))
    return tuple(bounds)


def partition_cost(bytes_a: float, bytes_b: float, bytes_c: float,
                   n_ac: int, n_b: int, algorithm: str) -> float:
    """The paper's copy-cost formulas."""
    if algorithm == "chunk1":
        return bytes_a + bytes_c + bytes_b * n_ac
    if algorithm == "chunk2":
        return bytes_b + bytes_a * n_b + bytes_c * max(n_b - 1, 0)
    raise ValueError(algorithm)


def staged_row_bytes(row_bytes: np.ndarray, bounds: tuple,
                     index_bytes: int = 4) -> float:
    """Padded-envelope fast footprint of one staged piece of a row partition,
    in the planner's per-row byte units.

    The executors pad every piece to the largest piece's capacity and row
    count, so what fast memory holds is ``max_rows`` row pointers plus the
    byte envelope ``max_piece_bytes`` — the partition-level analogue of
    :func:`staged_chunk_bytes` for operands the planner only knows as a
    per-row byte vector (the symbolic C estimate)."""
    rb = np.asarray(row_bytes, np.float64)
    cap = max(float(rb[s:e].sum()) for s, e in zip(bounds[:-1], bounds[1:]))
    rows = max(e - s for s, e in zip(bounds[:-1], bounds[1:]))
    return float((rows + 1) * index_bytes) + max(cap, 1.0)


def plan_chunks(A: CSR, B: CSR, c_row_bytes: np.ndarray, system: MemorySystem,
                fast_limit_bytes: float | None = None,
                big_portion: float = 0.75) -> ChunkPlan:
    """Algorithm 4. ``c_row_bytes`` is the symbolic-phase estimate of C's per-row
    footprint (A and C are always co-partitioned: same row boundaries).

    ``fast_bytes_needed`` models the *staged* peak footprint the executors
    actually allocate: resident operands at their full size plus the padded
    envelope of every streamed piece (every chunk/strip is padded to the
    largest one's rows and capacity). Modeling the streamed term as the
    densest single row — the pre-fix behavior — undercounts whenever the row
    distribution is skewed, exactly the staging overhead Nagasaka & Azad
    (1804.01698) flag on KNL."""
    fast = float(fast_limit_bytes or system.fast.capacity_bytes)
    small_portion = 1.0 - big_portion
    a_rows = row_bytes_csr(A)
    b_rows = row_bytes_csr(B)
    c_rows = np.asarray(c_row_bytes, np.float64)
    ac_rows = a_rows + c_rows
    size_a, size_b, size_c = float(a_rows.sum()), float(b_rows.sum()), float(c_rows.sum())

    whole = size_a + size_b + size_c
    if whole <= fast:
        return ChunkPlan("whole_fast", (0, A.n_rows), (0, B.n_rows),
                         copy_bytes=whole, fast_bytes_needed=whole)

    def staged_ac(p_ac: tuple) -> float:
        # the executors stage the padded A strip and the C partial separately
        return staged_chunk_bytes(A, p_ac) + staged_row_bytes(c_rows, p_ac)

    if size_b <= big_portion * fast:
        # B resident; stream A, C through the leftover (paper: "Add left over from
        # big to small portion").
        leftover = fast - size_b
        p_ac = binary_search_partition(ac_rows, leftover)
        return ChunkPlan("chunk2", p_ac, (0, B.n_rows),
                         copy_bytes=partition_cost(size_a, size_b, size_c,
                                                   len(p_ac) - 1, 1, "chunk2"),
                         fast_bytes_needed=size_b + staged_ac(p_ac))

    if size_a + size_c <= big_portion * fast:
        leftover = fast - (size_a + size_c)
        p_b = binary_search_partition(b_rows, leftover)
        return ChunkPlan("chunk1", (0, A.n_rows), p_b,
                         copy_bytes=partition_cost(size_a, size_b, size_c,
                                                   1, len(p_b) - 1, "chunk1"),
                         fast_bytes_needed=size_a + size_c
                         + staged_chunk_bytes(B, p_b))

    # Neither fits: 2-D chunking. Give the big portion to the costlier operand set
    # (paper: "if size(A) + 2*size(C) > size(B)" -> A,C get the big portion).
    if size_a + 2.0 * size_c > size_b:
        p_ac = binary_search_partition(ac_rows, big_portion * fast)
        p_b = binary_search_partition(b_rows, small_portion * fast)
    else:
        p_b = binary_search_partition(b_rows, big_portion * fast)
        p_ac = binary_search_partition(ac_rows, small_portion * fast)
    n_ac, n_b = len(p_ac) - 1, len(p_b) - 1
    cost1 = partition_cost(size_a, size_b, size_c, n_ac, n_b, "chunk1")
    cost2 = partition_cost(size_a, size_b, size_c, n_ac, n_b, "chunk2")
    algorithm = "chunk1" if cost1 <= cost2 else "chunk2"
    # peak staged footprint is one padded A strip + C partial + one padded B
    # chunk resident together, in either streaming order — the actual
    # requirement, not the limit the partitions were searched against
    return ChunkPlan(algorithm, p_ac, p_b,
                     copy_bytes=min(cost1, cost2),
                     fast_bytes_needed=staged_ac(p_ac)
                     + staged_chunk_bytes(B, p_b))


def staged_chunk_bytes(m: CSR, bounds: tuple, value_bytes: int = 8,
                       index_bytes: int = 4) -> float:
    """Modeled fast-memory footprint of one *staged* chunk of a row partition.

    The executors pad every chunk to the largest chunk's nnz and row count
    (static shapes), so what fast memory must hold is the padded envelope —
    ``cap`` entries plus the padded row pointers — not the unpadded bytes of
    whichever chunk is resident. Summing unpadded per-chunk bytes undercounts
    exactly when the row distribution is skewed."""
    lens = np.asarray(m.indptr[1:]) - np.asarray(m.indptr[:-1])
    cap = max(int(lens[s:e].sum()) for s, e in zip(bounds[:-1], bounds[1:]))
    rows = max(e - s for s, e in zip(bounds[:-1], bounds[1:]))
    return float((rows + 1) * index_bytes
                 + max(cap, 1) * (value_bytes + index_bytes))


# ---------------------------------------------------------------------------
# backend fast-memory models: what each executor actually keeps resident
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BackendFastModel:
    """Peak resident fast-memory (VMEM) footprint of one streaming backend
    under a plan + envelope: both double-buffer slots of the streamed
    operand, the stationary operand's staged block, the persistent C
    accumulator (all ``n_ac`` strips for the Chunk2 order, whose partials
    never leave VMEM), and the backend's per-step compute workspace.

    This is deliberately *not* :class:`ChunkPlan.fast_bytes_needed` (the
    paper-level staged model the planner searches partitions against): it is
    the backend-specific answer to "does this plan's strip sizing actually
    fit the fast memory", which for the dense-slab Pallas backend is bounded
    by ``strip_rows * n_cols`` and for the sparse-output backend by the
    symbolic phase's ``nnz(C)`` caps — the reason plans can admit larger
    strips when C is sparse.
    """

    backend: str                 # "pallas" (dense slab) | "sparse" (CSR)
    fast_bytes_needed: float     # peak resident footprint, bytes
    streamed_bytes: float        # one streamed element (held x2: double buffer)
    stationary_bytes: float      # stationary operand's staged block
    c_accum_bytes: float         # persistent accumulator block(s)
    workspace_bytes: float       # per-step compute scratch (ESC expansion)


def _csr_staged_bytes(rows: int, nnz_cap: int, itemsize: int) -> float:
    """Padded CSR triple footprint: row pointers + (index, value) per slot."""
    return float((rows + 1) * 4 + max(nnz_cap, 1) * (4 + itemsize))


def csr_field_nbytes(rows: int, nnz_cap: int, itemsize: int) -> tuple:
    """Per-field ``(indptr, indices, data)`` byte sizes of one staged padded
    CSR triple — the three copy events a CSR operand performs per staging
    step in the sparse/hash kernels, whose sum is exactly the staged
    ``CSR.nbytes()``. Unlike :func:`_csr_staged_bytes` (the planner's
    domination *model*, floored at one slot) this is staging truth: a
    zero-capacity envelope stages zero-size index/data arrays and therefore
    moves zero bytes for those fields, and the traffic-equality audit holds
    the traced jaxpr to these exact sizes."""
    return (float((rows + 1) * 4), float(nnz_cap * 4),
            float(nnz_cap * itemsize))


def planned_stats_dense_slab(plan: ChunkPlan, envelope) -> BackendFastModel:
    """The dense-accumulator (``backend="pallas"``) resident footprint: the
    streamed/stationary pieces are dense f32 slabs and the C accumulator is a
    dense ``[strip_rows, n_cols]`` block per resident strip."""
    k, n = envelope.a_shape[1], envelope.b_shape[1]
    span, strip_rows = envelope.chunk_rows, envelope.strip_rows
    slab = float(span * n * 4)                       # streamed B chunk
    a_stage = float(strip_rows * (k + span) * 4)     # column-padded A strip
    c_block = float(strip_rows * n * 4)
    if plan.algorithm == "chunk2":
        streamed, stationary = a_stage, slab
        c_accum = plan.n_ac * c_block                # all partials persist
    else:                                            # knl / chunk1
        streamed, stationary = slab, a_stage
        c_accum = c_block
    return BackendFastModel(
        backend="pallas",
        fast_bytes_needed=2 * streamed + stationary + c_accum,
        streamed_bytes=streamed, stationary_bytes=stationary,
        c_accum_bytes=c_accum, workspace_bytes=0.0,
    )


def _csr_accum_model(plan: ChunkPlan, envelope, backend: str,
                     workspace: float) -> BackendFastModel:
    """Shared resident-footprint shape of the CSR-scratch accumulators (ESC
    and hash): staged pieces are padded CSR triples, the C accumulator is
    the fixed-capacity scratch at the symbolic ``c_pad`` (all ``n_ac``
    strips resident in the Chunk2 order), and only the per-step
    ``workspace`` term differs between the backends. One definition, so the
    models ``select_accumulator_backend`` compares cannot drift apart."""
    itemsize = int(np.dtype(envelope.dtype).itemsize)
    chunk_csr = _csr_staged_bytes(envelope.chunk_rows, envelope.chunk_nnz_cap,
                                  itemsize)
    strip_csr = _csr_staged_bytes(envelope.strip_rows, envelope.strip_nnz_cap,
                                  itemsize)
    c_csr = _csr_staged_bytes(envelope.strip_rows, envelope.c_pad, itemsize)
    if plan.algorithm == "chunk2":
        streamed, stationary = strip_csr, chunk_csr
        c_accum = plan.n_ac * c_csr
    else:                                            # knl / chunk1
        streamed, stationary = chunk_csr, strip_csr
        c_accum = c_csr
    return BackendFastModel(
        backend=backend,
        fast_bytes_needed=2 * streamed + stationary + c_accum + workspace,
        streamed_bytes=streamed, stationary_bytes=stationary,
        c_accum_bytes=c_accum, workspace_bytes=workspace,
    )


def planned_stats_sparse(plan: ChunkPlan, envelope) -> BackendFastModel:
    """The sparse-output (``backend="sparse"``) resident footprint: every
    staged piece is a padded CSR triple and the C accumulator is the
    fixed-capacity CSR scratch at the symbolic ``c_pad`` — so the model
    scales with the envelope's nnz caps, never with ``n_cols``. The ESC
    workspace term is the expand-sort-compress product buffer
    (``strip_nnz_cap * b_max_row_nnz + c_pad`` slots of row, column, value),
    the price of compressed accumulation that the crossover bench lane
    (``benchmarks/chunking_bench.py --lane accumulator_shootout``) measures
    against the dense slab and the hash tables."""
    itemsize = int(np.dtype(envelope.dtype).itemsize)
    esc_slots = (max(envelope.strip_nnz_cap, 1)
                 * max(envelope.b_max_row_nnz, 1) + envelope.c_pad)
    workspace = float(esc_slots * (4 + 4 + itemsize))
    return _csr_accum_model(plan, envelope, "sparse", workspace)


def hash_table_slots(c_max_row_nnz: int) -> int:
    """Per-row hash-table capacity of the hash-probe backend: the smallest
    power of two holding the densest C row. Power-of-two so the probe wrap is
    a mask (``slot & (T - 1)``); >= ``c_max_row_nnz`` so — the symbolic bound
    being exact — insertion can never fail to find its key or a free slot.

    The single source of truth: the kernel (``kernels/hash_accum_spgemm``),
    the byte model (:func:`planned_stats_hash`) and the executors all size
    the table through this function, so the planner's workspace term is the
    table the kernel actually allocates."""
    v = max(int(c_max_row_nnz), 1)
    return 1 << (v - 1).bit_length()


def planned_stats_hash(plan: ChunkPlan, envelope) -> BackendFastModel:
    """The hash-probe (``backend="hash"``) resident footprint: staged CSR
    triples and the CSR accumulator scratch exactly as in
    :func:`planned_stats_sparse` — the two backends share the streaming
    schedule — but the per-step workspace is the per-row hash table
    (``strip_rows x hash_table_slots(c_max_row_nnz)`` key/value pairs,
    Nagasaka & Azad's compressed accumulator) instead of the ESC
    expand-sort-compress buffer. The workspace therefore scales with the
    densest *output* row, not with ``strip_nnz_cap * b_max_row_nnz`` — the
    term that erodes the ESC backend's VMEM win as outputs densify."""
    itemsize = int(np.dtype(envelope.dtype).itemsize)
    # c_max_row_nnz == 0 is *exact* (empty output, 1-slot tables) whenever
    # the symbolic phase ran, which c_nnz_cap witnesses (its rounding floor
    # makes it nonzero when computed); only a legacy both-zero envelope
    # falls back to the always-valid n_cols bound — keeping this model equal
    # to the table the executors actually allocate
    slots = hash_table_slots(
        envelope.c_max_row_nnz if envelope.c_nnz_cap else envelope.b_shape[1])
    workspace = float(envelope.strip_rows * slots * (4 + itemsize))
    return _csr_accum_model(plan, envelope, "hash", workspace)


def planned_stats_bsr(plan: ChunkPlan, envelope) -> BackendFastModel:
    """The BSR (``backend="bsr"``) resident footprint: every staged piece is
    a padded BSR triple — block pointers + block-column indices + dense
    ``bs x bs`` f32 tiles, plus the appended zero-sentinel block — sized by
    the envelope's block caps (``repro.core.symbolic.bsr_plan_caps``), and
    the C accumulator holds ``nc_cap`` output tiles. The workspace term is
    the kernel's scalar-prefetched slot tables (``2 x nc x u`` int32 in
    SMEM) plus the per-step ``bs x bs`` f32 accumulator tile.

    The block caps are *quantized* bounds, so the model honestly prices the
    zero-sentinel/padding waste: a scattered-sparsity instance whose every
    entry lands in its own block pays ``bs^2`` floats per entry and loses to
    the CSR accumulators, while a block-structured instance amortizes each
    tile across up to ``bs^2`` entries and wins. An envelope without block
    caps (the default — block analysis is opt-in) prices at infinity, which
    removes ``bsr`` from that ``auto`` resolve without special-casing the
    dispatch."""
    if not envelope.bsr_caps:
        inf = float("inf")
        return BackendFastModel(backend="bsr", fast_bytes_needed=inf,
                                streamed_bytes=inf, stationary_bytes=inf,
                                c_accum_bytes=inf, workspace_bytes=inf)
    bs, nbl_a, nbl_b, nc, u = envelope.bsr_caps
    block_bytes = bs * bs * 4                        # staged tiles are f32
    k = envelope.a_shape[1]
    srb = -(-envelope.strip_rows // bs)              # strip block rows
    kb = -(-k // bs)                                 # contraction block rows
    # BSR triple + appended zero-sentinel block (the slot tables' padding target)
    slab = float((kb + 1) * 4 + nbl_b * (4 + block_bytes) + block_bytes)
    a_stage = float((srb + 1) * 4 + nbl_a * (4 + block_bytes) + block_bytes)
    c_block = float(nc * block_bytes)
    if plan.algorithm == "chunk2":
        streamed, stationary = a_stage, slab
        c_accum = plan.n_ac * c_block
    else:                                            # knl / chunk1
        streamed, stationary = slab, a_stage
        c_accum = c_block
    workspace = float(2 * nc * u * 4 + block_bytes)
    return BackendFastModel(
        backend="bsr",
        fast_bytes_needed=2 * streamed + stationary + c_accum + workspace,
        streamed_bytes=streamed, stationary_bytes=stationary,
        c_accum_bytes=c_accum, workspace_bytes=workspace,
    )


def accumulator_backends() -> tuple:
    """Deterministic evaluation (and tie-break) order of the auto dispatch:
    the registry's accumulator specs in registration order."""
    from repro.core import backend_registry

    return tuple(s.name for s in backend_registry.accumulator_specs())


def backend_fast_models(plan: ChunkPlan, envelope) -> dict:
    """Every registered accumulator's byte model under one plan + envelope,
    in the registry's priority order."""
    from repro.core import backend_registry

    return {s.name: s.byte_model(plan, envelope)
            for s in backend_registry.accumulator_specs()}


def select_accumulator_backend(plan: ChunkPlan, envelope) -> str:
    """The ``backend="auto"`` rule: run the accumulator whose modeled peak
    resident fast-memory footprint is smallest under this plan + envelope —
    dense slab (``pallas``) vs ESC CSR scratch (``sparse``) vs hash probe
    (``hash``) vs blocked MXU tiles (``bsr``, only under block-capped
    envelopes — uncapped ones price it at infinity). Ties break toward the
    earlier registry entry (dense slab first: on real hardware it is the
    MXU-shaped one). This is the per-geometry accumulator choice ROADMAP
    asked the planner to make instead of picking one unconditionally."""
    models = backend_fast_models(plan, envelope)
    return min(models, key=lambda b: models[b].fast_bytes_needed)


def check_output_caps(strip_nnz, c_max_row_nnz: int, c_pad: int,
                      row_cap: int | None, *, backend: str, a_shape: tuple,
                      b_shape: tuple, instance: int | None = None) -> None:
    """Fail loudly when a realized output structure exceeds the capacities a
    sparse-output kernel was sized with.

    The ESC and hash kernels silently *drop or misplace* entries past their
    fixed capacities (the scatter's overflow bucket, a full hash table), so
    an under-capped launch must be a planner-level :class:`ValueError` naming
    the offending geometry, not wrong values. ``strip_nnz``/``c_max_row_nnz``
    are the exact realized structure (symbolic phase); ``c_pad`` is the CSR
    scratch capacity and ``row_cap`` (hash only, ``None`` otherwise) the
    per-row hash-table slot count."""
    where = (f"batch instance {instance} of " if instance is not None else "")
    geom = f"{where}A{a_shape} x B{b_shape}"
    worst = max(strip_nnz) if strip_nnz else 0
    if worst > c_pad:
        raise ValueError(
            f"backend={backend!r}: realized strip output nnz {worst} exceeds "
            f"the accumulator capacity c_pad={c_pad} for {geom}; the kernel "
            f"would silently drop entries — raise c_pad (the symbolic default "
            f"from strip_output_caps is always sufficient)"
        )
    if row_cap is not None and c_max_row_nnz > row_cap:
        raise ValueError(
            f"backend={backend!r}: densest realized C row "
            f"({c_max_row_nnz} nnz) exceeds the hash-table capacity "
            f"{row_cap} slots for {geom}; insertion would overflow — size "
            f"the table from the exact symbolic c_max_row_nnz"
        )


def replan_for_latency(plan: ChunkPlan) -> ChunkPlan:
    """Coarsen a plan's streamed-B partition one step: drop every other
    interior boundary of ``p_b``, halving the chunk count (rounding up) and
    with it the per-request kernel-launch count.

    This is the serving layer's latency lever: when a bucket's observed
    per-request execution time exceeds its SLO, the bottleneck on small
    serving-scale instances is per-chunk launch/staging overhead, not the
    fast-memory limit the partition was originally searched against — so
    trading chunk granularity for fewer launches moves latency directly.
    The coarser chunks need roughly twice the staged fast bytes; the cost
    fields are scaled to reflect that (streamed copy volume is unchanged —
    the same bytes arrive in fewer, larger pieces). A single-chunk plan is
    returned unchanged (nothing left to coarsen)."""
    if plan.n_b <= 1:
        return plan
    interior = plan.p_b[1:-1]
    p_b = (plan.p_b[0], *interior[1::2], plan.p_b[-1])
    scale = (len(p_b) - 1) / plan.n_b
    return dataclasses.replace(
        plan, p_b=p_b,
        fast_bytes_needed=plan.fast_bytes_needed / max(scale, 1e-9))


def plan_knl(A: CSR, B: CSR, fast_limit_bytes: float,
             system: MemorySystem | None = None) -> ChunkPlan:
    """Algorithm 1 planning: np = ceil(size(B)/FastSize), equal-byte row partition of
    B via binary search. A and C stay in slow memory (never copied)."""
    del system   # accepted for signature parity with plan_chunks; sizing is byte-only
    b_rows = row_bytes_csr(B)
    size_b = float(b_rows.sum())
    n_p = max(1, int(np.ceil(size_b / fast_limit_bytes)))
    p_size = size_b / n_p
    p_b = binary_search_partition(b_rows, p_size)
    return ChunkPlan("knl", (0, A.n_rows), p_b, copy_bytes=size_b,
                     fast_bytes_needed=staged_chunk_bytes(B, p_b))


# ---------------------------------------------------------------------------
# two-hop pipeline planning: resident intermediate vs spill-to-slow
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """Plan for the fused triple product ``C = R x (A x P)``: one
    :class:`ChunkPlan` per hop plus the resident-intermediate decision.

    ``t_resident=True`` means the intermediate ``T = A x P`` stays staged in
    fast memory between the hops — its CSR triple (``t_bytes``) is budgeted
    *on top of* each hop's own staged peak, and the modeled copy cost drops
    the slow-memory round trip (hop 1's C write-out plus hop 2's streamed-B
    reads). When the combined footprint exceeds the fast limit the planner
    falls back to spilling: T round-trips through slow memory exactly as two
    independent products would, and ``copy_bytes`` keeps those events."""

    plan1: ChunkPlan          # hop 1: T = A x P
    plan2: ChunkPlan          # hop 2: C = R x T
    t_resident: bool          # T's CSR triple stays in fast between hops
    t_bytes: float            # staged footprint of the full intermediate
    copy_bytes: float         # modeled fast<->slow traffic for both hops
    fast_bytes_needed: float  # peak staged footprint across both hops


def plan_pipeline(A: CSR, P: CSR, R: CSR, system: MemorySystem,
                  fast_limit_bytes: float | None = None,
                  big_portion: float = 0.75,
                  t_pattern: CSR | None = None) -> PipelinePlan:
    """Plan both hops of ``C = R x (A x P)`` and budget fast memory for the
    resident intermediate.

    Hop 1 is planned with T's *exact* per-row bytes as the C estimate (the
    composed symbolic expansion is structure-exact, so no heuristic row
    estimate is needed); hop 2 streams T as its B operand and is planned
    against C's exact structure the same way. T stays resident iff both
    hops' staged peaks still fit the fast limit with the whole intermediate
    held alongside them; otherwise the plan records the spill and the copy
    model keeps T's round trip (one write-out after hop 1 plus one read per
    hop-2 strip pass — the exact bytes the resident path saves)."""
    from repro.core.symbolic import spgemm_pattern_host

    if t_pattern is None:
        t_pattern = spgemm_pattern_host(A, P)
    fast = float(fast_limit_bytes or system.fast.capacity_bytes)
    crb1 = row_bytes_csr(t_pattern)
    c_pattern = spgemm_pattern_host(R, t_pattern)
    crb2 = row_bytes_csr(c_pattern)
    t_ptr = np.asarray(t_pattern.indptr)
    t_nnz = int(t_ptr[-1])
    t_bytes = _csr_staged_bytes(t_pattern.n_rows, t_nnz, 8)

    def plan_hops(limit: float) -> tuple:
        p1 = plan_chunks(A, P, crb1, system, fast_limit_bytes=limit,
                         big_portion=big_portion)
        p2 = plan_chunks(R, t_pattern, crb2, system, fast_limit_bytes=limit,
                         big_portion=big_portion)
        return p1, p2

    # T's slow-memory round trip: hop 1 writes it once; hop 2's streamed-B
    # reads repeat per A/C strip pass in the chunk1 order (cost1's |B|*n_ac
    # term), once otherwise. These bytes are inside the per-hop copy models,
    # so residency *subtracts* them.
    size_t = float(crb1.sum())

    def pipeline_copy(p1: ChunkPlan, p2: ChunkPlan, resident: bool) -> float:
        copy = p1.copy_bytes + p2.copy_bytes
        if resident:
            t_reads = p2.n_ac if p2.algorithm == "chunk1" else 1
            copy -= size_t * (1 + t_reads)
        return max(copy, 0.0)

    # Budget for residency: reserve T's staged triple off the top and search
    # both hops' partitions against the remainder. Staged padding can push a
    # plan's realized peak past the limit it was searched against, so the
    # reservation is re-checked against the realized peaks — backing the
    # search limit off geometrically when the overshoot breaks it. Residency
    # only wins if the saved round trip beats what the tighter partitions
    # cost in extra streaming passes; otherwise plan at the full limit and
    # spill.
    resident_plans = None
    reserve = fast - t_bytes
    if reserve > 0:
        limit = reserve
        for _ in range(6):
            p1, p2 = plan_hops(limit)
            if (p1.fast_bytes_needed + t_bytes <= fast
                    and p2.fast_bytes_needed + t_bytes <= fast):
                resident_plans = (p1, p2)
                break
            limit *= 0.85
    spill_plans = plan_hops(fast)
    spill_copy = pipeline_copy(*spill_plans, resident=False)
    if resident_plans is not None:
        resident_copy = pipeline_copy(*resident_plans, resident=True)
        if resident_copy <= spill_copy:
            plan1, plan2 = resident_plans
            return PipelinePlan(
                plan1=plan1, plan2=plan2, t_resident=True, t_bytes=t_bytes,
                copy_bytes=resident_copy,
                fast_bytes_needed=max(plan1.fast_bytes_needed,
                                      plan2.fast_bytes_needed) + t_bytes)
    plan1, plan2 = spill_plans
    return PipelinePlan(
        plan1=plan1, plan2=plan2, t_resident=False, t_bytes=t_bytes,
        copy_bytes=spill_copy,
        fast_bytes_needed=max(plan1.fast_bytes_needed,
                              plan2.fast_bytes_needed))
