"""Streaming chunk executors behind the backend registry.

The paper's three chunk orders (KNL / Chunk1 / Chunk2) admit many numeric
backends — host loop, device scan, hand-DMA'd Pallas pipelines, compressed
accumulators, MXU-blocked tiles. This module implements the executor
*cores* and registers each backend with
``repro.core.backend_registry`` (registrations at the bottom of the file);
every dispatch layer — ``chunked_spgemm``, :func:`chunked_spgemm_batched`,
``SpGEMMService``, the planner's ``backend="auto"`` resolve, the
conformance matrix, the bench lanes — derives its backend set from the
registry rather than naming backends by hand. Adding a backend is a kernel
module plus one ``BackendSpec`` registration (see ``docs/backends.md``).

The registered backends, in registry (= auto tie-break) order:

* ``loop`` — host-driven Python loop (``repro.core.chunking``); every chunk
  boundary is a device round-trip. Retained as the bitwise oracle.
* ``scan`` — the same three algorithms as **one jitted program each**: the
  uniformly-padded B chunks and A/C strips stack host-side into batched
  CSRs (``csr_stack``) and the chunk loop is a ``lax.scan`` with the fused
  ``spgemm_ranged`` body inlined, so the multiply never leaves the device.
  XLA is *free* to double-buffer the slow->fast transfers, but nothing
  forces the overlap.
* ``pallas`` — forces it: ``repro.kernels.ranged_spgemm``'s pallas_call
  hand-DMAs the streamed operand through a two-slot VMEM buffer (copy
  chunk j+1 while chunk j multiplies — the paper's ``copy2Fast`` overlap
  made explicit), accumulating into dense per-strip slabs.
* ``sparse`` — lifts the dense-C memory bound: the same two-slot DMA
  schedule through ``repro.kernels.sparse_accum_spgemm``, accumulating
  into a fixed-capacity **CSR triple in VMEM** sized by the symbolic phase
  (``repro.core.symbolic``) — footprint scales with ``nnz(C)``, not
  ``strip_rows * n_cols``.
* ``hash`` — shrinks the ESC workspace: ``repro.kernels.hash_accum_spgemm``
  merges through per-row linear-probing hash tables sized by the symbolic
  ``c_max_row_nnz`` (densest output row, not the expand size).
* ``bsr`` — trades entry-level sparsity for MXU-shaped tiles: each
  (strip, chunk) pair stages as BSR (``repro.sparse.bsr``) and runs the
  blocked kernel ``repro.kernels.bsr_spgemm``, whose scalar-prefetched
  slot tables schedule one dense ``bs x bs`` MAC per contributing block
  pair (padding slots point at an appended zero-sentinel block). Its
  compile geometry is the envelope's ``bsr_caps`` block bounds
  (``symbolic.bsr_plan_caps``), so the whole *envelope* is the jit key.

``backend="auto"`` is the planner-driven dispatch over the registered
accumulator backends: ``planner.select_accumulator_backend`` argmins their
``BackendFastModel`` peak-resident byte models — dense slabs when C
densifies, ESC when the expand stream is small, hash when outputs are wide
but rows stay sparse, BSR when the operands are block-structured (its
model prices the ``bs^2``-per-entry padding waste honestly, and an
envelope without block caps prices it at infinity, keeping block analysis
opt-in). The ``accumulator_shootout`` and ``bsr_blocking`` bench lanes
measure where the models cross.

Because a traced scan (or Pallas grid) cannot mutate Python-side counters,
ChunkStats for these backends is *computed from the plan*: the uniform padding
makes every staged chunk/strip/partial the same size, so the per-copy event
sequence is reproducible host-side. ``planned_stats`` replays the loop
executors' CSR-staging events (asserted identical in tests);
``planned_stats_pallas`` replays the Pallas pipeline's dense-slab DMA events,
which differ structurally (dense staged sizes; Chunk2's C partials persist in
VMEM instead of bouncing to slow memory).

Each backend's compile accounting is observable through ``TRACE_COUNTS``
under the spec's ``trace_key``/``trace_key_batched`` templates
(``"{alg}"``, ``"{alg}_pallas_batched"``, ...): one bump per (re)trace of
the backend's jitted core, pinned exactly by the conformance suite.

``chunked_spgemm_batched`` runs a backend's batched entry over stacked
problem instances sharing one plan: the many-small-matrices serving
scenario. Batches may mix sparsity structures: every instance is repadded
to a shared ``GeometryEnvelope`` (the batch union, or a caller-provided
bucket envelope) before stacking. ``repro.serve.spgemm_service`` builds
the request-bucketing service on top.
"""

from __future__ import annotations

import collections
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import backend_registry
from repro.core.chunking import (
    ChunkStats, _assemble, a_strips, b_chunks, batch_envelope,
    chunk_gpu1, chunk_gpu2, chunk_knl, instance_envelope,
)
from repro.core.kkmem import spgemm_ranged_impl
from repro.core.planner import (
    ChunkPlan, check_output_caps, csr_field_nbytes, hash_table_slots,
    planned_stats_bsr, planned_stats_dense_slab, planned_stats_hash,
    planned_stats_sparse, select_accumulator_backend,
)
from repro.core.symbolic import masked_output_caps, strip_output_caps
from repro.kernels.bsr_spgemm import bsr_spgemm_blocks, bsr_spgemm_symbolic
from repro.kernels.hash_accum_spgemm import (
    hash_accum_spgemm_stream, hash_masked_accum_spgemm_stream,
)
from repro.kernels.ranged_spgemm import default_interpret, ranged_spgemm_stream
from repro.kernels.sparse_accum_spgemm import sparse_accum_spgemm_stream
from repro.sparse.bsr import bsr_blocks_with_sentinel, bsr_from_dense
from repro.sparse.csr import (
    CSR, GeometryEnvelope, csr_from_dense, csr_pad_to, csr_stack, csr_to_dense,
    csr_unstack,
)

# Python-side trace counters: each key increments once per (re)trace of the
# corresponding jitted wrapper / scan body. Tests assert these stay O(1) in
# the chunk count — the whole point of the single-trace executors.
TRACE_COUNTS: collections.Counter = collections.Counter()


def _empty_c(n_rows: int, n_cols: int, c_pad: int, dtype) -> CSR:
    """Empty C with ``max_row_nnz=c_pad`` so the scan carry has exactly the
    pytree structure ``spgemm_ranged_impl`` returns (aux mismatch would fail
    the carry check)."""
    return CSR(
        indptr=jnp.zeros(n_rows + 1, jnp.int32),
        indices=jnp.zeros(c_pad, jnp.int32),
        data=jnp.zeros(c_pad, dtype),
        shape=(n_rows, n_cols),
        max_row_nnz=c_pad,
    )


def _empty_c_stack(n: int, n_rows: int, n_cols: int, c_pad: int, dtype) -> CSR:
    """Stacked empty partials ([n, ...] leading axis) for the Chunk2 carry."""
    return CSR(
        indptr=jnp.zeros((n, n_rows + 1), jnp.int32),
        indices=jnp.zeros((n, c_pad), jnp.int32),
        data=jnp.zeros((n, c_pad), dtype),
        shape=(n_rows, n_cols),
        max_row_nnz=c_pad,
    )


# ---------------------------------------------------------------------------
# jitted scan cores (one compilation per padded geometry, not per chunk)
# ---------------------------------------------------------------------------


def _knl_scan_impl(A: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    def body(C, x):
        TRACE_COUNTS["knl_body"] += 1
        Bc, r0, r1 = x
        return spgemm_ranged_impl(A, Bc, r0, r1, C, c_pad), None

    C, _ = lax.scan(body, C0, (Bs, r0s, r1s))
    return C


def _chunk1_scan_impl(As: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    def outer(carry, Ai):
        def inner(C, x):
            TRACE_COUNTS["chunk1_body"] += 1
            Bc, r0, r1 = x
            return spgemm_ranged_impl(Ai, Bc, r0, r1, C, c_pad), None

        Ci, _ = lax.scan(inner, C0, (Bs, r0s, r1s))
        return carry, Ci

    _, Cs = lax.scan(outer, None, As)
    return Cs


def _chunk2_scan_impl(As: CSR, Bs: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    def outer(Cs, x):
        Bc, r0, r1 = x

        def inner(carry, y):
            TRACE_COUNTS["chunk2_body"] += 1
            Ai, Ci = y
            return carry, spgemm_ranged_impl(Ai, Bc, r0, r1, Ci, c_pad)

        _, Cs2 = lax.scan(inner, None, (As, Cs))
        return Cs2, None

    Cs, _ = lax.scan(outer, C0s, (Bs, r0s, r1s))
    return Cs


@partial(jax.jit, static_argnames=("c_pad",))
def _knl_scan(A: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["knl"] += 1
    return _knl_scan_impl(A, Bs, r0s, r1s, C0, c_pad)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk1_scan(As: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    """A/C strips outer (stationary), B chunks inner (streamed). Returns the
    stacked per-strip results ([n_ac] leading axis)."""
    TRACE_COUNTS["chunk1"] += 1
    return _chunk1_scan_impl(As, Bs, r0s, r1s, C0, c_pad)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk2_scan(As: CSR, Bs: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    """B chunk outer (stationary), A/C strips inner (streamed); all per-strip
    partials ride the scan carry. Returns the stacked per-strip results."""
    TRACE_COUNTS["chunk2"] += 1
    return _chunk2_scan_impl(As, Bs, r0s, r1s, C0s, c_pad)


# Batched (vmapped) cores: one jitted program per (envelope, plan, batch)
# geometry. Each gets its own TRACE_COUNTS key so the serving layer can assert
# "one compile per geometry bucket" directly.


@partial(jax.jit, static_argnames=("c_pad",))
def _knl_scan_batched(Ast: CSR, Bst: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["knl_batched"] += 1
    return jax.vmap(
        lambda A, Bs, C0: _knl_scan_impl(A, Bs, r0s, r1s, C0, c_pad)
    )(Ast, Bst, C0s)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk1_scan_batched(Ast: CSR, Bst: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["chunk1_batched"] += 1
    return jax.vmap(
        lambda As, Bs: _chunk1_scan_impl(As, Bs, r0s, r1s, C0, c_pad)
    )(Ast, Bst)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk2_scan_batched(Ast: CSR, Bst: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["chunk2_batched"] += 1
    return jax.vmap(
        lambda As, Bs: _chunk2_scan_impl(As, Bs, r0s, r1s, C0s, c_pad)
    )(Ast, Bst)


_SCAN_CORES_BATCHED = {"knl": _knl_scan_batched,
                       "chunk1": _chunk1_scan_batched,
                       "chunk2": _chunk2_scan_batched}


def _make_scan_batched_cores(donate: bool = False) -> dict:
    """A fresh jitted set of the three batched scan cores (same
    ``TRACE_COUNTS`` keys as the module-level set, so compile accounting is
    backend-uniform regardless of which set ran). Module-level cores cache
    compilations in a module-global jit cache for the life of the process;
    a caller that owns a set from this factory (a serving bucket) is the
    sole owner of its executables, so dropping the set really frees them.

    ``donate=True`` donates the knl C-accumulator stack — the one scan core
    whose output aliases its ``C0s`` argument shape-for-shape, letting XLA
    write results into the staged accumulator's buffer. The chunk1/chunk2
    ``C0`` is a shared per-strip template the vmap broadcasts, so its shape
    never matches the stacked output and donation would only warn."""
    knl_jit = partial(jax.jit, static_argnames=("c_pad",),
                      donate_argnums=(4,) if donate else ())

    @knl_jit
    def knl(Ast: CSR, Bst: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
        TRACE_COUNTS["knl_batched"] += 1
        return jax.vmap(
            lambda A, Bc, C0: _knl_scan_impl(A, Bc, r0s, r1s, C0, c_pad)
        )(Ast, Bst, C0s)

    @partial(jax.jit, static_argnames=("c_pad",))
    def chunk1(Ast: CSR, Bst: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
        TRACE_COUNTS["chunk1_batched"] += 1
        return jax.vmap(
            lambda As, Bs: _chunk1_scan_impl(As, Bs, r0s, r1s, C0, c_pad)
        )(Ast, Bst)

    @partial(jax.jit, static_argnames=("c_pad",))
    def chunk2(Ast: CSR, Bst: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
        TRACE_COUNTS["chunk2_batched"] += 1
        return jax.vmap(
            lambda As, Bs: _chunk2_scan_impl(As, Bs, r0s, r1s, C0s, c_pad)
        )(Ast, Bst)

    return {"knl": knl, "chunk1": chunk1, "chunk2": chunk2}


# ---------------------------------------------------------------------------
# plan-derived copy accounting (the scan cannot mutate Python stats)
# ---------------------------------------------------------------------------


def planned_stats(plan: ChunkPlan, chunk_nbytes: int, strip_nbytes: int,
                  c_strip_nbytes: int) -> ChunkStats:
    """Replay the loop executors' per-copy event sequence from the plan.

    Uniform padding makes every B chunk / A strip / C partial the same size,
    so the event stream is fully determined by (algorithm, n_ac, n_b) plus the
    three footprints — tests assert event-for-event equality with the loop.
    """
    stats = ChunkStats(plan.algorithm, plan.n_ac, plan.n_b)
    if plan.algorithm == "knl":
        for _ in range(plan.n_b):
            stats.add_in(chunk_nbytes)
        stats.kernel_calls = plan.n_b
        return stats
    if plan.algorithm == "chunk1":
        for a0, a1 in zip(plan.p_ac[:-1], plan.p_ac[1:]):
            stats.add_in(strip_nbytes)
            stats.add_in((a1 - a0 + 1) * 4)
            for _ in range(plan.n_b):
                stats.add_in(chunk_nbytes)
                stats.kernel_calls += 1
            stats.add_out(c_strip_nbytes)
        return stats
    if plan.algorithm == "chunk2":
        for jb in range(plan.n_b):
            stats.add_in(chunk_nbytes)
            for _ in range(plan.n_ac):
                stats.add_in(strip_nbytes)
                if jb > 0:
                    stats.add_in(c_strip_nbytes)
                stats.kernel_calls += 1
                if jb < plan.n_b - 1:
                    stats.add_out(c_strip_nbytes)
            if jb == plan.n_b - 1:
                for _ in range(plan.n_ac):
                    stats.add_out(c_strip_nbytes)
        return stats
    raise ValueError(f"unknown algorithm {plan.algorithm!r}")


def _c_strip_nbytes(strip_rows: int, c_pad: int, dtype) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    return (strip_rows + 1) * 4 + c_pad * (4 + itemsize)


def planned_stats_pallas(plan: ChunkPlan, slab_nbytes: int, a_stage_nbytes: int,
                         c_stage_nbytes: int) -> ChunkStats:
    """Replay the Pallas pipeline's per-copy event sequence from the plan.

    The event model differs from :func:`planned_stats` in three structural
    ways, all of them properties of the kernel rather than modeling choices:

      * staged pieces are **dense** (slab = ``chunk_rows x n`` floats, strip =
        ``strip_rows x k_pad`` floats), not padded CSR triples;
      * the stationary operand is staged by the Pallas pipeline once per outer
        step, and the streamed operand is hand-DMA'd once per grid step — the
        double-buffer *overlaps* those copies with compute but their byte
        volume is unchanged;
      * in the Chunk2 order the per-strip C partials persist in the VMEM
        output block across outer steps, so the ``(n_b - 1)`` per-strip
        out+in partial bounces of the loop/scan model collapse into **one**
        whole-block ``C_prev`` fetch and **one** final writeback: the kernel
        maps all ``n_ac`` partials as a single ``(n_ac, strip_rows, n)``
        block at a constant index, so the pipeline stages it as one copy
        event of ``n_ac * c_stage_nbytes``, not ``n_ac`` per-strip events
        (the traffic-equality audit holds this model to the traced jaxpr
        event-for-event).
    """
    stats = ChunkStats(plan.algorithm, plan.n_ac, plan.n_b)
    if plan.algorithm in ("knl", "chunk1"):
        for _ in range(plan.n_ac):           # knl is the 1-strip special case
            stats.add_in(a_stage_nbytes)     # stationary strip -> VMEM
            stats.add_in(c_stage_nbytes)     # fused C_prev block
            for _ in range(plan.n_b):
                stats.add_in(slab_nbytes)    # double-buffered slab DMA
                stats.kernel_calls += 1
            stats.add_out(c_stage_nbytes)    # strip result writeback
        return stats
    if plan.algorithm == "chunk2":
        for jb in range(plan.n_b):
            stats.add_in(slab_nbytes)        # stationary chunk -> VMEM
            if jb == 0:
                # C_prev: one whole-block fetch (all n_ac partials at once)
                stats.add_in(plan.n_ac * c_stage_nbytes)
            for _ in range(plan.n_ac):
                stats.add_in(a_stage_nbytes)       # streamed strip DMA
                stats.kernel_calls += 1
        # single whole-block final writeback
        stats.add_out(plan.n_ac * c_stage_nbytes)
        return stats
    raise ValueError(f"unknown algorithm {plan.algorithm!r}")


def _pallas_stage_nbytes(strip_rows: int, k: int, span: int, n: int) -> tuple:
    """(slab, a_stage, c_stage) dense staged footprints in bytes (f32)."""
    return span * n * 4, strip_rows * (k + span) * 4, strip_rows * n * 4


# ---------------------------------------------------------------------------
# executors (drop-in signatures of chunk_knl / chunk_gpu1 / chunk_gpu2)
# ---------------------------------------------------------------------------


def chunk_knl_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    chunks = b_chunks(B, plan.p_b)
    Bs = csr_stack(chunks)
    r0s, r1s = plan.b_ranges()
    C0 = _empty_c(A.n_rows, B.n_cols, c_pad, A.dtype)
    C = _knl_scan(A, Bs, jnp.asarray(r0s), jnp.asarray(r1s), C0, c_pad)
    stats = planned_stats(plan, chunks[0].nbytes(), 0, 0)
    return C, stats


def chunk_gpu1_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0 = _empty_c(strip_rows, B.n_cols, c_pad, A.dtype)
    Cs = _chunk1_scan(As, Bs, jnp.asarray(r0s), jnp.asarray(r1s), C0, c_pad)
    stats = planned_stats(plan, chunks[0].nbytes(), strips[0].nbytes(),
                          _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    return _assemble(csr_unstack(Cs), plan.p_ac, B.n_cols), stats


def chunk_gpu2_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0s = _empty_c_stack(plan.n_ac, strip_rows, B.n_cols, c_pad, A.dtype)
    Cs = _chunk2_scan(As, Bs, jnp.asarray(r0s), jnp.asarray(r1s), C0s, c_pad)
    stats = planned_stats(plan, chunks[0].nbytes(), strips[0].nbytes(),
                          _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    return _assemble(csr_unstack(Cs), plan.p_ac, B.n_cols), stats


# ---------------------------------------------------------------------------
# Pallas backend: explicit double-buffered prefetch (kernels/ranged_spgemm)
# ---------------------------------------------------------------------------


def _dense_stack(stacked: CSR, levels: int = 1) -> jax.Array:
    """Densify a (possibly doubly) ``csr_stack``-ed CSR: ``levels`` leading
    stack axes become leading dense axes."""
    shape, mrn = stacked.shape, stacked.max_row_nnz

    def densify(ip, ix, d):
        return csr_to_dense(CSR(ip, ix, d, shape, mrn))

    fn = densify
    for _ in range(levels):
        fn = jax.vmap(fn)
    return fn(stacked.indptr, stacked.indices, stacked.data)


def _pad_cols(a: jax.Array, span: int) -> jax.Array:
    """Zero-pad the last (column) axis by ``span`` so the kernel's ranged
    slice of the final chunk never reads out of bounds."""
    pad = [(0, 0)] * (a.ndim - 1) + [(0, span)]
    return jnp.pad(a.astype(jnp.float32), pad)


def _make_pallas_core(key: str, order: str, *, batched: bool, strips: bool):
    """One jitted staging-and-launch core; the six variants differ only in
    the streaming order, the trace-counter key, and whether A arrives as a
    plain CSR (knl), a strip stack, or a per-instance (doubly) stacked batch.

    Batched cores ride the batch on a leading grid dimension of the same
    kernel (one pallas_call for the whole microbatch — no vmap-of-pallas),
    with their own TRACE_COUNTS keys so the serving layer's compile
    accounting stays exact.
    """
    a_levels = (1 if strips else 0) + (1 if batched else 0)

    @jax.jit
    def core(Ast: CSR, Bst: CSR, r0s) -> jax.Array:
        TRACE_COUNTS[key] += 1
        span = Bst.n_rows
        a = _pad_cols(_dense_stack(Ast, levels=a_levels), span)
        slabs = _dense_stack(Bst, levels=2 if batched else 1).astype(jnp.float32)
        if not strips:               # knl: the whole A is the single strip
            a = a[:, None] if batched else a[None]
        if not batched:              # width-1 batch axis
            a, slabs = a[None], slabs[None]
        c0 = jnp.zeros(a.shape[:3] + (Bst.n_cols,), jnp.float32)
        out = ranged_spgemm_stream(a, slabs, c0, r0s, order=order)
        if not batched:
            out = out[0]
        if not strips:
            out = out[:, 0] if batched else out[0]
        return out

    return core


_knl_pallas = _make_pallas_core("knl_pallas", "chunk1",
                                batched=False, strips=False)
_chunk1_pallas = _make_pallas_core("chunk1_pallas", "chunk1",
                                   batched=False, strips=True)
_chunk2_pallas = _make_pallas_core("chunk2_pallas", "chunk2",
                                   batched=False, strips=True)
_knl_pallas_batched = _make_pallas_core("knl_pallas_batched", "chunk1",
                                        batched=True, strips=False)
_chunk1_pallas_batched = _make_pallas_core("chunk1_pallas_batched", "chunk1",
                                           batched=True, strips=True)
_chunk2_pallas_batched = _make_pallas_core("chunk2_pallas_batched", "chunk2",
                                           batched=True, strips=True)

_PALLAS_CORES_BATCHED = {"knl": _knl_pallas_batched,
                         "chunk1": _chunk1_pallas_batched,
                         "chunk2": _chunk2_pallas_batched}


def _make_pallas_batched_cores(donate: bool = False) -> dict:
    """Fresh jitted batched Pallas cores (see ``_make_scan_batched_cores``
    for why a caller-owned set exists). The dense accumulator is allocated
    inside the jit and the staged CSR operands never alias the dense
    outputs, so there is nothing donation could usefully alias here."""
    del donate
    return {
        "knl": _make_pallas_core("knl_pallas_batched", "chunk1",
                                 batched=True, strips=False),
        "chunk1": _make_pallas_core("chunk1_pallas_batched", "chunk1",
                                    batched=True, strips=True),
        "chunk2": _make_pallas_core("chunk2_pallas_batched", "chunk2",
                                    batched=True, strips=True),
    }


def _pallas_assemble(dense, p_ac: tuple, dtype) -> CSR:
    """Crop per-strip dense results to their true rows, concatenate, and
    sparsify (host). The Pallas backend's CSR keeps exactly the nonzeros of
    the dense result, so comparisons against the loop oracle are allclose on
    the densified values rather than bitwise on padding structure."""
    dense = np.asarray(dense)
    whole = np.concatenate([
        dense[i][: e - s]
        for i, (s, e) in enumerate(zip(p_ac[:-1], p_ac[1:]))
    ])
    return csr_from_dense(whole.astype(dtype))


def chunk_knl_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    del c_pad  # capacity is implicit in the dense accumulator
    chunks = b_chunks(B, plan.p_b)
    Bs = csr_stack(chunks)
    r0s, _ = plan.b_ranges()
    dense = _knl_pallas(A, Bs, jnp.asarray(r0s))
    C = csr_from_dense(np.asarray(dense).astype(np.dtype(A.dtype)))
    stats = planned_stats_pallas(
        plan, *_pallas_stage_nbytes(A.n_rows, A.n_cols, Bs.n_rows, B.n_cols))
    return C, stats


def chunk_gpu1_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    del c_pad
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, _ = plan.b_ranges()
    dense = _chunk1_pallas(As, Bs, jnp.asarray(r0s))
    stats = planned_stats_pallas(
        plan, *_pallas_stage_nbytes(As.n_rows, A.n_cols, Bs.n_rows, B.n_cols))
    return _pallas_assemble(dense, plan.p_ac, np.dtype(A.dtype)), stats


def chunk_gpu2_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    del c_pad
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, _ = plan.b_ranges()
    dense = _chunk2_pallas(As, Bs, jnp.asarray(r0s))
    stats = planned_stats_pallas(
        plan, *_pallas_stage_nbytes(As.n_rows, A.n_cols, Bs.n_rows, B.n_cols))
    return _pallas_assemble(dense, plan.p_ac, np.dtype(A.dtype)), stats


# ---------------------------------------------------------------------------
# Sparse-output backend: CSR-native accumulator (kernels/sparse_accum_spgemm)
# ---------------------------------------------------------------------------


def _sparse_c0_stack(batch: int, n_ac: int, strip_rows: int, n_cols: int,
                     c_cap: int, dtype) -> CSR:
    """Empty stacked C_prev strips ([batch, n_ac] leading axes) at the CSR
    scratch capacity ``c_cap`` (the symbolic phase's strip output bound)."""
    return CSR(
        indptr=jnp.zeros((batch, n_ac, strip_rows + 1), jnp.int32),
        indices=jnp.zeros((batch, n_ac, c_cap), jnp.int32),
        data=jnp.zeros((batch, n_ac, c_cap), dtype),
        shape=(strip_rows, n_cols),
        max_row_nnz=c_cap,
    )


def _make_sparse_core(key: str, order: str, donate: bool = False):
    """One jitted launch core for the sparse-output kernel; the six variants
    differ only in the streaming order and the trace-counter key (all staging
    is host-side, so batched cores share the same body — the batch rides the
    kernel's leading grid dimension). ``donate=True`` donates the ``C0st``
    scratch stack, whose (indptr, indices, data) leaves match the kernel
    outputs shape-for-shape — the serving layer allocates it fresh per
    flush, so XLA may write results straight into it."""

    @partial(jax.jit, donate_argnums=(2,) if donate else ())
    def core(Ast: CSR, Bst: CSR, C0st: CSR, r0s, r1s):
        TRACE_COUNTS[key] += 1
        return sparse_accum_spgemm_stream(Ast, Bst, C0st, r0s, r1s,
                                          order=order)

    return core


_knl_sparse = _make_sparse_core("knl_sparse", "chunk1")
_chunk1_sparse = _make_sparse_core("chunk1_sparse", "chunk1")
_chunk2_sparse = _make_sparse_core("chunk2_sparse", "chunk2")
_knl_sparse_batched = _make_sparse_core("knl_sparse_batched", "chunk1")
_chunk1_sparse_batched = _make_sparse_core("chunk1_sparse_batched", "chunk1")
_chunk2_sparse_batched = _make_sparse_core("chunk2_sparse_batched", "chunk2")

_SPARSE_CORES = {"knl": _knl_sparse, "chunk1": _chunk1_sparse,
                 "chunk2": _chunk2_sparse}
_SPARSE_CORES_BATCHED = {"knl": _knl_sparse_batched,
                         "chunk1": _chunk1_sparse_batched,
                         "chunk2": _chunk2_sparse_batched}


def _make_hash_core(key: str, order: str, donate: bool = False):
    """Launch core for the hash-probe kernel; ``table_size`` (the per-row
    hash-table slot count, from the envelope's ``c_max_row_nnz``) is a static
    jit argument, so two geometries differing only in the densest-output-row
    bound compile separate tables — exactly the retrace the envelope's
    ``c_max_row_nnz`` field exists to key. ``donate`` as in
    :func:`_make_sparse_core` (the ``C0st`` scratch aliases the outputs)."""

    @partial(jax.jit, static_argnames=("table_size",),
             donate_argnums=(2,) if donate else ())
    def core(Ast: CSR, Bst: CSR, C0st: CSR, r0s, r1s, table_size: int):
        TRACE_COUNTS[key] += 1
        return hash_accum_spgemm_stream(Ast, Bst, C0st, r0s, r1s,
                                        order=order, table_size=table_size)

    return core


_knl_hash = _make_hash_core("knl_hash", "chunk1")
_chunk1_hash = _make_hash_core("chunk1_hash", "chunk1")
_chunk2_hash = _make_hash_core("chunk2_hash", "chunk2")
_knl_hash_batched = _make_hash_core("knl_hash_batched", "chunk1")
_chunk1_hash_batched = _make_hash_core("chunk1_hash_batched", "chunk1")
_chunk2_hash_batched = _make_hash_core("chunk2_hash_batched", "chunk2")

_HASH_CORES = {"knl": _knl_hash, "chunk1": _chunk1_hash,
               "chunk2": _chunk2_hash}
_HASH_CORES_BATCHED = {"knl": _knl_hash_batched,
                       "chunk1": _chunk1_hash_batched,
                       "chunk2": _chunk2_hash_batched}

_CSR_ACCUM_ORDERS = {"knl": "chunk1", "chunk1": "chunk1", "chunk2": "chunk2"}


def _make_sparse_batched_cores(donate: bool = False) -> dict:
    """Fresh jitted batched ESC cores (caller-owned executables; see
    ``_make_scan_batched_cores``)."""
    return {alg: _make_sparse_core(f"{alg}_sparse_batched", order,
                                   donate=donate)
            for alg, order in _CSR_ACCUM_ORDERS.items()}


def _make_hash_batched_cores(donate: bool = False) -> dict:
    """Fresh jitted batched hash-probe cores (caller-owned executables)."""
    return {alg: _make_hash_core(f"{alg}_hash_batched", order, donate=donate)
            for alg, order in _CSR_ACCUM_ORDERS.items()}


def _sparse_strip_csrs(ip, ix, d, strip_rows: int, n_cols: int,
                       c_cap: int) -> list:
    """Wrap one batch element's kernel outputs ([n_ac, ...]) as strip CSRs."""
    return [
        CSR(ip[i], ix[i], d[i], (strip_rows, n_cols), c_cap)
        for i in range(ip.shape[0])
    ]


def _sparse_run(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, backend: str,
                caps=None):
    """Shared body of the unbatched sparse-output executors (ESC and hash):
    stage CSR strips and chunks (knl is the 1-strip special case of the
    chunk1 order), validate the realized output structure against the
    capacities, launch, and assemble the accumulated strip CSRs.

    ``caps`` is the symbolic phase's :class:`StripOutputCaps` when the caller
    (the ``chunked_spgemm`` dispatch) already ran the expansion — the
    symbolic module's amortization contract; recomputed here only for direct
    executor calls.

    The per-copy event model is structurally the Pallas pipeline's
    (:func:`planned_stats_pallas`: stationary operand staged once per outer
    step, streamed triple DMA'd per grid step, C persists in VMEM with one
    final writeback) — only the staged byte sizes differ: padded **CSR**
    footprints instead of dense slabs.
    """
    if caps is None:
        caps = strip_output_caps(A, B, plan.p_ac)
    table = (hash_table_slots(caps.c_max_row_nnz) if backend == "hash"
             else None)
    check_output_caps(caps.strip_nnz, caps.c_max_row_nnz, c_pad, table,
                      backend=backend, a_shape=A.shape, b_shape=B.shape)
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    Ast = csr_stack([csr_stack(strips)])
    Bst = csr_stack([csr_stack(chunks)])
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0 = _sparse_c0_stack(1, plan.n_ac, strip_rows, B.n_cols, c_pad, A.dtype)
    if backend == "hash":
        ip, ix, d = _HASH_CORES[plan.algorithm](
            Ast, Bst, C0, jnp.asarray(r0s), jnp.asarray(r1s),
            table_size=table)
    else:
        ip, ix, d = _SPARSE_CORES[plan.algorithm](
            Ast, Bst, C0, jnp.asarray(r0s), jnp.asarray(r1s))
    stats = planned_stats_pallas(
        plan, chunks[0].nbytes(), strips[0].nbytes(),
        _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    out = _sparse_strip_csrs(ip[0], ix[0], d[0], strip_rows, B.n_cols, c_pad)
    return _assemble(out, plan.p_ac, B.n_cols), stats


def chunk_sparse(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, caps=None):
    """ESC sparse-output executor for any plan algorithm (``_sparse_run``
    dispatches the core on ``plan.algorithm``, so unlike the scan/pallas
    backends there is no per-algorithm staging difference to name)."""
    return _sparse_run(A, B, plan, c_pad, "sparse", caps=caps)


def chunk_hash(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, caps=None):
    """Hash-probe executor for any plan algorithm (see :func:`chunk_sparse`)."""
    return _sparse_run(A, B, plan, c_pad, "hash", caps=caps)


# ---------------------------------------------------------------------------
# masked hash executor: fused output mask (triangle counting's fast path)
# ---------------------------------------------------------------------------


def _make_masked_hash_core(key: str, order: str):
    """Launch core for the mask-fused hash kernel (``_make_hash_core`` with
    the mask's stacked structure as a fourth staged operand and the
    probe-only masked merge plugged in). Own ``TRACE_COUNTS`` keys: a masked
    product is a different program than the unmasked hash product, so its
    compile accounting must not alias the unmasked cores'."""

    @partial(jax.jit, static_argnames=("table_size",))
    def core(Ast: CSR, Bst: CSR, C0st: CSR, Mst: CSR, r0s, r1s,
             table_size: int):
        TRACE_COUNTS[key] += 1
        return hash_masked_accum_spgemm_stream(
            Ast, Bst, C0st, Mst, r0s, r1s, order=order,
            table_size=table_size)

    return core


_HASH_MASKED_CORES = {
    alg: _make_masked_hash_core(f"{alg}_hash_masked", order)
    for alg, order in {"knl": "chunk1", "chunk1": "chunk1",
                       "chunk2": "chunk2"}.items()
}


def chunk_hash_masked(A: CSR, B: CSR, mask: CSR, plan: ChunkPlan,
                      c_pad: int, caps=None):
    """Mask-fused hash executor: ``C = (A x B) ∘ mask``, mask inside the
    kernel.

    The registry's ``run_masked`` capability for the hash backend. C's
    structure is pinned to the mask's (explicit zeros where no product
    lands), so *every* capacity derives from the mask alone
    (``symbolic.masked_output_caps``): the probe tables are sized from the
    densest mask row and the CSR scratch from the largest strip's mask nnz
    — the unmasked product's structure is never expanded, let alone
    materialized. ``caps`` amortizes the (cheap, mask-only) host pass like
    the unmasked executors' ``StripOutputCaps``.
    """
    if mask.shape != (A.n_rows, B.n_cols):
        raise ValueError(
            f"mask shape {mask.shape} != output shape "
            f"{(A.n_rows, B.n_cols)}")
    if caps is None:
        caps = masked_output_caps(mask, plan.p_ac)
    table = hash_table_slots(caps.c_max_row_nnz)
    check_output_caps(caps.strip_nnz, caps.c_max_row_nnz, c_pad, table,
                      backend="hash", a_shape=A.shape, b_shape=B.shape)
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    mstrips = a_strips(mask, plan.p_ac)
    Ast = csr_stack([csr_stack(strips)])
    Bst = csr_stack([csr_stack(chunks)])
    Mst = csr_stack([csr_stack(mstrips)])
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0 = _sparse_c0_stack(1, plan.n_ac, strip_rows, B.n_cols, c_pad, A.dtype)
    ip, ix, d = _HASH_MASKED_CORES[plan.algorithm](
        Ast, Bst, C0, Mst, jnp.asarray(r0s), jnp.asarray(r1s),
        table_size=table)
    stats = planned_stats_pallas(
        plan, chunks[0].nbytes(), strips[0].nbytes(),
        _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    # the mask's structure operands (indptr + indices, no data) stage with
    # the fused C_prev block's index maps: once per strip in the chunk1
    # orders, one whole block in chunk2
    m_struct = (strip_rows + 1) * 4 + mstrips[0].indices.shape[-1] * 4
    if plan.algorithm == "chunk2":
        stats.add_in(plan.n_ac * m_struct)
    else:
        for _ in range(plan.n_ac):
            stats.add_in(m_struct)
    out = _sparse_strip_csrs(ip[0], ix[0], d[0], strip_rows, B.n_cols, c_pad)
    return _assemble(out, plan.p_ac, B.n_cols), stats


# ---------------------------------------------------------------------------
# BSR backend: MXU-blocked tiles (kernels/bsr_spgemm), envelope-keyed cores
# ---------------------------------------------------------------------------

_BSR_DEFAULT_BLOCK = 8


def _make_bsr_core(key: str, *, batched: bool):
    """One jitted launch core for the blocked kernel. The whole
    :class:`GeometryEnvelope` is the *static* jit key: the kernel geometry
    (``nc_pad``, ``u_max``, ``bs``) comes from its ``bsr_caps``, and keying
    on the envelope — not just the caps — gives the backend the same
    retrace-per-envelope semantics as every other backend (two geometries
    whose block caps happen to quantize equal still account separately).

    Batched cores take width-stacked operands (leading axis) and unroll the
    width inside the jit, so the serving layer's width-ladder compile
    accounting sees one (re)trace per (envelope, width)."""

    @partial(jax.jit, static_argnames=("envelope",))
    def core(a_blocks, b_blocks, a_slots, b_slots,
             envelope: GeometryEnvelope):
        TRACE_COUNTS[key] += 1
        bs, _, _, nc_pad, u_max = envelope.bsr_caps
        interpret = default_interpret()

        def one(ab, bb, asl, bsl):
            return bsr_spgemm_blocks(ab, bb, asl, bsl, nc_pad=nc_pad,
                                     u_max=u_max, bs=bs, interpret=interpret)

        if batched:
            return jnp.stack([
                one(a_blocks[w], b_blocks[w], a_slots[w], b_slots[w])
                for w in range(a_blocks.shape[0])
            ])
        return one(a_blocks, b_blocks, a_slots, b_slots)

    return core


_BSR_CORES = {alg: _make_bsr_core(f"{alg}_bsr", batched=False)
              for alg in ("knl", "chunk1", "chunk2")}
_BSR_CORES_BATCHED = {alg: _make_bsr_core(f"{alg}_bsr_batched", batched=True)
                      for alg in ("knl", "chunk1", "chunk2")}


def _make_bsr_batched_cores(donate: bool = False) -> dict:
    """Fresh jitted batched BSR cores (caller-owned executables). Staging is
    a host loop over (strip, chunk) pairs, so there is no device scratch to
    donate."""
    del donate
    return {alg: _make_bsr_core(f"{alg}_bsr_batched", batched=True)
            for alg in ("knl", "chunk1", "chunk2")}


def _bsr_execute(As, Bs, plan: ChunkPlan, envelope: GeometryEnvelope, *,
                 batched: bool, cores: dict | None = None):
    """Shared body of the BSR executors: stage every (strip, chunk) pair as
    BSR at the envelope's block caps, launch the blocked kernel per pair
    (Chunk2 streams strips under a stationary chunk, the other orders stream
    chunks under a stationary strip), and accumulate the per-pair outputs
    into per-strip dense C.

    Staging is host-side (like the symbolic phase): the pair's A piece is
    the strip's rows with columns outside the chunk zeroed, at full
    contraction width, and the B piece is the chunk's rows at full output
    width — so summing pair products over chunks is exactly the strip
    product. The per-pair block symbolic runs at the envelope's ``nc``/``u``
    floors, which makes every pair one kernel geometry (and fails loudly if
    the envelope does not dominate an instance). Accumulation and staging
    are f32, so comparisons against the loop oracle are allclose on values,
    like the Pallas dense-slab backend."""
    bs, nbl_a_cap, nbl_b_cap, nc_cap, u_cap = envelope.bsr_caps
    width = len(As)
    k, n = Bs[0].shape
    kpad = -(-k // bs) * bs
    npad = -(-n // bs) * bs
    srpad = -(-envelope.strip_rows // bs) * bs
    mbs, nbp = srpad // bs, npad // bs
    np_dtype = np.dtype(As[0].dtype)
    Ads = [np.asarray(csr_to_dense(A), np.float32) for A in As]
    Bds = [np.asarray(csr_to_dense(B), np.float32) for B in Bs]
    strips = list(zip(plan.p_ac[:-1], plan.p_ac[1:]))
    chunks = list(zip(plan.p_b[:-1], plan.p_b[1:]))
    if cores is None:
        cores = _BSR_CORES_BATCHED if batched else _BSR_CORES
    core = cores[plan.algorithm]
    accs = np.zeros((width, len(strips), mbs, nbp, bs, bs), np.float32)
    pairs = ([(ia, jb) for jb in range(len(chunks))
              for ia in range(len(strips))]
             if plan.algorithm == "chunk2" else
             [(ia, jb) for ia in range(len(strips))
              for jb in range(len(chunks))])
    for ia, jb in pairs:
        s, e = strips[ia]
        r0, r1 = chunks[jb]
        a_bl, b_bl, a_sl, b_sl, metas = [], [], [], [], []
        for w in range(width):
            Am = np.zeros((srpad, kpad), np.float32)
            Am[: e - s, r0:r1] = Ads[w][s:e, r0:r1]
            Bm = np.zeros((kpad, npad), np.float32)
            Bm[r0:r1, :n] = Bds[w][r0:r1, :]
            Ab = bsr_from_dense(Am, bs, pad_to=nbl_a_cap)
            Bb = bsr_from_dense(Bm, bs, pad_to=nbl_b_cap)
            meta = bsr_spgemm_symbolic(Ab, Bb, nc_pad=nc_cap, u_max=u_cap)
            metas.append(meta)
            a_bl.append(bsr_blocks_with_sentinel(Ab))
            b_bl.append(bsr_blocks_with_sentinel(Bb))
            a_sl.append(jnp.asarray(meta.a_slots))
            b_sl.append(jnp.asarray(meta.b_slots))
        if batched:
            out = core(jnp.stack(a_bl), jnp.stack(b_bl), jnp.stack(a_sl),
                       jnp.stack(b_sl), envelope=envelope)
        else:
            out = core(a_bl[0], b_bl[0], a_sl[0], b_sl[0],
                       envelope=envelope)[None]
        out_np = np.asarray(out)
        for w, meta in enumerate(metas):
            n_c = meta.n_c_blocks
            if not n_c:
                continue
            # crop to the real blocks: padded rows carry c_indices == 0 and
            # would alias block column 0 of every strip if scattered
            brows = np.repeat(np.arange(mbs),
                              np.diff(np.asarray(meta.c_indptr, np.int64)))
            np.add.at(accs[w, ia], (brows, meta.c_indices[:n_c]),
                      out_np[w, :n_c])
    block_bytes = bs * bs * 4
    slab = (kpad // bs + 1) * 4 + nbl_b_cap * (4 + block_bytes) + block_bytes
    a_stage = (mbs + 1) * 4 + nbl_a_cap * (4 + block_bytes) + block_bytes
    c_stage = (mbs + 1) * 4 + nc_cap * (4 + block_bytes)
    stats = planned_stats_pallas(plan, slab, a_stage, c_stage)
    out_csrs = []
    for w in range(width):
        dense = accs[w].transpose(0, 1, 3, 2, 4).reshape(len(strips), srpad,
                                                         npad)
        whole = np.concatenate([
            dense[i][: e - s, :n] for i, (s, e) in enumerate(strips)
        ])
        out_csrs.append(csr_from_dense(whole.astype(np_dtype)))
    return out_csrs, stats


def chunk_bsr(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, caps=None,
              block_size: int | None = None):
    """Blocked-tile executor for any plan algorithm (``_bsr_execute``
    orders the pair loop by ``plan.algorithm``). Builds the block-capped
    instance envelope itself when called directly; the dispatch passes
    ``caps`` to amortize the element-level symbolic phase and
    ``block_size`` to override the registered default block edge."""
    env = instance_envelope(A, B, plan, c_pad=c_pad, caps=caps,
                            block_size=block_size or _BSR_DEFAULT_BLOCK)
    out, stats = _bsr_execute([A], [B], plan, env, batched=False)
    return out[0], stats


# ---------------------------------------------------------------------------
# batched entry point: many problem instances, one plan, one compilation
# ---------------------------------------------------------------------------


def _stage_chunks_batched(Bs, plan: ChunkPlan, envelope: GeometryEnvelope):
    """Every instance's B chunks repadded to the envelope and doubly stacked
    ([batch, n_b, ...]); returns the stack and one staged chunk's bytes."""
    chunk_lists = [b_chunks(B, plan.p_b, envelope=envelope) for B in Bs]
    return (csr_stack([csr_stack(cl) for cl in chunk_lists]),
            chunk_lists[0][0].nbytes())


def _stage_strips_batched(As, plan: ChunkPlan, envelope: GeometryEnvelope):
    """Every instance's A strips repadded to the envelope and doubly stacked
    ([batch, n_ac, ...]); returns the stack and one staged strip's bytes."""
    strip_lists = [a_strips(A, plan.p_ac, envelope=envelope) for A in As]
    return (csr_stack([csr_stack(sl) for sl in strip_lists]),
            strip_lists[0][0].nbytes())


def _scan_run_batched(As, Bs, plan: ChunkPlan, envelope: GeometryEnvelope, *,
                      caps_list=None, validate_caps: bool = True,
                      cores: dict | None = None):
    """Batched entry of the scan backend: vmapped lax.scan cores, bitwise-
    identical to the unbatched executors for same-structure batches.
    ``cores`` substitutes a caller-owned core set from
    :func:`_make_scan_batched_cores` for the module-level one."""
    del caps_list, validate_caps  # the ranged merge cannot overflow c_pad
    if cores is None:
        cores = _SCAN_CORES_BATCHED
    c_pad = envelope.c_pad
    r0s, r1s = plan.b_ranges()
    r0s, r1s = jnp.asarray(r0s), jnp.asarray(r1s)
    n_cols = Bs[0].n_cols
    dtype = As[0].dtype
    Bst, chunk_nbytes = _stage_chunks_batched(Bs, plan, envelope)
    if plan.algorithm == "knl":
        Ast = csr_stack([
            csr_pad_to(A, nnz_cap=envelope.a_nnz_cap,
                       max_row_nnz=envelope.a_max_row_nnz)
            for A in As
        ])
        C0s = _empty_c_stack(len(As), envelope.a_shape[0], n_cols, c_pad,
                             dtype)
        Cb = cores["knl"](Ast, Bst, r0s, r1s, C0s, c_pad=c_pad)
        return csr_unstack(Cb), planned_stats(plan, chunk_nbytes, 0, 0)
    Ast, strip_nbytes = _stage_strips_batched(As, plan, envelope)
    strip_rows = envelope.strip_rows
    stats = planned_stats(plan, chunk_nbytes, strip_nbytes,
                          _c_strip_nbytes(strip_rows, c_pad, dtype))
    if plan.algorithm == "chunk1":
        C0 = _empty_c(strip_rows, n_cols, c_pad, dtype)
        Cb = cores["chunk1"](Ast, Bst, r0s, r1s, C0, c_pad=c_pad)
    else:
        C0s = _empty_c_stack(plan.n_ac, strip_rows, n_cols, c_pad, dtype)
        Cb = cores["chunk2"](Ast, Bst, r0s, r1s, C0s, c_pad=c_pad)
    return [
        _assemble(csr_unstack(Ci), plan.p_ac, n_cols)
        for Ci in csr_unstack(Cb)
    ], stats


def _pallas_run_batched(As, Bs, plan: ChunkPlan, envelope: GeometryEnvelope, *,
                        caps_list=None, validate_caps: bool = True,
                        cores: dict | None = None):
    """Batched entry of the Pallas backend: the whole microbatch through one
    ``ranged_spgemm_stream`` launch whose leading grid dimension is the
    batch (staging and accumulation in f32 — allclose, not bitwise, against
    the loop oracle)."""
    del caps_list, validate_caps  # dense accumulators cannot overflow
    if cores is None:
        cores = _PALLAS_CORES_BATCHED
    r0s = jnp.asarray(plan.b_ranges()[0])
    n_cols = Bs[0].n_cols
    np_dtype = np.dtype(As[0].dtype)
    Bst, _ = _stage_chunks_batched(Bs, plan, envelope)
    if plan.algorithm == "knl":
        Ast = csr_stack([
            csr_pad_to(A, nnz_cap=envelope.a_nnz_cap,
                       max_row_nnz=envelope.a_max_row_nnz)
            for A in As
        ])
        dense = cores["knl"](Ast, Bst, r0s)
        stats = planned_stats_pallas(plan, *_pallas_stage_nbytes(
            envelope.a_shape[0], envelope.a_shape[1], envelope.chunk_rows,
            n_cols))
        return [
            csr_from_dense(np.asarray(d).astype(np_dtype)) for d in dense
        ], stats
    Ast, _ = _stage_strips_batched(As, plan, envelope)
    dense = cores[plan.algorithm](Ast, Bst, r0s)
    stats = planned_stats_pallas(plan, *_pallas_stage_nbytes(
        envelope.strip_rows, envelope.a_shape[1], envelope.chunk_rows,
        n_cols))
    return [_pallas_assemble(d, plan.p_ac, np_dtype) for d in dense], stats


def _csr_accum_run_batched(As, Bs, plan: ChunkPlan,
                           envelope: GeometryEnvelope, kind: str, *,
                           caps_list=None, validate_caps: bool = True,
                           cores: dict | None = None):
    """Shared batched entry of the CSR-scratch accumulators (ESC and hash):
    one batch-on-the-grid kernel launch into fixed-capacity CSR scratch
    sized by the envelope.

    ``validate_caps`` checks every instance's exact realized output
    structure against the envelope capacities and raises a loud
    ``ValueError`` on overflow (the kernels silently drop entries past
    capacity). Callers whose envelopes dominate the instances *by
    construction* — the serving layer, whose bucket envelopes start from
    exact submit-time instance envelopes and only ever grow by
    union/quantization — pass ``False`` to skip the per-call host symbolic
    expansion the check costs; callers that already ran the expansions pass
    them as ``caps_list``."""
    c_pad = envelope.c_pad
    n_cols = Bs[0].n_cols
    dtype = As[0].dtype
    # the table size is a compile key, so it derives from the envelope
    # alone, never from the per-call instances. A zero c_max_row_nnz is
    # exact (empty output, 1-slot tables) when the symbolic phase ran —
    # witnessed by c_nnz_cap, whose rounding floor makes it nonzero
    # whenever computed; only a legacy both-zero envelope falls back to
    # the always-valid n_cols bound.
    table = None
    if kind == "hash":
        table = hash_table_slots(
            envelope.c_max_row_nnz if envelope.c_nnz_cap else n_cols)
    if validate_caps:
        if caps_list is None:
            caps_list = [strip_output_caps(A, B, plan.p_ac)
                         for A, B in zip(As, Bs)]
        for i, (A, caps) in enumerate(zip(As, caps_list)):
            check_output_caps(caps.strip_nnz, caps.c_max_row_nnz, c_pad,
                              table, backend=kind, a_shape=A.shape,
                              b_shape=Bs[i].shape, instance=i)
    r0s, r1s = plan.b_ranges()
    r0s, r1s = jnp.asarray(r0s), jnp.asarray(r1s)
    Bst, chunk_nbytes = _stage_chunks_batched(Bs, plan, envelope)
    # uniform across all three algorithms: knl is the 1-strip special
    # case (p_ac == (0, n_rows)), so every instance stages as strips
    Ast, strip_nbytes = _stage_strips_batched(As, plan, envelope)
    strip_rows = envelope.strip_rows
    C0 = _sparse_c0_stack(len(As), plan.n_ac, strip_rows, n_cols, c_pad,
                          dtype)
    if cores is None:
        cores = _HASH_CORES_BATCHED if kind == "hash" else _SPARSE_CORES_BATCHED
    if kind == "hash":
        ip, ix, d = cores[plan.algorithm](Ast, Bst, C0, r0s, r1s,
                                          table_size=table)
    else:
        ip, ix, d = cores[plan.algorithm](Ast, Bst, C0, r0s, r1s)
    stats = planned_stats_pallas(
        plan, chunk_nbytes, strip_nbytes,
        _c_strip_nbytes(strip_rows, c_pad, dtype))
    return [
        _assemble(
            _sparse_strip_csrs(ip[b], ix[b], d[b], strip_rows, n_cols,
                               c_pad),
            plan.p_ac, n_cols)
        for b in range(len(As))
    ], stats


def _sparse_run_batched(As, Bs, plan, envelope, *, caps_list=None,
                        validate_caps=True, cores=None):
    return _csr_accum_run_batched(As, Bs, plan, envelope, "sparse",
                                  caps_list=caps_list,
                                  validate_caps=validate_caps, cores=cores)


def _hash_run_batched(As, Bs, plan, envelope, *, caps_list=None,
                      validate_caps=True, cores=None):
    return _csr_accum_run_batched(As, Bs, plan, envelope, "hash",
                                  caps_list=caps_list,
                                  validate_caps=validate_caps, cores=cores)


def _bsr_run_batched(As, Bs, plan, envelope, *, caps_list=None,
                     validate_caps=True, cores=None):
    """Batched entry of the BSR backend. Cap overflow is caught by the
    per-pair block symbolic itself (``bsr_spgemm_symbolic`` raises when the
    envelope's floors do not dominate an instance), so there is no separate
    validation pass to skip."""
    del caps_list, validate_caps
    if not envelope.bsr_caps:
        raise ValueError(
            "backend 'bsr' needs a block-capped envelope; rebuild it with "
            "batch_envelope(..., block_size=...)"
        )
    return _bsr_execute(As, Bs, plan, envelope, batched=True, cores=cores)


def chunked_spgemm_batched(As, Bs, plan: ChunkPlan, c_pad: int | None = None,
                           envelope: GeometryEnvelope | None = None,
                           backend: str = "scan", validate_caps: bool = True,
                           cores: dict | None = None):
    """Run a backend's batched entry over stacked problem instances sharing
    one plan.

    Instances must share shapes and dtype but may differ in sparsity
    *structure* (nnz, nnz capacities, ``max_row_nnz``): every instance's chunks
    and strips are repadded to a shared :class:`GeometryEnvelope` — by default
    the batch's union envelope, or a caller-provided (e.g. bucket-quantized)
    one — before stacking, so one compiled program serves the whole batch.
    Same-structure batches repad to their own geometry (a no-op), keeping the
    scan backend's results bitwise-identical to the unbatched executors.

    ``backend`` names any registered spec with a batched entry
    (``backend_registry.batched_backends()``) or ``"auto"``, which resolves
    to the accumulator whose planner byte model is smallest under the batch
    envelope (``select_accumulator_backend``); the dispatch hands the whole
    batch to the spec's ``run_batched``. Backends with ``needs_block_caps``
    (``"bsr"``) get a block-capped default envelope built at the spec's
    registered ``block_size``; a caller-provided envelope must already carry
    block caps for them. ``validate_caps`` is forwarded to the spec (the
    CSR-scratch accumulators use it to check realized output structure
    against the envelope capacities; see ``_csr_accum_run_batched``).
    ``cores`` substitutes a caller-owned jitted core set (from the spec's
    ``make_batched_cores`` factory) for the module-level cores — the
    serving layer's bounded executable cache passes per-bucket sets so that
    evicting a bucket really frees its compiled programs.

    Returns ``(list_of_C, stats)`` where ``stats`` is the per-instance modeled
    copy accounting at the *envelope-padded* staged sizes (identical across the
    batch by construction).
    """
    As, Bs = list(As), list(Bs)
    if len(As) != len(Bs) or not As:
        raise ValueError("need equal, nonzero numbers of A and B instances")
    if plan.algorithm not in backend_registry.ALGORITHMS:
        raise ValueError(f"unsupported algorithm {plan.algorithm!r}")
    spec = None if backend == "auto" else backend_registry.get(backend)
    if spec is not None and not spec.supports_batched:
        raise ValueError(
            f"backend {backend!r} does not support batched execution")
    for A, B in zip(As, Bs):
        if A.shape != As[0].shape or B.shape != Bs[0].shape:
            raise ValueError(
                "batched instances must share shapes: "
                f"{A.shape}x{B.shape} vs {As[0].shape}x{Bs[0].shape}"
            )
    caps_list = None
    if envelope is None:
        # the per-instance symbolic expansions feeding the union envelope
        # are exactly what cap validation needs — run them once
        caps_list = [strip_output_caps(A, B, plan.p_ac)
                     for A, B in zip(As, Bs)]
        block = (spec.block_size
                 if spec is not None and spec.needs_block_caps else None)
        envelope = batch_envelope(As, Bs, plan, c_pad=c_pad,
                                  caps_list=caps_list, block_size=block)
    elif c_pad is not None and c_pad != envelope.c_pad:
        raise ValueError(
            f"conflicting c_pad={c_pad} vs envelope.c_pad={envelope.c_pad}"
        )
    if envelope.a_shape != As[0].shape or envelope.b_shape != Bs[0].shape:
        raise ValueError(
            f"envelope shapes {envelope.a_shape}x{envelope.b_shape} do not "
            f"match instances {As[0].shape}x{Bs[0].shape}"
        )
    if spec is None:
        spec = backend_registry.get(
            select_accumulator_backend(plan, envelope))
    if spec.needs_block_caps and not envelope.bsr_caps:
        raise ValueError(
            f"backend {spec.name!r} needs a block-capped envelope; rebuild "
            "it with batch_envelope(..., block_size=...)"
        )
    return spec.run_batched(As, Bs, plan, envelope, caps_list=caps_list,
                            validate_caps=validate_caps, cores=cores)


# ---------------------------------------------------------------------------
# registrations: the one place each backend is wired into the stack
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# audit staging: TraceTargets for the static verifier (repro.analysis)
# ---------------------------------------------------------------------------
#
# Each helper stages one instance at an explicit GeometryEnvelope — exactly
# the envelope-driven padding the batched executors perform — and binds the
# statics into the backend's jitted core so `jax.make_jaxpr(fn)(*args)`
# abstract-traces the very program the executors launch. Two same-envelope
# instances must therefore produce byte-identical jaxprs (the retrace-leak
# contract); the traced program is also what the VMEM and DMA audits read.


def _audit_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int,
                envelope: GeometryEnvelope):
    Bst = csr_stack(b_chunks(B, plan.p_b, envelope=envelope))
    r0s, r1s = plan.b_ranges()
    if plan.algorithm == "knl":
        Ast = csr_pad_to(A, nnz_cap=envelope.a_nnz_cap,
                         max_row_nnz=envelope.a_max_row_nnz)
        C0 = _empty_c(A.n_rows, B.n_cols, c_pad, A.dtype)
        core = _knl_scan
    else:
        Ast = csr_stack(a_strips(A, plan.p_ac, envelope=envelope))
        strip_rows = envelope.strip_rows
        if plan.algorithm == "chunk1":
            C0 = _empty_c(strip_rows, B.n_cols, c_pad, A.dtype)
            core = _chunk1_scan
        else:
            C0 = _empty_c_stack(plan.n_ac, strip_rows, B.n_cols, c_pad,
                                A.dtype)
            core = _chunk2_scan
    return backend_registry.TraceTarget(
        fn=partial(core, c_pad=c_pad),
        args=(Ast, Bst, jnp.asarray(r0s), jnp.asarray(r1s), C0))


def _audit_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int,
                  envelope: GeometryEnvelope):
    del c_pad  # capacity is implicit in the dense accumulator
    Bst = csr_stack(b_chunks(B, plan.p_b, envelope=envelope))
    r0s, _ = plan.b_ranges()
    if plan.algorithm == "knl":
        Ast = csr_pad_to(A, nnz_cap=envelope.a_nnz_cap,
                         max_row_nnz=envelope.a_max_row_nnz)
        core = _knl_pallas
    else:
        Ast = csr_stack(a_strips(A, plan.p_ac, envelope=envelope))
        core = _chunk1_pallas if plan.algorithm == "chunk1" else _chunk2_pallas
    return backend_registry.TraceTarget(
        fn=core, args=(Ast, Bst, jnp.asarray(r0s)),
        meta={"scalar_args": (jnp.asarray(r0s),)})


def _make_audit_csr_accum(kind: str):
    """Audit staging shared by the ESC ("sparse") and hash backends — the
    doubly stacked width-1 staging of ``_sparse_run``, envelope-padded."""

    def audit(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int,
              envelope: GeometryEnvelope):
        Ast = csr_stack([csr_stack(a_strips(A, plan.p_ac,
                                            envelope=envelope))])
        Bst = csr_stack([csr_stack(b_chunks(B, plan.p_b,
                                            envelope=envelope))])
        r0s, r1s = plan.b_ranges()
        C0 = _sparse_c0_stack(1, plan.n_ac, envelope.strip_rows, B.n_cols,
                              c_pad, A.dtype)
        args = (Ast, Bst, C0, jnp.asarray(r0s), jnp.asarray(r1s))
        scalar_args = (jnp.asarray(r0s), jnp.asarray(r1s))
        if kind == "hash":
            # compile key: the table derives from the envelope, exactly as
            # in the batched run (see _csr_accum_run_batched)
            table = hash_table_slots(
                envelope.c_max_row_nnz if envelope.c_nnz_cap else B.n_cols)
            return backend_registry.TraceTarget(
                fn=partial(_HASH_CORES[plan.algorithm], table_size=table),
                args=args,
                meta={"table_size": table, "scalar_args": scalar_args})
        return backend_registry.TraceTarget(
            fn=_SPARSE_CORES[plan.algorithm], args=args,
            meta={"scalar_args": scalar_args})

    return audit


def _audit_bsr(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int,
               envelope: GeometryEnvelope):
    """Stage the first (strip, chunk) pair exactly as ``_bsr_execute`` does;
    every pair launches the same envelope-keyed kernel geometry, so one pair
    is the whole compile surface."""
    del c_pad
    bs, nbl_a_cap, nbl_b_cap, nc_cap, u_cap = envelope.bsr_caps
    k, n = B.shape
    kpad = -(-k // bs) * bs
    npad = -(-n // bs) * bs
    srpad = -(-envelope.strip_rows // bs) * bs
    Ad = np.asarray(csr_to_dense(A), np.float32)
    Bd = np.asarray(csr_to_dense(B), np.float32)
    s, e = plan.p_ac[0], plan.p_ac[1]
    r0, r1 = plan.p_b[0], plan.p_b[1]
    Am = np.zeros((srpad, kpad), np.float32)
    Am[: e - s, r0:r1] = Ad[s:e, r0:r1]
    Bm = np.zeros((kpad, npad), np.float32)
    Bm[r0:r1, :n] = Bd[r0:r1, :]
    Ab = bsr_from_dense(Am, bs, pad_to=nbl_a_cap)
    Bb = bsr_from_dense(Bm, bs, pad_to=nbl_b_cap)
    meta = bsr_spgemm_symbolic(Ab, Bb, nc_pad=nc_cap, u_max=u_cap)
    a_slots, b_slots = jnp.asarray(meta.a_slots), jnp.asarray(meta.b_slots)
    return backend_registry.TraceTarget(
        fn=partial(_BSR_CORES[plan.algorithm], envelope=envelope),
        args=(bsr_blocks_with_sentinel(Ab), bsr_blocks_with_sentinel(Bb),
              a_slots, b_slots),
        meta={"scalar_args": (a_slots, b_slots)})


# ---------------------------------------------------------------------------
# traffic models: the per-copy-event byte flows the traced jaxprs must equal
# ---------------------------------------------------------------------------
#
# Each hook declares, per pallas operand and in spec order, the ordered list
# of copy-event byte sizes the staged launch performs over its whole grid —
# the planner-side half of the flow-equality audit (repro.analysis.traffic),
# which reconstructs the same lists from the traced jaxpr and demands exact
# equality, then ties the merged flows to the ChunkStats the executors log.


def _traffic_pallas(A, B, plan: ChunkPlan, c_pad: int,
                    envelope: GeometryEnvelope, meta):
    """Dense-slab pipeline flows. knl/chunk1 grid is (1, n_ac, n_b): the
    stationary strip and fused C_prev block refetch per strip, the slab
    hand-DMAs every grid step; chunk2 swaps the roles and maps all C
    partials as one constant-index block (one fetch, one writeback)."""
    del A, B, c_pad, meta
    OpFlow = backend_registry.OpFlow
    k, n = envelope.a_shape[1], envelope.b_shape[1]
    strip_rows = (envelope.a_shape[0] if plan.algorithm == "knl"
                  else envelope.strip_rows)
    slab, a_stage, c_stage = (
        float(v) for v in _pallas_stage_nbytes(strip_rows, k,
                                               envelope.chunk_rows, n))
    n_ac, n_b = plan.n_ac, plan.n_b
    if plan.algorithm in ("knl", "chunk1"):
        in_ops = (OpFlow("stationary", (a_stage,) * n_ac),
                  OpFlow("streamed", (slab,) * (n_ac * n_b)),
                  OpFlow("c_prev", (c_stage,) * n_ac))
        out_ops = (OpFlow("c_out", (c_stage,) * n_ac),)
    else:
        in_ops = (OpFlow("stationary", (slab,) * n_b),
                  OpFlow("streamed", (a_stage,) * (n_b * n_ac)),
                  OpFlow("c_prev", (n_ac * c_stage,)))
        out_ops = (OpFlow("c_out", (n_ac * c_stage,)),)
    st = planned_stats_pallas(plan, slab, a_stage, c_stage)
    return backend_registry.ExpectedTraffic(
        in_ops=in_ops, out_ops=out_ops,
        stats_in=tuple(st.per_copy_in), stats_out=tuple(st.per_copy_out))


def _traffic_csr_accum(A, B, plan: ChunkPlan, c_pad: int,
                       envelope: GeometryEnvelope, meta):
    """CSR-accumulator (ESC and hash) flows: every logical operand is three
    field operands (indptr, indices, data) whose per-event bytes sum to the
    staged triple's ``CSR.nbytes()`` — same-key fields merge event-wise into
    the single ChunkStats event the executors log. knl stages as the
    1-strip chunk1 special case (see ``_sparse_run``)."""
    del A, B, meta
    OpFlow = backend_registry.OpFlow
    itemsize = int(np.dtype(envelope.dtype).itemsize)
    strip_f = csr_field_nbytes(envelope.strip_rows, envelope.strip_nnz_cap,
                               itemsize)
    chunk_f = csr_field_nbytes(envelope.chunk_rows, envelope.chunk_nnz_cap,
                               itemsize)
    c_f = csr_field_nbytes(envelope.strip_rows, c_pad, itemsize)
    n_ac, n_b = plan.n_ac, plan.n_b
    if plan.algorithm in ("knl", "chunk1"):
        stat_f, stream_f = strip_f, chunk_f
        n_stat, n_stream = n_ac, n_ac * n_b
        c_in = tuple(OpFlow("c_prev", (f,) * n_ac) for f in c_f)
        c_out = tuple(OpFlow("c_out", (f,) * n_ac) for f in c_f)
    else:
        stat_f, stream_f = chunk_f, strip_f
        n_stat, n_stream = n_b, n_b * n_ac
        c_in = tuple(OpFlow("c_prev", (n_ac * f,)) for f in c_f)
        c_out = tuple(OpFlow("c_out", (n_ac * f,)) for f in c_f)
    in_ops = (
        tuple(OpFlow("stationary", (f,) * n_stat) for f in stat_f)
        + tuple(OpFlow("streamed", (f,) * n_stream) for f in stream_f)
        + c_in
    )
    st = planned_stats_pallas(
        plan, int(sum(chunk_f)), int(sum(strip_f)),
        _c_strip_nbytes(envelope.strip_rows, c_pad, envelope.dtype))
    return backend_registry.ExpectedTraffic(
        in_ops=in_ops, out_ops=c_out,
        stats_in=tuple(st.per_copy_in), stats_out=tuple(st.per_copy_out))


def _traffic_bsr(A, B, plan: ChunkPlan, c_pad: int,
                 envelope: GeometryEnvelope, meta):
    """Blocked-kernel flows, replayed from the audited pair's scalar-prefetch
    slot tables: a ``bs x bs`` tile is fetched whenever the slot value
    changes between consecutive grid steps (the pipeline reuses a resident
    block when the index map lands on the same slot), and each output block
    row writes back once. The ChunkStats tie is exempt: ``_bsr_execute``
    stages every (strip, chunk) pair through a host loop while its stats
    model the idealized BSR pipeline — a documented modeling fiction
    (see ``_bsr_execute``) the flow audit does not re-litigate."""
    del A, B, plan, c_pad
    OpFlow = backend_registry.OpFlow
    bs = envelope.bsr_caps[0]
    block_bytes = float(bs * bs * 4)
    a_slots = np.asarray(meta["scalar_args"][0])
    b_slots = np.asarray(meta["scalar_args"][1])

    def slot_flow(table):
        events, prev = [], None
        for val in table.reshape(-1):      # row-major == grid order (e, u)
            v = int(val)
            if prev is None or v != prev:
                events.append(block_bytes)
            prev = v
        return tuple(events)

    nc_pad = int(a_slots.shape[0])
    return backend_registry.ExpectedTraffic(
        in_ops=(OpFlow("a_blocks", slot_flow(a_slots)),
                OpFlow("b_blocks", slot_flow(b_slots))),
        out_ops=(OpFlow("c_blocks", (block_bytes,) * nc_pad),),
        stats_exempt=(
            "bsr executor stages per (strip, chunk) pair host-side; its "
            "ChunkStats model the idealized BSR pipeline, not the audited "
            "single-pair launch (documented in _bsr_execute)"))


def _register_all() -> None:
    if "scan" in backend_registry._REGISTRY:   # tolerate importlib.reload
        return
    register, Spec = backend_registry.register, backend_registry.BackendSpec
    algs = backend_registry.ALGORITHMS
    register(Spec(
        name="loop",
        executors={"knl": chunk_knl, "chunk1": chunk_gpu1,
                   "chunk2": chunk_gpu2},
    ))
    register(Spec(
        name="scan",
        executors={"knl": chunk_knl_scan, "chunk1": chunk_gpu1_scan,
                   "chunk2": chunk_gpu2_scan},
        run_batched=_scan_run_batched,
        trace_key="{alg}",
        trace_key_batched="{alg}_batched",
        audit_trace=_audit_scan,
        make_batched_cores=_make_scan_batched_cores,
    ))
    register(Spec(
        name="pallas",
        executors={"knl": chunk_knl_pallas, "chunk1": chunk_gpu1_pallas,
                   "chunk2": chunk_gpu2_pallas},
        run_batched=_pallas_run_batched,
        byte_model=planned_stats_dense_slab,
        trace_key="{alg}_pallas",
        trace_key_batched="{alg}_pallas_batched",
        is_accumulator=True,
        audit_trace=_audit_pallas,
        traffic_model=_traffic_pallas,
        make_batched_cores=_make_pallas_batched_cores,
    ))
    register(Spec(
        name="sparse",
        executors=dict.fromkeys(algs, chunk_sparse),
        run_batched=_sparse_run_batched,
        byte_model=planned_stats_sparse,
        trace_key="{alg}_sparse",
        trace_key_batched="{alg}_sparse_batched",
        needs_output_caps=True,
        is_accumulator=True,
        audit_trace=_make_audit_csr_accum("sparse"),
        traffic_model=_traffic_csr_accum,
        make_batched_cores=_make_sparse_batched_cores,
    ))
    register(Spec(
        name="hash",
        executors=dict.fromkeys(algs, chunk_hash),
        run_batched=_hash_run_batched,
        byte_model=planned_stats_hash,
        trace_key="{alg}_hash",
        trace_key_batched="{alg}_hash_batched",
        needs_output_caps=True,
        is_accumulator=True,
        run_masked=chunk_hash_masked,
        audit_trace=_make_audit_csr_accum("hash"),
        traffic_model=_traffic_csr_accum,
        make_batched_cores=_make_hash_batched_cores,
    ))
    register(Spec(
        name="bsr",
        executors=dict.fromkeys(algs, chunk_bsr),
        run_batched=_bsr_run_batched,
        byte_model=planned_stats_bsr,
        trace_key="{alg}_bsr",
        trace_key_batched="{alg}_bsr_batched",
        needs_output_caps=True,
        needs_block_caps=True,
        is_accumulator=True,
        block_size=_BSR_DEFAULT_BLOCK,
        audit_trace=_audit_bsr,
        traffic_model=_traffic_bsr,
        make_batched_cores=_make_bsr_batched_cores,
    ))


_register_all()
