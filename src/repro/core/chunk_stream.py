"""Device-resident streaming chunk executors: the scan backend.

The loop executors in repro.core.chunking drive the paper's chunk streams from
host Python — every chunk boundary is a device->host->device round-trip, which
forfeits exactly the copy/compute overlap the paper identifies as the point of
multi-memory-aware chunking. Here the same three algorithms (KNL / Chunk1 /
Chunk2) run as **one jitted program each**:

  * the uniformly-padded B chunks and A/C strips are stacked host-side into
    batched CSRs (``csr_stack`` — a plain CSR whose array fields carry a
    leading ``[n_chunks]`` axis, sliced back into per-chunk CSRs by scan),
  * the chunk loop is a ``jax.lax.scan`` (nested scans for the 2-D Chunk1 /
    Chunk2 orders) over the stacked chunks with the fused ``spgemm_ranged``
    body inlined,

so the whole multi-chunk multiply compiles once and never leaves the device
between chunks. The scan backend leaves the slow->fast chunk transfers to
XLA's scheduler — it is *free* to double-buffer them behind the kernel, but
nothing forces the overlap. The third backend closes that gap: the
``chunk_*_pallas`` executors run the same three streaming orders through
``repro.kernels.ranged_spgemm``, whose pallas_call hand-DMAs the streamed
operand through a two-slot VMEM buffer (copy chunk j+1 while chunk j
multiplies — the paper's `copy2Fast` overlap made explicit rather than hoped
for). The fourth backend lifts that kernel's dense-C memory bound: the
``chunk_*_sparse`` executors stream the same two-slot DMA schedule through
``repro.kernels.sparse_accum_spgemm``, whose per-strip accumulator is a
fixed-capacity **CSR triple in VMEM** sized by the symbolic phase
(``repro.core.symbolic``) instead of a dense ``[strip_rows, n]`` slab — the
first backend whose fast-memory footprint scales with ``nnz(C)`` rather than
``strip_rows * n_cols`` (``repro.core.planner.planned_stats_sparse`` is the
matching planner-side model). The fifth backend shrinks that backend's
workspace: the ``chunk_*_hash`` executors run the same streaming schedule
through ``repro.kernels.hash_accum_spgemm``, whose merge body is a per-row
linear-probing hash table sized by the symbolic ``c_max_row_nnz`` — the
workspace scales with the densest output row, not with the
``strip_nnz_cap * b_max_row_nnz`` ESC expand size
(``planner.planned_stats_hash``).

``backend="auto"`` is the planner-driven dispatch over the three
accumulators: ``planner.select_accumulator_backend(plan, envelope)`` compares
the dense-slab (``planned_stats_dense_slab``), ESC
(``planned_stats_sparse``) and hash (``planned_stats_hash``) peak-resident
byte models and runs the smallest — dense slabs when C densifies (MXU
tiles beat any compressed accumulator's bookkeeping), ESC when the expand
stream is small relative to the row count, hash when outputs are wide but
rows stay sparse. Ties break toward the dense slab. The
``accumulator_shootout`` bench lane measures where the three models cross.

Because a traced scan (or Pallas grid) cannot mutate Python-side counters,
ChunkStats for these backends is *computed from the plan*: the uniform padding
makes every staged chunk/strip/partial the same size, so the per-copy event
sequence is reproducible host-side. ``planned_stats`` replays the loop
executors' CSR-staging events (asserted identical in tests);
``planned_stats_pallas`` replays the Pallas pipeline's dense-slab DMA events,
which differ structurally (dense staged sizes; Chunk2's C partials persist in
VMEM instead of bouncing to slow memory).

``chunked_spgemm_batched`` runs the scan executors vmapped — or the Pallas
kernel with a leading batch grid dimension — over stacked problem instances
sharing one plan: the many-small-matrices serving scenario. Batches may mix
sparsity structures: every instance is repadded to a shared
``GeometryEnvelope`` (the batch union, or a caller-provided bucket envelope)
before stacking. ``repro.serve.spgemm_service`` builds the request-bucketing
service on top.
"""

from __future__ import annotations

import collections
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.chunking import (
    ChunkStats, _assemble, a_strips, b_chunks, batch_envelope,
)
from repro.core.kkmem import spgemm_ranged_impl
from repro.core.planner import (
    ChunkPlan, check_output_caps, hash_table_slots,
    select_accumulator_backend,
)
from repro.core.symbolic import strip_output_caps
from repro.kernels.hash_accum_spgemm import hash_accum_spgemm_stream
from repro.kernels.ranged_spgemm import ranged_spgemm_stream
from repro.kernels.sparse_accum_spgemm import sparse_accum_spgemm_stream
from repro.sparse.csr import (
    CSR, GeometryEnvelope, csr_from_dense, csr_pad_to, csr_stack, csr_to_dense,
    csr_unstack,
)

# Python-side trace counters: each key increments once per (re)trace of the
# corresponding jitted wrapper / scan body. Tests assert these stay O(1) in
# the chunk count — the whole point of the single-trace executors.
TRACE_COUNTS: collections.Counter = collections.Counter()


def _empty_c(n_rows: int, n_cols: int, c_pad: int, dtype) -> CSR:
    """Empty C with ``max_row_nnz=c_pad`` so the scan carry has exactly the
    pytree structure ``spgemm_ranged_impl`` returns (aux mismatch would fail
    the carry check)."""
    return CSR(
        indptr=jnp.zeros(n_rows + 1, jnp.int32),
        indices=jnp.zeros(c_pad, jnp.int32),
        data=jnp.zeros(c_pad, dtype),
        shape=(n_rows, n_cols),
        max_row_nnz=c_pad,
    )


def _empty_c_stack(n: int, n_rows: int, n_cols: int, c_pad: int, dtype) -> CSR:
    """Stacked empty partials ([n, ...] leading axis) for the Chunk2 carry."""
    return CSR(
        indptr=jnp.zeros((n, n_rows + 1), jnp.int32),
        indices=jnp.zeros((n, c_pad), jnp.int32),
        data=jnp.zeros((n, c_pad), dtype),
        shape=(n_rows, n_cols),
        max_row_nnz=c_pad,
    )


# ---------------------------------------------------------------------------
# jitted scan cores (one compilation per padded geometry, not per chunk)
# ---------------------------------------------------------------------------


def _knl_scan_impl(A: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    def body(C, x):
        TRACE_COUNTS["knl_body"] += 1
        Bc, r0, r1 = x
        return spgemm_ranged_impl(A, Bc, r0, r1, C, c_pad), None

    C, _ = lax.scan(body, C0, (Bs, r0s, r1s))
    return C


def _chunk1_scan_impl(As: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    def outer(carry, Ai):
        def inner(C, x):
            TRACE_COUNTS["chunk1_body"] += 1
            Bc, r0, r1 = x
            return spgemm_ranged_impl(Ai, Bc, r0, r1, C, c_pad), None

        Ci, _ = lax.scan(inner, C0, (Bs, r0s, r1s))
        return carry, Ci

    _, Cs = lax.scan(outer, None, As)
    return Cs


def _chunk2_scan_impl(As: CSR, Bs: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    def outer(Cs, x):
        Bc, r0, r1 = x

        def inner(carry, y):
            TRACE_COUNTS["chunk2_body"] += 1
            Ai, Ci = y
            return carry, spgemm_ranged_impl(Ai, Bc, r0, r1, Ci, c_pad)

        _, Cs2 = lax.scan(inner, None, (As, Cs))
        return Cs2, None

    Cs, _ = lax.scan(outer, C0s, (Bs, r0s, r1s))
    return Cs


@partial(jax.jit, static_argnames=("c_pad",))
def _knl_scan(A: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["knl"] += 1
    return _knl_scan_impl(A, Bs, r0s, r1s, C0, c_pad)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk1_scan(As: CSR, Bs: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    """A/C strips outer (stationary), B chunks inner (streamed). Returns the
    stacked per-strip results ([n_ac] leading axis)."""
    TRACE_COUNTS["chunk1"] += 1
    return _chunk1_scan_impl(As, Bs, r0s, r1s, C0, c_pad)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk2_scan(As: CSR, Bs: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    """B chunk outer (stationary), A/C strips inner (streamed); all per-strip
    partials ride the scan carry. Returns the stacked per-strip results."""
    TRACE_COUNTS["chunk2"] += 1
    return _chunk2_scan_impl(As, Bs, r0s, r1s, C0s, c_pad)


# Batched (vmapped) cores: one jitted program per (envelope, plan, batch)
# geometry. Each gets its own TRACE_COUNTS key so the serving layer can assert
# "one compile per geometry bucket" directly.


@partial(jax.jit, static_argnames=("c_pad",))
def _knl_scan_batched(Ast: CSR, Bst: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["knl_batched"] += 1
    return jax.vmap(
        lambda A, Bs, C0: _knl_scan_impl(A, Bs, r0s, r1s, C0, c_pad)
    )(Ast, Bst, C0s)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk1_scan_batched(Ast: CSR, Bst: CSR, r0s, r1s, C0: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["chunk1_batched"] += 1
    return jax.vmap(
        lambda As, Bs: _chunk1_scan_impl(As, Bs, r0s, r1s, C0, c_pad)
    )(Ast, Bst)


@partial(jax.jit, static_argnames=("c_pad",))
def _chunk2_scan_batched(Ast: CSR, Bst: CSR, r0s, r1s, C0s: CSR, c_pad: int) -> CSR:
    TRACE_COUNTS["chunk2_batched"] += 1
    return jax.vmap(
        lambda As, Bs: _chunk2_scan_impl(As, Bs, r0s, r1s, C0s, c_pad)
    )(Ast, Bst)


# ---------------------------------------------------------------------------
# plan-derived copy accounting (the scan cannot mutate Python stats)
# ---------------------------------------------------------------------------


def planned_stats(plan: ChunkPlan, chunk_nbytes: int, strip_nbytes: int,
                  c_strip_nbytes: int) -> ChunkStats:
    """Replay the loop executors' per-copy event sequence from the plan.

    Uniform padding makes every B chunk / A strip / C partial the same size,
    so the event stream is fully determined by (algorithm, n_ac, n_b) plus the
    three footprints — tests assert event-for-event equality with the loop.
    """
    stats = ChunkStats(plan.algorithm, plan.n_ac, plan.n_b)
    if plan.algorithm == "knl":
        for _ in range(plan.n_b):
            stats.add_in(chunk_nbytes)
        stats.kernel_calls = plan.n_b
        return stats
    if plan.algorithm == "chunk1":
        for a0, a1 in zip(plan.p_ac[:-1], plan.p_ac[1:]):
            stats.add_in(strip_nbytes)
            stats.add_in((a1 - a0 + 1) * 4)
            for _ in range(plan.n_b):
                stats.add_in(chunk_nbytes)
                stats.kernel_calls += 1
            stats.add_out(c_strip_nbytes)
        return stats
    if plan.algorithm == "chunk2":
        for jb in range(plan.n_b):
            stats.add_in(chunk_nbytes)
            for _ in range(plan.n_ac):
                stats.add_in(strip_nbytes)
                if jb > 0:
                    stats.add_in(c_strip_nbytes)
                stats.kernel_calls += 1
                if jb < plan.n_b - 1:
                    stats.add_out(c_strip_nbytes)
            if jb == plan.n_b - 1:
                for _ in range(plan.n_ac):
                    stats.add_out(c_strip_nbytes)
        return stats
    raise ValueError(f"unknown algorithm {plan.algorithm!r}")


def _c_strip_nbytes(strip_rows: int, c_pad: int, dtype) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    return (strip_rows + 1) * 4 + c_pad * (4 + itemsize)


def planned_stats_pallas(plan: ChunkPlan, slab_nbytes: int, a_stage_nbytes: int,
                         c_stage_nbytes: int) -> ChunkStats:
    """Replay the Pallas pipeline's per-copy event sequence from the plan.

    The event model differs from :func:`planned_stats` in three structural
    ways, all of them properties of the kernel rather than modeling choices:

      * staged pieces are **dense** (slab = ``chunk_rows x n`` floats, strip =
        ``strip_rows x k_pad`` floats), not padded CSR triples;
      * the stationary operand is staged by the Pallas pipeline once per outer
        step, and the streamed operand is hand-DMA'd once per grid step — the
        double-buffer *overlaps* those copies with compute but their byte
        volume is unchanged;
      * in the Chunk2 order the per-strip C partials persist in the VMEM
        output block across outer steps, so the ``(n_b - 1)`` per-strip
        out+in partial bounces of the loop/scan model collapse into one
        ``C_prev`` fetch and one final writeback per strip.
    """
    stats = ChunkStats(plan.algorithm, plan.n_ac, plan.n_b)
    if plan.algorithm in ("knl", "chunk1"):
        for _ in range(plan.n_ac):           # knl is the 1-strip special case
            stats.add_in(a_stage_nbytes)     # stationary strip -> VMEM
            stats.add_in(c_stage_nbytes)     # fused C_prev block
            for _ in range(plan.n_b):
                stats.add_in(slab_nbytes)    # double-buffered slab DMA
                stats.kernel_calls += 1
            stats.add_out(c_stage_nbytes)    # strip result writeback
        return stats
    if plan.algorithm == "chunk2":
        for jb in range(plan.n_b):
            stats.add_in(slab_nbytes)        # stationary chunk -> VMEM
            for _ in range(plan.n_ac):
                if jb == 0:
                    stats.add_in(c_stage_nbytes)   # C_prev fetched once
                stats.add_in(a_stage_nbytes)       # streamed strip DMA
                stats.kernel_calls += 1
        for _ in range(plan.n_ac):
            stats.add_out(c_stage_nbytes)    # single final writeback
        return stats
    raise ValueError(f"unknown algorithm {plan.algorithm!r}")


def _pallas_stage_nbytes(strip_rows: int, k: int, span: int, n: int) -> tuple:
    """(slab, a_stage, c_stage) dense staged footprints in bytes (f32)."""
    return span * n * 4, strip_rows * (k + span) * 4, strip_rows * n * 4


# ---------------------------------------------------------------------------
# executors (drop-in signatures of chunk_knl / chunk_gpu1 / chunk_gpu2)
# ---------------------------------------------------------------------------


def chunk_knl_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    chunks = b_chunks(B, plan.p_b)
    Bs = csr_stack(chunks)
    r0s, r1s = plan.b_ranges()
    C0 = _empty_c(A.n_rows, B.n_cols, c_pad, A.dtype)
    C = _knl_scan(A, Bs, jnp.asarray(r0s), jnp.asarray(r1s), C0, c_pad)
    stats = planned_stats(plan, chunks[0].nbytes(), 0, 0)
    return C, stats


def chunk_gpu1_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0 = _empty_c(strip_rows, B.n_cols, c_pad, A.dtype)
    Cs = _chunk1_scan(As, Bs, jnp.asarray(r0s), jnp.asarray(r1s), C0, c_pad)
    stats = planned_stats(plan, chunks[0].nbytes(), strips[0].nbytes(),
                          _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    return _assemble(csr_unstack(Cs), plan.p_ac, B.n_cols), stats


def chunk_gpu2_scan(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0s = _empty_c_stack(plan.n_ac, strip_rows, B.n_cols, c_pad, A.dtype)
    Cs = _chunk2_scan(As, Bs, jnp.asarray(r0s), jnp.asarray(r1s), C0s, c_pad)
    stats = planned_stats(plan, chunks[0].nbytes(), strips[0].nbytes(),
                          _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    return _assemble(csr_unstack(Cs), plan.p_ac, B.n_cols), stats


# ---------------------------------------------------------------------------
# Pallas backend: explicit double-buffered prefetch (kernels/ranged_spgemm)
# ---------------------------------------------------------------------------


def _dense_stack(stacked: CSR, levels: int = 1) -> jax.Array:
    """Densify a (possibly doubly) ``csr_stack``-ed CSR: ``levels`` leading
    stack axes become leading dense axes."""
    shape, mrn = stacked.shape, stacked.max_row_nnz

    def densify(ip, ix, d):
        return csr_to_dense(CSR(ip, ix, d, shape, mrn))

    fn = densify
    for _ in range(levels):
        fn = jax.vmap(fn)
    return fn(stacked.indptr, stacked.indices, stacked.data)


def _pad_cols(a: jax.Array, span: int) -> jax.Array:
    """Zero-pad the last (column) axis by ``span`` so the kernel's ranged
    slice of the final chunk never reads out of bounds."""
    pad = [(0, 0)] * (a.ndim - 1) + [(0, span)]
    return jnp.pad(a.astype(jnp.float32), pad)


def _make_pallas_core(key: str, order: str, *, batched: bool, strips: bool):
    """One jitted staging-and-launch core; the six variants differ only in
    the streaming order, the trace-counter key, and whether A arrives as a
    plain CSR (knl), a strip stack, or a per-instance (doubly) stacked batch.

    Batched cores ride the batch on a leading grid dimension of the same
    kernel (one pallas_call for the whole microbatch — no vmap-of-pallas),
    with their own TRACE_COUNTS keys so the serving layer's compile
    accounting stays exact.
    """
    a_levels = (1 if strips else 0) + (1 if batched else 0)

    @jax.jit
    def core(Ast: CSR, Bst: CSR, r0s) -> jax.Array:
        TRACE_COUNTS[key] += 1
        span = Bst.n_rows
        a = _pad_cols(_dense_stack(Ast, levels=a_levels), span)
        slabs = _dense_stack(Bst, levels=2 if batched else 1).astype(jnp.float32)
        if not strips:               # knl: the whole A is the single strip
            a = a[:, None] if batched else a[None]
        if not batched:              # width-1 batch axis
            a, slabs = a[None], slabs[None]
        c0 = jnp.zeros(a.shape[:3] + (Bst.n_cols,), jnp.float32)
        out = ranged_spgemm_stream(a, slabs, c0, r0s, order=order)
        if not batched:
            out = out[0]
        if not strips:
            out = out[:, 0] if batched else out[0]
        return out

    return core


_knl_pallas = _make_pallas_core("knl_pallas", "chunk1",
                                batched=False, strips=False)
_chunk1_pallas = _make_pallas_core("chunk1_pallas", "chunk1",
                                   batched=False, strips=True)
_chunk2_pallas = _make_pallas_core("chunk2_pallas", "chunk2",
                                   batched=False, strips=True)
_knl_pallas_batched = _make_pallas_core("knl_pallas_batched", "chunk1",
                                        batched=True, strips=False)
_chunk1_pallas_batched = _make_pallas_core("chunk1_pallas_batched", "chunk1",
                                           batched=True, strips=True)
_chunk2_pallas_batched = _make_pallas_core("chunk2_pallas_batched", "chunk2",
                                           batched=True, strips=True)


def _pallas_assemble(dense, p_ac: tuple, dtype) -> CSR:
    """Crop per-strip dense results to their true rows, concatenate, and
    sparsify (host). The Pallas backend's CSR keeps exactly the nonzeros of
    the dense result, so comparisons against the loop oracle are allclose on
    the densified values rather than bitwise on padding structure."""
    dense = np.asarray(dense)
    whole = np.concatenate([
        dense[i][: e - s]
        for i, (s, e) in enumerate(zip(p_ac[:-1], p_ac[1:]))
    ])
    return csr_from_dense(whole.astype(dtype))


def chunk_knl_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    del c_pad  # capacity is implicit in the dense accumulator
    chunks = b_chunks(B, plan.p_b)
    Bs = csr_stack(chunks)
    r0s, _ = plan.b_ranges()
    dense = _knl_pallas(A, Bs, jnp.asarray(r0s))
    C = csr_from_dense(np.asarray(dense).astype(np.dtype(A.dtype)))
    stats = planned_stats_pallas(
        plan, *_pallas_stage_nbytes(A.n_rows, A.n_cols, Bs.n_rows, B.n_cols))
    return C, stats


def chunk_gpu1_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    del c_pad
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, _ = plan.b_ranges()
    dense = _chunk1_pallas(As, Bs, jnp.asarray(r0s))
    stats = planned_stats_pallas(
        plan, *_pallas_stage_nbytes(As.n_rows, A.n_cols, Bs.n_rows, B.n_cols))
    return _pallas_assemble(dense, plan.p_ac, np.dtype(A.dtype)), stats


def chunk_gpu2_pallas(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int):
    del c_pad
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    As, Bs = csr_stack(strips), csr_stack(chunks)
    r0s, _ = plan.b_ranges()
    dense = _chunk2_pallas(As, Bs, jnp.asarray(r0s))
    stats = planned_stats_pallas(
        plan, *_pallas_stage_nbytes(As.n_rows, A.n_cols, Bs.n_rows, B.n_cols))
    return _pallas_assemble(dense, plan.p_ac, np.dtype(A.dtype)), stats


# ---------------------------------------------------------------------------
# Sparse-output backend: CSR-native accumulator (kernels/sparse_accum_spgemm)
# ---------------------------------------------------------------------------


def _sparse_c0_stack(batch: int, n_ac: int, strip_rows: int, n_cols: int,
                     c_cap: int, dtype) -> CSR:
    """Empty stacked C_prev strips ([batch, n_ac] leading axes) at the CSR
    scratch capacity ``c_cap`` (the symbolic phase's strip output bound)."""
    return CSR(
        indptr=jnp.zeros((batch, n_ac, strip_rows + 1), jnp.int32),
        indices=jnp.zeros((batch, n_ac, c_cap), jnp.int32),
        data=jnp.zeros((batch, n_ac, c_cap), dtype),
        shape=(strip_rows, n_cols),
        max_row_nnz=c_cap,
    )


def _make_sparse_core(key: str, order: str):
    """One jitted launch core for the sparse-output kernel; the six variants
    differ only in the streaming order and the trace-counter key (all staging
    is host-side, so batched cores share the same body — the batch rides the
    kernel's leading grid dimension)."""

    @jax.jit
    def core(Ast: CSR, Bst: CSR, C0st: CSR, r0s, r1s):
        TRACE_COUNTS[key] += 1
        return sparse_accum_spgemm_stream(Ast, Bst, C0st, r0s, r1s,
                                          order=order)

    return core


_knl_sparse = _make_sparse_core("knl_sparse", "chunk1")
_chunk1_sparse = _make_sparse_core("chunk1_sparse", "chunk1")
_chunk2_sparse = _make_sparse_core("chunk2_sparse", "chunk2")
_knl_sparse_batched = _make_sparse_core("knl_sparse_batched", "chunk1")
_chunk1_sparse_batched = _make_sparse_core("chunk1_sparse_batched", "chunk1")
_chunk2_sparse_batched = _make_sparse_core("chunk2_sparse_batched", "chunk2")

_SPARSE_CORES = {"knl": _knl_sparse, "chunk1": _chunk1_sparse,
                 "chunk2": _chunk2_sparse}
_SPARSE_CORES_BATCHED = {"knl": _knl_sparse_batched,
                         "chunk1": _chunk1_sparse_batched,
                         "chunk2": _chunk2_sparse_batched}


def _make_hash_core(key: str, order: str):
    """Launch core for the hash-probe kernel; ``table_size`` (the per-row
    hash-table slot count, from the envelope's ``c_max_row_nnz``) is a static
    jit argument, so two geometries differing only in the densest-output-row
    bound compile separate tables — exactly the retrace the envelope's
    ``c_max_row_nnz`` field exists to key."""

    @partial(jax.jit, static_argnames=("table_size",))
    def core(Ast: CSR, Bst: CSR, C0st: CSR, r0s, r1s, table_size: int):
        TRACE_COUNTS[key] += 1
        return hash_accum_spgemm_stream(Ast, Bst, C0st, r0s, r1s,
                                        order=order, table_size=table_size)

    return core


_knl_hash = _make_hash_core("knl_hash", "chunk1")
_chunk1_hash = _make_hash_core("chunk1_hash", "chunk1")
_chunk2_hash = _make_hash_core("chunk2_hash", "chunk2")
_knl_hash_batched = _make_hash_core("knl_hash_batched", "chunk1")
_chunk1_hash_batched = _make_hash_core("chunk1_hash_batched", "chunk1")
_chunk2_hash_batched = _make_hash_core("chunk2_hash_batched", "chunk2")

_HASH_CORES = {"knl": _knl_hash, "chunk1": _chunk1_hash,
               "chunk2": _chunk2_hash}
_HASH_CORES_BATCHED = {"knl": _knl_hash_batched,
                       "chunk1": _chunk1_hash_batched,
                       "chunk2": _chunk2_hash_batched}


def _sparse_strip_csrs(ip, ix, d, strip_rows: int, n_cols: int,
                       c_cap: int) -> list:
    """Wrap one batch element's kernel outputs ([n_ac, ...]) as strip CSRs."""
    return [
        CSR(ip[i], ix[i], d[i], (strip_rows, n_cols), c_cap)
        for i in range(ip.shape[0])
    ]


def _sparse_run(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, backend: str,
                caps=None):
    """Shared body of the unbatched sparse-output executors (ESC and hash):
    stage CSR strips and chunks (knl is the 1-strip special case of the
    chunk1 order), validate the realized output structure against the
    capacities, launch, and assemble the accumulated strip CSRs.

    ``caps`` is the symbolic phase's :class:`StripOutputCaps` when the caller
    (the ``chunked_spgemm`` dispatch) already ran the expansion — the
    symbolic module's amortization contract; recomputed here only for direct
    executor calls.

    The per-copy event model is structurally the Pallas pipeline's
    (:func:`planned_stats_pallas`: stationary operand staged once per outer
    step, streamed triple DMA'd per grid step, C persists in VMEM with one
    final writeback) — only the staged byte sizes differ: padded **CSR**
    footprints instead of dense slabs.
    """
    if caps is None:
        caps = strip_output_caps(A, B, plan.p_ac)
    table = (hash_table_slots(caps.c_max_row_nnz) if backend == "hash"
             else None)
    check_output_caps(caps.strip_nnz, caps.c_max_row_nnz, c_pad, table,
                      backend=backend, a_shape=A.shape, b_shape=B.shape)
    strips = a_strips(A, plan.p_ac)
    chunks = b_chunks(B, plan.p_b)
    Ast = csr_stack([csr_stack(strips)])
    Bst = csr_stack([csr_stack(chunks)])
    r0s, r1s = plan.b_ranges()
    strip_rows = strips[0].n_rows
    C0 = _sparse_c0_stack(1, plan.n_ac, strip_rows, B.n_cols, c_pad, A.dtype)
    if backend == "hash":
        ip, ix, d = _HASH_CORES[plan.algorithm](
            Ast, Bst, C0, jnp.asarray(r0s), jnp.asarray(r1s),
            table_size=table)
    else:
        ip, ix, d = _SPARSE_CORES[plan.algorithm](
            Ast, Bst, C0, jnp.asarray(r0s), jnp.asarray(r1s))
    stats = planned_stats_pallas(
        plan, chunks[0].nbytes(), strips[0].nbytes(),
        _c_strip_nbytes(strip_rows, c_pad, A.dtype))
    out = _sparse_strip_csrs(ip[0], ix[0], d[0], strip_rows, B.n_cols, c_pad)
    return _assemble(out, plan.p_ac, B.n_cols), stats


def chunk_sparse(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, caps=None):
    """ESC sparse-output executor for any plan algorithm (``_sparse_run``
    dispatches the core on ``plan.algorithm``, so unlike the scan/pallas
    backends there is no per-algorithm staging difference to name)."""
    return _sparse_run(A, B, plan, c_pad, "sparse", caps=caps)


def chunk_hash(A: CSR, B: CSR, plan: ChunkPlan, c_pad: int, caps=None):
    """Hash-probe executor for any plan algorithm (see :func:`chunk_sparse`)."""
    return _sparse_run(A, B, plan, c_pad, "hash", caps=caps)


# ---------------------------------------------------------------------------
# batched entry point: many problem instances, one plan, one compilation
# ---------------------------------------------------------------------------


def chunked_spgemm_batched(As, Bs, plan: ChunkPlan, c_pad: int | None = None,
                           envelope: GeometryEnvelope | None = None,
                           backend: str = "scan", validate_caps: bool = True):
    """Run the batched executor over stacked problem instances sharing one plan.

    Instances must share shapes and dtype but may differ in sparsity
    *structure* (nnz, nnz capacities, ``max_row_nnz``): every instance's chunks
    and strips are repadded to a shared :class:`GeometryEnvelope` — by default
    the batch's union envelope, or a caller-provided (e.g. bucket-quantized)
    one — before stacking, so one compiled program serves the whole batch.
    Same-structure batches repad to their own geometry (a no-op), keeping the
    results bitwise-identical to the unbatched scan executors.

    ``backend="scan"`` (default) vmaps the jitted lax.scan executors;
    ``backend="pallas"`` runs the whole microbatch through one
    ``ranged_spgemm_stream`` launch whose leading grid dimension is the batch
    (explicit double-buffered chunk prefetch; allclose rather than bitwise
    against the loop oracle, with staging and accumulation in float32
    regardless of the instances' dtype); ``backend="sparse"`` runs one
    ``sparse_accum_spgemm_stream`` launch — the same batch-on-the-grid DMA
    schedule, but accumulating into fixed-capacity CSR scratch sized by the
    envelope's ``c_pad`` (its fast-memory footprint scales with ``nnz(C)``,
    not ``strip_rows * n_cols``); ``backend="hash"`` swaps that kernel's ESC
    merge for the per-row linear-probing hash tables sized by the envelope's
    ``c_max_row_nnz``; ``backend="auto"`` resolves to the accumulator
    (pallas/sparse/hash) whose ``planner`` byte model is smallest under the
    batch envelope (``select_accumulator_backend``).

    ``validate_caps`` (sparse/hash only) checks every instance's exact
    realized output structure against the envelope capacities and raises a
    loud ``ValueError`` on overflow. Callers whose envelopes dominate the
    instances *by construction* — the serving layer, whose bucket envelopes
    start from exact submit-time instance envelopes and only ever grow by
    union/quantization — may pass ``False`` to skip the per-call host
    symbolic expansion the check costs.

    Returns ``(list_of_C, stats)`` where ``stats`` is the per-instance modeled
    copy accounting at the *envelope-padded* staged sizes (identical across the
    batch by construction).
    """
    As, Bs = list(As), list(Bs)
    if len(As) != len(Bs) or not As:
        raise ValueError("need equal, nonzero numbers of A and B instances")
    if plan.algorithm not in ("knl", "chunk1", "chunk2"):
        raise ValueError(f"unsupported algorithm {plan.algorithm!r}")
    if backend not in ("scan", "pallas", "sparse", "hash", "auto"):
        raise ValueError(f"unknown backend {backend!r}")
    for A, B in zip(As, Bs):
        if A.shape != As[0].shape or B.shape != Bs[0].shape:
            raise ValueError(
                "batched instances must share shapes: "
                f"{A.shape}x{B.shape} vs {As[0].shape}x{Bs[0].shape}"
            )
    caps_list = None
    if envelope is None:
        # the per-instance symbolic expansions feeding the union envelope
        # are exactly what cap validation needs — run them once
        caps_list = [strip_output_caps(A, B, plan.p_ac)
                     for A, B in zip(As, Bs)]
        envelope = batch_envelope(As, Bs, plan, c_pad=c_pad,
                                  caps_list=caps_list)
    elif c_pad is not None and c_pad != envelope.c_pad:
        raise ValueError(
            f"conflicting c_pad={c_pad} vs envelope.c_pad={envelope.c_pad}"
        )
    if envelope.a_shape != As[0].shape or envelope.b_shape != Bs[0].shape:
        raise ValueError(
            f"envelope shapes {envelope.a_shape}x{envelope.b_shape} do not "
            f"match instances {As[0].shape}x{Bs[0].shape}"
        )
    if backend == "auto":
        backend = select_accumulator_backend(plan, envelope)
    c_pad = envelope.c_pad
    r0s, r1s = plan.b_ranges()
    r0s, r1s = jnp.asarray(r0s), jnp.asarray(r1s)
    n_cols = Bs[0].n_cols
    dtype = As[0].dtype
    chunk_lists = [b_chunks(B, plan.p_b, envelope=envelope) for B in Bs]
    Bst = csr_stack([csr_stack(cl) for cl in chunk_lists])   # [batch, n_b, ...]
    chunk_nbytes = chunk_lists[0][0].nbytes()

    if backend in ("sparse", "hash"):
        # the table size is a compile key, so it derives from the envelope
        # alone, never from the per-call instances. A zero c_max_row_nnz is
        # exact (empty output, 1-slot tables) when the symbolic phase ran —
        # witnessed by c_nnz_cap, whose rounding floor makes it nonzero
        # whenever computed; only a legacy both-zero envelope falls back to
        # the always-valid n_cols bound.
        table = None
        if backend == "hash":
            table = hash_table_slots(
                envelope.c_max_row_nnz if envelope.c_nnz_cap else n_cols)
        if validate_caps:
            if caps_list is None:
                caps_list = [strip_output_caps(A, B, plan.p_ac)
                             for A, B in zip(As, Bs)]
            for i, (A, caps) in enumerate(zip(As, caps_list)):
                check_output_caps(caps.strip_nnz, caps.c_max_row_nnz, c_pad,
                                  table, backend=backend, a_shape=A.shape,
                                  b_shape=Bs[i].shape, instance=i)
        # uniform across all three algorithms: knl is the 1-strip special
        # case (p_ac == (0, n_rows)), so every instance stages as strips
        strip_lists = [a_strips(A, plan.p_ac, envelope=envelope) for A in As]
        Ast = csr_stack([csr_stack(sl) for sl in strip_lists])
        strip_rows = envelope.strip_rows
        C0 = _sparse_c0_stack(len(As), plan.n_ac, strip_rows, n_cols, c_pad,
                              dtype)
        if backend == "hash":
            ip, ix, d = _HASH_CORES_BATCHED[plan.algorithm](
                Ast, Bst, C0, r0s, r1s, table_size=table)
        else:
            ip, ix, d = _SPARSE_CORES_BATCHED[plan.algorithm](
                Ast, Bst, C0, r0s, r1s)
        stats = planned_stats_pallas(
            plan, chunk_nbytes, strip_lists[0][0].nbytes(),
            _c_strip_nbytes(strip_rows, c_pad, dtype))
        return [
            _assemble(
                _sparse_strip_csrs(ip[b], ix[b], d[b], strip_rows, n_cols,
                                   c_pad),
                plan.p_ac, n_cols)
            for b in range(len(As))
        ], stats

    if plan.algorithm == "knl":
        Ast = csr_stack([
            csr_pad_to(A, nnz_cap=envelope.a_nnz_cap,
                       max_row_nnz=envelope.a_max_row_nnz)
            for A in As
        ])
        n_rows = envelope.a_shape[0]
        if backend == "pallas":
            dense = _knl_pallas_batched(Ast, Bst, r0s)
            stats = planned_stats_pallas(plan, *_pallas_stage_nbytes(
                n_rows, envelope.a_shape[1], envelope.chunk_rows, n_cols))
            np_dtype = np.dtype(dtype)
            return [
                csr_from_dense(np.asarray(d).astype(np_dtype)) for d in dense
            ], stats
        C0s = _empty_c_stack(len(As), n_rows, n_cols, c_pad, dtype)
        Cb = _knl_scan_batched(Ast, Bst, r0s, r1s, C0s, c_pad=c_pad)
        stats = planned_stats(plan, chunk_nbytes, 0, 0)
        return csr_unstack(Cb), stats

    strip_lists = [a_strips(A, plan.p_ac, envelope=envelope) for A in As]
    Ast = csr_stack([csr_stack(sl) for sl in strip_lists])   # [batch, n_ac, ...]
    strip_rows = envelope.strip_rows
    if backend == "pallas":
        core = (_chunk1_pallas_batched if plan.algorithm == "chunk1"
                else _chunk2_pallas_batched)
        dense = core(Ast, Bst, r0s)
        stats = planned_stats_pallas(plan, *_pallas_stage_nbytes(
            strip_rows, envelope.a_shape[1], envelope.chunk_rows, n_cols))
        np_dtype = np.dtype(dtype)
        return [
            _pallas_assemble(d, plan.p_ac, np_dtype) for d in dense
        ], stats
    stats = planned_stats(plan, chunk_nbytes, strip_lists[0][0].nbytes(),
                          _c_strip_nbytes(strip_rows, c_pad, dtype))
    if plan.algorithm == "chunk1":
        C0 = _empty_c(strip_rows, n_cols, c_pad, dtype)
        Cb = _chunk1_scan_batched(Ast, Bst, r0s, r1s, C0, c_pad=c_pad)
    else:
        C0s = _empty_c_stack(plan.n_ac, strip_rows, n_cols, c_pad, dtype)
        Cb = _chunk2_scan_batched(Ast, Bst, r0s, r1s, C0s, c_pad=c_pad)
    out = [
        _assemble(csr_unstack(Ci), plan.p_ac, n_cols)
        for Ci in csr_unstack(Cb)
    ]
    return out, stats
