"""Symbolic phase for sparse-output chunked SpGEMM: exact C structure on host.

Two-phase (symbolic/numeric) SpGEMM is the standard scheme on manycore
hardware — Deveci et al.'s KKMEM and the hash/ESC variants of Nagasaka & Azad
both first compute the *structure* (or an upper bound) of C, then run the
numeric phase into preallocated storage. In this codebase the split maps onto
the JAX compilation model:

  * the **symbolic phase** (this module) runs on host, in NumPy, *before*
    tracing: it computes the exact per-row nonzero counts of C = A x B, and
    from them the per-strip output capacities a chunk plan needs
    (:func:`strip_output_caps`);
  * the **numeric phase** (``repro.core.kkmem``, ``repro.core.chunk_stream``,
    ``repro.kernels.sparse_accum_spgemm``) is traced/compiled with those
    capacities baked in as *static* shapes.

The capacities feed :class:`repro.sparse.csr.GeometryEnvelope` — the hashable
compile key every batched/serving executable is specialized on — through
``repro.core.chunking.instance_envelope``: ``c_pad`` (largest-strip output
capacity), ``c_nnz_cap`` (whole-C capacity) and ``c_max_row_nnz`` (densest C
row) become envelope fields, so two instances whose *output* structure differs
land in different buckets exactly when the difference would force a retrace,
and batches stay compile-stable under the envelope union/quantize algebra.
This is what lets the sparse-output backend (``backend="sparse"``) size its
fixed-capacity CSR accumulator scratch to ``nnz(C)`` instead of a dense
``[strip_rows, n_cols]`` slab: the symbolic counts are exact upper bounds, so
the numeric phase can never overflow the scratch.

Everything here is exact (a full structural expansion, not a probabilistic
estimate); at the matrix sizes where the host pass would dominate, the
paper's answer — and ours — is to amortize it across the many numeric calls
that reuse one plan/envelope.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.csr import CSR


@dataclasses.dataclass(frozen=True)
class SymbolicStructure:
    """Exact structure of C = A x B (host-side, all concrete ints)."""

    per_row_nnz: np.ndarray  # int64[n_rows(A)] — exact nnz of every C row
    c_nnz: int               # exact total nnz of C
    c_max_row_nnz: int       # densest C row
    flops: int               # 2 * number of scalar products


def _structure_expand(A: CSR, B: CSR):
    """Shared expansion core: unique C coordinate keys + scalar-product count.

    Returns ``(keys, total)`` where ``keys`` are the sorted unique
    ``row * n_cols + col`` coordinates of C's exact structure and ``total``
    is the number of scalar products (half the flops)."""
    a_ptr = np.asarray(A.indptr).astype(np.int64)
    a_idx = np.asarray(A.indices).astype(np.int64)
    b_ptr = np.asarray(B.indptr).astype(np.int64)
    b_idx = np.asarray(B.indices).astype(np.int64)
    nnz_a = int(a_ptr[-1])
    a_rows = np.repeat(np.arange(A.n_rows, dtype=np.int64),
                       a_ptr[1:] - a_ptr[:-1])
    a_cols = a_idx[:nnz_a]
    lens = b_ptr[a_cols + 1] - b_ptr[a_cols]
    total = int(lens.sum())
    cum = np.concatenate([[0], np.cumsum(lens)])
    p = np.arange(total, dtype=np.int64)
    t = np.searchsorted(cum, p, side="right") - 1
    prod_rows = a_rows[t]
    prod_cols = b_idx[b_ptr[a_cols[t]] + (p - cum[t])]
    keys = np.unique(prod_rows * np.int64(B.n_cols) + prod_cols)
    return keys, total


def spgemm_structure_host(A: CSR, B: CSR) -> SymbolicStructure:
    """Exact per-row structure of C = A x B (the symbolic phase proper)."""
    keys, total = _structure_expand(A, B)
    per_row = np.bincount(keys // B.n_cols, minlength=A.n_rows)
    return SymbolicStructure(
        per_row_nnz=per_row,
        c_nnz=int(keys.size),
        c_max_row_nnz=int(per_row.max()) if per_row.size else 0,
        flops=2 * total,
    )


@dataclasses.dataclass(frozen=True)
class StripOutputCaps:
    """Per-strip output capacities of a chunk plan, from the symbolic phase.

    ``c_pad`` is what every strip's CSR accumulator is allocated to (the
    largest strip's exact nnz, rounded up); ``c_nnz_cap`` bounds the whole
    assembled C; ``c_max_row_nnz`` bounds any single C row. All three fold
    into :class:`repro.sparse.csr.GeometryEnvelope`.
    """

    c_pad: int             # capacity of the largest strip (rounded up)
    c_nnz_cap: int         # whole-C capacity (rounded up)
    c_max_row_nnz: int     # exact densest C row
    strip_nnz: tuple       # exact nnz of each strip's C rows


def _round_up(v: int, multiple: int) -> int:
    return -(-max(int(v), 1) // multiple) * multiple


def strip_output_caps(A: CSR, B: CSR, p_ac: tuple,
                      pad_multiple: int = 64) -> StripOutputCaps:
    """Output capacities for the A/C row partition ``p_ac`` of C = A x B.

    One global symbolic expansion; per-strip capacities are partial sums of
    the per-row counts — identical values to running the symbolic phase on
    each row slice, without re-expanding per strip.
    """
    structure = spgemm_structure_host(A, B)
    cum = np.concatenate([[0], np.cumsum(structure.per_row_nnz)])
    strip_nnz = tuple(
        int(cum[e] - cum[s]) for s, e in zip(p_ac[:-1], p_ac[1:])
    )
    return StripOutputCaps(
        c_pad=_round_up(max(strip_nnz) if strip_nnz else 0, pad_multiple),
        c_nnz_cap=_round_up(structure.c_nnz, pad_multiple),
        c_max_row_nnz=structure.c_max_row_nnz,
        strip_nnz=strip_nnz,
    )


# ---------------------------------------------------------------------------
# composed symbolic phase: two-hop pipelines (Galerkin R x A x P)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineCaps:
    """Output capacities of a two-hop pipeline ``C = R x (A x P)``.

    One host expansion per hop, composed: hop 1's exact structure (the
    intermediate ``T = A x P``) is materialized as a *pattern* CSR and fed
    to hop 2's symbolic phase, so hop 1's **output** caps become hop 2's
    **input** caps — ``t_max_row_nnz`` is hop 2's streamed-operand
    ``b_max_row_nnz`` and ``t_nnz`` sizes the resident intermediate the
    planner budgets fast memory for. Both hops' :class:`StripOutputCaps`
    fold into one :class:`repro.sparse.csr.GeometryEnvelope` per hop, so
    the whole triple product is pre-sized before any tracing.
    """

    hop1: StripOutputCaps   # caps of T = A x P under p_ac1
    hop2: StripOutputCaps   # caps of C = R x T under p_ac2
    t_pattern: CSR          # exact structure of T (data = 1.0), host-built
    t_nnz: int              # exact nnz of the resident intermediate
    t_max_row_nnz: int      # densest T row = hop 2's streamed b_max_row_nnz


def spgemm_pattern_host(A: CSR, B: CSR) -> CSR:
    """Exact structure of ``A x B`` as a host pattern CSR (data = 1.0).

    The composed symbolic phase and the pipeline planner both consume the
    intermediate's structure — as hop 2's symbolic input and as the per-row
    byte vector the resident-intermediate budget is computed from — so the
    expansion is shared here and run once per pipeline."""
    from repro.sparse.csr import csr_from_coo

    keys, _ = _structure_expand(A, B)
    rows = keys // np.int64(B.n_cols)
    cols = keys % np.int64(B.n_cols)
    return csr_from_coo(rows, cols, np.ones(keys.size),
                        (A.n_rows, B.n_cols))


def pipeline_output_caps(A: CSR, P: CSR, R: CSR, p_ac1: tuple, p_ac2: tuple,
                         pad_multiple: int = 64,
                         t_pattern: CSR | None = None) -> PipelineCaps:
    """Composed symbolic phase for ``C = R x (A x P)``.

    Expands hop 1 exactly, builds T's pattern CSR from the unique coordinate
    keys, then expands hop 2 against that pattern — structure only, so the
    ones-valued pattern gives bitwise-identical caps to running the symbolic
    phase on the numeric T. Callers that already expanded T (the pipeline
    planner) pass it as ``t_pattern`` to skip the repeat expansion.
    """
    if t_pattern is None:
        t_pattern = spgemm_pattern_host(A, P)
    t_ptr = np.asarray(t_pattern.indptr).astype(np.int64)
    per_row = t_ptr[1 : A.n_rows + 1] - t_ptr[: A.n_rows]
    hop1 = strip_output_caps(A, P, p_ac1, pad_multiple=pad_multiple)
    hop2 = strip_output_caps(R, t_pattern, p_ac2, pad_multiple=pad_multiple)
    return PipelineCaps(
        hop1=hop1,
        hop2=hop2,
        t_pattern=t_pattern,
        t_nnz=int(per_row.sum()),
        t_max_row_nnz=int(per_row.max()) if per_row.size else 0,
    )


# ---------------------------------------------------------------------------
# masked symbolic phase (fused-mask products: triangle counting)
# ---------------------------------------------------------------------------


def masked_output_caps(mask: CSR, p_ac: tuple,
                       pad_multiple: int = 64) -> StripOutputCaps:
    """Output capacities of a mask-fused product ``C = (A x B) ∘ M``.

    A fused in-kernel mask pins C's structure to ``M``'s: every output
    position is a mask position (explicit zeros where the product has no
    contribution), so the caps come from the mask alone — no product
    expansion. ``c_max_row_nnz`` is the densest mask row (it sizes the hash
    backend's probe tables), ``strip_nnz`` the exact mask nnz per strip.
    """
    m_ptr = np.asarray(mask.indptr).astype(np.int64)
    per_row = m_ptr[1:] - m_ptr[:-1]
    cum = np.concatenate([[0], np.cumsum(per_row)])
    strip_nnz = tuple(
        int(cum[e] - cum[s]) for s, e in zip(p_ac[:-1], p_ac[1:])
    )
    return StripOutputCaps(
        c_pad=_round_up(max(strip_nnz) if strip_nnz else 0, pad_multiple),
        c_nnz_cap=_round_up(int(per_row.sum()), pad_multiple),
        c_max_row_nnz=int(per_row.max()) if per_row.size else 0,
        strip_nnz=strip_nnz,
    )


# ---------------------------------------------------------------------------
# block-level symbolic phase (the BSR backend's output-cap analogue)
# ---------------------------------------------------------------------------


def _next_pow2(v: int) -> int:
    p = 1
    while p < v:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class BsrPlanCaps:
    """Block-geometry capacities of a chunk plan at block size ``block_size``.

    These bound the BSR backend's staged shapes for every (strip, chunk)
    pair of the plan, the way :class:`StripOutputCaps` bounds the sparse
    backends' CSR scratch: ``nbl_a`` bounds the blocks of any staged A
    strip x chunk-column slice, ``nbl_b`` the blocks of any staged B chunk,
    ``nc`` the C blocks of any strip's full output, and ``u`` the
    contributor (k-block) count of any C block. All are quantized here —
    block counts to a multiple of ``quantum``, ``u`` to a power of two — so
    ``as_tuple()`` doubles as the envelope's ``bsr_caps`` compile-key field.
    """

    block_size: int
    nbl_a: int   # blocks of any staged A (strip x chunk-columns) piece
    nbl_b: int   # blocks of any staged B chunk
    nc: int      # block-expanded C blocks of any (strip, chunk) pair
    u: int       # contributor (A block, B block) pairs of any C block

    def as_tuple(self) -> tuple:
        return (self.block_size, self.nbl_a, self.nbl_b, self.nc, self.u)


def bsr_plan_caps(A: CSR, B: CSR, plan, block_size: int,
                  quantum: int = 8) -> BsrPlanCaps:
    """Exact block-structure capacities of ``plan`` on (A, B) at ``block_size``.

    A pair stages as A's strip rows restricted to the chunk's columns (full
    element width, out-of-range columns zeroed) against B's chunk rows (full
    element height). ``nc`` and ``u`` bound the *block-level expansion* the
    BSR kernel's symbolic phase performs — C block (i, j) is scheduled when
    A block (i, kb) meets B block (kb, j), even if no element-level product
    lands in it — so they must be computed by the same join, not from C's
    element structure (which can be strictly smaller). Like
    :func:`strip_output_caps`, this is exact (no probabilistic estimate) and
    meant to be amortized across the numeric calls that reuse one
    plan/envelope.
    """
    bs = int(block_size)
    if bs < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    k, n = B.shape
    kb = -(-k // bs)
    nb = -(-n // bs)
    a_ptr = np.asarray(A.indptr).astype(np.int64)
    a_idx = np.asarray(A.indices).astype(np.int64)
    nnz_a = int(a_ptr[-1])
    rows_a = np.repeat(np.arange(A.n_rows, dtype=np.int64),
                       a_ptr[1:] - a_ptr[:-1])
    cols_a = a_idx[:nnz_a]
    b_ptr = np.asarray(B.indptr).astype(np.int64)
    b_idx = np.asarray(B.indices).astype(np.int64)
    nnz_b = int(b_ptr[-1])
    rows_b = np.repeat(np.arange(B.n_rows, dtype=np.int64),
                       b_ptr[1:] - b_ptr[:-1])
    cols_b = b_idx[:nnz_b]
    strips = list(zip(plan.p_ac[:-1], plan.p_ac[1:]))
    chunks = list(zip(plan.p_b[:-1], plan.p_b[1:]))

    nbl_a = nbl_b = nc = u = 1
    # block-level CSR pattern of each staged B chunk, for the expansion join
    chunk_patterns = []
    for r0, r1 in chunks:
        sel = (rows_b >= r0) & (rows_b < r1)
        keys = np.unique((rows_b[sel] // bs) * nb + cols_b[sel] // bs)
        nbl_b = max(nbl_b, int(keys.size))
        ptr = np.zeros(kb + 1, np.int64)
        np.add.at(ptr, keys // nb + 1, 1)
        chunk_patterns.append((np.cumsum(ptr), keys % nb))

    for s, e in strips:
        sela = (rows_a >= s) & (rows_a < e)
        abr = (rows_a[sela] - s) // bs
        acol = cols_a[sela]
        abc = acol // bs
        for (r0, r1), (bptr, bjb) in zip(chunks, chunk_patterns):
            selp = (acol >= r0) & (acol < r1)
            akeys = np.unique(abr[selp] * kb + abc[selp])
            nbl_a = max(nbl_a, int(akeys.size))
            ai, ak = akeys // kb, akeys % kb
            lens = bptr[ak + 1] - bptr[ak]
            total = int(lens.sum())
            if not total:
                continue
            cum = np.concatenate([[0], np.cumsum(lens)])
            p = np.arange(total, dtype=np.int64)
            t = np.searchsorted(cum, p, side="right") - 1
            ckeys = ai[t] * nb + bjb[bptr[ak[t]] + (p - cum[t])]
            uniq, counts = np.unique(ckeys, return_counts=True)
            nc = max(nc, int(uniq.size))
            u = max(u, int(counts.max()))

    def up(v: int) -> int:
        return -(-int(v) // quantum) * quantum

    return BsrPlanCaps(block_size=bs, nbl_a=up(nbl_a), nbl_b=up(nbl_b),
                       nc=up(nc), u=_next_pow2(u))
