"""Reuse-distance (LRU stack-distance) analysis of KKMEM's B-access trace.

Paper §3.1: for ``C = A x B`` the trace of *B-row* accesses is exactly the column
stream of A (each nonzero a_ik triggers a read of B row k). Temporal locality is
"overlapping columns in consecutive rows of A"; spatial locality is the density of
B's rows. Both are measurable offline:

  * stack distance of each access  -> miss fraction at any cache capacity
    (one simulation, every capacity; Mattson et al. 1970)
  * delta of B                     -> bytes per discrete access (prefetch amortization)

This module is the quantitative bridge between the matrices and the memory cost
model — it produces the ``b_miss_fraction`` used by repro.core.memory_model and
reproduces the paper's Table 1 / Table 2 / Table 4 locality orderings.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.csr import CSR


class _Fenwick:
    """Binary indexed tree over trace positions (counts most-recent-access marks)."""

    def __init__(self, n: int):
        self.n = n
        self.tree = np.zeros(n + 1, np.int64)

    def add(self, i: int, v: int) -> None:
        i += 1
        while i <= self.n:
            self.tree[i] += v
            i += i & (-i)

    def prefix(self, i: int) -> int:
        """Sum of [0, i)."""
        s = 0
        while i > 0:
            s += self.tree[i]
            i -= i & (-i)
        return int(s)

    def range(self, lo: int, hi: int) -> int:
        """Sum of [lo, hi)."""
        return self.prefix(hi) - self.prefix(lo)


def stack_distances(trace: np.ndarray, n_ids: int) -> np.ndarray:
    """LRU stack distance per access; -1 for cold (first) accesses.

    distance d means: d distinct other ids were touched since the previous access to
    this id -> the access hits an LRU cache holding > d ids.
    """
    trace = np.asarray(trace, np.int64)
    t_len = trace.size
    bit = _Fenwick(t_len)
    last = np.full(n_ids, -1, np.int64)
    out = np.empty(t_len, np.int64)
    for t in range(t_len):
        r = trace[t]
        lt = last[r]
        if lt < 0:
            out[t] = -1
        else:
            out[t] = bit.range(lt + 1, t)
            bit.add(lt, -1)
        bit.add(t, 1)
        last[r] = t
    return out


@dataclasses.dataclass(frozen=True)
class LocalityStats:
    """Locality profile of one SpGEMM's B-access trace."""

    n_accesses: int
    n_cold: int
    distances: np.ndarray        # stack distance histogram support (sorted, cold excl.)
    counts: np.ndarray           # histogram counts
    avg_b_row_bytes: float       # spatial-locality proxy (prefetch amortization)
    mean_reuse: float            # mean stack distance over warm accesses

    def miss_fraction(self, capacity_rows: float) -> float:
        """Fraction of accesses missing an LRU cache holding ``capacity_rows`` rows
        (cold misses always count)."""
        if self.n_accesses == 0:
            return 0.0
        warm_misses = int(self.counts[self.distances >= capacity_rows].sum())
        return (warm_misses + self.n_cold) / self.n_accesses

    def miss_fraction_bytes(self, capacity_bytes: float) -> float:
        rows = max(1.0, capacity_bytes / max(self.avg_b_row_bytes, 1.0))
        return self.miss_fraction(rows)


def b_access_trace(A: CSR) -> np.ndarray:
    """The B-row access trace of C = A x B: A's column stream in row order."""
    indptr = np.asarray(A.indptr)
    indices = np.asarray(A.indices)
    return indices[: int(indptr[-1])]


def analyze(A: CSR, B: CSR, value_bytes: int = 8, index_bytes: int = 4,
            max_trace: int = 200_000, seed: int = 0) -> LocalityStats:
    """Locality profile of C = A x B (subsampled for very long traces: a contiguous
    window keeps the row-to-row overlap structure intact)."""
    trace = b_access_trace(A)
    if trace.size > max_trace:
        rng = np.random.default_rng(seed)
        start = int(rng.integers(0, trace.size - max_trace))
        trace = trace[start : start + max_trace]
    d = stack_distances(trace, B.n_rows)
    cold = int((d < 0).sum())
    warm = d[d >= 0]
    if warm.size:
        support, counts = np.unique(warm, return_counts=True)
        mean_reuse = float(warm.mean())
    else:
        support, counts = np.empty(0, np.int64), np.empty(0, np.int64)
        mean_reuse = float("inf")
    b_lens = np.asarray(B.indptr[1:] - B.indptr[:-1])
    avg_row_bytes = float(b_lens.mean()) * (value_bytes + index_bytes) if b_lens.size else 0.0
    return LocalityStats(
        n_accesses=int(trace.size),
        n_cold=cold,
        distances=support,
        counts=counts,
        avg_b_row_bytes=avg_row_bytes,
        mean_reuse=mean_reuse,
    )


def miss_table(A: CSR, B: CSR, capacities_bytes: dict | None = None) -> dict:
    """Paper Table 1/4 analogue: miss fractions at L1/L2-like capacities."""
    caps = capacities_bytes or {"L1": 32 << 10, "L2": 1 << 20}
    st = analyze(A, B)
    return {name: st.miss_fraction_bytes(cap) for name, cap in caps.items()} | {
        "mean_reuse_rows": st.mean_reuse,
        "avg_b_row_bytes": st.avg_b_row_bytes,
    }
