"""Linear-algebra triangle counting (paper §4.1.2, after Wolf et al. HPEC'17).

Vertices are sorted by degree, L = strictly-lower-triangular part of the permuted
adjacency; triangles = sum over nonzeros (i,j) of L of (L x L)[i, j] — i.e. the
SpGEMM result *masked* by L. The mask is fused into the accumulation read-out via a
sort-merge of C's and L's (row, col) keys — the JAX analogue of KKMEM's fused
masking. No flat 64-bit keys are formed, so there is no overflow limit on n.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.kkmem import spgemm, spgemm_symbolic_host
from repro.sparse.csr import CSR, csr_row_of_entry, csr_to_dense


def count_triangles(L: CSR) -> jnp.ndarray:
    """Triangles = sum((L @ L) o L) with L strictly lower triangular, 0/1 values."""
    ws = spgemm_symbolic_host(L, L)
    C = spgemm(L, L, ws.c_pad)
    n = L.n_rows

    c_entry = jnp.arange(C.nnz_pad, dtype=jnp.int32)
    c_valid = c_entry < C.indptr[-1]
    c_rows = jnp.where(c_valid, csr_row_of_entry(C), n).astype(jnp.int32)
    c_cols = jnp.where(c_valid, C.indices, 0)
    c_vals = jnp.where(c_valid, C.data, 0.0)

    l_entry = jnp.arange(L.nnz_pad, dtype=jnp.int32)
    l_valid = l_entry < L.indptr[-1]
    l_rows = jnp.where(l_valid, csr_row_of_entry(L), n).astype(jnp.int32)
    l_cols = jnp.where(l_valid, L.indices, 0)

    # Sort-merge on (row, col, tag): C entries (tag 0) land directly before the L
    # probes (tag 1) that share their key; both key sets are individually duplicate-
    # free, so probe p matches iff element p-1 is a C entry with the same key.
    rows = jnp.concatenate([c_rows, l_rows])
    cols = jnp.concatenate([c_cols, l_cols])
    tags = jnp.concatenate(
        [jnp.zeros(C.nnz_pad, jnp.int32), jnp.ones(L.nnz_pad, jnp.int32)]
    )
    vals = jnp.concatenate([c_vals, jnp.zeros(L.nnz_pad, C.data.dtype)])
    order = jnp.argsort(tags, stable=True)
    rows, cols, tags, vals = rows[order], cols[order], tags[order], vals[order]
    order = jnp.argsort(cols, stable=True)
    rows, cols, tags, vals = rows[order], cols[order], tags[order], vals[order]
    order = jnp.argsort(rows, stable=True)
    rows, cols, tags, vals = rows[order], cols[order], tags[order], vals[order]

    probe = (tags == 1) & (rows < n)
    prev_match = jnp.concatenate(
        [
            jnp.array([False]),
            (tags[:-1] == 0) & (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1]),
        ]
    )
    hit = probe & prev_match
    prev_vals = jnp.concatenate([jnp.zeros(1, vals.dtype), vals[:-1]])
    return jnp.sum(jnp.where(hit, prev_vals, 0.0))


def count_triangles_dense(L: CSR) -> jnp.ndarray:
    """Dense oracle."""
    Ld = csr_to_dense(L)
    return jnp.sum((Ld @ Ld) * (Ld != 0))
