"""Linear-algebra triangle counting (paper §4.1.2, after Wolf et al. HPEC'17).

Vertices are sorted by degree, L = strictly-lower-triangular part of the
permuted adjacency; triangles = sum over nonzeros (i,j) of L of (L x L)[i, j]
— i.e. the SpGEMM result *masked* by L.

Two paths:

* :func:`count_triangles` — the fused path: the product routes through a
  mask-capable registered chunked backend (``BackendSpec.run_masked``, the
  hash accumulator by default), with the L-mask applied **inside** the
  kernel's merge. The accumulator only ever holds mask positions, so no
  unmasked C is materialized at any point — KKMEM's fused masking, for real.
* :func:`count_triangles_kkmem` — the unfused baseline: the full C = L x L
  materialized at its symbolic capacity, then masked by a sort-merge of C's
  and L's (row, col) keys. No flat 64-bit keys are formed, so there is no
  overflow limit on n. Kept as the comparison target the triangle bench
  lane times the fused path against.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.kkmem import spgemm, spgemm_symbolic_host
from repro.core.planner import ChunkPlan, plan_knl
from repro.sparse.csr import CSR, csr_row_of_entry, csr_to_dense


def count_triangles(L: CSR, plan: ChunkPlan | None = None,
                    backend: str | None = None, caps=None) -> jnp.ndarray:
    """Triangles = sum((L @ L) o L) with L strictly lower triangular, 0/1
    values, the mask fused into the chunked kernel.

    ``backend`` must be mask-capable (``supports_mask``); ``None`` resolves
    to the first registered one (``backend_registry.masked_backends()``).
    ``plan`` defaults to a single-chunk KNL plan (one kernel launch);
    ``caps`` to the masked symbolic phase at the plan's partitions — both
    are host-only precomputations callers on a timing path hoist out."""
    from repro.core import backend_registry
    from repro.core.symbolic import masked_output_caps

    if backend is None:
        names = backend_registry.masked_backends()
        if not names:
            raise ValueError("no registered backend supports a fused mask")
        backend = names[0]
    spec = backend_registry.get(backend)
    if not spec.supports_mask:
        raise ValueError(
            f"backend {backend!r} does not support a fused output mask; "
            f"mask-capable: {list(backend_registry.masked_backends())}")
    if plan is None:
        plan = plan_knl(L, L, float("inf"))
    if caps is None:
        caps = masked_output_caps(L, plan.p_ac)
    C, _ = spec.run_masked(L, L, L, plan, caps.c_pad, caps=caps)
    # C's structure is exactly L's (explicit zeros where the product has no
    # contribution), so the masked sum is the sum of the stored values
    return jnp.sum(C.data)


def count_triangles_kkmem(L: CSR, c_pad: int | None = None) -> jnp.ndarray:
    """The unfused baseline: materialize C = L x L at ``c_pad`` (defaulting
    to the host symbolic phase's capacity — precompute it to keep the host
    pass out of timed regions), then mask by sort-merge against L."""
    if c_pad is None:
        c_pad = spgemm_symbolic_host(L, L).c_pad
    C = spgemm(L, L, c_pad)
    n = L.n_rows

    c_entry = jnp.arange(C.nnz_pad, dtype=jnp.int32)
    c_valid = c_entry < C.indptr[-1]
    c_rows = jnp.where(c_valid, csr_row_of_entry(C), n).astype(jnp.int32)
    c_cols = jnp.where(c_valid, C.indices, 0)
    c_vals = jnp.where(c_valid, C.data, 0.0)

    l_entry = jnp.arange(L.nnz_pad, dtype=jnp.int32)
    l_valid = l_entry < L.indptr[-1]
    l_rows = jnp.where(l_valid, csr_row_of_entry(L), n).astype(jnp.int32)
    l_cols = jnp.where(l_valid, L.indices, 0)

    # Sort-merge on (row, col, tag): C entries (tag 0) land directly before the L
    # probes (tag 1) that share their key; both key sets are individually duplicate-
    # free, so probe p matches iff element p-1 is a C entry with the same key.
    rows = jnp.concatenate([c_rows, l_rows])
    cols = jnp.concatenate([c_cols, l_cols])
    tags = jnp.concatenate(
        [jnp.zeros(C.nnz_pad, jnp.int32), jnp.ones(L.nnz_pad, jnp.int32)]
    )
    vals = jnp.concatenate([c_vals, jnp.zeros(L.nnz_pad, C.data.dtype)])
    order = jnp.argsort(tags, stable=True)
    rows, cols, tags, vals = rows[order], cols[order], tags[order], vals[order]
    order = jnp.argsort(cols, stable=True)
    rows, cols, tags, vals = rows[order], cols[order], tags[order], vals[order]
    order = jnp.argsort(rows, stable=True)
    rows, cols, tags, vals = rows[order], cols[order], tags[order], vals[order]

    probe = (tags == 1) & (rows < n)
    prev_match = jnp.concatenate(
        [
            jnp.array([False]),
            (tags[:-1] == 0) & (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1]),
        ]
    )
    hit = probe & prev_match
    prev_vals = jnp.concatenate([jnp.zeros(1, vals.dtype), vals[:-1]])
    return jnp.sum(jnp.where(hit, prev_vals, 0.0))


def count_triangles_dense(L: CSR) -> jnp.ndarray:
    """Dense oracle."""
    Ld = csr_to_dense(L)
    return jnp.sum((Ld @ Ld) * (Ld != 0))
