"""Fused two-hop sparse pipelines: the Galerkin triple product ``R x (A x P)``.

The multigrid setup phase (paper §4.1.1) and the masked triangle count
(§4.1.2) are both *products of products*: a first SpGEMM whose output is
immediately consumed by a second. Running them as two independent
``chunked_spgemm`` calls wastes the structure the composed symbolic phase
already knows — the intermediate ``T = A x P`` round-trips through slow
memory even when it would fit in fast memory alongside hop 2's staging.

This module is the two-hop planner+executor:

* the **composed symbolic phase** (``repro.core.symbolic.
  pipeline_output_caps``) pre-sizes both hops in one pass — hop 1's exact
  output structure *is* hop 2's streamed-operand input, so one
  :class:`PipelineEnvelope` (a hop-1 + hop-2 envelope pair, hashable)
  covers the whole triple product before any tracing;
* the **planner extension** (``repro.core.planner.plan_pipeline``) budgets
  fast memory for the *resident intermediate*: T's CSR triple stays staged
  between the hops when both hops' peaks still fit with it held alongside,
  and spills to slow memory otherwise;
* the **executor** (:func:`pipeline_spgemm`) runs both hops through any
  registered backend, propagating the pre-sized caps so neither hop
  re-expands the symbolic structure;
* the **audit hook** (:func:`pipeline_audit_traces`, :func:`audit_pipeline`)
  stages both hops' cores exactly as the executor would, so the static
  verifier's VMEM/traffic/retrace analyses cover two-hop staging, and the
  composed byte model (:func:`pipeline_fast_model`) is held to counting the
  resident intermediate **exactly once** (:func:`check_pipeline_model`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import backend_registry
from repro.core.chunking import ChunkStats, instance_envelope
from repro.core.kkmem import spgemm
from repro.core.planner import BackendFastModel, PipelinePlan, plan_pipeline
from repro.core.symbolic import PipelineCaps, pipeline_output_caps
from repro.sparse.csr import CSR, GeometryEnvelope


@dataclasses.dataclass(frozen=True)
class PipelineEnvelope:
    """The compile key of one two-hop pipeline: both hops' padded
    geometries, pre-sized together by the composed symbolic phase. Hop 1's
    output caps are hop 2's input caps by construction (``hop2.b_max_row_nnz``
    is the densest row of the intermediate ``T``), which is what makes the
    pair a *single* envelope over the triple product rather than two
    independent ones."""

    hop1: GeometryEnvelope   # T = A x P
    hop2: GeometryEnvelope   # C = R x T


def pipeline_envelope(A: CSR, P: CSR, R: CSR, plan: PipelinePlan,
                      caps: PipelineCaps) -> PipelineEnvelope:
    """Both hop envelopes from one composed symbolic pass. The hop-2
    envelope is built against the intermediate's exact *pattern* (structure
    equals the numeric T bitwise), so it can be constructed — and an
    executable compiled — before hop 1 ever runs."""
    return PipelineEnvelope(
        hop1=instance_envelope(A, P, plan.plan1, caps=caps.hop1),
        hop2=instance_envelope(R, caps.t_pattern, plan.plan2, caps=caps.hop2),
    )


@dataclasses.dataclass
class PipelineStats:
    """Observed staging traffic of one pipeline run. The per-hop
    :class:`ChunkStats` log the executors' staging events; ``spill_bytes``
    is the *extra* slow-memory round trip of the intermediate when the plan
    spilled it (one write-out after hop 1 plus one read per hop-2 streamed
    pass) — zero on the resident path, where T never leaves fast memory
    between the hops."""

    plan: PipelinePlan
    hop1: ChunkStats
    hop2: ChunkStats
    spilled: bool
    spill_bytes: float

    @property
    def copy_bytes(self) -> float:
        return self.hop1.copy_bytes + self.hop2.copy_bytes + self.spill_bytes


def _run_hop(X: CSR, Y: CSR, plan, caps, backend: str):
    """One hop through a registered backend at pre-sized caps (no repeat
    symbolic expansion — the composed phase already ran)."""
    if plan.algorithm == "whole_fast":
        stats = ChunkStats("whole_fast", 1, 1)
        stats.add_in(X.nbytes() + Y.nbytes())
        C = spgemm(X, Y, caps.c_pad)
        stats.add_out(C.nbytes())
        stats.kernel_calls = 1
        return C, stats
    spec = backend_registry.get(backend)
    fn = spec.executors.get(plan.algorithm)
    if fn is None:
        raise ValueError(f"unknown algorithm {plan.algorithm!r}")
    kwargs = {"caps": caps} if spec.needs_output_caps else {}
    return fn(X, Y, plan, caps.c_pad, **kwargs)


def _spill_to_slow(T: CSR) -> CSR:
    """Round-trip the intermediate through slow (host) memory: the spill
    path's physical analogue — hop 2 restages T from slow instead of
    consuming the fast-resident triple."""
    return CSR(
        indptr=np.asarray(T.indptr),
        indices=np.asarray(T.indices),
        data=np.asarray(T.data),
        shape=T.shape,
        max_row_nnz=T.max_row_nnz,
    )


def pipeline_spgemm(A: CSR, P: CSR, R: CSR, plan: PipelinePlan | None = None,
                    *, system=None, fast_limit_bytes: float | None = None,
                    backend: str = "sparse", caps: PipelineCaps | None = None):
    """Execute ``C = R x (A x P)`` as a fused two-hop pipeline.

    Returns ``(C, PipelineStats)``. ``plan`` defaults to
    ``planner.plan_pipeline(A, P, R, system, fast_limit_bytes)`` (``system``
    is then required); ``caps`` defaults to the composed symbolic phase at
    the plan's partitions. ``backend`` names any registered backend; both
    hops run through it. On the resident path the intermediate's device CSR
    flows straight into hop 2's staging; on the spill path it round-trips
    through host memory and the stats carry the extra copy events.
    """
    if plan is None:
        if system is None:
            raise ValueError(
                "pipeline_spgemm needs either a PipelinePlan or a "
                "MemorySystem to plan against")
        plan = plan_pipeline(A, P, R, system,
                             fast_limit_bytes=fast_limit_bytes)
    if caps is None:
        caps = pipeline_output_caps(A, P, R, plan.plan1.p_ac, plan.plan2.p_ac)
    T, stats1 = _run_hop(A, P, plan.plan1, caps.hop1, backend)
    spilled = not plan.t_resident
    spill_bytes = 0.0
    if spilled:
        T = _spill_to_slow(T)
        t_reads = plan.plan2.n_ac if plan.plan2.algorithm == "chunk1" else 1
        spill_bytes = float(T.nbytes()) * (1 + t_reads)
    C, stats2 = _run_hop(R, T, plan.plan2, caps.hop2, backend)
    return C, PipelineStats(plan=plan, hop1=stats1, hop2=stats2,
                            spilled=spilled, spill_bytes=spill_bytes)


# ---------------------------------------------------------------------------
# static-audit hook: two-hop staging under the analysis passes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineFastModel:
    """Composed peak-resident claim of one pipeline under one backend: each
    hop's registered byte model, plus the resident intermediate counted
    **exactly once** on top of whichever hop peaks — T is one buffer that
    persists across both hops, not a per-hop allocation. Double-counting it
    is the modeling bug :func:`check_pipeline_model` exists to catch."""

    backend: str
    hop1: BackendFastModel
    hop2: BackendFastModel
    t_bytes: float           # staged footprint of the resident intermediate
    t_resident: bool
    fast_bytes_needed: float  # max(hop peaks) + (t_bytes if resident)


def pipeline_fast_model(plan: PipelinePlan, penv: PipelineEnvelope,
                        backend: str) -> PipelineFastModel:
    """Compose the backend's per-hop byte models into the pipeline claim."""
    spec = backend_registry.get(backend)
    if spec.byte_model is None:
        raise ValueError(f"backend {backend!r} registers no byte model")
    m1 = spec.byte_model(plan.plan1, penv.hop1)
    m2 = spec.byte_model(plan.plan2, penv.hop2)
    extra = plan.t_bytes if plan.t_resident else 0.0
    return PipelineFastModel(
        backend=spec.name, hop1=m1, hop2=m2, t_bytes=plan.t_bytes,
        t_resident=plan.t_resident,
        fast_bytes_needed=max(m1.fast_bytes_needed, m2.fast_bytes_needed)
        + extra,
    )


def check_pipeline_model(model: PipelineFastModel) -> list:
    """The composed model's consistency invariant: its claim must equal
    max(hop peaks) plus the resident intermediate counted exactly once.
    A model that adds ``t_bytes`` into both hops (or on top of their sum)
    inflates the claim — it still *dominates* any trace, which is exactly
    why domination alone cannot catch it and this equality check exists."""
    extra = model.t_bytes if model.t_resident else 0.0
    want = (max(model.hop1.fast_bytes_needed, model.hop2.fast_bytes_needed)
            + extra)
    if model.fast_bytes_needed != want:
        return [
            f"composed pipeline byte model is inconsistent: claims "
            f"{model.fast_bytes_needed:.0f} B but max(hop1 "
            f"{model.hop1.fast_bytes_needed:.0f}, hop2 "
            f"{model.hop2.fast_bytes_needed:.0f}) + resident intermediate "
            f"{extra:.0f} = {want:.0f} B — the intermediate persists across "
            f"both hops and must be counted exactly once"]
    return []


def pipeline_audit_traces(A: CSR, P: CSR, R: CSR, plan: PipelinePlan,
                          backend: str,
                          caps: PipelineCaps | None = None) -> list:
    """Stage both hops' cores for abstract tracing, exactly as the executor
    would. Returns ``[(hop_label, TraceTarget, hop_plan, hop_envelope),
    ...]``; hop 2 is staged against the intermediate's exact *pattern* (the
    audit never needs numeric values). ``whole_fast`` hops have no chunked
    core and are omitted."""
    spec = backend_registry.get(backend)
    if not spec.supports_audit:
        raise ValueError(f"backend {backend!r} registers no audit_trace")
    if caps is None:
        caps = pipeline_output_caps(A, P, R, plan.plan1.p_ac, plan.plan2.p_ac)
    penv = pipeline_envelope(A, P, R, plan, caps)
    out = []
    for label, X, Y, hplan, henv in (
            ("hop1", A, P, plan.plan1, penv.hop1),
            ("hop2", R, caps.t_pattern, plan.plan2, penv.hop2)):
        if hplan.algorithm == "whole_fast":
            continue
        target = spec.audit_trace(X, Y, hplan, henv.c_pad, henv)
        out.append((label, target, hplan, henv))
    return out


def audit_pipeline(A: CSR, P: CSR, R: CSR, plan: PipelinePlan,
                   backend: str = "sparse",
                   caps: PipelineCaps | None = None):
    """Static audit of one pipeline: trace each hop's core, check the
    backend's per-hop byte model dominates each traced VMEM footprint, and
    hold the composed :class:`PipelineFastModel` to its once-counted
    resident-intermediate invariant *and* to dominating the traced two-hop
    peak. Returns ``(record, violations)`` in the shape of
    ``repro.analysis.report.audit_backend_case``."""
    import jax

    from repro.analysis.vmem import audit_vmem

    if caps is None:
        caps = pipeline_output_caps(A, P, R, plan.plan1.p_ac, plan.plan2.p_ac)
    penv = pipeline_envelope(A, P, R, plan, caps)
    model = pipeline_fast_model(plan, penv, backend)
    violations = list(check_pipeline_model(model))
    record = {"backend": backend, "t_resident": plan.t_resident,
              "t_bytes": plan.t_bytes, "hops": {}}
    traced_peak = 0.0
    spec = backend_registry.get(backend)
    for label, target, hplan, henv in pipeline_audit_traces(
            A, P, R, plan, backend, caps=caps):
        traced = jax.make_jaxpr(target.fn)(*target.args)
        hmodel = spec.byte_model(hplan, henv)
        vaudit = audit_vmem(traced, hmodel)
        if vaudit.dominated is False:
            violations.append(
                f"{label}: byte model undercounts the traced VMEM footprint "
                f"(model {vaudit.model_bytes:.0f} B < traced "
                f"{vaudit.traced_bytes:.0f} B)")
        traced_peak = max(traced_peak, vaudit.traced_bytes)
        record["hops"][label] = dataclasses.asdict(vaudit)
    resident_extra = plan.t_bytes if plan.t_resident else 0.0
    if traced_peak and model.fast_bytes_needed < traced_peak + resident_extra:
        violations.append(
            f"composed model {model.fast_bytes_needed:.0f} B does not cover "
            f"the traced two-hop peak {traced_peak:.0f} B plus the resident "
            f"intermediate {resident_extra:.0f} B")
    record["fast_bytes_needed"] = model.fast_bytes_needed
    record["traced_peak"] = traced_peak
    record["n_violations"] = len(violations)
    return record, violations
