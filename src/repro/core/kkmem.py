"""KKMEM-style two-phase SpGEMM in pure JAX (the paper's baseline, §2.1).

KKMEM assigns rows of A to threads and multiplications within a row to vector lanes,
accumulating into sparse hashmap accumulators. A scalar hashmap has no efficient
SIMD/XLA analogue, so the TPU/JAX-idiomatic equivalent keeps the *two-phase*
row-wise structure but realizes the accumulator as **sort + segment-reduce** over the
expanded product stream — the same multiset-union semantics, fully vectorized:

  expand:     every nonzero a_ik fans out into products with B's row k
              (the access pattern of Fig. 1 — A streamed, B gathered)
  accumulate: stable two-key sort brings duplicate (row, col) products together;
              a boundary scan + scatter-add coalesces them (== hashmap insert)

Shapes are static: the product buffer has capacity nnzA_pad x B.max_row_nnz, the
output CSR has a caller-provided capacity from the symbolic phase. Everything here
jits and vmaps cleanly.

``spgemm_ranged`` is the paper's *modified KKMEM sub-procedure* used by the chunked
algorithms: it multiplies only the columns of A inside a B-row-range [r0, r1)
("skip any columns of A outside of this range" — §3.2.2) and *fuses the previous
partial C into the accumulation* ("inserts the existing values of C^1 into its
hashmap accumulators"), i.e. C^t = A_t x B_t + C^{t-1}.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSR, csr_row_of_entry


@dataclasses.dataclass(frozen=True)
class SpGEMMWorkspace:
    """Output of the symbolic phase: static capacities for the numeric phase."""

    c_nnz: int          # exact nnz of C
    c_pad: int          # padded capacity (>= c_nnz)
    c_max_row_nnz: int  # densest row of C
    flops: int          # 2 * (number of scalar products)


# ---------------------------------------------------------------------------
# symbolic phase (host, NumPy — the paper computes structure ahead of numerics)
# ---------------------------------------------------------------------------


def spgemm_symbolic_host(A: CSR, B: CSR, pad_multiple: int = 64) -> SpGEMMWorkspace:
    """Exact structure of C = A x B on host: nnz, densest row, flops.

    Thin wrapper over the one structural expansion
    (``repro.core.symbolic.spgemm_structure_host``) so the symbolic phase has
    a single implementation to fix/extend."""
    from repro.core.symbolic import spgemm_structure_host

    s = spgemm_structure_host(A, B)
    return SpGEMMWorkspace(
        c_nnz=s.c_nnz,
        c_pad=-(-max(s.c_nnz, 1) // pad_multiple) * pad_multiple,
        c_max_row_nnz=s.c_max_row_nnz,
        flops=s.flops,
    )


# ---------------------------------------------------------------------------
# numeric phase (pure JAX, jit-able)
# ---------------------------------------------------------------------------


def _expand_products(A: CSR, B: CSR, r0, r1):
    """Fan every (valid, in-range) A entry out into its products with B's rows.

    Returns (rows, cols, vals) of static length nnzA_pad * B.max_row_nnz; invalid
    slots get row = A.n_rows (sorts to the tail) and val = 0.

    ``r0, r1`` bound the *global* column range of A handled by this call; B is the
    CSR of exactly that row range (local row r_global - r0). For the unchunked case
    pass r0=0, r1=A.n_cols with B the full matrix.
    """
    bmax = max(B.max_row_nnz, 1)
    n_ent = A.nnz_pad
    t = jnp.arange(n_ent, dtype=jnp.int32)
    row_a = csr_row_of_entry(A)                      # [n_ent]
    col_a = A.indices                                # [n_ent]
    valid_t = t < A.indptr[-1]
    in_range = (col_a >= r0) & (col_a < r1) & valid_t
    b_row = jnp.clip(col_a - r0, 0, B.n_rows - 1)
    b_start = B.indptr[b_row]                        # [n_ent]
    b_len = B.indptr[b_row + 1] - b_start
    j = jnp.arange(bmax, dtype=jnp.int32)            # [bmax]
    valid = in_range[:, None] & (j[None, :] < b_len[:, None])   # [n_ent, bmax]
    src = jnp.clip(b_start[:, None] + j[None, :], 0, B.nnz_pad - 1)
    cols = jnp.where(valid, B.indices[src], 0)
    vals = jnp.where(valid, A.data[:, None] * B.data[src], 0.0)
    rows = jnp.where(valid, row_a[:, None], A.n_rows)
    return rows.reshape(-1), cols.reshape(-1), vals.reshape(-1)


def _accumulate(rows, cols, vals, m: int, _n: int, c_pad: int):
    """Sort-based accumulator: coalesce duplicate (row, col) into CSR arrays.

    Two stable sorts == lexsort by (row, col) without 64-bit keys. Boundary scan
    assigns each distinct key a dense output slot; scatter-add realizes the
    "hashmap" accumulation. Returns (indptr[m+1], indices[c_pad], data[c_pad]).
    """
    order_c = jnp.argsort(cols, stable=True)
    rows_c, cols_c, vals_c = rows[order_c], cols[order_c], vals[order_c]
    order_r = jnp.argsort(rows_c, stable=True)
    rows_s, cols_s, vals_s = rows_c[order_r], cols_c[order_r], vals_c[order_r]
    valid = rows_s < m
    # scalar-constant pad (not jnp.array([True])) so this body also traces
    # inside Pallas kernels, which reject captured array constants
    new_key = jnp.pad(
        (rows_s[1:] != rows_s[:-1]) | (cols_s[1:] != cols_s[:-1]),
        (1, 0), constant_values=True,
    ) & valid
    slot = jnp.cumsum(new_key) - 1                       # dense slot per product
    slot = jnp.where(valid, slot, c_pad)                 # invalid -> dropped bucket
    data = jnp.zeros(c_pad + 1, vals.dtype).at[slot].add(vals_s)[:c_pad]
    indices = jnp.zeros(c_pad + 1, jnp.int32).at[slot].max(
        jnp.where(valid, cols_s, 0).astype(jnp.int32)
    )[:c_pad]
    out_rows = jnp.full(c_pad + 1, m, jnp.int32).at[slot].min(
        jnp.where(valid, rows_s, m).astype(jnp.int32)
    )[:c_pad]
    # rows are sorted ascending over slots -> indptr by binary search
    indptr = jnp.searchsorted(out_rows, jnp.arange(m + 1, dtype=jnp.int32)).astype(
        jnp.int32
    )
    return indptr, indices, data


@partial(jax.jit, static_argnames=("c_pad", "c_max_row_nnz"))
def spgemm(A: CSR, B: CSR, c_pad: int, c_max_row_nnz: int = 0) -> CSR:
    """Numeric phase of C = A x B. ``c_pad`` comes from ``spgemm_symbolic_host``."""
    rows, cols, vals = _expand_products(A, B, 0, A.n_cols)
    indptr, indices, data = _accumulate(rows, cols, vals, A.n_rows, B.n_cols, c_pad)
    return CSR(indptr, indices, data, (A.n_rows, B.n_cols),
               c_max_row_nnz or c_pad)


def spgemm_ranged_impl(A: CSR, B_chunk: CSR, r0, r1, C_prev: CSR, c_pad: int,
                       c_max_row_nnz: int = 0) -> CSR:
    """Fused multiply-add over a B row-range: C = A[:, r0:r1] x B_chunk + C_prev.

    The previous partial result's entries join the product stream before
    accumulation — the paper's fused-add into the hashmap accumulators. A is NOT
    physically column-partitioned; out-of-range entries are masked ("skipped").

    This is the traceable body; ``spgemm_ranged`` is the jitted entry point. The
    scan executors (repro.core.chunk_stream) inline this body inside a
    ``lax.scan`` so the whole chunk loop compiles as one program.
    """
    rows, cols, vals = _expand_products(A, B_chunk, r0, r1)
    prev_entry = jnp.arange(C_prev.nnz_pad, dtype=jnp.int32)
    prev_valid = prev_entry < C_prev.indptr[-1]
    prev_rows = jnp.where(prev_valid, csr_row_of_entry(C_prev), A.n_rows)
    prev_cols = jnp.where(prev_valid, C_prev.indices, 0)
    prev_vals = jnp.where(prev_valid, C_prev.data, 0.0)
    rows = jnp.concatenate([rows, prev_rows])
    cols = jnp.concatenate([cols, prev_cols])
    vals = jnp.concatenate([vals, prev_vals])
    indptr, indices, data = _accumulate(rows, cols, vals, A.n_rows, B_chunk.n_cols, c_pad)
    return CSR(indptr, indices, data, (A.n_rows, B_chunk.n_cols),
               c_max_row_nnz or c_pad)


spgemm_ranged = partial(jax.jit, static_argnames=("c_pad", "c_max_row_nnz"))(
    spgemm_ranged_impl
)


def spgemm_full(A: CSR, B: CSR) -> CSR:
    """Convenience: symbolic + numeric in one call (host symbolic, jitted numeric)."""
    ws = spgemm_symbolic_host(A, B)
    return spgemm(A, B, ws.c_pad, ws.c_max_row_nnz)


# ---------------------------------------------------------------------------
# reference oracle
# ---------------------------------------------------------------------------


def spgemm_dense_oracle(A: CSR, B: CSR) -> jax.Array:
    """Trustworthy dense reference: densify and matmul."""
    from repro.sparse.csr import csr_to_dense

    return csr_to_dense(A) @ csr_to_dense(B)
