"""Random sparse matrix generators (paper Table 2: uniform-degree delta RHS)."""

from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSR, csr_from_coo


def random_uniform_degree(n_rows: int, n_cols: int, delta: int, seed: int = 0,
                          exact: bool = True, pad_to: int | None = None) -> CSR:
    """Each row gets ~delta nonzeros in random columns, values U(0, 1).

    With ``exact=True`` every row has exactly delta *distinct* columns (sampled by
    ranking random keys); otherwise columns are sampled with replacement and
    coalesced (degree <= delta) — cheaper for very wide matrices.
    """
    rng = np.random.default_rng(seed)
    delta = int(min(delta, n_cols))
    if exact and n_cols <= 1 << 20:
        # Rank partial random keys: distinct columns per row.
        if delta * 8 >= n_cols:
            keys = rng.random((n_rows, n_cols))
            cols = np.argpartition(keys, delta - 1, axis=1)[:, :delta]
        else:
            # Oversample + dedup refill (vectorized rejection sampling).
            cols = rng.integers(0, n_cols, (n_rows, delta * 2))
            cols.sort(axis=1)
            dup = np.zeros_like(cols, bool)
            dup[:, 1:] = cols[:, 1:] == cols[:, :-1]
            # Replace duplicates by re-rolls until rows have >= delta distinct.
            for _ in range(8):
                n_dup = int(dup.sum())
                if not n_dup:
                    break
                cols[dup] = rng.integers(0, n_cols, n_dup)
                cols.sort(axis=1)
                dup[:, :] = False
                dup[:, 1:] = cols[:, 1:] == cols[:, :-1]
            keep = ~dup
            # Take the first delta distinct columns of each row.
            rank = np.cumsum(keep, axis=1) - 1
            sel = keep & (rank < delta)
            counts = sel.sum(axis=1)
            if (counts < delta).any():  # extremely unlikely; fall back
                return random_uniform_degree(n_rows, n_cols, delta, seed + 1,
                                             exact=True, pad_to=pad_to)
            rows = np.repeat(np.arange(n_rows), delta)
            cc = cols[sel]
            vals = rng.random(rows.size)
            return csr_from_coo(rows, cc, vals, (n_rows, n_cols), pad_to=pad_to,
                                sum_duplicates=False)
        rows = np.repeat(np.arange(n_rows), delta)
        cols = cols.ravel()
        vals = rng.random(rows.size)
        return csr_from_coo(rows, cols, vals, (n_rows, n_cols), pad_to=pad_to,
                            sum_duplicates=False)
    rows = np.repeat(np.arange(n_rows), delta)
    cols = rng.integers(0, n_cols, rows.size)
    vals = rng.random(rows.size)
    return csr_from_coo(rows, cols, vals, (n_rows, n_cols), pad_to=pad_to)


def random_banded(n: int, bandwidth: int, density: float, seed: int = 0,
                  pad_to: int | None = None) -> CSR:
    """Banded random matrix — high spatial locality workload for locality studies."""
    rng = np.random.default_rng(seed)
    per_row = max(1, int(density * (2 * bandwidth + 1)))
    rows = np.repeat(np.arange(n), per_row)
    offs = rng.integers(-bandwidth, bandwidth + 1, rows.size)
    cols = np.clip(rows + offs, 0, n - 1)
    vals = rng.random(rows.size)
    return csr_from_coo(rows, cols, vals, (n, n), pad_to=pad_to)
