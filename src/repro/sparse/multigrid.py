"""Multigrid problem generators: the paper's four domains at any scale.

The paper evaluates triple products ``A_c = R x A_f x P`` from multigrid setup, with
``P = R^T``. The A matrices are stencil matrices with nnz/row:

  Laplace3D   7   (7-point 3D Laplacian)
  BigStar2D  13   (13-point 2D star stencil)
  Brick3D    27   (27-point 3D brick stencil)
  Elasticity 81   (27-point 3D stencil x 3x3 dof coupling)

``R`` is the short-and-wide geometric restriction (factor-2 coarsening, full-weighting):
rows have strided column patterns and consecutive rows share little structure — exactly
the low-temporal-locality access pattern the paper analyzes for R x A.

All generation is host-side NumPy; outputs are repro.sparse.CSR.
"""

from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSR, csr_from_coo, csr_transpose_host

# ---------------------------------------------------------------------------
# stencil machinery
# ---------------------------------------------------------------------------


def _stencil_coo(grid: tuple, offsets: np.ndarray, weights: np.ndarray):
    """COO for a stencil matrix on a structured grid with truncation at boundaries."""
    grid = tuple(int(g) for g in grid)
    d = len(grid)
    n = int(np.prod(grid))
    coords = np.stack(np.unravel_index(np.arange(n), grid), axis=1)  # [n, d]
    rows_all, cols_all, vals_all = [], [], []
    for off, w in zip(offsets, weights):
        nbr = coords + off[None, :]
        ok = np.ones(n, bool)
        for k in range(d):
            ok &= (nbr[:, k] >= 0) & (nbr[:, k] < grid[k])
        r = np.nonzero(ok)[0]
        c = np.ravel_multi_index(tuple(nbr[ok].T), grid)
        rows_all.append(r)
        cols_all.append(c)
        vals_all.append(np.full(r.size, w))
    return (
        np.concatenate(rows_all),
        np.concatenate(cols_all),
        np.concatenate(vals_all),
        n,
    )


def stencil_matrix(grid: tuple, offsets, weights, dof: int = 1,
                   coupling: np.ndarray | None = None, pad_to: int | None = None) -> CSR:
    """General stencil matrix; with dof>1 each scalar entry becomes a dof x dof block
    (Kronecker with ``coupling``)."""
    offsets = np.asarray(offsets, np.int64)
    weights = np.asarray(weights, np.float64)
    rows, cols, vals, n = _stencil_coo(grid, offsets, weights)
    if dof > 1:
        if coupling is None:
            coupling = np.eye(dof)
        bi, bj = np.nonzero(coupling)
        rows = (rows[:, None] * dof + bi[None, :]).ravel()
        cols = (cols[:, None] * dof + bj[None, :]).ravel()
        vals = (vals[:, None] * coupling[bi, bj][None, :]).ravel()
        n *= dof
    return csr_from_coo(rows, cols, vals, (n, n), pad_to=pad_to)


def _offsets_box(d: int, radius: int = 1) -> np.ndarray:
    """All offsets in {-radius..radius}^d."""
    ax = np.arange(-radius, radius + 1)
    grids = np.meshgrid(*([ax] * d), indexing="ij")
    return np.stack([g.ravel() for g in grids], axis=1)


# ---------------------------------------------------------------------------
# the paper's four A matrices
# ---------------------------------------------------------------------------


def laplace3d(n: int, pad_to: int | None = None) -> CSR:
    """7-point 3D Laplacian on an n^3 grid (nnz/row = 7 in the interior)."""
    offs = [(0, 0, 0)]
    wts = [6.0]
    for k in range(3):
        for s in (-1, 1):
            o = [0, 0, 0]
            o[k] = s
            offs.append(tuple(o))
            wts.append(-1.0)
    return stencil_matrix((n, n, n), offs, wts, pad_to=pad_to)


def bigstar2d(n: int, pad_to: int | None = None) -> CSR:
    """13-point 2D star stencil on an n^2 grid (nnz/row = 13)."""
    offs = [(0, 0)]
    wts = [12.0]
    for k in range(2):
        for s in (-1, 1, -2, 2):
            o = [0, 0]
            o[k] = s
            offs.append(tuple(o))
            wts.append(-1.0 if abs(s) == 1 else -0.5)
    for sx in (-1, 1):
        for sy in (-1, 1):
            offs.append((sx, sy))
            wts.append(-1.0)
    return stencil_matrix((n, n), offs, wts, pad_to=pad_to)


def brick3d(n: int, pad_to: int | None = None) -> CSR:
    """27-point 3D brick stencil on an n^3 grid (nnz/row = 27)."""
    offs = _offsets_box(3, 1)
    dist = np.abs(offs).sum(axis=1)
    wts = np.where(dist == 0, 26.0, -1.0 / np.maximum(dist, 1))
    return stencil_matrix((n, n, n), offs, wts, pad_to=pad_to)


def elasticity3d(n: int, pad_to: int | None = None) -> CSR:
    """3D elasticity-like operator: 27-point stencil x 3 dof/node (nnz/row = 81)."""
    offs = _offsets_box(3, 1)
    dist = np.abs(offs).sum(axis=1)
    wts = np.where(dist == 0, 26.0, -1.0 / np.maximum(dist, 1))
    coupling = np.array(
        [[2.0, 0.3, 0.2],
         [0.3, 2.0, 0.3],
         [0.2, 0.3, 2.0]]
    )
    return stencil_matrix((n, n, n), offs, wts, dof=3, coupling=coupling, pad_to=pad_to)


# ---------------------------------------------------------------------------
# restriction / prolongation
# ---------------------------------------------------------------------------


def restriction(grid: tuple, dof: int = 1, pad_to: int | None = None) -> CSR:
    """Full-weighting restriction R for factor-2 coarsening on a structured grid.

    Coarse node at fine coords 2*c; row weights are the tensor-product of
    (0.5, 1.0, 0.5) over dimensions, truncated at boundaries. Shape (Nc*dof, Nf*dof):
    short and wide, strided columns — the paper's R access pattern.
    """
    grid = tuple(int(g) for g in grid)
    d = len(grid)
    cgrid = tuple((g + 1) // 2 for g in grid)
    nf = int(np.prod(grid))
    nc = int(np.prod(cgrid))
    ccoords = np.stack(np.unravel_index(np.arange(nc), cgrid), axis=1)  # [nc, d]
    offsets = _offsets_box(d, 1)
    w1 = np.array([0.5, 1.0, 0.5])
    rows_all, cols_all, vals_all = [], [], []
    for off in offsets:
        w = float(np.prod(w1[off + 1]))
        fine = ccoords * 2 + off[None, :]
        ok = np.ones(nc, bool)
        for k in range(d):
            ok &= (fine[:, k] >= 0) & (fine[:, k] < grid[k])
        r = np.nonzero(ok)[0]
        c = np.ravel_multi_index(tuple(fine[ok].T), grid)
        rows_all.append(r)
        cols_all.append(c)
        vals_all.append(np.full(r.size, w))
    rows = np.concatenate(rows_all)
    cols = np.concatenate(cols_all)
    vals = np.concatenate(vals_all)
    if dof > 1:
        k = np.arange(dof)
        rows = (rows[:, None] * dof + k[None, :]).ravel()
        cols = (cols[:, None] * dof + k[None, :]).ravel()
        vals = np.repeat(vals, dof)
        nc *= dof
        nf *= dof
    return csr_from_coo(rows, cols, vals, (nc, nf), pad_to=pad_to)


# ---------------------------------------------------------------------------
# problem registry: name -> (A, R, P) factory
# ---------------------------------------------------------------------------

PROBLEMS = ("laplace3d", "bigstar2d", "brick3d", "elasticity")


def problem(name: str, n: int, pad_to: int | None = None):
    """Return (A, R, P) for one of the paper's four problems at grid size n.

    P = R^T (the paper: "P is transpose of R in our examples").
    ``pad_to`` is forwarded to every generator so callers building
    envelope-aligned triple products (``R x A x P`` through one
    :class:`~repro.sparse.csr.GeometryEnvelope`) get nnz storage padded to
    a shared multiple.
    """
    name = name.lower()
    if name == "laplace3d":
        A = laplace3d(n, pad_to=pad_to)
        R = restriction((n, n, n), pad_to=pad_to)
    elif name == "bigstar2d":
        A = bigstar2d(n, pad_to=pad_to)
        R = restriction((n, n), pad_to=pad_to)
    elif name == "brick3d":
        A = brick3d(n, pad_to=pad_to)
        R = restriction((n, n, n), pad_to=pad_to)
    elif name == "elasticity":
        A = elasticity3d(n, pad_to=pad_to)
        R = restriction((n, n, n), dof=3, pad_to=pad_to)
    else:
        raise ValueError(f"unknown problem {name!r}; choose from {PROBLEMS}")
    P = csr_transpose_host(R)
    # the P = R^T contract is load-bearing for the fused pipeline's composed
    # symbolic phase (hop-1 caps are computed on (A, P)); pin it bitwise so a
    # future generator change can't silently break it
    check = csr_transpose_host(R)
    assert (np.array_equal(np.asarray(P.indptr), np.asarray(check.indptr))
            and np.array_equal(np.asarray(P.indices), np.asarray(check.indices))
            and np.array_equal(np.asarray(P.data), np.asarray(check.data))), \
        "P must be bitwise csr_transpose_host(R)"
    return A, R, P
