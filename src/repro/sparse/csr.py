"""Static-shape padded CSR container (a JAX pytree) + conversions.

Conventions (chosen so every array has a *static* shape — a JAX requirement):
  * ``indptr``  : int32[n_rows + 1]  -- standard CSR row pointers. ``indptr[-1]`` is the
                  true nnz; entries past it in ``indices``/``data`` are padding.
  * ``indices`` : int32[nnz_pad]     -- column index per entry; padding entries are 0.
  * ``data``    : dtype[nnz_pad]     -- value per entry; padding entries are 0.0.
  * rows are contiguous (no per-row padding); all padding lives in the tail.
  * ``shape``, ``max_row_nnz`` are static metadata (pytree aux), so jit retraces only
    when the padded geometry changes, never per-value.

``max_row_nnz`` upper-bounds the densest row and sizes the per-row expansion buffers in
the KKMEM numeric phase (repro.core.kkmem).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("indptr", "indices", "data"),
    meta_fields=("shape", "max_row_nnz"),
)
@dataclasses.dataclass(frozen=True)
class CSR:
    """Padded compressed-sparse-row matrix."""

    indptr: jax.Array   # int32[n_rows + 1]
    indices: jax.Array  # int32[nnz_pad]
    data: jax.Array     # dtype[nnz_pad]
    shape: tuple        # (n_rows, n_cols), static
    max_row_nnz: int    # static upper bound on nnz of any row

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_pad(self) -> int:
        """Padded capacity (static)."""
        return self.indices.shape[0]

    def nnz(self):
        """True nnz (traced value under jit; concrete int outside)."""
        return self.indptr[-1]

    @property
    def dtype(self):
        return self.data.dtype

    def nbytes(self) -> int:
        """Padded byte footprint — what a memory level must actually hold."""
        return (
            self.indptr.size * self.indptr.dtype.itemsize
            + self.indices.size * self.indices.dtype.itemsize
            + self.data.size * self.data.dtype.itemsize
        )

    def row_lengths(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def astype(self, dtype) -> "CSR":
        return CSR(self.indptr, self.indices, self.data.astype(dtype), self.shape, self.max_row_nnz)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"CSR(shape={self.shape}, nnz_pad={self.nnz_pad}, "
            f"max_row_nnz={self.max_row_nnz}, dtype={self.dtype})"
        )


def csr_from_scipy_like(indptr, indices, data, shape, pad_to: int | None = None,
                        dtype=jnp.float32) -> CSR:
    """Build a CSR from host arrays (NumPy), padding the tail to ``pad_to``."""
    indptr = np.asarray(indptr, dtype=np.int32)
    indices = np.asarray(indices, dtype=np.int32)
    data = np.asarray(data)
    nnz = int(indptr[-1])
    cap = int(pad_to) if pad_to is not None else nnz
    if cap < nnz:
        raise ValueError(f"pad_to={cap} < nnz={nnz}")
    cap = max(cap, 1)   # zero-capacity arrays break XLA gathers downstream
    pad = cap - nnz
    if pad:
        indices = np.concatenate([indices[:nnz], np.zeros(pad, np.int32)])
        data = np.concatenate([data[:nnz], np.zeros(pad, data.dtype)])
    else:
        indices, data = indices[:nnz], data[:nnz]
    row_len = indptr[1:] - indptr[:-1]
    max_row = int(row_len.max()) if len(row_len) else 0
    return CSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(indices),
        data=jnp.asarray(data, dtype=dtype),
        shape=(int(shape[0]), int(shape[1])),
        max_row_nnz=max_row,
    )


def csr_from_coo(rows, cols, vals, shape, pad_to: int | None = None, dtype=jnp.float32,
                 sum_duplicates: bool = True) -> CSR:
    """Host-side COO -> CSR (sorts by (row, col), optionally coalescing duplicates)."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    n_rows, n_cols = int(shape[0]), int(shape[1])
    key = rows * n_cols + cols
    order = np.argsort(key, kind="stable")
    key, vals = key[order], vals[order]
    if sum_duplicates and key.size:
        uniq, inv = np.unique(key, return_inverse=True)
        acc = np.zeros(uniq.size, np.float64)
        np.add.at(acc, inv, vals)
        key, vals = uniq, acc
    out_rows = key // n_cols
    out_cols = key % n_cols
    indptr = np.zeros(n_rows + 1, np.int64)
    np.add.at(indptr, out_rows + 1, 1)
    indptr = np.cumsum(indptr)
    return csr_from_scipy_like(indptr, out_cols, vals, (n_rows, n_cols), pad_to, dtype)


def csr_from_dense(dense, pad_to: int | None = None) -> CSR:
    """Host-side dense -> CSR."""
    dense = np.asarray(dense)
    rows, cols = np.nonzero(dense)
    return csr_from_coo(rows, cols, dense[rows, cols], dense.shape, pad_to,
                        dtype=jnp.asarray(dense).dtype, sum_duplicates=False)


def csr_to_dense(m: CSR) -> jax.Array:
    """JAX-traceable densify (scatter-add; padding entries carry data==0 so they only
    ever add zero into column 0)."""
    n_rows, n_cols = m.shape
    entry = jnp.arange(m.nnz_pad, dtype=jnp.int32)
    row = jnp.searchsorted(m.indptr, entry, side="right") - 1
    row = jnp.clip(row, 0, n_rows - 1)
    dense = jnp.zeros((n_rows, n_cols), m.dtype)
    return dense.at[row, m.indices].add(m.data)


def csr_row_of_entry(m: CSR) -> jax.Array:
    """Row id of every padded entry (padding maps to the last row; its data is 0)."""
    entry = jnp.arange(m.nnz_pad, dtype=jnp.int32)
    row = jnp.searchsorted(m.indptr, entry, side="right") - 1
    return jnp.clip(row, 0, m.n_rows - 1).astype(jnp.int32)


def csr_select_rows_host(m: CSR, r0: int, r1: int, pad_to: int | None = None) -> CSR:
    """Host-side row slice m[r0:r1, :] as a new CSR (used by chunk planners/tests)."""
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    data = np.asarray(m.data)
    s, e = int(indptr[r0]), int(indptr[r1])
    new_ptr = indptr[r0 : r1 + 1] - s
    return csr_from_scipy_like(new_ptr, indices[s:e], data[s:e], (r1 - r0, m.shape[1]),
                               pad_to, dtype=m.dtype)


def _union_bsr_caps(a: tuple, b: tuple) -> tuple:
    """Elementwise max of two block-cap tuples. Mixing a block-capped
    envelope with an uncapped one (or two different block sizes) is a caller
    bug — the union would either silently drop the caps or silently change
    the block geometry — so both fail loudly."""
    if not a and not b:
        return ()
    if not a or not b:
        raise ValueError(
            "cannot union a block-capped envelope with an uncapped one; "
            "build every instance envelope with the same block_size")
    if a[0] != b[0]:
        raise ValueError(f"block_size mismatch in envelope union: {a[0]} vs {b[0]}")
    return (a[0], *(max(x, y) for x, y in zip(a[1:], b[1:])))


@dataclasses.dataclass(frozen=True)
class GeometryEnvelope:
    """Padded geometry that a chunked-SpGEMM executable is compiled for.

    Every field is host-static, so the envelope doubles as a hashable compile
    key: two (A, B) instances with the same envelope (and plan) run through the
    same jitted scan without retracing. ``union`` over a batch of per-instance
    envelopes yields the smallest geometry that fits them all — the fix for
    heterogeneous-structure batches, where per-instance padding caps used to
    make ``csr_stack`` reject the batch.

    ``chunk_rows``/``strip_rows`` derive from the plan's row partitions (shared
    across a batch by construction); the nnz caps and ``max_row_nnz`` bounds
    are per-instance quantities that the envelope maxes over the batch.

    The *output-cap* fields (``c_nnz_cap``, ``c_max_row_nnz``) come from the
    symbolic phase (``repro.core.symbolic``): they bound the structure of C
    itself, which is what the sparse-output backend sizes its fixed-capacity
    CSR accumulator scratch with (``c_pad`` is the per-strip capacity that
    scratch is allocated to). A value of 0 means "not computed" (envelopes
    predating the symbolic fold-in); the algebra below absorbs 0 into any
    computed value under union and preserves it under quantization, so legacy
    envelopes stay valid compile keys.
    """

    a_shape: tuple      # (m, k) of every A instance
    b_shape: tuple      # (k, n) of every B instance
    a_nnz_cap: int      # padded nnz capacity of the whole-A operand (KNL)
    a_max_row_nnz: int  # bound on any A row (sizes nothing directly; meta)
    b_max_row_nnz: int  # bound on any B row (sizes the expansion buffer)
    chunk_rows: int     # rows every staged B chunk is padded to
    chunk_nnz_cap: int  # nnz capacity every staged B chunk is padded to
    strip_rows: int     # rows every staged A/C strip is padded to
    strip_nnz_cap: int  # nnz capacity every staged A strip is padded to
    c_pad: int          # output capacity (>= exact symbolic nnz of any C strip)
    dtype: str          # value dtype name ("float32", ...)
    c_nnz_cap: int = 0      # whole-C structure capacity (symbolic; 0 = unset)
    c_max_row_nnz: int = 0  # densest C row bound (symbolic; 0 = unset)
    # Block-geometry caps for block-structured (BSR) backends, as the tuple
    # (block_size, nbl_a_cap, nbl_b_cap, nc_cap, u_cap) from
    # ``repro.core.symbolic.bsr_plan_caps`` — already quantized there, so the
    # tuple IS the backend's compile key. ``()`` = not computed: block
    # analysis is opt-in (costs a host pass), and an uncapped envelope prices
    # block backends at infinity in the planner, excluding them from ``auto``.
    bsr_caps: tuple = ()

    def _check_compatible(self, other: "GeometryEnvelope") -> None:
        if (self.a_shape != other.a_shape or self.b_shape != other.b_shape
                or self.dtype != other.dtype):
            raise ValueError(
                "incompatible envelopes: "
                f"{self.a_shape}x{self.b_shape}/{self.dtype} vs "
                f"{other.a_shape}x{other.b_shape}/{other.dtype}"
            )

    def union(self, other: "GeometryEnvelope") -> "GeometryEnvelope":
        """Smallest envelope covering both (same shapes/dtype required)."""
        self._check_compatible(other)
        return GeometryEnvelope(
            a_shape=self.a_shape, b_shape=self.b_shape,
            a_nnz_cap=max(self.a_nnz_cap, other.a_nnz_cap),
            a_max_row_nnz=max(self.a_max_row_nnz, other.a_max_row_nnz),
            b_max_row_nnz=max(self.b_max_row_nnz, other.b_max_row_nnz),
            chunk_rows=max(self.chunk_rows, other.chunk_rows),
            chunk_nnz_cap=max(self.chunk_nnz_cap, other.chunk_nnz_cap),
            strip_rows=max(self.strip_rows, other.strip_rows),
            strip_nnz_cap=max(self.strip_nnz_cap, other.strip_nnz_cap),
            c_pad=max(self.c_pad, other.c_pad),
            dtype=self.dtype,
            c_nnz_cap=max(self.c_nnz_cap, other.c_nnz_cap),
            c_max_row_nnz=max(self.c_max_row_nnz, other.c_max_row_nnz),
            bsr_caps=_union_bsr_caps(self.bsr_caps, other.bsr_caps),
        )

    def dominates(self, other: "GeometryEnvelope") -> bool:
        """True when instances fitting ``other`` also fit this envelope."""
        try:
            self._check_compatible(other)
        except ValueError:
            return False
        return (self.a_nnz_cap >= other.a_nnz_cap
                and self.a_max_row_nnz >= other.a_max_row_nnz
                and self.b_max_row_nnz >= other.b_max_row_nnz
                and self.chunk_rows >= other.chunk_rows
                and self.chunk_nnz_cap >= other.chunk_nnz_cap
                and self.strip_rows >= other.strip_rows
                and self.strip_nnz_cap >= other.strip_nnz_cap
                and self.c_pad >= other.c_pad
                and self.c_nnz_cap >= other.c_nnz_cap
                and self.c_max_row_nnz >= other.c_max_row_nnz
                and self._dominates_bsr_caps(other))

    def _dominates_bsr_caps(self, other: "GeometryEnvelope") -> bool:
        # An uncapped request fits any envelope (block caps only matter to
        # block backends, which demand a capped envelope at dispatch); a
        # capped request needs same-block-size caps at least as large.
        if not other.bsr_caps:
            return True
        if not self.bsr_caps or self.bsr_caps[0] != other.bsr_caps[0]:
            return False
        return all(s >= o for s, o in zip(self.bsr_caps[1:], other.bsr_caps[1:]))

    def quantized(self, quantum: int = 32) -> "GeometryEnvelope":
        """Round the nnz caps up to ``quantum`` multiples and the row-nnz
        bounds up to powers of two, collapsing near-identical geometries into
        one bucket (fewer compiles, bounded padding waste)."""

        def up(v: int) -> int:
            return max(quantum, -(-int(v) // quantum) * quantum)

        def up_pow2(v: int) -> int:
            return 1 << max(int(v) - 1, 0).bit_length() if v > 1 else max(v, 1)

        return GeometryEnvelope(
            a_shape=self.a_shape, b_shape=self.b_shape,
            a_nnz_cap=up(self.a_nnz_cap),
            a_max_row_nnz=up_pow2(self.a_max_row_nnz),
            b_max_row_nnz=up_pow2(self.b_max_row_nnz),
            chunk_rows=self.chunk_rows,
            chunk_nnz_cap=up(self.chunk_nnz_cap),
            strip_rows=self.strip_rows,
            strip_nnz_cap=up(self.strip_nnz_cap),
            c_pad=up(self.c_pad),
            dtype=self.dtype,
            c_nnz_cap=up(self.c_nnz_cap) if self.c_nnz_cap else 0,
            c_max_row_nnz=(up_pow2(self.c_max_row_nnz)
                           if self.c_max_row_nnz else 0),
            # block caps arrive pre-quantized from the block symbolic phase
            # (their own block-count quantum, not the nnz quantum)
            bsr_caps=self.bsr_caps,
        )

    def staged_nbytes(self) -> int:
        """Bytes one instance's staged buffers occupy when padded to this
        envelope: the whole-A operand, one A strip, one B chunk, and the C
        output capacity, each as (indices + data) entries plus an int32
        indptr. A comparison measure for "how much padding does serving this
        request out of that envelope cost" — larger envelopes always score
        strictly higher, which is all the tightest-dominator argmin needs."""
        itemsize = int(np.dtype(self.dtype).itemsize)
        entry = 4 + itemsize          # int32 index + one value per nnz slot
        return int(
            self.a_nnz_cap * entry
            + self.strip_nnz_cap * entry + (self.strip_rows + 1) * 4
            + self.chunk_nnz_cap * entry + (self.chunk_rows + 1) * 4
            + self.c_pad * entry
        )

    @classmethod
    def batch(cls, envelopes) -> "GeometryEnvelope":
        """Union over per-instance envelopes (the batch's shared geometry)."""
        envelopes = list(envelopes)
        if not envelopes:
            raise ValueError("GeometryEnvelope.batch needs at least one envelope")
        out = envelopes[0]
        for env in envelopes[1:]:
            out = out.union(env)
        return out


def csr_pad_to(m: CSR, nnz_cap: int | None = None, rows: int | None = None,
               max_row_nnz: int | None = None) -> CSR:
    """Repad a CSR to a larger static geometry: grow the entry tail to
    ``nnz_cap``, append empty rows up to ``rows``, and/or raise the
    ``max_row_nnz`` bound. Growing only — shrinking the capacities would need
    the true nnz (a traced value under jit), and lowering ``max_row_nnz``
    below the actual densest row would silently truncate the SpGEMM expansion
    buffer downstream, so an undersized target (e.g. a stale envelope applied
    to a denser batch) fails loudly here instead."""
    nnz_cap = m.nnz_pad if nnz_cap is None else int(nnz_cap)
    rows = m.n_rows if rows is None else int(rows)
    mrn = m.max_row_nnz if max_row_nnz is None else int(max_row_nnz)
    if nnz_cap < m.nnz_pad or rows < m.n_rows or mrn < m.max_row_nnz:
        raise ValueError(
            f"csr_pad_to only grows: nnz_cap={nnz_cap} rows={rows} "
            f"max_row_nnz={mrn} vs nnz_pad={m.nnz_pad} n_rows={m.n_rows} "
            f"max_row_nnz={m.max_row_nnz}"
        )
    indptr, indices, data = m.indptr, m.indices, m.data
    if rows > m.n_rows:
        indptr = jnp.concatenate(
            [indptr, jnp.full(rows - m.n_rows, indptr[-1], jnp.int32)]
        )
    if nnz_cap > m.nnz_pad:
        indices = jnp.concatenate(
            [indices, jnp.zeros(nnz_cap - m.nnz_pad, jnp.int32)]
        )
        data = jnp.concatenate([data, jnp.zeros(nnz_cap - m.nnz_pad, m.dtype)])
    return CSR(indptr, indices, data, (rows, m.shape[1]), mrn)


def csr_stack(mats) -> CSR:
    """Stack uniformly-padded CSRs along a new leading axis (host-side).

    The result reuses the ``CSR`` container: every array field gains a leading
    ``len(mats)`` axis while ``shape``/``max_row_nnz`` keep the *per-element*
    geometry. That makes the stack directly usable as ``lax.scan`` xs (or
    ``vmap`` operands): slicing the leading axis of each field yields a valid
    per-chunk ``CSR`` with identical static metadata, so the scan body traces
    once. Element-wise host accessors (``nnz_pad`` etc.) are meaningless on the
    stacked object — unstack first.

    All inputs must share shape, indptr length, nnz capacity and
    ``max_row_nnz`` (what the chunkers' uniform padding guarantees).
    """
    mats = list(mats)
    if not mats:
        raise ValueError("csr_stack needs at least one matrix")
    first = mats[0]
    for m in mats[1:]:
        if (m.shape != first.shape or m.indptr.shape != first.indptr.shape
                or m.indices.shape != first.indices.shape
                or m.max_row_nnz != first.max_row_nnz
                or m.dtype != first.dtype):
            raise ValueError(
                "csr_stack requires uniform padded geometry: "
                f"{m!r} vs {first!r}"
            )
    return CSR(
        indptr=jnp.stack([m.indptr for m in mats]),
        indices=jnp.stack([m.indices for m in mats]),
        data=jnp.stack([m.data for m in mats]),
        shape=first.shape,
        max_row_nnz=first.max_row_nnz,
    )


def csr_unstack(stacked: CSR) -> list:
    """Inverse of ``csr_stack``: split the leading axis back into CSRs."""
    n = stacked.indptr.shape[0]
    return [
        CSR(stacked.indptr[i], stacked.indices[i], stacked.data[i],
            stacked.shape, stacked.max_row_nnz)
        for i in range(n)
    ]


def csr_transpose_host(m: CSR, pad_to: int | None = None) -> CSR:
    """Host-side transpose (multigrid P = R^T)."""
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    data = np.asarray(m.data)
    nnz = int(indptr[-1])
    rows = np.repeat(np.arange(m.n_rows), indptr[1:] - indptr[:-1])
    return csr_from_coo(indices[:nnz], rows, data[:nnz], (m.shape[1], m.shape[0]),
                        pad_to, dtype=m.dtype, sum_duplicates=False)
