"""Static-shape padded CSR container (a JAX pytree) + conversions.

Conventions (chosen so every array has a *static* shape — a JAX requirement):
  * ``indptr``  : int32[n_rows + 1]  -- standard CSR row pointers. ``indptr[-1]`` is the
                  true nnz; entries past it in ``indices``/``data`` are padding.
  * ``indices`` : int32[nnz_pad]     -- column index per entry; padding entries are 0.
  * ``data``    : dtype[nnz_pad]     -- value per entry; padding entries are 0.0.
  * rows are contiguous (no per-row padding); all padding lives in the tail.
  * ``shape``, ``max_row_nnz`` are static metadata (pytree aux), so jit retraces only
    when the padded geometry changes, never per-value.

``max_row_nnz`` upper-bounds the densest row and sizes the per-row expansion buffers in
the KKMEM numeric phase (repro.core.kkmem).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("indptr", "indices", "data"),
    meta_fields=("shape", "max_row_nnz"),
)
@dataclasses.dataclass(frozen=True)
class CSR:
    """Padded compressed-sparse-row matrix."""

    indptr: jax.Array   # int32[n_rows + 1]
    indices: jax.Array  # int32[nnz_pad]
    data: jax.Array     # dtype[nnz_pad]
    shape: tuple        # (n_rows, n_cols), static
    max_row_nnz: int    # static upper bound on nnz of any row

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_pad(self) -> int:
        """Padded capacity (static)."""
        return self.indices.shape[0]

    def nnz(self):
        """True nnz (traced value under jit; concrete int outside)."""
        return self.indptr[-1]

    @property
    def dtype(self):
        return self.data.dtype

    def nbytes(self) -> int:
        """Padded byte footprint — what a memory level must actually hold."""
        return (
            self.indptr.size * self.indptr.dtype.itemsize
            + self.indices.size * self.indices.dtype.itemsize
            + self.data.size * self.data.dtype.itemsize
        )

    def row_lengths(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def astype(self, dtype) -> "CSR":
        return CSR(self.indptr, self.indices, self.data.astype(dtype), self.shape, self.max_row_nnz)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"CSR(shape={self.shape}, nnz_pad={self.nnz_pad}, "
            f"max_row_nnz={self.max_row_nnz}, dtype={self.dtype})"
        )


def csr_from_scipy_like(indptr, indices, data, shape, pad_to: int | None = None,
                        dtype=jnp.float32) -> CSR:
    """Build a CSR from host arrays (NumPy), padding the tail to ``pad_to``."""
    indptr = np.asarray(indptr, dtype=np.int32)
    indices = np.asarray(indices, dtype=np.int32)
    data = np.asarray(data)
    nnz = int(indptr[-1])
    cap = int(pad_to) if pad_to is not None else nnz
    if cap < nnz:
        raise ValueError(f"pad_to={cap} < nnz={nnz}")
    cap = max(cap, 1)   # zero-capacity arrays break XLA gathers downstream
    pad = cap - nnz
    if pad:
        indices = np.concatenate([indices[:nnz], np.zeros(pad, np.int32)])
        data = np.concatenate([data[:nnz], np.zeros(pad, data.dtype)])
    else:
        indices, data = indices[:nnz], data[:nnz]
    row_len = indptr[1:] - indptr[:-1]
    max_row = int(row_len.max()) if len(row_len) else 0
    return CSR(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(indices),
        data=jnp.asarray(data, dtype=dtype),
        shape=(int(shape[0]), int(shape[1])),
        max_row_nnz=max_row,
    )


def csr_from_coo(rows, cols, vals, shape, pad_to: int | None = None, dtype=jnp.float32,
                 sum_duplicates: bool = True) -> CSR:
    """Host-side COO -> CSR (sorts by (row, col), optionally coalescing duplicates)."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float64)
    n_rows, n_cols = int(shape[0]), int(shape[1])
    key = rows * n_cols + cols
    order = np.argsort(key, kind="stable")
    key, vals = key[order], vals[order]
    if sum_duplicates and key.size:
        uniq, inv = np.unique(key, return_inverse=True)
        acc = np.zeros(uniq.size, np.float64)
        np.add.at(acc, inv, vals)
        key, vals = uniq, acc
    out_rows = key // n_cols
    out_cols = key % n_cols
    indptr = np.zeros(n_rows + 1, np.int64)
    np.add.at(indptr, out_rows + 1, 1)
    indptr = np.cumsum(indptr)
    return csr_from_scipy_like(indptr, out_cols, vals, (n_rows, n_cols), pad_to, dtype)


def csr_from_dense(dense, pad_to: int | None = None) -> CSR:
    """Host-side dense -> CSR."""
    dense = np.asarray(dense)
    rows, cols = np.nonzero(dense)
    return csr_from_coo(rows, cols, dense[rows, cols], dense.shape, pad_to,
                        dtype=jnp.asarray(dense).dtype, sum_duplicates=False)


def csr_to_dense(m: CSR) -> jax.Array:
    """JAX-traceable densify (scatter-add; padding entries carry data==0 so they only
    ever add zero into column 0)."""
    n_rows, n_cols = m.shape
    entry = jnp.arange(m.nnz_pad, dtype=jnp.int32)
    row = jnp.searchsorted(m.indptr, entry, side="right") - 1
    row = jnp.clip(row, 0, n_rows - 1)
    dense = jnp.zeros((n_rows, n_cols), m.dtype)
    return dense.at[row, m.indices].add(m.data)


def csr_row_of_entry(m: CSR) -> jax.Array:
    """Row id of every padded entry (padding maps to the last row; its data is 0)."""
    entry = jnp.arange(m.nnz_pad, dtype=jnp.int32)
    row = jnp.searchsorted(m.indptr, entry, side="right") - 1
    return jnp.clip(row, 0, m.n_rows - 1).astype(jnp.int32)


def csr_select_rows_host(m: CSR, r0: int, r1: int, pad_to: int | None = None) -> CSR:
    """Host-side row slice m[r0:r1, :] as a new CSR (used by chunk planners/tests)."""
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    data = np.asarray(m.data)
    s, e = int(indptr[r0]), int(indptr[r1])
    new_ptr = indptr[r0 : r1 + 1] - s
    return csr_from_scipy_like(new_ptr, indices[s:e], data[s:e], (r1 - r0, m.shape[1]),
                               pad_to, dtype=m.dtype)


def csr_stack(mats) -> CSR:
    """Stack uniformly-padded CSRs along a new leading axis (host-side).

    The result reuses the ``CSR`` container: every array field gains a leading
    ``len(mats)`` axis while ``shape``/``max_row_nnz`` keep the *per-element*
    geometry. That makes the stack directly usable as ``lax.scan`` xs (or
    ``vmap`` operands): slicing the leading axis of each field yields a valid
    per-chunk ``CSR`` with identical static metadata, so the scan body traces
    once. Element-wise host accessors (``nnz_pad`` etc.) are meaningless on the
    stacked object — unstack first.

    All inputs must share shape, indptr length, nnz capacity and
    ``max_row_nnz`` (what the chunkers' uniform padding guarantees).
    """
    mats = list(mats)
    if not mats:
        raise ValueError("csr_stack needs at least one matrix")
    first = mats[0]
    for m in mats[1:]:
        if (m.shape != first.shape or m.indptr.shape != first.indptr.shape
                or m.indices.shape != first.indices.shape
                or m.max_row_nnz != first.max_row_nnz
                or m.dtype != first.dtype):
            raise ValueError(
                "csr_stack requires uniform padded geometry: "
                f"{m!r} vs {first!r}"
            )
    return CSR(
        indptr=jnp.stack([m.indptr for m in mats]),
        indices=jnp.stack([m.indices for m in mats]),
        data=jnp.stack([m.data for m in mats]),
        shape=first.shape,
        max_row_nnz=first.max_row_nnz,
    )


def csr_unstack(stacked: CSR) -> list:
    """Inverse of ``csr_stack``: split the leading axis back into CSRs."""
    n = stacked.indptr.shape[0]
    return [
        CSR(stacked.indptr[i], stacked.indices[i], stacked.data[i],
            stacked.shape, stacked.max_row_nnz)
        for i in range(n)
    ]


def csr_transpose_host(m: CSR, pad_to: int | None = None) -> CSR:
    """Host-side transpose (multigrid P = R^T)."""
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    data = np.asarray(m.data)
    nnz = int(indptr[-1])
    rows = np.repeat(np.arange(m.n_rows), indptr[1:] - indptr[:-1])
    return csr_from_coo(indices[:nnz], rows, data[:nnz], (m.shape[1], m.shape[0]),
                        pad_to, dtype=m.dtype, sum_duplicates=False)
