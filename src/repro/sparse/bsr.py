"""Static-shape padded BSR (block CSR) container.

This is the TPU-native sparse format: sparsity at *block* granularity so each nonzero
block is a dense ``bs x bs`` tile that feeds the MXU directly. The paper's KKMEM exploits
entry-level sparsity with hashmap accumulators; on TPU the idiomatic equivalent keeps
the two-phase structure but works on 128-aligned blocks (see DESIGN.md §2).

Layout mirrors CSR: ``block_indptr`` (exact, per block-row), ``block_indices`` /
``blocks`` padded in the tail. Padding blocks are all-zero with block-column 0.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import CSR


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("block_indptr", "block_indices", "blocks"),
    meta_fields=("shape", "block_size", "max_row_blocks"),
)
@dataclasses.dataclass(frozen=True)
class BSR:
    """Padded block-sparse-row matrix with square ``block_size`` blocks."""

    block_indptr: jax.Array   # int32[mb + 1]
    block_indices: jax.Array  # int32[nbl_pad]
    blocks: jax.Array         # dtype[nbl_pad, bs, bs]
    shape: tuple              # (n_rows, n_cols) in *elements*, static
    block_size: int
    max_row_blocks: int       # static upper bound on blocks in any block-row

    @property
    def mb(self) -> int:
        """Number of block rows."""
        return self.shape[0] // self.block_size

    @property
    def nb(self) -> int:
        """Number of block columns."""
        return self.shape[1] // self.block_size

    @property
    def nbl_pad(self) -> int:
        return self.block_indices.shape[0]

    def n_blocks(self):
        return self.block_indptr[-1]

    @property
    def dtype(self):
        return self.blocks.dtype

    def nbytes(self) -> int:
        return (
            self.block_indptr.size * self.block_indptr.dtype.itemsize
            + self.block_indices.size * self.block_indices.dtype.itemsize
            + self.blocks.size * self.blocks.dtype.itemsize
        )

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"BSR(shape={self.shape}, bs={self.block_size}, nbl_pad={self.nbl_pad}, "
            f"dtype={self.dtype})"
        )


def bsr_from_dense(dense, block_size: int, pad_to: int | None = None,
                   keep_zero_blocks: bool = False) -> BSR:
    """Host-side dense -> BSR. A block is kept iff it has any nonzero (or all, if
    ``keep_zero_blocks``)."""
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    bs = int(block_size)
    if n_rows % bs or n_cols % bs:
        raise ValueError(f"shape {dense.shape} not divisible by block_size {bs}")
    mb, nb = n_rows // bs, n_cols // bs
    tiles = dense.reshape(mb, bs, nb, bs).transpose(0, 2, 1, 3)  # [mb, nb, bs, bs]
    mask = np.ones((mb, nb), bool) if keep_zero_blocks else (tiles != 0).any(axis=(2, 3))
    bi, bj = np.nonzero(mask)
    order = np.lexsort((bj, bi))
    bi, bj = bi[order], bj[order]
    nbl = bi.size
    cap = int(pad_to) if pad_to is not None else max(nbl, 1)
    if cap < nbl:
        raise ValueError(f"pad_to={cap} < n_blocks={nbl}")
    indptr = np.zeros(mb + 1, np.int64)
    np.add.at(indptr, bi + 1, 1)
    indptr = np.cumsum(indptr)
    blocks = np.zeros((cap, bs, bs), dense.dtype)
    blocks[:nbl] = tiles[bi, bj]
    indices = np.zeros(cap, np.int32)
    indices[:nbl] = bj
    row_blocks = indptr[1:] - indptr[:-1]
    return BSR(
        block_indptr=jnp.asarray(indptr, jnp.int32),
        block_indices=jnp.asarray(indices),
        blocks=jnp.asarray(blocks),
        shape=(n_rows, n_cols),
        block_size=bs,
        max_row_blocks=int(row_blocks.max()) if mb else 0,
    )


def bsr_blocks_with_sentinel(m: BSR) -> jax.Array:
    """Blocks array with the zero-sentinel block appended at index ``nbl_pad``.

    The BSR SpGEMM kernel's padding slots all point at ``nbl_pad``
    (``kernels.bsr_spgemm.bsr_spgemm_symbolic``), so slot ``nbl_pad`` being
    all-zero is what makes padding grid steps MAC nothing. This helper is the
    one place the sentinel is appended, and it *verifies* the container
    contract on the way: the padding tail (``blocks[n_blocks:]``) must be
    all-zero, because a conversion that left garbage there would hand any
    mis-aimed slot a nonzero tile and corrupt C silently instead of loudly.
    """
    blocks = np.asarray(m.blocks)
    nbl = int(np.asarray(m.block_indptr)[-1])
    if blocks[nbl:].any():
        raise ValueError(
            f"BSR padding tail (blocks {nbl}..{blocks.shape[0]}) contains "
            "nonzeros; the kernel's zero-sentinel contract requires padding "
            "blocks to be all-zero"
        )
    zero = np.zeros((1,) + blocks.shape[1:], blocks.dtype)
    return jnp.asarray(np.concatenate([blocks, zero]))


def bsr_to_dense(m: BSR) -> jax.Array:
    """JAX-traceable densify via scatter-add of blocks."""
    bs, mb, nb = m.block_size, m.mb, m.nb
    entry = jnp.arange(m.nbl_pad, dtype=jnp.int32)
    brow = jnp.searchsorted(m.block_indptr, entry, side="right") - 1
    brow = jnp.clip(brow, 0, mb - 1)
    tiles = jnp.zeros((mb, nb, bs, bs), m.dtype)
    tiles = tiles.at[brow, m.block_indices].add(m.blocks)
    return tiles.transpose(0, 2, 1, 3).reshape(m.shape)


def bsr_from_csr(m: CSR, block_size: int, pad_to: int | None = None) -> BSR:
    """Host-side CSR -> BSR (pads the element shape up to a block multiple)."""
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    data = np.asarray(m.data)
    nnz = int(indptr[-1])
    bs = int(block_size)
    n_rows = -(-m.shape[0] // bs) * bs
    n_cols = -(-m.shape[1] // bs) * bs
    rows = np.repeat(np.arange(m.shape[0]), indptr[1:] - indptr[:-1])
    cols = indices[:nnz]
    vals = data[:nnz]
    mb, nb = n_rows // bs, n_cols // bs
    bi, bj = rows // bs, cols // bs
    bkey = bi * nb + bj
    order = np.argsort(bkey, kind="stable")
    bkey_s = bkey[order]
    uniq, inv_start = np.unique(bkey_s, return_index=True)
    nbl = uniq.size
    cap = int(pad_to) if pad_to is not None else max(nbl, 1)
    if cap < nbl:
        raise ValueError(f"pad_to={cap} < n_blocks={nbl}")
    blocks = np.zeros((cap, bs, bs), vals.dtype)
    # dense index of each entry's block among the unique sorted blocks
    entry_block = np.searchsorted(uniq, bkey)
    np.add.at(blocks, (entry_block, rows % bs, cols % bs), vals)
    ubi, ubj = uniq // nb, uniq % nb
    indptr_b = np.zeros(mb + 1, np.int64)
    np.add.at(indptr_b, ubi + 1, 1)
    indptr_b = np.cumsum(indptr_b)
    indices_b = np.zeros(cap, np.int32)
    indices_b[:nbl] = ubj
    row_blocks = indptr_b[1:] - indptr_b[:-1]
    return BSR(
        block_indptr=jnp.asarray(indptr_b, jnp.int32),
        block_indices=jnp.asarray(indices_b),
        blocks=jnp.asarray(blocks),
        shape=(n_rows, n_cols),
        block_size=bs,
        max_row_blocks=int(row_blocks.max()) if mb else 0,
    )
