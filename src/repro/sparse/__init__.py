from repro.sparse.csr import (
    CSR, GeometryEnvelope, csr_from_dense, csr_to_dense, csr_from_coo,
    csr_pad_to,
)
from repro.sparse.bsr import BSR, bsr_from_dense, bsr_to_dense, bsr_from_csr
from repro.sparse import multigrid, generators, graphs

__all__ = [
    "CSR", "GeometryEnvelope", "csr_from_dense", "csr_to_dense",
    "csr_from_coo", "csr_pad_to",
    "BSR", "bsr_from_dense", "bsr_to_dense", "bsr_from_csr",
    "multigrid", "generators", "graphs",
]
