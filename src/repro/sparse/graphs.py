"""Graph generators + triangle-counting preprocessing (paper §4.1.2, Fig 11).

The paper uses twitter-2010 / uk-2005 / graph500-scale25. Offline, we generate
structurally similar synthetic graphs: RMAT (graph500-like, skewed) and a power-law
configuration-style graph (social-network-like).
"""

from __future__ import annotations

import numpy as np

from repro.sparse.csr import CSR, csr_from_coo


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0) -> CSR:
    """RMAT adjacency matrix (symmetrized, self-loops removed, 0/1 values)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    rows = np.zeros(m, np.int64)
    cols = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities: a (0,0), b (0,1), c (1,0), d (1,1)
        right = (r >= a) & (r < a + b) | (r >= a + b + c)
        down = r >= a + b
        rows |= down.astype(np.int64) << bit
        cols |= right.astype(np.int64) << bit
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    vals = np.ones(r2.size)
    adj = csr_from_coo(r2, c2, vals, (n, n))
    # binarize values (duplicates were summed)
    import jax.numpy as jnp

    return CSR(adj.indptr, adj.indices, jnp.minimum(adj.data, 1.0), adj.shape,
               adj.max_row_nnz)


def powerlaw(n: int, m_per_node: int = 8, exponent: float = 2.1, seed: int = 0) -> CSR:
    """Configuration-model-ish power-law graph (social-network-like degree skew)."""
    rng = np.random.default_rng(seed)
    # degree ~ zipf, capped
    deg = np.minimum(rng.zipf(exponent, n) * m_per_node // 2, n // 2).astype(np.int64)
    deg = np.maximum(deg, 1)
    stubs = np.repeat(np.arange(n), deg)
    rng.shuffle(stubs)
    if stubs.size % 2:
        stubs = stubs[:-1]
    half = stubs.size // 2
    rows, cols = stubs[:half], stubs[half:]
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    adj = csr_from_coo(r2, c2, np.ones(r2.size), (n, n))
    import jax.numpy as jnp

    return CSR(adj.indptr, adj.indices, jnp.minimum(adj.data, 1.0), adj.shape,
               adj.max_row_nnz)


def lower_triangular_degree_sorted(adj: CSR) -> CSR:
    """Wolf et al. triangle-counting preprocessing: permute vertices by ascending
    degree, then take the strictly-lower-triangular part L. Triangles = sum(L.L o L)."""
    indptr = np.asarray(adj.indptr)
    indices = np.asarray(adj.indices)
    data = np.asarray(adj.data)
    n = adj.n_rows
    deg = indptr[1:] - indptr[:-1]
    order = np.argsort(deg, kind="stable")  # old -> sorted position by rank
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    nnz = int(indptr[-1])
    rows = np.repeat(np.arange(n), deg)
    cols = indices[:nnz]
    pr, pc = rank[rows], rank[cols]
    keep = pr > pc  # strictly lower triangular in permuted order
    return csr_from_coo(pr[keep], pc[keep], data[:nnz][keep], (n, n),
                        sum_duplicates=False)
