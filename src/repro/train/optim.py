"""AdamW with cosine schedule + global-norm clipping (self-contained, no optax).

Optimizer states mirror the parameter pytree, so they inherit the FSDP/TP param
shardings (ZeRO-style sharded optimizer state for free).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    min_lr_fraction: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1
    grad_compression: str = "none"    # "none" | "int8"
    aux_weight: float = 0.01


def lr_schedule(tcfg: TrainConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(tcfg.warmup_steps, 1)
    t = (step - tcfg.warmup_steps) / jnp.maximum(
        tcfg.total_steps - tcfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = tcfg.min_lr_fraction + (1 - tcfg.min_lr_fraction) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return tcfg.learning_rate * jnp.where(step < tcfg.warmup_steps, warm, cos)


def adamw_init(params):
    return {
        "mu": jax.tree.map(jnp.zeros_like, params),
        "nu": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0.0)))


def adamw_update(tcfg: TrainConfig, params, grads, opt_state):
    """One AdamW step. Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(tcfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = tcfg.beta1 * mu + (1 - tcfg.beta1) * g
        nu2 = tcfg.beta2 * nu + (1 - tcfg.beta2) * g * g
        mu_hat = mu2 / (1 - tcfg.beta1 ** step.astype(jnp.float32))
        nu_hat = nu2 / (1 - tcfg.beta2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + tcfg.eps)
        p2 = p.astype(jnp.float32) * (1 - lr * tcfg.weight_decay) - lr * delta
        return p2.astype(p.dtype), mu2.astype(mu.dtype), nu2.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    params2 = jax.tree.unflatten(treedef, [o[0] for o in out])
    mu2 = jax.tree.unflatten(treedef, [o[1] for o in out])
    nu2 = jax.tree.unflatten(treedef, [o[2] for o in out])
    return params2, {"mu": mu2, "nu": nu2, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
