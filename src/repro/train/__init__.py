from repro.train.optim import adamw_init, adamw_update, TrainConfig, lr_schedule
from repro.train.compress import compress_grads, decompress_grads, ef_init
from repro.train.step import make_train_step, make_serve_step, make_prefill

__all__ = [
    "adamw_init", "adamw_update", "TrainConfig", "lr_schedule",
    "compress_grads", "decompress_grads", "ef_init",
    "make_train_step", "make_serve_step", "make_prefill",
]
