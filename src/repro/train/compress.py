"""Gradient compression with error feedback (distributed-optimization trick).

int8 symmetric quantization per tensor before the cross-replica reduction, with an
error-feedback buffer that re-injects the quantization residual into the next
step's gradient — keeping convergence within O(quantization noise) of exact SGD
(Seide et al. / Karimireddy et al.). At 512 chips the gradient all-reduce crosses
the slow inter-pod links once per step; int8 cuts that traffic 4x vs fp32 (2x vs
bf16), directly shrinking the §Roofline collective term of train shapes.

Enabled by TrainConfig(grad_compression="int8").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    """Error-feedback buffers (zero residuals), matching the param pytree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(g):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef):
    """Returns (quantized pytree of (int8, scale), new error-feedback buffers)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quant(g32)
        err = g32 - _dequant(q, s)
        return (q, s), err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    ef2 = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return qtree, ef2


def decompress_grads(qtree):
    def is_pair(x):
        return isinstance(x, tuple) and len(x) == 2
    return jax.tree.map(lambda p: _dequant(*p), qtree, is_leaf=is_pair)


def roundtrip(grads, ef):
    """compress -> decompress in one step (what the reduction endpoint sees)."""
    q, ef2 = compress_grads(grads, ef)
    return decompress_grads(q), ef2
