"""train_step / serve_step factories: microbatch gradient accumulation, optional
gradient compression, AdamW — the functions the launcher jits and the dry-run
lowers.

Microbatching: the global batch is reshaped to (n_micro, B/n_micro, S) and
scanned; gradients accumulate in fp32 across microbatches, and the (FSDP)
gradient reduction materializes once per step, after the scan — the reduce-once
overlap trick (DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as tf
from repro.train.optim import TrainConfig, adamw_update
from repro.train.compress import roundtrip


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``opt_state`` carries {"mu", "nu", "step"} (+ "ef" when compression is on).
    """
    use_ef = tcfg.grad_compression == "int8"

    def loss_for(p, mb):
        return tf.loss_fn(p, mb, cfg, aux_weight=tcfg.aux_weight)

    def train_step(params, opt_state, batch):
        n_micro = tcfg.microbatches
        if n_micro == 1:
            (loss, _), grads = jax.value_and_grad(loss_for, has_aux=True)(
                params, batch)
        else:
            def resh(x):
                return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

            mbatch = jax.tree.map(resh, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_for, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.float32(0.0)), mbatch)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro

        if use_ef:
            grads, ef2 = roundtrip(grads, opt_state["ef"])
        params2, opt2, om = adamw_update(
            tcfg, params,
            grads,
            {k: opt_state[k] for k in ("mu", "nu", "step")},
        )
        if use_ef:
            opt2 = dict(opt2, ef=ef2)
        metrics = {"loss": loss, **om}
        return params2, opt2, metrics

    return train_step


def init_opt_state(cfg: ModelConfig, tcfg: TrainConfig, params):
    del cfg   # uniform init(cfg, tcfg, params) signature; state is shaped by params
    from repro.train.optim import adamw_init
    from repro.train.compress import ef_init

    state = adamw_init(params)
    if tcfg.grad_compression == "int8":
        state["ef"] = ef_init(params)
    return state


def abstract_opt_state(cfg: ModelConfig, tcfg: TrainConfig, abstract_params):
    return jax.eval_shape(lambda p: init_opt_state(cfg, tcfg, p), abstract_params)


def make_prefill(cfg: ModelConfig, cache_len: int):
    def prefill_fn(params, batch):
        return tf.prefill(params, batch, cfg, cache_len)

    return prefill_fn


def make_serve_step(cfg: ModelConfig, greedy: bool = True):
    """serve_step(params, cache, tokens[B,1]) -> (next_tokens[B,1], cache).

    One new token against the full KV cache — what decode_* shape cells lower."""
    del greedy   # only greedy (argmax) decode is lowered; the flag is the serve API

    def serve_step(params, cache, tokens):
        logits, cache = tf.decode_step(params, cache, tokens, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step
