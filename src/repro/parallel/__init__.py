from repro.parallel.sharding import (
    DATA_AXES, MODEL_AXIS, param_shardings, batch_shardings, cache_shardings,
    divisible, best_effort_spec,
)
from repro.parallel.pipeline import (
    pipeline_forward, sequential_reference, split_stages, pad_layers_identity,
)

__all__ = [
    "DATA_AXES", "MODEL_AXIS", "param_shardings", "batch_shardings",
    "cache_shardings", "divisible", "best_effort_spec",
    "pipeline_forward", "sequential_reference", "split_stages",
    "pad_layers_identity",
]
