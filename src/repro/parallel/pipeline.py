"""Pipeline parallelism (GPipe schedule) over a mesh axis, via shard_map.

Each device along the ``stage`` axis holds one contiguous slice of the layer
stack; microbatches flow through stages with ``lax.ppermute`` handing
activations to the next stage every tick. The schedule runs
``n_micro + n_stages - 1`` ticks; stage s computes microbatch t-s at tick t
(bubble fraction = (S-1)/(T+S-1)).

Differentiable by construction: reverse-mode AD through ppermute yields the
reverse permute, so jax.grad of a pipelined forward IS the GPipe backward
schedule (activation stash = AD residuals). Tested for forward and gradient
equality against the sequential stack in tests/test_pipeline.py (subprocess
with placeholder devices, like the dry-run).

Layer-count padding: stages must be equal-depth; ``pad_layers_identity``
appends zero-initialized layers, which are exact identities under pre-norm
residual blocks (zero attn/mlp output => x + 0 = x).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def split_stages(stacked_params, n_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...] stage-major."""
    def resh(a):
        l = a.shape[0]
        assert l % n_stages == 0, f"layers {l} % stages {n_stages} != 0"
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])

    return jax.tree.map(resh, stacked_params)


def pad_layers_identity(stacked_params, n_layers: int, target: int):
    """Append ``target - n_layers`` zero layers (identity under pre-norm)."""
    if target == n_layers:
        return stacked_params
    pad = target - n_layers

    def ext(a):
        z = jnp.zeros((pad, *a.shape[1:]), a.dtype)
        return jnp.concatenate([a, z], axis=0)

    return jax.tree.map(ext, stacked_params)


def pipeline_forward(stage_params, microbatches, body_fn, mesh,
                     axis: str = "stage"):
    """Run ``body_fn(layer_params, x) -> x`` through the pipeline.

    stage_params: pytree with leading dims [S, L/S, ...] (S = mesh axis size).
    microbatches: [T, mb, ...] (replicated; stage 0 consumes them in order).
    Returns [T, mb, ...] outputs (as produced by the last stage).
    """
    n_stages = mesh.shape[axis]
    t_micro = microbatches.shape[0]
    n_ticks = t_micro + n_stages - 1

    def stage_fn(params_s, mb_s):
        # params_s: [1, L/S, ...] (this stage's slice); mb_s: [T, mb, ...]
        params_local = jax.tree.map(lambda a: a[0], params_s)
        sid = jax.lax.axis_index(axis)
        mb_shape = mb_s.shape[1:]

        def stage_apply(x):
            def one(h, lp):
                return body_fn(lp, h), None

            h, _ = jax.lax.scan(one, x, params_local)
            return h

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; everyone else uses the handed-over buf
            inject = jax.lax.dynamic_index_in_dim(
                mb_s, jnp.clip(t, 0, t_micro - 1), 0, keepdims=False)
            x_in = jnp.where(sid == 0, inject, buf)
            active = (sid <= t) & (t < sid + t_micro)
            y = stage_apply(x_in)
            y = jnp.where(active, y, x_in)
            # hand to the next stage (ring; the wraparound edge is ignored)
            buf_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage emits microbatch t - (S-1) at tick t
            emit_idx = t - (n_stages - 1)
            emit = (sid == n_stages - 1) & (emit_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(emit_idx, 0), 0),
                lambda o: o,
                outs)
            return (buf_next, outs), None

        buf0 = jnp.zeros(mb_shape, mb_s.dtype)
        outs0 = jnp.zeros_like(mb_s)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(n_ticks))
        # broadcast the last stage's outputs to everyone (replicated result):
        # masked psum is the collective idiom for single-source broadcast
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        stage_fn, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_rep=False,
    )(stage_params, microbatches)


def sequential_reference(stacked_params, microbatches, body_fn):
    """Oracle: apply the whole stack to each microbatch, no pipeline."""
    def apply_all(x):
        def one(h, lp):
            return body_fn(lp, h), None

        h, _ = jax.lax.scan(one, x, stacked_params)
        return h

    return jax.vmap(apply_all)(microbatches)
