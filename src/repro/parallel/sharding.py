"""Sharding rules: FSDP + TP + EP + SP over the (pod, data, model) mesh.

Strategy (DESIGN.md §6):
  * params: tensor-parallel on the "model" axis (attention heads / d_ff / experts)
    AND fully-sharded (ZeRO-3 / FSDP) on the ("pod", "data") axes — the per-layer
    all-gather of FSDP weights inside the scanned layer body is the paper's Chunk2
    streaming order (weights streamed through fast memory, activations stationary).
  * batch: data-parallel over ("pod", "data").
  * KV caches: batch on data axes, KV heads on "model" when they divide, else the
    sequence axis on "model" (SP — used by long_500k where batch=1).
  * every rule is divisibility-checked: an axis that does not divide its dimension
    is dropped (e.g. starcoder2's kv=4 heads on a 16-way model axis -> replicated,
    the GSPMD-standard fallback for narrow KV).

All functions work on abstract (ShapeDtypeStruct) pytrees — nothing allocates.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

DATA_AXES = ("pod", "data")   # flattened into FSDP/DP when "pod" exists
MODEL_AXIS = "model"


def _mesh_axes(mesh: Mesh):
    names = mesh.axis_names
    data = tuple(a for a in DATA_AXES if a in names)
    model = MODEL_AXIS if MODEL_AXIS in names else None
    return data, model


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def divisible(dim: int, mesh: Mesh, axes) -> bool:
    return dim % max(_axis_size(mesh, axes), 1) == 0


def best_effort_spec(shape, mesh: Mesh, wanted) -> P:
    """Build a PartitionSpec, dropping axis assignments that don't divide."""
    out = []
    for dim, axes in zip(shape, wanted):
        if axes is not None and divisible(dim, mesh, axes):
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


def _param_rule(path: tuple, leaf, _cfg: ModelConfig, mesh: Mesh,
                fsdp, model) -> P:
    """Map one parameter (by its pytree path) to a PartitionSpec.

    Layer-stacked leaves carry a leading L dim (never sharded). ``fsdp`` is the
    combined data axes tuple; ``model`` the TP axis name (or None).
    """
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    key = names[-1]
    stacked = "layers" in names
    shape = leaf.shape
    body = shape[1:] if stacked else shape

    def spec(*wanted):
        s = best_effort_spec(body, mesh, wanted)
        return P(*((None,) + tuple(s))) if stacked else s

    # --- embeddings ---------------------------------------------------------
    if key == "embedding":
        return spec(model, fsdp)
    if key == "head":
        return spec(fsdp, model)
    if key == "proj":          # frontend stub projector
        return spec(fsdp, model)

    # --- attention ----------------------------------------------------------
    if key == "wq":
        return spec(fsdp, model, None)
    if key in ("wk", "wv") and "attn" in names:
        return spec(fsdp, model, None)     # kv heads sharded only if divisible
    if key == "wo" and "attn" in names:
        return spec(model, None, fsdp)

    # --- dense MLP ----------------------------------------------------------
    if key in ("w1", "w3") and len(body) == 2:
        return spec(fsdp, model)
    if key == "w2" and len(body) == 2:
        return spec(model, fsdp)

    # --- MoE (experts on the model axis = EP) ---------------------------------
    if key == "router":
        return spec(fsdp, None)
    if key in ("w1", "w3") and len(body) == 3:
        return spec(model, fsdp, None)
    if key == "w2" and len(body) == 3:
        return spec(model, None, fsdp)

    # --- rwkv6 ----------------------------------------------------------------
    if key in ("wr", "wk", "wv", "wg") and "rwkv" in names:
        return spec(fsdp, model)
    if key == "wo" and "rwkv" in names:
        return spec(model, fsdp)
    if key in ("cm_k",):
        return spec(fsdp, model)
    if key in ("cm_v",):
        return spec(model, fsdp)
    if key in ("cm_r",):
        return spec(fsdp, model)

    # --- mamba2 ---------------------------------------------------------------
    if key == "in_proj":
        return spec(fsdp, model)
    if key == "out_proj":
        return spec(model, fsdp)
    if key == "conv_w":
        return spec(None, model)
    if key == "conv_b":
        return spec(model)

    # --- everything else (norms, loras, biases, per-head scalars) -------------
    if len(body) >= 2:
        return spec(*([fsdp] + [None] * (len(body) - 1)))
    return spec(*([None] * len(body)))


def param_shardings(cfg: ModelConfig, mesh: Mesh, abstract_params,
                    tp_enabled: bool = True):
    """NamedSharding pytree matching the abstract param pytree.

    ``tp_enabled=False`` = pure-DP layout: the "model" axis joins the FSDP axes
    instead of carrying tensor parallelism — measured in §Perf to be the right
    mapping for small models whose TP slices would be narrower than an MXU tile
    (olmoe's 1024-wide experts / 16 = 64)."""
    fsdp, model = _mesh_axes(mesh)
    if not tp_enabled and model is not None:
        fsdp = tuple(fsdp) + (model,)
        model = None
    fsdp = fsdp if fsdp else None

    def assign(path, leaf):
        return NamedSharding(mesh, _param_rule(path, leaf, cfg, mesh, fsdp, model))

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, abstract_batch, extra_axes: tuple = ()):
    """tokens/labels/embeds: batch dim over the data axes (+ ``extra_axes`` for
    the pure-DP layout where "model" also carries batch)."""
    fsdp, _ = _mesh_axes(mesh)
    fsdp = tuple(fsdp) + tuple(extra_axes) if fsdp else tuple(extra_axes) or None
    dp = tuple(fsdp) if fsdp else None

    def assign(leaf):
        wanted = [dp] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, best_effort_spec(leaf.shape, mesh, wanted))

    return jax.tree.map(assign, abstract_batch)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, abstract_cache):
    """Decode caches: batch over data axes; KV heads over model when they divide,
    else sequence-parallel (SP) over model (the long_500k batch=1 case)."""
    del cfg   # uniform *_shardings(cfg, mesh, tree) signature; rules are shape-driven
    fsdp, model = _mesh_axes(mesh)
    dp = tuple(fsdp) if fsdp else None

    def assign(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        key = names[-1]
        shape = leaf.shape
        if key in ("k", "v"):
            # [L_or_sites, B, S, Hkv, D]
            wanted = [None, dp, None, model, None]
            if not divisible(shape[3], mesh, model) or shape[1] == 1:
                # SP fallback: shard the sequence axis instead
                wanted = [None, dp if shape[1] > 1 else None, model, None, None]
            return NamedSharding(
                mesh, best_effort_spec(shape, mesh, wanted[: len(shape)]))
        if key == "S":          # rwkv state [L, B, nh, p, p]
            wanted = [None, dp, model, None, None]
            return NamedSharding(
                mesh, best_effort_spec(shape, mesh, wanted[: len(shape)]))
        if key == "h":          # mamba state [L, B, nh, P, N]
            wanted = [None, dp, model, None, None]
            return NamedSharding(
                mesh, best_effort_spec(shape, mesh, wanted[: len(shape)]))
        if key == "conv":       # [L, B, W-1, C]
            wanted = [None, dp, None, model]
            return NamedSharding(
                mesh, best_effort_spec(shape, mesh, wanted[: len(shape)]))
        if key == "pos":
            return NamedSharding(mesh, best_effort_spec(shape, mesh, [dp]))
        wanted = [None, dp] + [None] * (len(shape) - 2)
        return NamedSharding(mesh, best_effort_spec(shape, mesh, wanted[: len(shape)]))

    return jax.tree_util.tree_map_with_path(assign, abstract_cache)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
