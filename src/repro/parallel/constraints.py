"""Activation sharding constraints (§Perf lever: shard_activations).

GSPMD propagates parameter shardings outward, but leaves several big
intermediates replicated when propagation is ambiguous (measured in the baseline
dry-run: the embedding gather triggers "involuntary full rematerialization" and
the residual stream replicates at layer boundaries — mixtral prefill peaked at
643 GiB/device). Pinning the residual stream and the MoE expert buffer with
explicit constraints resolves the ambiguity.

The helpers no-op when the config carries no mesh axes (CPU tests, smoke runs),
so models stay mesh-agnostic.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def _wsc(x, spec: P):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):   # no ambient mesh (plain CPU execution)
        return x


def hidden(x, cfg: ModelConfig):
    """Residual stream [B, S, d]: batch over the data axes, d replicated
    (megatron-style: TP lives inside attn/mlp bodies, not on the stream)."""
    if not cfg.shard_activations or not cfg.dp_axes:
        return x
    dp = tuple(cfg.dp_axes)
    return _wsc(x, P(dp if len(dp) > 1 else dp[0], None, None))


def expert_buffer(he, cfg: ModelConfig):
    """MoE gathered buffer [B, E, cap, d]: batch over data axes ONLY.

    Measured (§Perf, olmoe iterations 2-3): sharding E here forces the token
    scatter that BUILDS the buffer to cross the model axis — GSPMD emits an
    order of magnitude more collective traffic than it saves. Keeping the
    buffer batch-sharded and letting the expert einsums contract against
    model-sharded expert weights (EP lives on the weights) is strictly
    better."""
    if not cfg.shard_activations or not cfg.dp_axes:
        return he
    dp = tuple(cfg.dp_axes)
    dp_spec = dp if len(dp) > 1 else dp[0]
    return _wsc(he, P(dp_spec, None, None, None))


def logits(x, cfg: ModelConfig):
    """LM head output [B, S, V]: batch over data, vocab over TP."""
    if not cfg.shard_activations or not cfg.dp_axes:
        return x
    dp = tuple(cfg.dp_axes)
    tp = cfg.tp_axis or None
    return _wsc(x, P(dp if len(dp) > 1 else dp[0], None, tp))
