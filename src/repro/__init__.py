"""repro: multilevel-memory SpGEMM (Deveci et al. 2018) as a production JAX framework.

Layers:
  repro.sparse   -- CSR/BSR containers + problem generators (multigrid, random, graphs)
  repro.core     -- the paper's contribution: KKMEM SpGEMM, data placement, chunking, planner
  repro.kernels  -- Pallas TPU kernels (BSR SpGEMM, grouped matmul, chunked attention, SpMM)
  repro.models   -- LM architectures (dense/GQA, MoE, RWKV6, Mamba2 hybrid)
  repro.parallel -- mesh + sharding rules (FSDP/TP/EP/SP over (pod, data, model))
  repro.train    -- optimizer, train_step, grad compression, microbatching
  repro.ckpt     -- sharded checkpoint/restore with elastic resharding
  repro.launch   -- mesh/dryrun/train/serve entry points
"""

__version__ = "1.0.0"
