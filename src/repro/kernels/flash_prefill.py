"""Flash-attention prefill Pallas kernel: the training/prefill twin of
chunked_attention.py.

Grid (B, Hkv, nQ, nK) with the K loop innermost ("arbitrary"): for each Q block
the online-softmax state (m, l, acc) lives in VMEM scratch across K steps while
(bq x d) Q stays resident and (bk x d) KV blocks stream HBM->VMEM — the paper's
Chunk1 order. Causality is enforced two ways:
  * whole KV blocks strictly in the future are SKIPPED via pl.when (no MXU work
    — the Pallas analogue of "skip columns of A outside the range"), and
  * the diagonal block is masked elementwise.
Sliding windows additionally skip blocks entirely behind the window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, window: int, scale: float, g: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = qi * bq
    k0 = ki * bk
    # visible iff the block intersects the causal (and window) band
    visible = k0 <= q0 + bq - 1
    if window:
        visible = visible & (k0 + bk - 1 > q0 - window)

    @pl.when(visible)
    def _step():
        q = q_ref[0, :, 0].astype(jnp.float32)     # [bq*g, d] (g folded into rows)
        k = k_ref[0, :, 0]                          # [bk, d]
        v = v_ref[0, :, 0]
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq*g, bk]
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = qpos >= kpos
        if window:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0, :, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  bq: int = 256, bk: int = 512, window: int = 0,
                  interpret: bool = False) -> jax.Array:
    """q: [B, S, H, D]; k, v: [B, S, Hkv, D] -> [B, S, H, D]. Causal.

    GQA is handled by folding the q-heads-per-kv-head factor g into the Q-block
    rows ([bq*g, d] tiles), so every kernel instance is a plain matmul pair."""
    b, s, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    assert s % bq == 0 and sk % bk == 0, (s, bq, sk, bk)
    n_q, n_k = s // bq, sk // bk
    scale = 1.0 / (d ** 0.5)
    # [B, S, Hkv, g, D] -> [B, nq*(bq*g), Hkv, D] with q-position major
    qr = (q.reshape(b, s, hkv, g, d)
           .transpose(0, 2, 1, 3, 4)           # [B, Hkv, S, g, D]
           .reshape(b, hkv, s * g, d)
           .transpose(0, 2, 1, 3))             # [B, S*g, Hkv, D]
    grid = (b, hkv, n_q, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, window=window,
                          scale=scale, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq * g, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq * g, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s * g, hkv, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qr, k, v)
    # [B, S*g, Hkv, D] -> [B, S, H, D]
    return (out.transpose(0, 2, 1, 3)
               .reshape(b, hkv, s, g, d)
               .transpose(0, 2, 1, 3, 4)
               .reshape(b, s, h, d))
