"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

Each kernel module contains the `pl.pallas_call` + explicit BlockSpec VMEM tiling;
`ops.py` carries the jit'd public wrappers (symbolic planning + padding + dispatch)
and `ref.py` the pure-jnp oracles every kernel is validated against (interpret=True
on CPU; compiled on real TPUs).

Kernels:
  bsr_spgemm        block-sparse x block-sparse  (the paper's chunked numeric phase)
  bsr_spmm          block-sparse x dense         (SpMM; Zheng et al. comparison)
  grouped_matmul    ragged grouped GEMM          (MoE expert compute == chunked SpGEMM
                                                  at block granularity)
  chunked_attention flash-decoding with KV chunks streamed HBM->VMEM (Chunk1 order:
                    Q/O stationary, KV streamed)
  flash_prefill     causal flash attention for training/prefill with whole-block
                    causal/window skipping (pl.when) and GQA head folding
"""
