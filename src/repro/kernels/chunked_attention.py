"""Flash-decoding attention Pallas kernel: KV cache chunks streamed HBM->VMEM.

This is the paper's Chunk1 order applied to decode attention (DESIGN.md §4.2):
Q and the output accumulator are *stationary* in VMEM (they are tiny: one query
token per sequence), the big operand — the KV cache, which for 500k-token contexts
exceeds even HBM per chip — is *streamed* in (bs_kv x d) chunks with an online
softmax taking the place of the fused multiply-add accumulator.

GQA layout: q [B, Hkv, G, D] (G = query heads per KV head), K/V [B, S, Hkv, D].
Grid (B, Hkv, S/bs_kv); per-sequence valid length is scalar-prefetched and masks
the tail chunk. Running max m, denominator l, and the weighted value accumulator
live in VMEM scratch across the S-chunk loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bs_kv: int, n_chunks: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]            # [G, D]
    k = k_ref[0, :, 0]         # [bs_kv, D]
    v = v_ref[0, :, 0]         # [bs_kv, D]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bs_kv]
    pos = s * bs_kv + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < len_ref[b], scores, NEG_INF)

    m_prev = m_ref[...]                         # [G, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)             # rescale of old accumulator
    p = jnp.exp(scores - m_new)                 # [G, bs_kv]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_chunks - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array,
                     bs_kv: int = 512, interpret: bool = False) -> jax.Array:
    """q: [B, Hkv, G, D]; k, v: [B, S, Hkv, D]; lengths: int32[B]. Returns
    [B, Hkv, G, D]."""
    bsz, hkv, g, d = q.shape
    _, s_len, _, _ = k.shape
    assert s_len % bs_kv == 0, f"S={s_len} not divisible by bs_kv={bs_kv}"
    n_chunks = s_len // bs_kv
    scale = 1.0 / (d ** 0.5)
    grid = (bsz, hkv, n_chunks)
    return pl.pallas_call(
        functools.partial(_kernel, bs_kv=bs_kv, n_chunks=n_chunks, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b, h, s, ln: (b, h, 0, 0)),
                pl.BlockSpec((1, bs_kv, 1, d), lambda b, h, s, ln: (b, s, h, 0)),
                pl.BlockSpec((1, bs_kv, 1, d), lambda b, h, s, ln: (b, s, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, s, ln: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
