"""Ragged grouped GEMM Pallas kernel — MoE expert compute as chunked block-sparse
matmul (the production descendant of the paper's technique; DESIGN.md §4.1).

y[t] = x[t] @ w[g(t)] for tokens pre-sorted by group (expert), with each group's
token count padded to a multiple of the token tile ``bt`` so no tile straddles two
groups. The per-tile group id is scalar-prefetched; the expert weight chunk
(bk x bn of w[g]) streams HBM->VMEM per grid step — the Chunk2 order (weights
streamed, activations stationary per tile).

Grid: (T/bt, N/bn, K/bk), accumulator in VMEM scratch over the K loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(gid_ref, x_ref, w_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def grouped_matmul_padded(x: jax.Array, w: jax.Array, tile_group: jax.Array,
                          bt: int = 128, bn: int = 128, bk: int = 128,
                          out_dtype=None, interpret: bool = False) -> jax.Array:
    """x: [T_pad, K] tokens sorted+padded by group; w: [E, K, N];
    tile_group: int32[T_pad // bt] group id per token tile. Returns [T_pad, N]."""
    t_pad, kdim = x.shape
    _, _, ndim = w.shape
    assert t_pad % bt == 0 and kdim % bk == 0 and ndim % bn == 0, (
        f"shapes ({t_pad},{kdim},{ndim}) not divisible by tiles ({bt},{bk},{bn})"
    )
    nk = kdim // bk
    grid = (t_pad // bt, ndim // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bk), lambda t, n, k, g: (t, k)),
                pl.BlockSpec((1, bk, bn), lambda t, n, k, g: (g[t], k, n)),
            ],
            out_specs=pl.BlockSpec((bt, bn), lambda t, n, k, g: (t, n)),
            scratch_shapes=[pltpu.VMEM((bt, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t_pad, ndim), out_dtype or x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tile_group, x, w)


def plan_groups(group_sizes: np.ndarray, bt: int):
    """Host-side plan: padded offsets + per-tile group ids for ragged groups.

    Returns (padded_offsets[E+1], tile_group[T_pad//bt], t_pad)."""
    sizes = np.asarray(group_sizes, np.int64)
    padded = -(-sizes // bt) * bt
    offsets = np.concatenate([[0], np.cumsum(padded)])
    t_pad = int(offsets[-1])
    tile_group = np.repeat(np.arange(sizes.size, dtype=np.int32), padded // bt)
    return offsets.astype(np.int64), tile_group, max(t_pad, bt)
