"""Pallas CSR-native sparse-output SpGEMM: sorted-merge accumulation in VMEM.

The ranged-SpGEMM kernel (``kernels/ranged_spgemm.py``) trades entry sparsity
for MXU tiles: its accumulator is a dense ``[strip_rows, n_cols]`` slab, so
VMEM — not the chunk plan — bounds strip sizing, and a very sparse C pays
dense-C traffic. This kernel is the numeric phase of the two-phase
symbolic/numeric scheme (``repro.core.symbolic`` is the symbolic phase): the
per-strip accumulator is a **fixed-capacity CSR triple**
(``indptr[strip_rows+1]``, ``indices[c_cap]``, ``data[c_cap]``) whose
capacity ``c_cap`` comes from the symbolic phase's exact structure bound, so
the fast-memory footprint scales with ``nnz(C)`` instead of
``strip_rows * n_cols`` — the compressed-accumulator idea of Deveci et al.'s
KKMEM and Nagasaka & Azad's ESC/hash variants, in the streaming-chunk setting.

Per grid step the kernel runs one fused ranged multiply-add
``C = A[:, r0:r1] x B_chunk + C_prev`` entirely against CSR operands: expand
the in-range products, concatenate the previous accumulator entries, two-key
sort, and compress duplicates back into the CSR scratch
(``repro.core.kkmem.spgemm_ranged_impl`` — the same expand-sort-compress
(ESC) accumulator the scan backend scans over, here executed inside the
kernel so the accumulator never leaves VMEM). Because the symbolic caps are
exact upper bounds and a partial C's structure is always a subset of the
final strip structure, the scratch can never overflow mid-stream.

The streaming schedule is the same explicit two-slot DMA pattern as
``ranged_spgemm_stream``: the stationary operand (the A strip in the Chunk1
order, the B chunk in Chunk2) rides a normal blocked ``BlockSpec``; the
streamed operand's CSR triple lives in slow memory (``pltpu.ANY``) and is
hand-DMA'd — three async copies per element, one per CSR field — through
``[2, ...]`` VMEM scratch buffers, starting element j+1 while element j
multiplies. Scalar-prefetched ``r0s``/``r1s`` realize the ranged column skip.

``interpret=default_interpret()`` validates the whole pipeline (DMA semantics
included) on CPU. On real TPU the ESC body leans on sort/scatter lowerings
inside the kernel — the open item tracked in ROADMAP.md next to the existing
"run the Pallas lanes on real TPU" note; the CSR-native *memory model* (what
the planner sizes against) is backend-independent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kkmem import spgemm_ranged_impl
from repro.kernels import dma_schedule
from repro.kernels._compat import ANY as _ANY
# shared with the dense-slab streaming kernel: same interpret heuristic, same
# linear-grid decomposition, same slot schedule (the two kernels are one DMA
# pattern — kernels/dma_schedule — two accumulators)
from repro.kernels.ranged_spgemm import _decompose, default_interpret
from repro.sparse.csr import CSR


def _kernel(r0s_ref, r1s_ref, *refs, order: str, batch: int,
            n_ac: int, n_b: int, strip_rows: int, chunk_rows: int,
            k_cols: int, n_cols: int, a_mrn: int, b_mrn: int, c_cap: int,
            masked: bool, merge_fn):
    """One grid step: DMA-stream a CSR triple, merge into the CSR scratch.

    ``merge_fn(A, B_chunk, r0, r1, C_prev, c_cap) -> CSR`` is the pluggable
    accumulator body: the ESC sorted merge (``spgemm_ranged_impl``, the
    default) or the linear-probing hash merge
    (``repro.kernels.hash_accum_spgemm.hash_merge_impl``). The streaming
    schedule around it is identical. With ``masked`` the positional refs
    carry two extra stationary operands — the fused output mask's strip
    structure (indptr + indices, no data) — and the merge is called with
    them appended: ``merge_fn(A, B_chunk, r0, r1, C_prev, c_cap,
    mask_indptr, mask_indices)``. The unmasked operand list (and therefore
    the traced jaxpr the static auditor pins) is unchanged.

    Grid is (batch, outer, inner); ``order`` fixes which operand streams:
      chunk1: outer = strips, inner = chunks  -> B triples stream through VMEM
      chunk2: outer = chunks, inner = strips  -> A triples stream through VMEM
    """
    if masked:
        (stat_ip, stat_ix, stat_d,
         stream_ip_hbm, stream_ix_hbm, stream_d_hbm,
         c0_ip, c0_ix, c0_d, m_ip, m_ix,
         out_ip, out_ix, out_d, buf_ip, buf_ix, buf_d, sems) = refs
    else:
        (stat_ip, stat_ix, stat_d,
         stream_ip_hbm, stream_ix_hbm, stream_d_hbm,
         c0_ip, c0_ix, c0_d,
         out_ip, out_ix, out_d, buf_ip, buf_ix, buf_d, sems) = refs
        m_ip = m_ix = None
    b = pl.program_id(0)
    outer_ix = pl.program_id(1)
    inner_ix = pl.program_id(2)
    outer, inner = (n_ac, n_b) if order == "chunk1" else (n_b, n_ac)
    total = batch * outer * inner
    lin = (b * outer + outer_ix) * inner + inner_ix

    def dma(slot, step):
        bb, ii = _decompose(step, outer, inner)
        return [
            pltpu.make_async_copy(stream_ip_hbm.at[bb, ii], buf_ip.at[slot],
                                  sems.at[slot, 0]),
            pltpu.make_async_copy(stream_ix_hbm.at[bb, ii], buf_ix.at[slot],
                                  sems.at[slot, 1]),
            pltpu.make_async_copy(stream_d_hbm.at[bb, ii], buf_d.at[slot],
                                  sems.at[slot, 2]),
        ]

    # warm-up: the very first streamed element has no previous step to
    # prefetch it, so stage it synchronously before the overlap steady-state.
    # Slot arithmetic comes from kernels/dma_schedule — the module the static
    # DMA checker (repro.analysis.dma) simulates host-side.
    @pl.when(dma_schedule.is_prime_step(lin))
    def _prime():
        for copy in dma(dma_schedule.prime_slot(), 0):
            copy.start()

    # the explicit copy2Fast overlap: start element lin+1 into the other
    # slot while this step's merge consumes the read slot
    @pl.when(dma_schedule.has_prefetch(lin, total))
    def _prefetch():
        for copy in dma(dma_schedule.prefetch_slot(lin), lin + 1):
            copy.start()

    for copy in dma(dma_schedule.read_slot(lin), lin):
        copy.wait()
    slot = dma_schedule.read_slot(lin)
    s_ip, s_ix, s_d = buf_ip[slot], buf_ix[slot], buf_d[slot]

    if order == "chunk1":
        j, i = inner_ix, outer_ix
        A = CSR(stat_ip[0, 0], stat_ix[0, 0], stat_d[0, 0],
                (strip_rows, k_cols), a_mrn)
        Bc = CSR(s_ip, s_ix, s_d, (chunk_rows, n_cols), b_mrn)
        prev = (c0_ip[0, 0], c0_ix[0, 0], c0_d[0, 0],
                out_ip[0, 0], out_ix[0, 0], out_d[0, 0])
        mask = (m_ip[0, 0], m_ix[0, 0]) if masked else None
    else:
        j, i = outer_ix, inner_ix
        A = CSR(s_ip, s_ix, s_d, (strip_rows, k_cols), a_mrn)
        Bc = CSR(stat_ip[0, 0], stat_ix[0, 0], stat_d[0, 0],
                 (chunk_rows, n_cols), b_mrn)
        prev = (c0_ip[0, i], c0_ix[0, i], c0_d[0, i],
                out_ip[0, i], out_ix[0, i], out_d[0, i])
        mask = (m_ip[0, i], m_ix[0, i]) if masked else None

    # the fused C_prev: the caller's c0 on the first chunk step, the
    # persistent VMEM accumulator afterwards (out_ref is only ever read
    # behind the j > 0 select, so the j == 0 read of the uninitialized
    # block is discarded)
    first = j == 0
    c_prev = CSR(
        jnp.where(first, prev[0], prev[3]),
        jnp.where(first, prev[1], prev[4]),
        jnp.where(first, prev[2], prev[5]),
        (strip_rows, n_cols), c_cap,
    )
    if masked:
        merged = merge_fn(A, Bc, r0s_ref[j], r1s_ref[j], c_prev, c_cap,
                          mask[0], mask[1])
    else:
        merged = merge_fn(A, Bc, r0s_ref[j], r1s_ref[j], c_prev, c_cap)
    if order == "chunk1":
        out_ip[0, 0] = merged.indptr
        out_ix[0, 0] = merged.indices
        out_d[0, 0] = merged.data
    else:
        out_ip[0, i] = merged.indptr
        out_ix[0, i] = merged.indices
        out_d[0, i] = merged.data


def sparse_accum_spgemm_stream(Ast: CSR, Bst: CSR, C0st: CSR,
                               r0s: jax.Array, r1s: jax.Array, *, order: str,
                               interpret: bool | None = None,
                               merge_fn=None, mask_st: CSR | None = None):
    """Streamed sparse-output multiply over stacked CSR strips and chunks.

    Args:
      Ast: doubly-stacked A strips — a :class:`CSR` whose array fields carry
        leading ``[batch, n_ac]`` axes (``csr_stack`` of ``csr_stack``), with
        per-element ``shape == (strip_rows, k_cols)``.
      Bst: doubly-stacked B chunks, leading ``[batch, n_b]`` axes,
        per-element ``shape == (chunk_rows, n_cols)``; ``max_row_nnz`` sizes
        the product expansion.
      C0st: the fused ``C_prev`` per strip, leading ``[batch, n_ac]`` axes;
        its entry capacity is the CSR scratch capacity ``c_cap`` (from the
        symbolic phase — must bound every strip's exact output nnz).
      r0s, r1s: i32[n_b] global row range of each B chunk (scalar-prefetched).
      order: "chunk1" (strips outer, B streamed) or "chunk2" (chunks outer,
        A streamed; per-strip accumulators persist in the VMEM out block).
      merge_fn: per-step accumulator body ``(A, B_chunk, r0, r1, C_prev,
        c_cap) -> CSR``; defaults to the ESC sorted merge
        (``spgemm_ranged_impl``). ``repro.kernels.hash_accum_spgemm`` passes
        its linear-probing hash merge through here, reusing this exact
        streaming schedule.
      mask_st: optional fused output mask, stacked like ``C0st`` (leading
        ``[batch, n_ac]`` axes, per-element shape ``(strip_rows, n_cols)``).
        Only its structure (indptr + indices) enters the kernel — as two
        extra stationary operands with the accumulator blocks' index maps —
        and ``merge_fn`` must then accept them appended: ``(A, B_chunk, r0,
        r1, C_prev, c_cap, mask_indptr, mask_indices) -> CSR`` (the masked
        hash merge). ``C0st``'s capacity must bound every strip's mask nnz.

    Returns ``(indptr, indices, data)`` with leading ``[batch, n_ac]`` axes —
    the accumulated C strip CSRs at capacity ``c_cap``.
    """
    if merge_fn is None:
        merge_fn = spgemm_ranged_impl
    if order not in ("chunk1", "chunk2"):
        raise ValueError(f"unknown streaming order {order!r}")
    batch, n_ac = Ast.indptr.shape[0], Ast.indptr.shape[1]
    n_b = Bst.indptr.shape[1]
    strip_rows, k_cols = Ast.shape
    chunk_rows, n_cols = Bst.shape
    a_cap = Ast.indices.shape[-1]
    chunk_cap = Bst.indices.shape[-1]
    c_cap = C0st.indices.shape[-1]
    dtype = C0st.data.dtype
    if Bst.indptr.shape[0] != batch or C0st.indptr.shape[:2] != (batch, n_ac):
        raise ValueError(
            f"inconsistent stack axes: A[{Ast.indptr.shape[:2]}] "
            f"B[{Bst.indptr.shape[:2]}] C0[{C0st.indptr.shape[:2]}]"
        )
    if C0st.shape != (strip_rows, n_cols):
        raise ValueError(f"C0 shape {C0st.shape} != {(strip_rows, n_cols)}")
    masked = mask_st is not None
    if masked:
        if merge_fn is None:
            raise ValueError("mask_st requires an explicit masked merge_fn")
        if mask_st.indptr.shape[:2] != (batch, n_ac):
            raise ValueError(
                f"mask stack axes {mask_st.indptr.shape[:2]} != "
                f"{(batch, n_ac)}")
        if mask_st.shape != (strip_rows, n_cols):
            raise ValueError(
                f"mask shape {mask_st.shape} != {(strip_rows, n_cols)}")
        m_cap = mask_st.indices.shape[-1]
    interpret = default_interpret() if interpret is None else interpret

    def blocked(trail, index_map):
        return pl.BlockSpec((1, 1) + trail, index_map)

    any_spec = pl.BlockSpec(memory_space=_ANY)
    if order == "chunk1":
        grid = (batch, n_ac, n_b)
        stat = Ast
        streamed = Bst
        stat_ix_map = lambda b, i, j, r0s, r1s: (b, i, 0)     # noqa: E731
        stat_specs = [blocked((strip_rows + 1,), stat_ix_map),
                      blocked((a_cap,), stat_ix_map),
                      blocked((a_cap,), stat_ix_map)]
        c_map = lambda b, i, j, r0s, r1s: (b, i, 0)           # noqa: E731
        c0_specs = [blocked((strip_rows + 1,), c_map),
                    blocked((c_cap,), c_map), blocked((c_cap,), c_map)]
        out_specs = (blocked((strip_rows + 1,), c_map),
                     blocked((c_cap,), c_map), blocked((c_cap,), c_map))
        mask_specs = ([blocked((strip_rows + 1,), c_map),
                       blocked((m_cap,), c_map)] if masked else [])
        ns = dma_schedule.N_SLOTS
        bufs = [pltpu.VMEM((ns, chunk_rows + 1), jnp.int32),
                pltpu.VMEM((ns, chunk_cap), jnp.int32),
                pltpu.VMEM((ns, chunk_cap), dtype)]
    else:
        grid = (batch, n_b, n_ac)
        stat = Bst
        streamed = Ast
        stat_ix_map = lambda b, j, i, r0s, r1s: (b, j, 0)     # noqa: E731
        stat_specs = [blocked((chunk_rows + 1,), stat_ix_map),
                      blocked((chunk_cap,), stat_ix_map),
                      blocked((chunk_cap,), stat_ix_map)]
        # whole-batch-element C blocks: every (j, i) step addresses the same
        # persistent out block, strips' accumulators never leave VMEM
        c_map = lambda b, j, i, r0s, r1s: (b, 0, 0)           # noqa: E731
        c0_specs = [pl.BlockSpec((1, n_ac, strip_rows + 1), c_map),
                    pl.BlockSpec((1, n_ac, c_cap), c_map),
                    pl.BlockSpec((1, n_ac, c_cap), c_map)]
        out_specs = (pl.BlockSpec((1, n_ac, strip_rows + 1), c_map),
                     pl.BlockSpec((1, n_ac, c_cap), c_map),
                     pl.BlockSpec((1, n_ac, c_cap), c_map))
        mask_specs = ([pl.BlockSpec((1, n_ac, strip_rows + 1), c_map),
                       pl.BlockSpec((1, n_ac, m_cap), c_map)]
                      if masked else [])
        ns = dma_schedule.N_SLOTS
        bufs = [pltpu.VMEM((ns, strip_rows + 1), jnp.int32),
                pltpu.VMEM((ns, a_cap), jnp.int32),
                pltpu.VMEM((ns, a_cap), dtype)]

    kernel = functools.partial(
        _kernel, order=order, batch=batch, n_ac=n_ac, n_b=n_b,
        strip_rows=strip_rows, chunk_rows=chunk_rows, k_cols=k_cols,
        n_cols=n_cols, a_mrn=Ast.max_row_nnz, b_mrn=Bst.max_row_nnz,
        c_cap=c_cap, masked=masked, merge_fn=merge_fn,
    )
    out_shape = (
        jax.ShapeDtypeStruct((batch, n_ac, strip_rows + 1), jnp.int32),
        jax.ShapeDtypeStruct((batch, n_ac, c_cap), jnp.int32),
        jax.ShapeDtypeStruct((batch, n_ac, c_cap), dtype),
    )
    operands = [r0s, r1s, stat.indptr, stat.indices, stat.data,
                streamed.indptr, streamed.indices, streamed.data,
                C0st.indptr, C0st.indices, C0st.data]
    if masked:
        operands += [mask_st.indptr, mask_st.indices]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[*stat_specs, any_spec, any_spec, any_spec,
                      *c0_specs, *mask_specs],
            out_specs=out_specs,
            scratch_shapes=[*bufs,
                            pltpu.SemaphoreType.DMA((dma_schedule.N_SLOTS, 3))],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
