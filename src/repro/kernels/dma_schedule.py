"""Slot arithmetic of the streaming kernels' two-slot DMA double buffer.

The paper's ``copy2Fast`` overlap — start copying streamed element j+1 while
element j multiplies — is realized in both streaming kernels
(``kernels/ranged_spgemm.py`` dense slabs, ``kernels/sparse_accum_spgemm.py``
CSR triples, and through the latter ``kernels/hash_accum_spgemm.py``) as the
same schedule over a ``[N_SLOTS, ...]`` VMEM scratch buffer:

  * step ``lin == 0`` primes the pipeline: element 0 is copied into slot 0
    synchronously-before-use (started, then immediately waited on below);
  * every step with a successor starts the async copy of element ``lin + 1``
    into slot ``(lin + 1) % 2`` — the *other* slot;
  * every step waits on and reads element ``lin`` from slot ``lin % 2``.

This module is the **single source of truth** for that arithmetic: the
kernels call these functions with traced grid indices, and the static
verifier (``repro.analysis.dma``) calls them with concrete ints to simulate
the whole grid host-side and prove the schedule is race-free (the j+1 copy
never targets the slot step j reads, every copy is waited on before its
element is consumed, every element streams exactly once). One definition, so
the kernels and the checker cannot drift apart.

Every function works on both traced JAX scalars and host ints — plain
``%``/``+``/comparison arithmetic only.
"""

from __future__ import annotations

N_SLOTS = 2


class SlotSchedule:
    """The two-slot double-buffer schedule as an object, so the DMA checker
    can be handed a deliberately broken schedule (the negative fixtures in
    ``tests/test_static_audit.py``) without touching the real one."""

    n_slots = N_SLOTS

    def __init__(self):
        # double buffering is the point: with fewer than two slots the
        # prefetch of element lin+1 necessarily targets the slot step lin
        # is reading, so every schedule below two slots is a race by
        # construction — reject it before any kernel or checker runs it.
        if self.n_slots < 2:
            raise ValueError(
                f"SlotSchedule needs n_slots >= 2 (got {self.n_slots}): a "
                "single slot cannot overlap copy with compute")

    def read_slot(self, lin):
        """Slot holding streamed element ``lin`` when step ``lin`` runs."""
        return lin % self.n_slots

    def prefetch_slot(self, lin):
        """Slot the step-``lin`` prefetch of element ``lin + 1`` targets."""
        return (lin + 1) % self.n_slots

    def is_prime_step(self, lin):
        """Whether step ``lin`` must synchronously stage its own element
        (only the very first step has no predecessor to prefetch it)."""
        return lin == 0

    def prime_slot(self):
        """Slot the warm-up copy of element 0 targets (== read_slot(0))."""
        return 0

    def has_prefetch(self, lin, total):
        """Whether step ``lin`` starts the copy of element ``lin + 1``."""
        return lin + 1 < total


TWO_SLOT = SlotSchedule()

# module-level aliases: the kernels read these, keeping call sites terse
read_slot = TWO_SLOT.read_slot
prefetch_slot = TWO_SLOT.prefetch_slot
is_prime_step = TWO_SLOT.is_prime_step
prime_slot = TWO_SLOT.prime_slot
has_prefetch = TWO_SLOT.has_prefetch
