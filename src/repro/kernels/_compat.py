"""jax version compat shims shared by the Pallas kernels.

jax <= 0.4.x ships ``pltpu.TPUCompilerParams``; newer jax renamed it to
``pltpu.CompilerParams``. Every kernel imports the resolved name from here.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams
