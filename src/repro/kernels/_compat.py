"""jax version compat shims shared by the Pallas kernels.

jax <= 0.4.x ships ``pltpu.TPUCompilerParams``; newer jax renamed it to
``pltpu.CompilerParams``. Similarly the untiled slow-memory space is
``pltpu.TPUMemorySpace.ANY`` there and ``pltpu.MemorySpace.ANY`` (re-exported
as ``pltpu.ANY``) in newer jax. Every kernel imports the resolved names from
here.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams

if hasattr(pltpu, "ANY"):
    ANY = pltpu.ANY
else:  # pragma: no cover - newer jax spells it via the MemorySpace enum
    ANY = pltpu.MemorySpace.ANY
