"""Pallas CSR-native SpGEMM with a linear-probing hash accumulator in VMEM.

The ESC backend (``kernels/sparse_accum_spgemm.py``) pays for compressed
accumulation with an expand-sort-compress workspace of
``strip_nnz_cap * b_max_row_nnz + c_pad`` slots per step — the term that
erodes its VMEM win as outputs densify (ROADMAP). This kernel is the hash
variant of the same two-phase scheme (Nagasaka & Azad's hash accumulator,
the insight behind Deveci et al.'s kkmem GPU hashmap): each strip row owns a
**power-of-two linear-probing hash table** keyed by column index, sized by
the symbolic phase's ``c_max_row_nnz`` bound
(``repro.core.planner.hash_table_slots``), so the per-step workspace scales
with the densest *output* row — ``strip_rows x T`` key/value pairs — never
with the expand size.

Per grid step the merge (:func:`hash_merge_impl`) walks the in-range
products of ``A[:, r0:r1] x B_chunk`` plus the previous accumulator's
entries and insert-or-accumulates each into its row's table: probe from
``hash(col) & (T - 1)`` until the key or an empty slot is found
(a bounded ``lax.while_loop``), then scatter the value in. Because the
symbolic bound is exact and a partial C row's structure is a subset of the
final row structure, a row never holds more than ``c_max_row_nnz <= T``
distinct keys, so the probe always terminates at a match or a free slot.
Extraction sorts each row's table by key and compacts into the fixed-capacity
CSR scratch — column-sorted rows, same output convention as the ESC merge.

Everything around the merge — the symbolic phase, the fixed-capacity CSR
accumulator blocks, the two-slot DMA streaming of the non-stationary CSR
triple, the scalar-prefetched ranged column skip — is literally
``sparse_accum_spgemm_stream`` with this merge body plugged in
(``merge_fn``): one DMA pattern, three accumulators (dense slab / ESC / hash)
across the three streaming kernels.

``interpret=default_interpret()`` validates the pipeline on CPU. The probe
loops are plain ``lax.while_loop``/``lax.fori_loop`` over scalar gathers and
single-element scatters — no in-kernel argsort over the expand buffer — so
the body both interprets and traces for Mosaic; the per-row extraction sort
runs over the ``[strip_rows, T]`` table only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.planner import hash_table_slots
from repro.kernels.sparse_accum_spgemm import sparse_accum_spgemm_stream
from repro.sparse.csr import CSR

# Python ints, not jnp scalars: Pallas kernels reject captured array
# constants (same constraint the kkmem ESC body documents), and weak-typed
# int literals fold into the int32 arithmetic without promotion
_EMPTY = -1           # table key sentinel (column ids are >= 0)
_KNUTH = -1640531527  # 2654435769 as int32: Knuth's multiplicative hash


def probe_step_bound(table_size: int) -> int:
    """Static step bound of one linear-probe ``while_loop``: a probe visits
    at most every slot once, so ``table_size`` steps make the loop total even
    if a (host-checked) capacity invariant were violated. Named so the static
    DMA/loop checker (``repro.analysis.dma``) can assert the bound baked into
    the traced jaxpr *is* this function of ``planner.hash_table_slots`` —
    the kernel and the verifier derive the literal from one definition."""
    return int(table_size)


def _insert(tables, row, col, val, valid):
    """Insert-or-accumulate one (row, col, val) product into its row table.

    Linear probe from the hashed slot until the key or an empty slot is
    found; the step bound makes the while_loop total even if a (host-checked)
    capacity invariant were violated. Invalid products still probe — cheaper
    than a cond around the loop — and mask their writes.
    """
    keys, vals = tables
    size = keys.shape[1]
    bound = probe_step_bound(size)
    start = (col * _KNUTH) & (size - 1)

    def cond(state):
        slot, steps = state
        k = keys[row, slot]
        return (steps < bound) & (k != col) & (k != _EMPTY)

    def body(state):
        slot, steps = state
        return (slot + 1) & (size - 1), steps + 1

    slot, _ = lax.while_loop(cond, body, (start, jnp.int32(0)))
    keys = keys.at[row, slot].set(jnp.where(valid, col, keys[row, slot]))
    vals = vals.at[row, slot].add(
        jnp.where(valid, val, jnp.zeros((), vals.dtype)))
    return keys, vals


def hash_merge_impl(A: CSR, B_chunk: CSR, r0, r1, C_prev: CSR, c_pad: int,
                    *, table_size: int) -> CSR:
    """Hash-accumulated fused multiply-add: C = A[:, r0:r1] x B_chunk + C_prev.

    Drop-in for ``spgemm_ranged_impl`` as the streaming kernels' merge body:
    same operands, same fixed-capacity CSR output at ``c_pad``, different
    accumulator — per-row linear-probing hash tables of ``table_size``
    (power-of-two, >= the exact symbolic ``c_max_row_nnz``) instead of the
    expand-sort-compress buffer. Products are consumed entry-by-entry
    (``fori_loop`` over A's entry slots x ``b_max_row_nnz``), so no
    expand-size workspace is ever materialized.
    """
    m = A.n_rows
    size = int(table_size)
    bmax = max(B_chunk.max_row_nnz, 1)
    tables = (jnp.full((m, size), _EMPTY, jnp.int32),
              jnp.zeros((m, size), C_prev.data.dtype))

    a_nnz = A.indptr[-1]

    def per_a_entry(e, tables):
        row = jnp.clip(jnp.searchsorted(A.indptr, e, side="right") - 1,
                       0, m - 1).astype(jnp.int32)
        col_a = A.indices[e]
        in_range = (e < a_nnz) & (col_a >= r0) & (col_a < r1)
        b_row = jnp.clip(col_a - r0, 0, B_chunk.n_rows - 1)
        b_start = B_chunk.indptr[b_row]
        b_len = B_chunk.indptr[b_row + 1] - b_start
        a_val = A.data[e]

        def per_product(jj, tables):
            valid = in_range & (jj < b_len)
            src = jnp.clip(b_start + jj, 0, B_chunk.nnz_pad - 1)
            return _insert(tables, row, B_chunk.indices[src],
                           a_val * B_chunk.data[src], valid)

        return lax.fori_loop(0, bmax, per_product, tables)

    tables = lax.fori_loop(0, A.nnz_pad, per_a_entry, tables)

    prev_nnz = C_prev.indptr[-1]

    def per_prev_entry(e, tables):
        row = jnp.clip(jnp.searchsorted(C_prev.indptr, e, side="right") - 1,
                       0, m - 1).astype(jnp.int32)
        return _insert(tables, row, C_prev.indices[e], C_prev.data[e],
                       e < prev_nnz)

    keys, vals = lax.fori_loop(0, C_prev.nnz_pad, per_prev_entry, tables)

    # extraction: per-row sort by key (empties to the tail), compact into the
    # CSR scratch — realized overflow past c_pad lands in the dropped bucket,
    # but the host-side cap check makes that unreachable
    occupied = keys != _EMPTY
    counts = occupied.sum(axis=1).astype(jnp.int32)
    indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    tail = jnp.int32(jnp.iinfo(jnp.int32).max)
    sort_keys = jnp.where(occupied, keys, tail)
    order = jnp.argsort(sort_keys, axis=1)
    skeys = jnp.take_along_axis(sort_keys, order, axis=1)
    svals = jnp.take_along_axis(vals, order, axis=1)
    svalid = skeys != tail
    pos = indptr[:-1, None] + jnp.arange(size, dtype=jnp.int32)[None, :]
    slot = jnp.where(svalid, jnp.minimum(pos, c_pad), c_pad)
    indices = jnp.zeros(c_pad + 1, jnp.int32).at[slot.reshape(-1)].max(
        jnp.where(svalid, skeys, 0).reshape(-1))[:c_pad]
    data = jnp.zeros(c_pad + 1, svals.dtype).at[slot.reshape(-1)].add(
        jnp.where(svalid, svals, jnp.zeros((), svals.dtype)).reshape(-1)
    )[:c_pad]
    return CSR(indptr, indices, data, (m, B_chunk.n_cols), c_pad)


def _probe_only(tables, row, col, val, valid):
    """Accumulate ``val`` into ``(row, col)`` only if the key is already
    seeded; never inserts. Same bounded linear probe as :func:`_insert`, but
    the key array is read-only and a miss (empty slot) masks the write —
    this is what pins a masked product's output structure to the mask."""
    keys, vals = tables
    size = keys.shape[1]
    bound = probe_step_bound(size)
    start = (col * _KNUTH) & (size - 1)

    def cond(state):
        slot, steps = state
        k = keys[row, slot]
        return (steps < bound) & (k != col) & (k != _EMPTY)

    def body(state):
        slot, steps = state
        return (slot + 1) & (size - 1), steps + 1

    slot, _ = lax.while_loop(cond, body, (start, jnp.int32(0)))
    hit = keys[row, slot] == col
    vals = vals.at[row, slot].add(
        jnp.where(valid & hit, val, jnp.zeros((), vals.dtype)))
    return keys, vals


def hash_masked_merge_impl(A: CSR, B_chunk: CSR, r0, r1, C_prev: CSR,
                           c_pad: int, m_indptr, m_indices, *,
                           table_size: int) -> CSR:
    """Mask-fused hash multiply-add: C = ((A[:, r0:r1] x B_chunk) + C_prev) ∘ M.

    The masked variant of :func:`hash_merge_impl` — the fused-mask fast path
    for triangle counting (Wolf/Deveci et al.; Azad et al.'s masked SpGEMM).
    The per-row tables are **seeded** from the mask strip's structure
    (``m_indptr``/``m_indices``, value 0 — the only inserts allowed), then
    products and previous-accumulator entries accumulate *probe-only*:
    a product whose column is not a mask key hits an empty slot and its
    write is masked off. Extraction therefore emits exactly the mask
    structure (explicit zeros where no product landed) — the unmasked C is
    never materialized, at any capacity. ``table_size`` must cover the
    densest *mask* row (``hash_table_slots`` of the mask's max row nnz) and
    ``c_pad`` the largest strip's mask nnz
    (``repro.core.symbolic.masked_output_caps``).
    """
    m = A.n_rows
    size = int(table_size)
    bmax = max(B_chunk.max_row_nnz, 1)
    tables = (jnp.full((m, size), _EMPTY, jnp.int32),
              jnp.zeros((m, size), C_prev.data.dtype))

    # seed: every mask key enters its row's table with value 0 — after this,
    # the key set is frozen
    m_nnz = m_indptr[-1]

    def per_mask_entry(e, tables):
        row = jnp.clip(jnp.searchsorted(m_indptr, e, side="right") - 1,
                       0, m - 1).astype(jnp.int32)
        return _insert(tables, row, m_indices[e],
                       jnp.zeros((), tables[1].dtype), e < m_nnz)

    tables = lax.fori_loop(0, m_indices.shape[-1], per_mask_entry, tables)

    a_nnz = A.indptr[-1]

    def per_a_entry(e, tables):
        row = jnp.clip(jnp.searchsorted(A.indptr, e, side="right") - 1,
                       0, m - 1).astype(jnp.int32)
        col_a = A.indices[e]
        in_range = (e < a_nnz) & (col_a >= r0) & (col_a < r1)
        b_row = jnp.clip(col_a - r0, 0, B_chunk.n_rows - 1)
        b_start = B_chunk.indptr[b_row]
        b_len = B_chunk.indptr[b_row + 1] - b_start
        a_val = A.data[e]

        def per_product(jj, tables):
            valid = in_range & (jj < b_len)
            src = jnp.clip(b_start + jj, 0, B_chunk.nnz_pad - 1)
            return _probe_only(tables, row, B_chunk.indices[src],
                               a_val * B_chunk.data[src], valid)

        return lax.fori_loop(0, bmax, per_product, tables)

    tables = lax.fori_loop(0, A.nnz_pad, per_a_entry, tables)

    prev_nnz = C_prev.indptr[-1]

    def per_prev_entry(e, tables):
        # C_prev is a masked partial (or the zero C0): its keys are a subset
        # of the mask keys, so probe-only always hits
        row = jnp.clip(jnp.searchsorted(C_prev.indptr, e, side="right") - 1,
                       0, m - 1).astype(jnp.int32)
        return _probe_only(tables, row, C_prev.indices[e], C_prev.data[e],
                           e < prev_nnz)

    keys, vals = lax.fori_loop(0, C_prev.nnz_pad, per_prev_entry, tables)

    # extraction: identical to the unmasked merge — all *seeded* keys are
    # occupied, so the compacted output structure is the mask structure
    occupied = keys != _EMPTY
    counts = occupied.sum(axis=1).astype(jnp.int32)
    indptr = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    tail = jnp.int32(jnp.iinfo(jnp.int32).max)
    sort_keys = jnp.where(occupied, keys, tail)
    order = jnp.argsort(sort_keys, axis=1)
    skeys = jnp.take_along_axis(sort_keys, order, axis=1)
    svals = jnp.take_along_axis(vals, order, axis=1)
    svalid = skeys != tail
    pos = indptr[:-1, None] + jnp.arange(size, dtype=jnp.int32)[None, :]
    slot = jnp.where(svalid, jnp.minimum(pos, c_pad), c_pad)
    indices = jnp.zeros(c_pad + 1, jnp.int32).at[slot.reshape(-1)].max(
        jnp.where(svalid, skeys, 0).reshape(-1))[:c_pad]
    data = jnp.zeros(c_pad + 1, svals.dtype).at[slot.reshape(-1)].add(
        jnp.where(svalid, svals, jnp.zeros((), svals.dtype)).reshape(-1)
    )[:c_pad]
    return CSR(indptr, indices, data, (m, B_chunk.n_cols), c_pad)


def hash_masked_accum_spgemm_stream(Ast: CSR, Bst: CSR, C0st: CSR,
                                    mask_st: CSR, r0s: jax.Array,
                                    r1s: jax.Array, *, order: str,
                                    table_size: int,
                                    interpret: bool | None = None):
    """Streamed mask-fused hash multiply over stacked CSR strips and chunks.

    :func:`hash_accum_spgemm_stream` with the masked merge plugged in and
    the mask's stacked structure threaded through the streaming kernel's
    extra stationary operands; ``table_size`` sizes tables from the *mask*'s
    densest row (``masked_output_caps(...).c_max_row_nnz``).
    """
    if table_size < 1 or table_size != hash_table_slots(table_size):
        raise ValueError(f"table_size={table_size} must be a power of two "
                         ">= 1 (use planner.hash_table_slots)")
    merge = functools.partial(hash_masked_merge_impl, table_size=table_size)
    return sparse_accum_spgemm_stream(Ast, Bst, C0st, r0s, r1s, order=order,
                                      interpret=interpret, merge_fn=merge,
                                      mask_st=mask_st)


def hash_accum_spgemm_stream(Ast: CSR, Bst: CSR, C0st: CSR,
                             r0s: jax.Array, r1s: jax.Array, *, order: str,
                             table_size: int,
                             interpret: bool | None = None):
    """Streamed hash-accumulated multiply over stacked CSR strips and chunks.

    Operand layout, streaming orders and the returned stacked CSR triple are
    exactly :func:`sparse_accum_spgemm_stream` (which this wraps, passing the
    hash merge as ``merge_fn``); ``table_size`` is the per-row hash-table
    slot count — static, from :func:`repro.core.planner.hash_table_slots` of
    the envelope's ``c_max_row_nnz`` cap.
    """
    if table_size < 1 or table_size != hash_table_slots(table_size):
        raise ValueError(f"table_size={table_size} must be a power of two "
                         ">= 1 (use planner.hash_table_slots)")
    merge = functools.partial(hash_merge_impl, table_size=table_size)
    return sparse_accum_spgemm_stream(Ast, Bst, C0st, r0s, r1s, order=order,
                                      interpret=interpret, merge_fn=merge)
