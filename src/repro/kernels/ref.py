"""Pure-jnp oracles for every Pallas kernel (the ground truth for all kernel tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.bsr import BSR, bsr_to_dense


def bsr_spgemm_ref(A: BSR, B: BSR) -> jax.Array:
    """Dense C = A @ B (fp32 accumulation)."""
    return jnp.dot(
        bsr_to_dense(A).astype(jnp.float32),
        bsr_to_dense(B).astype(jnp.float32),
    )


def bsr_spmm_ref(A: BSR, x: jax.Array) -> jax.Array:
    return jnp.dot(bsr_to_dense(A).astype(jnp.float32), x.astype(jnp.float32))


def grouped_matmul_ref(x: jax.Array, w: jax.Array, token_group: jax.Array) -> jax.Array:
    """y[t] = x[t] @ w[token_group[t]] — per-token gather of the expert weight."""
    wt = w[token_group]  # [T, K, N]
    return jnp.einsum(
        "tk,tkn->tn", x.astype(jnp.float32), wt.astype(jnp.float32)
    )


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """Naive masked softmax attention. q: [B,Hkv,G,D]; k,v: [B,S,Hkv,D]."""
    bsz, hkv, g, d = q.shape
    s_len = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    # [B, Hkv, G, S]
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(s_len)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
