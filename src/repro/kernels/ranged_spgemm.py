"""Pallas ranged-SpGEMM with explicit double-buffered chunk prefetch.

This is the paper's `copy2Fast` overlap made explicit: the chunked algorithms
(Deveci et al. §3.2) stream one operand through fast memory while the other
stays resident, and the central GPU result is that copying chunk j+1 *while*
chunk j multiplies is what auto-caching cannot deliver. The scan executors
(repro.core.chunk_stream) leave that overlap to XLA's scheduler; here it is a
hand-written two-slot VMEM pipeline:

  * the **stationary** operand (the A strip in the Chunk1 order, the B chunk
    in the Chunk2 order) rides a normal blocked ``BlockSpec`` — Pallas stages
    it into VMEM once per outer step;
  * the **streamed** operand lives in slow memory (``pltpu.ANY``) and is
    hand-DMA'd through a ``[2, ...]`` VMEM scratch buffer: at every grid step
    the kernel starts the async copy of element j+1 into slot ``(j+1) % 2``,
    then waits on slot ``j % 2`` and multiplies — compute and the next
    transfer overlap by construction;
  * the ranged product ``C = A[:, r0:r1] x B_chunk + C_prev`` uses the
    paper's "skip columns of A outside the range" as a scalar-prefetched
    ``r0`` table (SMEM) indexing a dynamic column slice of the resident strip.

Like ``kernels/bsr_spgemm.py``, entry-level sparsity inside the staged pieces
is traded for MXU-shaped dense tiles: the staged B chunk becomes a dense
``[chunk_rows, n]`` slab (its padding rows are zero, so columns of A past the
chunk's true range multiply into nothing), the A strip a dense
``[strip_rows, k_pad]`` block. The accumulator is the output block itself,
initialized from ``C_prev`` — the fused add of the paper's modified KKMEM
sub-procedure — and flushed once per strip.

``interpret`` follows the ``default_interpret()`` pattern of ``kernels/ops.py``:
the same pallas_call validates on this CPU container (DMA semantics included)
and compiles on real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import dma_schedule
from repro.kernels._compat import ANY as _ANY


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _decompose(lin, outer: int, inner: int):
    """(b, inner index) of linear grid step ``lin`` over (batch, outer, inner)."""
    per_batch = outer * inner
    return lin // per_batch, (lin % per_batch) % inner


def _kernel(r0s_ref, stationary_ref, streamed_hbm, c0_ref, out_ref,
            stream_buf, sems, *, order: str, batch: int, n_ac: int, n_b: int,
            span: int):
    """One grid step of the streaming multiply.

    Grid is (batch, outer, inner); ``order`` fixes which operand streams:
      chunk1: outer = strips, inner = chunks  -> B slabs stream through VMEM
      chunk2: outer = chunks, inner = strips  -> A blocks stream through VMEM
    """
    b = pl.program_id(0)
    outer_ix = pl.program_id(1)
    inner_ix = pl.program_id(2)
    outer, inner = (n_ac, n_b) if order == "chunk1" else (n_b, n_ac)
    total = batch * outer * inner
    lin = (b * outer + outer_ix) * inner + inner_ix

    def dma(slot, step):
        bb, ii = _decompose(step, outer, inner)
        return pltpu.make_async_copy(
            streamed_hbm.at[bb, ii], stream_buf.at[slot], sems.at[slot]
        )

    # warm-up: the very first streamed element has no previous step to
    # prefetch it, so stage it synchronously before the overlap steady-state.
    # All slot arithmetic comes from kernels/dma_schedule — the module the
    # static DMA checker (repro.analysis.dma) simulates host-side.
    @pl.when(dma_schedule.is_prime_step(lin))
    def _prime():
        dma(dma_schedule.prime_slot(), 0).start()

    # the explicit copy2Fast overlap: start element lin+1 into the other
    # slot while this step's multiply consumes the read slot
    @pl.when(dma_schedule.has_prefetch(lin, total))
    def _prefetch():
        dma(dma_schedule.prefetch_slot(lin), lin + 1).start()

    dma(dma_schedule.read_slot(lin), lin).wait()
    streamed = stream_buf[dma_schedule.read_slot(lin)]

    if order == "chunk1":
        j, i = inner_ix, outer_ix
        r0 = r0s_ref[j]
        a_blk = stationary_ref[0, 0, :, pl.ds(r0, span)]
        b_slab = streamed
    else:
        j, i = outer_ix, inner_ix
        r0 = r0s_ref[j]
        a_blk = jax.lax.dynamic_slice_in_dim(streamed, r0, span, axis=1)
        b_slab = stationary_ref[0, 0]

    partial = jnp.dot(a_blk, b_slab, preferred_element_type=jnp.float32)

    if order == "chunk1":
        # out block = this strip; first chunk initializes from C_prev
        @pl.when(j == 0)
        def _init():
            out_ref[0, 0] = c0_ref[0, 0] + partial

        @pl.when(j > 0)
        def _acc():
            out_ref[0, 0] += partial
    else:
        # out block = the whole per-batch result; strips' partials persist in
        # it across outer (chunk) steps — no fast<->slow partial bounce
        @pl.when(j == 0)
        def _init():
            out_ref[0, i] = c0_ref[0, i] + partial

        @pl.when(j > 0)
        def _acc():
            out_ref[0, i] += partial


def ranged_spgemm_stream(a_dense: jax.Array, b_slabs: jax.Array,
                         c0: jax.Array, r0s: jax.Array, *, order: str,
                         interpret: bool | None = None) -> jax.Array:
    """Fused streaming multiply ``C[b, i] = sum_j A[b, i][:, r0_j:r0_j+span] @
    B_slab[b, j] + C_prev[b, i]`` with explicit double-buffered prefetch.

    Args:
      a_dense: f32[batch, n_ac, strip_rows, k_pad] — densified A strips, with
        ``k_pad >= n_cols(A) + span`` so the ranged column slice of the last
        chunk never reads out of bounds (the spill columns multiply the
        slab's zero padding rows).
      b_slabs: f32[batch, n_b, span, n] — densified staged B chunks; rows
        past a chunk's true span are zero.
      c0:      f32[batch, n_ac, strip_rows, n] — the fused ``C_prev``.
      r0s:     i32[n_b] — global start row of each B chunk (scalar-prefetched).
      order:   "chunk1" (strips outer, B slabs streamed) or "chunk2"
               (chunks outer, A blocks streamed).

    Returns f32[batch, n_ac, strip_rows, n].
    """
    if order not in ("chunk1", "chunk2"):
        raise ValueError(f"unknown streaming order {order!r}")
    batch, n_ac, strip_rows, k_pad = a_dense.shape
    _, n_b, span, n = b_slabs.shape
    if c0.shape != (batch, n_ac, strip_rows, n):
        raise ValueError(f"c0 shape {c0.shape} != {(batch, n_ac, strip_rows, n)}")
    if k_pad < span:
        raise ValueError(f"k_pad={k_pad} < span={span}: A not column-padded")
    interpret = default_interpret() if interpret is None else interpret

    if order == "chunk1":
        grid = (batch, n_ac, n_b)
        stationary_spec = pl.BlockSpec(
            (1, 1, strip_rows, k_pad), lambda b, i, j, r0s: (b, i, 0, 0)
        )
        streamed, stationary = b_slabs, a_dense
        stream_buf = pltpu.VMEM((dma_schedule.N_SLOTS, span, n), jnp.float32)
        c0_spec = pl.BlockSpec(
            (1, 1, strip_rows, n), lambda b, i, j, r0s: (b, i, 0, 0)
        )
        out_spec = pl.BlockSpec(
            (1, 1, strip_rows, n), lambda b, i, j, r0s: (b, i, 0, 0)
        )
        out_shape = jax.ShapeDtypeStruct((batch, n_ac, strip_rows, n),
                                         jnp.float32)
    else:
        grid = (batch, n_b, n_ac)
        stationary_spec = pl.BlockSpec(
            (1, 1, span, n), lambda b, j, i, r0s: (b, j, 0, 0)
        )
        streamed, stationary = a_dense, b_slabs
        stream_buf = pltpu.VMEM((dma_schedule.N_SLOTS, strip_rows, k_pad),
                                jnp.float32)
        # one whole-result c0 block per batch element (fetched once, read at
        # j == 0), matching the out block it initializes
        c0_spec = pl.BlockSpec(
            (1, n_ac, strip_rows, n), lambda b, j, i, r0s: (b, 0, 0, 0)
        )
        out_spec = pl.BlockSpec(
            (1, n_ac, strip_rows, n), lambda b, j, i, r0s: (b, 0, 0, 0)
        )
        out_shape = jax.ShapeDtypeStruct((batch, n_ac, strip_rows, n),
                                         jnp.float32)

    kernel = functools.partial(
        _kernel, order=order, batch=batch, n_ac=n_ac, n_b=n_b, span=span
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                stationary_spec,
                pl.BlockSpec(memory_space=_ANY),
                c0_spec,
            ],
            out_specs=out_spec,
            scratch_shapes=[
                stream_buf,
                pltpu.SemaphoreType.DMA((dma_schedule.N_SLOTS,)),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(r0s, stationary, streamed, c0)
