"""Public jit'd wrappers around the Pallas kernels.

Each wrapper does the host-side symbolic planning (NumPy), appends the zero-sentinel
blocks, and dispatches the pallas_call. ``interpret`` defaults to True off-TPU so the
same code path validates on this CPU container and compiles on real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.bsr import BSR
from repro.kernels import bsr_spgemm as _spgemm
from repro.kernels import bsr_spmm as _spmm
from repro.kernels import grouped_matmul as _gmm
from repro.kernels import chunked_attention as _attn


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _with_zero_block(blocks: jax.Array) -> jax.Array:
    """Append the guaranteed-zero sentinel block (slot index = old length)."""
    bs = blocks.shape[-1]
    return jnp.concatenate(
        [blocks, jnp.zeros((1, bs, bs), blocks.dtype)], axis=0
    )


def bsr_spgemm(A: BSR, B: BSR, meta: _spgemm.BsrSpgemmMeta | None = None,
               skip_zero: bool = True, interpret: bool | None = None) -> BSR:
    """C = A @ B as BSR with host-planned block structure."""
    if A.shape[1] != B.shape[0] or A.block_size != B.block_size:
        raise ValueError(f"incompatible operands {A.shape} x {B.shape}")
    meta = meta or _spgemm.bsr_spgemm_symbolic(A, B)
    interpret = default_interpret() if interpret is None else interpret
    blocks = _spgemm.bsr_spgemm_blocks(
        _with_zero_block(A.blocks),
        _with_zero_block(B.blocks),
        jnp.asarray(meta.a_slots),
        jnp.asarray(meta.b_slots),
        nc_pad=meta.nc_pad,
        u_max=meta.u_max,
        bs=A.block_size,
        out_dtype=jnp.float32,
        skip_zero=skip_zero,
        interpret=interpret,
    )
    per_row = meta.c_indptr[1:] - meta.c_indptr[:-1]
    return BSR(
        block_indptr=jnp.asarray(meta.c_indptr),
        block_indices=jnp.asarray(meta.c_indices),
        blocks=blocks,
        shape=(A.shape[0], B.shape[1]),
        block_size=A.block_size,
        max_row_blocks=int(per_row.max()) if per_row.size else 0,
    )


def bsr_spmm(A: BSR, x: jax.Array, meta: _spmm.BsrSpmmMeta | None = None,
             bn: int = 128, interpret: bool | None = None) -> jax.Array:
    """y = A @ x with dense x [A.shape[1], nf]."""
    if x.shape[0] != A.shape[1]:
        raise ValueError(f"incompatible {A.shape} @ {x.shape}")
    meta = meta or _spmm.bsr_spmm_symbolic(A)
    interpret = default_interpret() if interpret is None else interpret
    nf = x.shape[1]
    bn_eff = min(bn, nf)
    if nf % bn_eff:
        raise ValueError(f"nf={nf} not divisible by bn={bn_eff}")
    return _spmm.bsr_spmm_blocks(
        _with_zero_block(A.blocks),
        x,
        jnp.asarray(meta.a_slots),
        jnp.asarray(meta.a_cols),
        mb=A.mb,
        u_max=meta.u_max,
        bs=A.block_size,
        bn=bn_eff,
        interpret=interpret,
    )


def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes,
                   bt: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool | None = None):
    """Ragged grouped GEMM over *unsorted-by-tile* data already grouped by expert:
    x rows [sum(group_sizes), K] laid out group-contiguously.

    Returns (y [T_pad, N], padded_offsets) where rows [padded_offsets[g],
    padded_offsets[g] + group_sizes[g]) of y hold group g's outputs.
    """
    interpret = default_interpret() if interpret is None else interpret
    offsets, tile_group, t_pad = _gmm.plan_groups(np.asarray(group_sizes), bt)
    kdim = x.shape[1]
    # scatter group-contiguous rows into padded layout
    sizes = np.asarray(group_sizes, np.int64)
    src_off = np.concatenate([[0], np.cumsum(sizes)])
    dst_rows = np.concatenate(
        [np.arange(sizes[g]) + offsets[g] for g in range(sizes.size)]
    ) if sizes.size else np.zeros(0, np.int64)
    xp = jnp.zeros((t_pad, kdim), x.dtype).at[jnp.asarray(dst_rows)].set(
        x[: int(src_off[-1])]
    )
    y = _gmm.grouped_matmul_padded(
        xp, w, jnp.asarray(tile_group), bt=bt, bn=bn, bk=bk, interpret=interpret
    )
    return y, offsets


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, lengths: jax.Array,
                     bs_kv: int = 512, interpret: bool | None = None) -> jax.Array:
    interpret = default_interpret() if interpret is None else interpret
    return _attn.decode_attention(q, k, v, lengths, bs_kv=bs_kv, interpret=interpret)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, bq: int = 256,
                  bk: int = 512, window: int = 0,
                  interpret: bool | None = None) -> jax.Array:
    from repro.kernels import flash_prefill as _fp

    interpret = default_interpret() if interpret is None else interpret
    return _fp.flash_prefill(q, k, v, bq=bq, bk=bk, window=window,
                             interpret=interpret)
