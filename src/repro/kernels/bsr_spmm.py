"""BSR x dense SpMM Pallas kernel (paper's SpMM comparison point, Zheng et al. [24]).

Y = A_bsr @ X with X dense. Grid (mb, nf_tiles, U): each step stages one (bs x bs)
A block and the matching (bs x bn) X row-slab into VMEM; dense accumulation in a
VMEM scratch tile. Scalar-prefetched per-block-row slot/column tables realize the
"skip empty blocks" logic; padding points at the appended zero block, whose column
table entry 0 makes the X fetch harmless.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.sparse.bsr import BSR


@dataclasses.dataclass(frozen=True)
class BsrSpmmMeta:
    a_slots: np.ndarray   # int32[mb, U] -> index into A.blocks (zero sentinel = nbl_pad)
    a_cols: np.ndarray    # int32[mb, U] -> block-column of that slot (sentinel -> 0)
    u_max: int
    flops: int


def bsr_spmm_symbolic(A: BSR) -> BsrSpmmMeta:
    a_ptr = np.asarray(A.block_indptr, np.int64)
    a_idx = np.asarray(A.block_indices, np.int64)
    mb = A.mb
    lens = a_ptr[1:] - a_ptr[:-1]
    u_max = int(lens.max()) if mb else 1
    u_max = max(u_max, 1)
    slots = np.full((mb, u_max), A.nbl_pad, np.int32)
    cols = np.zeros((mb, u_max), np.int32)
    for i in range(mb):
        s, e = int(a_ptr[i]), int(a_ptr[i + 1])
        slots[i, : e - s] = np.arange(s, e, dtype=np.int32)
        cols[i, : e - s] = a_idx[s:e]
    return BsrSpmmMeta(a_slots=slots, a_cols=cols, u_max=u_max,
                       flops=2 * int(lens.sum()) * A.block_size ** 2)


def _kernel(a_slots_ref, a_cols_ref, a_blocks_ref, x_ref, out_ref, acc_ref, *,
            u_max: int):
    u = pl.program_id(2)

    @pl.when(u == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_blocks_ref[0], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(u == u_max - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def bsr_spmm_blocks(a_blocks: jax.Array, x: jax.Array, a_slots: jax.Array,
                    a_cols: jax.Array, mb: int, u_max: int, bs: int, bn: int,
                    out_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """Y[mb*bs, nf] = A @ X. ``a_blocks`` carries the appended zero block."""
    nf = x.shape[1]
    grid = (mb, nf // bn, u_max)
    return pl.pallas_call(
        functools.partial(_kernel, u_max=u_max),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bs, bs), lambda i, j, u, sl, co: (sl[i, u], 0, 0)),
                pl.BlockSpec((bs, bn), lambda i, j, u, sl, co: (co[i, u], j)),
            ],
            out_specs=pl.BlockSpec((bs, bn), lambda i, j, u, sl, co: (i, j)),
            scratch_shapes=[pltpu.VMEM((bs, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((mb * bs, nf), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_slots, a_cols, a_blocks, x)
