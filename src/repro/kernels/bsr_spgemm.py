"""BSR x BSR SpGEMM Pallas kernel — the paper's chunked numeric phase, TPU-native.

Mapping from the paper (DESIGN.md §2):
  * fast memory  = VMEM; slow memory = HBM.
  * `copy2Fast`  = the Pallas pipeline: each grid step DMAs one (bs x bs) block of A
    and B into VMEM while the MXU works on the previous pair (double-buffering — the
    paper's "future work" — is native here).
  * hashmap accumulator -> dense (bs x bs) fp32 VMEM scratch tile per C block.
  * "skip columns of A outside the range" -> scalar-prefetched (SMEM) slot tables:
    the index_map only ever schedules contributing blocks; padding slots point at a
    guaranteed all-zero block so the pipeline stays branch-free.

Grid: (n_c_blocks_pad, U) where U = max contributors (k-blocks) to any C block.
Work is proportional to nnz-blocks of C — entry-level sparsity inside a block is
given up in exchange for MXU-shaped dense tiles (the TPU trade the paper's GPU
hashmaps cannot make).

The symbolic phase (host, NumPy) is KKMEM's compression in block form: C's block
structure is the union of B's block-rows selected by A's block-columns.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

from repro.sparse.bsr import BSR


@dataclasses.dataclass(frozen=True)
class BsrSpgemmMeta:
    """Host-computed symbolic structure of C = A x B at block granularity."""

    c_indptr: np.ndarray     # int32[mb + 1]
    c_indices: np.ndarray    # int32[nc_pad]
    a_slots: np.ndarray      # int32[nc_pad, U]  (zero-sentinel = A's appended zero block)
    b_slots: np.ndarray      # int32[nc_pad, U]
    n_c_blocks: int
    nc_pad: int
    u_max: int
    flops: int               # 2 * bs^3 * total contributor pairs (MXU flops)


def bsr_spgemm_symbolic(A: BSR, B: BSR, pad_multiple: int = 8,
                        nc_pad: int | None = None,
                        u_max: int | None = None) -> BsrSpgemmMeta:
    """Block-level symbolic phase: structure of C and contributor slot tables.

    The zero-sentinel slot is ``A.nbl_pad`` / ``B.nbl_pad`` — the wrapper appends one
    guaranteed-zero block to each blocks array before the pallas_call.

    ``nc_pad`` / ``u_max``, when given, are envelope-level *floors* (from
    ``repro.core.symbolic.bsr_plan_caps``): the tables are shaped to them so
    every (strip, chunk) pair under one envelope compiles to one kernel
    geometry. A realized structure exceeding a floor raises ``ValueError``
    loudly — the kernel would otherwise drop contributor pairs (table
    columns past ``u_max``) or C blocks (rows past ``nc_pad``) silently.
    """
    a_ptr = np.asarray(A.block_indptr, np.int64)
    a_idx = np.asarray(A.block_indices, np.int64)
    b_ptr = np.asarray(B.block_indptr, np.int64)
    b_idx = np.asarray(B.block_indices, np.int64)
    mb = A.mb
    n_a = int(a_ptr[-1])
    a_rows = np.repeat(np.arange(mb, dtype=np.int64), a_ptr[1:] - a_ptr[:-1])
    a_cols = a_idx[:n_a]
    a_slot = np.arange(n_a, dtype=np.int64)
    # fan each A block out over B's block-row a_cols[s]
    lens = b_ptr[a_cols + 1] - b_ptr[a_cols]
    total = int(lens.sum())
    cum = np.concatenate([[0], np.cumsum(lens)])
    p = np.arange(total, dtype=np.int64)
    t = np.searchsorted(cum, p, side="right") - 1
    pos_in_row = p - cum[t]
    pair_a_slot = a_slot[t]
    pair_b_slot = b_ptr[a_cols[t]] + pos_in_row
    pair_i = a_rows[t]
    pair_j = b_idx[pair_b_slot]
    # group pairs by C block (i, j)
    key = pair_i * np.int64(B.nb) + pair_j
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, start = np.unique(key_s, return_index=True)
    counts = np.diff(np.concatenate([start, [total]]))
    n_c = int(uniq.size)
    u = int(counts.max()) if n_c else 1
    if u_max is None:
        u_max = u
    elif u > u_max:
        raise ValueError(
            f"u_max={u_max} < realized contributor count {u}: the envelope's "
            f"block caps do not dominate this instance — rebuild the envelope "
            f"(bsr_plan_caps) from the instances it serves"
        )
    if nc_pad is None:
        nc_pad = -(-max(n_c, 1) // pad_multiple) * pad_multiple
    elif n_c > nc_pad:
        raise ValueError(
            f"nc_pad={nc_pad} < realized C block count {n_c}: the envelope's "
            f"block caps do not dominate this instance — rebuild the envelope "
            f"(bsr_plan_caps) from the instances it serves"
        )
    a_zero, b_zero = A.nbl_pad, B.nbl_pad  # appended zero-block slots
    a_tab = np.full((nc_pad, u_max), a_zero, np.int32)
    b_tab = np.full((nc_pad, u_max), b_zero, np.int32)
    # scatter contributor slots into the per-C-block tables
    grp = np.repeat(np.arange(n_c), counts)
    col = p - np.repeat(start, counts)  # position within group (pairs are sorted)
    a_tab[grp, col] = pair_a_slot[order].astype(np.int32)
    b_tab[grp, col] = pair_b_slot[order].astype(np.int32)
    c_i = (uniq // B.nb).astype(np.int64)
    c_j = (uniq % B.nb).astype(np.int32)
    c_indptr = np.zeros(mb + 1, np.int64)
    np.add.at(c_indptr, c_i + 1, 1)
    c_indptr = np.cumsum(c_indptr).astype(np.int32)
    c_indices = np.zeros(nc_pad, np.int32)
    c_indices[:n_c] = c_j
    # padding invariants consumers rely on: c_indptr spans exactly the n_c
    # real blocks (so a scatter driven by it can never touch a padding row),
    # and padding table rows are all-sentinel (their grid steps MAC nothing,
    # flushing a zero tile). c_indices past n_c stays 0 — aliasing real block
    # (i, 0) if a consumer scattered the padded tail, which is why every
    # consumer must crop the kernel output to n_c_blocks first.
    assert int(c_indptr[-1]) == n_c, (c_indptr[-1], n_c)
    assert (a_tab[n_c:] == a_zero).all() and (b_tab[n_c:] == b_zero).all()
    return BsrSpgemmMeta(
        c_indptr=c_indptr,
        c_indices=c_indices,
        a_slots=a_tab,
        b_slots=b_tab,
        n_c_blocks=n_c,
        nc_pad=nc_pad,
        u_max=u_max,
        flops=2 * (A.block_size ** 3) * total,
    )


def _kernel(a_slots_ref, b_slots_ref, a_blocks_ref, b_blocks_ref, out_ref, acc_ref,
            *, u_max: int, skip_zero: bool, a_zero_slot: int):
    """One (C block e, contributor u) step: acc += A_blk @ B_blk."""
    u = pl.program_id(1)

    @pl.when(u == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if skip_zero:
        e = pl.program_id(0)
        valid = a_slots_ref[e, u] != a_zero_slot

        @pl.when(valid)
        def _mac():
            acc_ref[...] += jnp.dot(
                a_blocks_ref[0], b_blocks_ref[0], preferred_element_type=jnp.float32
            )
    else:
        acc_ref[...] += jnp.dot(
            a_blocks_ref[0], b_blocks_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(u == u_max - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def bsr_spgemm_blocks(a_blocks: jax.Array, b_blocks: jax.Array, a_slots: jax.Array,
                      b_slots: jax.Array, nc_pad: int, u_max: int, bs: int,
                      out_dtype=jnp.float32, skip_zero: bool = True,
                      interpret: bool = False) -> jax.Array:
    """Run the kernel. ``a_blocks``/``b_blocks`` must already carry the appended
    zero block at index nbl_pad (i.e. shapes (nbl_pad + 1, bs, bs))."""
    a_zero_slot = a_blocks.shape[0] - 1
    grid = (nc_pad, u_max)
    kernel = functools.partial(
        _kernel, u_max=u_max, skip_zero=skip_zero, a_zero_slot=a_zero_slot
    )
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, bs, bs), lambda e, u, a_s, b_s: (a_s[e, u], 0, 0)
                ),
                pl.BlockSpec(
                    (1, bs, bs), lambda e, u, a_s, b_s: (b_s[e, u], 0, 0)
                ),
            ],
            out_specs=pl.BlockSpec((1, bs, bs), lambda e, u, a_s, b_s: (e, 0, 0)),
            scratch_shapes=[pltpu.VMEM((bs, bs), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nc_pad, bs, bs), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_slots, b_slots, a_blocks, b_blocks)
