"""Deterministic synthetic LM data pipeline.

A structured pseudo-text stream (Zipf-ish unigram mixture with short-range
repetition so models have something learnable) generated from a counter-based
PRNG: batch ``i`` is reproducible from ``(seed, i)`` alone, which is what makes
checkpoint-resume exactly replayable — the restored step index fully determines
the remaining stream. Sharding: each batch is placed with the data-parallel batch
sharding (device_put with a NamedSharding) before it enters the jitted step.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    repeat_p: float = 0.3

    def batch(self, index: int) -> dict:
        """Batch ``index`` (stateless — any index at any time)."""
        rng = np.random.default_rng((self.seed, index))
        v = self.cfg.vocab_size
        b, s = self.batch_size, self.seq_len
        base = rng.zipf(self.zipf_a, size=(b, s + 1)) % v
        # short-range repetition: with prob repeat_p, copy the token 2 back
        rep = rng.random((b, s + 1)) < self.repeat_p
        toks = base.copy()
        toks[:, 2:] = np.where(rep[:, 2:], toks[:, :-2], toks[:, 2:])
        toks = toks.astype(np.int32)
        out = {}
        if self.cfg.frontend != "none":
            emb_rng = np.random.default_rng((self.seed, index, 1))
            out["embeds"] = emb_rng.standard_normal(
                (b, s, tf.frontend_dim(self.cfg)), dtype=np.float32)
        else:
            out["tokens"] = toks[:, :s]
        out["labels"] = toks[:, 1 : s + 1]
        return out


def make_batch_iterator(cfg: ModelConfig, batch_size: int, seq_len: int,
                        seed: int = 0, start_index: int = 0, shardings=None):
    """Infinite iterator of device-placed batches starting at ``start_index``."""
    src = SyntheticLM(cfg, batch_size, seq_len, seed)
    i = start_index
    while True:
        host = src.batch(i)
        if shardings is not None:
            batch = jax.tree.map(
                lambda x, s: jax.device_put(x, s), host, shardings)
        else:
            batch = jax.tree.map(jnp.asarray, host)
        yield i, batch
        i += 1
