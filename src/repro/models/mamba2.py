"""Mamba-2 (SSD) block for the Zamba2 hybrid (arXiv:2411.15242 / 2405.21060).

Per head h (P = head dim, N = state dim):
    h_t = exp(A * dt_t) h_{t-1} + dt_t * x_t  B_t^T        (state: P x N)
    y_t = h_t C_t + D * x_t
with scalar A < 0 per head, dt_t = softplus(dt_proj(u_t) + dt_bias), and B_t, C_t
shared across heads (n_groups = 1). A causal depthwise conv (width 4) precedes the
SSM on (x, B, C), and a SiLU gate z wraps the output — the Mamba-2 layout.

Two forms, tested equal:
  * ``ssd_scan``    — sequential scan (decode / oracle)
  * ``ssd_chunked`` — the SSD chunked-parallel form: intra-chunk masked matmuls +
    inter-chunk state recurrence. This IS the paper's chunking idea on the time
    axis (DESIGN.md §5) and the training path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, pdtype

CONV_W = 4
EXPAND = 2


def mamba_dims(cfg: ModelConfig):
    d_inner = EXPAND * cfg.d_model
    p = cfg.ssm_head_dim
    nh = d_inner // p
    n = cfg.ssm_state
    return d_inner, p, nh, n


def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, p, nh, n = mamba_dims(cfg)
    keys = jax.random.split(key, 8)
    s = d ** -0.5
    pd = pdtype(cfg)
    conv_ch = d_inner + 2 * n   # conv over (x, B, C)
    return {
        "in_proj": jax.random.normal(
            keys[0], (d, 2 * d_inner + 2 * n + nh), pd) * s,
        "conv_w": jax.random.normal(keys[1], (CONV_W, conv_ch), pd) * 0.5,
        "conv_b": jnp.zeros((conv_ch,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(pd),   # A = -exp(A_log)
        "dt_bias": jnp.full((nh,), -2.0, pd),
        "D": jnp.ones((nh,), pd),
        "norm_scale": jnp.ones((d_inner,), pd),   # gated RMSNorm before out proj
        "out_proj": jax.random.normal(keys[2], (d_inner, d), pd) * (d_inner ** -0.5),
    }


def _split_proj(params, u, cfg: ModelConfig, dt):
    d_inner, p, nh, n = mamba_dims(cfg)
    zxbcdt = u @ params["in_proj"].astype(dt)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * n :]
    return z, xbc, dt_raw


def _causal_conv(params, xbc, dt, conv_state=None, valid_len=None):
    """Depthwise causal conv width CONV_W. xbc: [B, S, C]. conv_state: [B, W-1, C].
    Returns (y, new_conv_state). ``valid_len`` marks the last real (unpadded)
    position so the carried conv state never contains padding."""
    b, s, c = xbc.shape
    w = params["conv_w"].astype(dt)   # [W, C]
    if conv_state is None:
        conv_state = jnp.zeros((b, CONV_W - 1, c), xbc.dtype)
    ext = jnp.concatenate([conv_state, xbc], axis=1)   # [B, W-1+S, C]
    out = sum(
        ext[:, i : i + s, :] * w[i] for i in range(CONV_W)
    ) + params["conv_b"].astype(dt)
    end = (valid_len if valid_len is not None else s) + (CONV_W - 1)
    return jax.nn.silu(out), ext[:, end - (CONV_W - 1) : end, :]


def _gated_norm(params, y, z, eps=1e-5):
    y32 = y.astype(jnp.float32)
    var = jnp.mean(y32 * y32, axis=-1, keepdims=True)
    y32 = y32 * jax.lax.rsqrt(var + eps) * params["norm_scale"].astype(jnp.float32)
    return (y32 * jax.nn.silu(z.astype(jnp.float32))).astype(y.dtype)


def _prep(params, u, cfg: ModelConfig, conv_state=None, valid_len=None):
    d_inner, p, nh, n = mamba_dims(cfg)
    dt = cdtype(cfg)
    b, s, _ = u.shape
    z, xbc, dt_raw = _split_proj(params, u, cfg, dt)
    xbc, conv_state = _causal_conv(params, xbc, dt, conv_state, valid_len=valid_len)
    x = xbc[..., :d_inner].reshape(b, s, nh, p)
    bmat = xbc[..., d_inner : d_inner + n]             # [B, S, N]
    cmat = xbc[..., d_inner + n :]                     # [B, S, N]
    delta = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                   # [B, S, nh]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))   # [nh]
    decay = jnp.exp(delta * a[None, None, :])           # [B, S, nh]
    return z, x, bmat, cmat, delta, decay, conv_state


def ssd_scan(params, u, cfg: ModelConfig, state=None, conv_state=None):
    """Sequential form. u: [B, S, d]. state: [B, nh, P, N].
    Returns (y [B, S, d], state, conv_state)."""
    d_inner, p, nh, n = mamba_dims(cfg)
    dt = cdtype(cfg)
    b, s, _ = u.shape
    z, x, bmat, cmat, delta, decay, conv_state = _prep(params, u, cfg, conv_state)
    dfac = params["D"].astype(jnp.float32)

    def step(h, inputs):
        xt, bt, ct, dlt, dct = inputs    # [b,nh,p], [b,n], [b,n], [b,nh], [b,nh]
        dx = (dlt[..., None] * xt.astype(jnp.float32))       # [b, nh, p]
        h_new = dct[..., None, None] * h + dx[..., :, None] * bt[:, None, None, :]
        yt = jnp.einsum("bhpn,bn->bhp", h_new, ct.astype(jnp.float32))
        yt = yt + dfac[None, :, None] * xt.astype(jnp.float32)
        return h_new, yt

    h0 = jnp.zeros((b, nh, p, n), jnp.float32) if state is None else state
    xs = (
        x.transpose(1, 0, 2, 3),
        bmat.transpose(1, 0, 2),
        cmat.transpose(1, 0, 2),
        delta.transpose(1, 0, 2),
        decay.transpose(1, 0, 2),
    )
    h_out, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d_inner)
    y = _gated_norm(params, y, z)
    return y @ params["out_proj"].astype(dt), h_out, conv_state


def ssd_chunked(params, u, cfg: ModelConfig, chunk: int = 64, state=None,
                conv_state=None):
    """SSD chunked-parallel form: identical math, chunked over time."""
    d_inner, p, nh, n = mamba_dims(cfg)
    dt = cdtype(cfg)
    b, s, _ = u.shape
    pad = (-s) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    z, x, bmat, cmat, delta, decay, conv_state = _prep(params, u, cfg, conv_state,
                                                       valid_len=s)
    dfac = params["D"].astype(jnp.float32)
    if pad:
        # padded steps must not touch the carried state: decay 1, contribution 0
        valid = (jnp.arange(sp) < s)[None, :, None]
        decay = jnp.where(valid, decay, 1.0)
        delta = jnp.where(valid, delta, 0.0)
    nc = sp // chunk

    xc = x.reshape(b, nc, chunk, nh, p).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3).astype(jnp.float32)
    dl = delta.reshape(b, nc, chunk, nh).transpose(1, 0, 3, 2)   # [nc,b,nh,c]
    la = jnp.log(jnp.maximum(decay, 1e-37)).reshape(b, nc, chunk, nh) \
        .transpose(1, 0, 3, 2)                                   # [nc,b,nh,c]
    ci = jnp.cumsum(la, axis=-1)        # inclusive cumlog within chunk
    tot = ci[..., -1:]

    def chunk_step(h, inputs):
        xt, bt, ct, dlt, ci_t, tot_t = inputs
        # intra-chunk: y_t += sum_{j<=t} (prod_{j<i<=t} a_i) dl_j x_j B_j^T C_t
        # pairwise decay L[t, j] = exp(ci_t - ci_j) for j <= t.
        # Mask in LOG space before exp: upper-triangle differences are positive and
        # can overflow; exp(inf) * 0 would poison reverse-mode cotangents.
        diff = ci_t[..., :, None] - ci_t[..., None, :]           # [b,nh,c,c]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.exp(jnp.where(tri[None, None], diff, -1e30))
        gram = jnp.einsum("btn,bjn->btj", ct, bt)                # [b,c,c]
        att = L * gram[:, None]                                  # [b,nh,c,c]
        dx = dlt[..., :, None] * xt                              # [b,nh,c,p]
        y_intra = jnp.einsum("bhtj,bhjp->bhtp", att, dx)
        # inter-chunk: y_t += C_t . (prod_{i<=t} a_i) h_in
        y_inter = jnp.einsum(
            "bhpn,btn,bht->bhtp", h, ct, jnp.exp(ci_t)
        )
        # state update: h' = exp(tot) h + sum_j (prod_{j<i<=C} a_i) dl_j x_j B_j^T
        k_tail = jnp.exp(tot_t - ci_t)[..., None] * dx           # [b,nh,c,p]
        h_new = jnp.exp(tot_t)[..., None] * h + jnp.einsum(
            "bhjp,bjn->bhpn", k_tail, bt)
        y = y_intra + y_inter + dfac[None, :, None, None] * xt
        return h_new, y

    h0 = jnp.zeros((b, nh, p, n), jnp.float32) if state is None else state
    h_out, ys = jax.lax.scan(chunk_step, h0, (xc, bc, cc, dl, ci, tot))
    # ys: [nc, b, nh, chunk, p] -> [b, s, d_inner]
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, sp, d_inner)[:, :s]
    y = _gated_norm(params, y, z[:, :s] if pad else z)
    return y @ params["out_proj"].astype(dt), h_out, conv_state
