"""repro.models — LM substrate for the 10 assigned architectures.

Pure-functional JAX models (param pytrees of plain dicts), with three entry points
per architecture: ``forward`` (training), ``prefill`` (build KV cache / state), and
``decode_step`` (one token with cache/state). Layer stacks are scanned + remat'd so
the 95-layer configs lower to compact HLO.
"""

from repro.models.config import ModelConfig

__all__ = ["ModelConfig"]
