"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

The expert compute is a grouped (ragged block) GEMM — exactly the paper's chunked
SpGEMM at block granularity (DESIGN.md §4.1). Two execution paths:

  * reference (default, this file): sort tokens by expert, gather into a dense
    [E, capacity, d] buffer, batched einsum over experts, weighted scatter-back.
    Pure jnp -> lowers/shards everywhere (the dry-run path; experts are
    EP-sharded on the "model" mesh axis so the gathers become all-to-alls).
  * kernels.grouped_matmul: the Pallas chunk-streamed path for real TPUs,
    validated against this one in tests.

Router: softmax over the top-k logits (Mixtral-style normalization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, pdtype


def moe_init(key, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    return {
        "router": jax.random.normal(k1, (d, e), pdtype(cfg)) * s_in,
        "w1": jax.random.normal(k2, (e, d, ff), pdtype(cfg)) * s_in,
        "w3": jax.random.normal(k3, (e, d, ff), pdtype(cfg)) * s_in,
        "w2": jax.random.normal(k4, (e, ff, d), pdtype(cfg)) * s_out,
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)


def moe_apply(params, x, cfg: ModelConfig):
    """x: [B, S, d] -> (y, aux_loss). Tokens over capacity are dropped (standard
    capacity-based MoE; the residual stream carries them unchanged).

    LOCAL (per-row) dispatch: the sort that groups assignments by expert runs
    within each batch row, never across rows. Capacity is per row (the
    production-standard "per-device capacity"). This keeps every tensor's
    leading batch dim intact, so data-parallel sharding propagates through the
    layer instead of being destroyed by a global argsort — measured in the
    §Perf log as the difference between a replicated 32 GB expert buffer per
    device and a properly sharded one (EXPERIMENTS.md)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(cfg, s)          # per row
    dt = cdtype(cfg)

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_logit, top_idx = jax.lax.top_k(logits, k)             # [B, S, k]
    top_w = jax.nn.softmax(top_logit, axis=-1)                # renormalized over k

    # ---- per-row sort-based dispatch -----------------------------------------
    sk = s * k
    expert_flat = top_idx.reshape(b, sk)                      # [B, S*k]
    w_flat = top_w.reshape(b, sk)
    order = jnp.argsort(expert_flat, axis=-1, stable=True)    # group by expert
    e_sorted = jnp.take_along_axis(expert_flat, order, axis=-1)
    tok_sorted = order // k                                   # token within row
    w_sorted = jnp.take_along_axis(w_flat, order, axis=-1)
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(e_sorted)
    pos_in_grp = jnp.arange(sk)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=-1)
    keep = pos_in_grp < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_grp, e * cap)   # [B, S*k]

    bidx = jnp.arange(b)[:, None]
    gathered = jnp.take_along_axis(
        x.astype(dt), tok_sorted[..., None], axis=1)          # [B, S*k, d]
    buf = jnp.zeros((b, e * cap + 1, d), dt).at[bidx, slot].set(gathered)
    he = buf[:, : e * cap].reshape(b, e, cap, d)
    from repro.parallel import constraints as con
    he = con.expert_buffer(he, cfg)

    # ---- expert FFN (batched over experts; EP-shardable einsums) -------------
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", he, params["w1"].astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", he, params["w3"].astype(dt))
    ye = jnp.einsum("becf,efd->becd", h, params["w2"].astype(dt))

    # ---- weighted scatter-back ------------------------------------------------
    ye_flat = jnp.concatenate(
        [ye.reshape(b, e * cap, d), jnp.zeros((b, 1, d), dt)], axis=1)
    contrib = ye_flat[bidx, slot] * (w_sorted[..., None].astype(dt)
                                     * keep[..., None])
    y = jnp.zeros((b, s, d), dt).at[bidx, tok_sorted].add(contrib)

    # ---- load-balancing auxiliary (Switch-style) ------------------------------
    frac_tokens = jnp.zeros((b, e), jnp.float32).at[
        bidx, expert_flat].add(1.0) / sk
    mean_prob = probs.mean(axis=1)                            # [B, E]
    aux = e * jnp.mean(jnp.sum(frac_tokens * mean_prob, axis=-1))
    return y, aux


def moe_apply_dense_oracle(params, x, cfg: ModelConfig):
    """Oracle: every token through every chosen expert, no capacity drops.
    Tests compare moe_apply against this with capacity_factor large enough that
    nothing drops."""
    b, s, d = x.shape
    xf = x.reshape(-1, d).astype(jnp.float32)
    logits = xf @ params["router"].astype(jnp.float32)
    top_logit, top_idx = jax.lax.top_k(logits, cfg.top_k)
    top_w = jax.nn.softmax(top_logit, axis=-1)
    w1 = params["w1"].astype(jnp.float32)
    w3 = params["w3"].astype(jnp.float32)
    w2 = params["w2"].astype(jnp.float32)

    def per_token(xt, idxs, ws):
        def one(eid, w):
            h = jax.nn.silu(xt @ w1[eid]) * (xt @ w3[eid])
            return (h @ w2[eid]) * w
        return sum(one(idxs[j], ws[j]) for j in range(cfg.top_k))

    y = jax.vmap(per_token)(xf, top_idx, top_w)
    return y.reshape(b, s, d)
