"""Model configuration: one dataclass covering all 10 assigned architectures."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # "dense" | "moe" | "ssm" | "hybrid"
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    n_heads: int = 0            # 0 for attention-free families
    n_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # attention flavor
    sliding_window: int = 0     # 0 = full causal attention
    rope_theta: float = 500000.0

    # SSM / hybrid
    ssm_family: str = ""        # "rwkv6" | "mamba2"
    ssm_state: int = 0          # N (state dim per head) for mamba2
    ssm_head_dim: int = 64      # P for mamba2 / head size for rwkv6
    attn_every: int = 0         # hybrid: shared attention block every N layers

    # modality frontend ("none" | "vision_stub" | "audio_stub")
    frontend: str = "none"

    # numerics / implementation knobs
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_chunk: int = 1024      # kv-chunk for scan-based flash attention (0 = full)
    q_chunk: int = 1024         # q-chunk for prefill flash attention
    remat: bool = True
    logit_softcap: float = 0.0

    # ---- perf levers (§Perf hillclimbing; all default OFF = paper-faithful
    # baseline). See EXPERIMENTS.md §Perf for the hypothesis log. ----
    shard_activations: bool = False   # with_sharding_constraint on residual stream
    dp_axes: tuple = ()               # data axes of the ambient mesh, e.g. ("pod","data")
    tp_axis: str = ""                 # tensor-parallel axis name, e.g. "model"
    precast_params: bool = False      # cast params to compute dtype BEFORE the layer
                                      # scan -> FSDP all-gathers move bf16, not fp32
    cast_free_attention: bool = False # einsum(preferred_element_type=f32) instead of
                                      # materializing fp32 copies of bf16 KV caches
    remat_policy: str = "full"        # "full" = recompute everything in backward;
                                      # "dots" = save matmul outputs (less recompute,
                                      # more activation memory)

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and not self.n_kv_heads:
            object.__setattr__(self, "n_kv_heads", self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0 and self.family != "ssm"

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count N (for 6ND model-flops accounting)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = v * d  # embedding (tied head adds another v*d if untied; we count once
        n += v * d  # output head (untied)
        per_layer = 0
        if self.family in ("dense", "moe"):
            hd = self.head_dim
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d  # q, k, v, o
            if self.is_moe:
                per_layer += d * self.n_experts  # router
                per_layer += self.n_experts * 3 * d * ff  # w1, w3, w2 per expert
            else:
                per_layer += 3 * d * ff
            per_layer += 2 * d  # norms
        elif self.family == "ssm" and self.ssm_family == "rwkv6":
            per_layer += 6 * d * d        # r,k,v,g,o,w projections (approx)
            per_layer += 3 * d * ff // 2  # channel mix (k, v, r)
            per_layer += 2 * d
        elif self.family == "hybrid":
            # mamba2 blocks on every layer + one shared attention block
            p, ns = self.ssm_head_dim, self.ssm_state
            nh = d // p
            per_layer += 2 * d * 2 * d            # in_proj (x, z)
            per_layer += d * (2 * ns + nh)        # B, C, dt projections
            per_layer += 2 * d * d                # out_proj approx + conv
            per_layer += 3 * d * ff               # MLP
            per_layer += 2 * d
        n += L * per_layer
        if self.family == "hybrid" and self.attn_every:
            hd = self.head_dim
            n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d  # the single shared attn block
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        total = self.param_count()
        moe_all = L * self.n_experts * 3 * d * ff
        moe_active = L * self.top_k * 3 * d * ff
        return total - moe_all + moe_active
