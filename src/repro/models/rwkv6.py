"""RWKV-6 "Finch": linear attention with data-dependent decay (arXiv:2404.05892).

Time mixing (per head, head size P = cfg.ssm_head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (matrix state, P x P per head)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (bonus u for the current token)
with w_t = exp(-exp(w0 + lora_w(x'_t))) — the data-dependent decay that
distinguishes Finch from RWKV-5 — and data-dependent token-shift interpolation
(ddlerp) feeding every projection.

Channel mixing is the RWKV squared-ReLU FFN over token-shifted inputs.

The sequential form below scans over time (O(1) decode state: exactly why this
arch RUNS the long_500k cell). ``time_mix_chunked`` is the chunked parallel form
(the paper's chunking idea applied to the time axis) used for training speed;
both are tested equal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import cdtype, pdtype

LORA_R = 32
MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv_init(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    p = cfg.ssm_head_dim
    nh = d // p
    keys = jax.random.split(key, 16)
    s = d ** -0.5
    pd = pdtype(cfg)
    params = {
        # ddlerp token-shift: base mus + low-rank data-dependent adjustments
        "mix_mu": jnp.full((len(MIX_NAMES), d), 0.5, pd),
        "mix_A": jax.random.normal(keys[0], (len(MIX_NAMES), d, LORA_R), pd) * s,
        "mix_B": jax.random.normal(keys[1], (len(MIX_NAMES), LORA_R, d), pd)
        * (LORA_R ** -0.5),
        # projections
        "wr": jax.random.normal(keys[2], (d, d), pd) * s,
        "wk": jax.random.normal(keys[3], (d, d), pd) * s,
        "wv": jax.random.normal(keys[4], (d, d), pd) * s,
        "wg": jax.random.normal(keys[5], (d, d), pd) * s,
        "wo": jax.random.normal(keys[6], (d, d), pd) * s,
        # decay: w0 + lora
        "w0": jnp.full((d,), -0.6, pd),   # exp(-exp(-0.6)) ~ 0.58 baseline decay
        "w_A": jax.random.normal(keys[7], (d, LORA_R), pd) * s,
        "w_B": jax.random.normal(keys[8], (LORA_R, d), pd) * (LORA_R ** -0.5),
        "u": jax.random.normal(keys[9], (nh, p), pd) * 0.1,  # per-head bonus
        "ln_x": jnp.ones((d,), pd),       # per-head group norm scale
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, pd),
        "cm_mu_r": jnp.full((d,), 0.5, pd),
        "cm_k": jax.random.normal(keys[10], (d, ff), pd) * s,
        "cm_v": jax.random.normal(keys[11], (ff, d), pd) * (ff ** -0.5),
        "cm_r": jax.random.normal(keys[12], (d, d), pd) * s,
    }
    return params


def _mix_inputs(params, x, x_prev, dt):
    """Returns dict name -> mixed input [B, S, d] (RWKV-6 ddlerp)."""
    # first-stage lerp shared across targets
    mu = params["mix_mu"].astype(dt)          # [5, d]
    A = params["mix_A"].astype(dt)            # [5, d, r]
    B = params["mix_B"].astype(dt)            # [5, r, d]
    delta = x_prev - x                        # [B, S, d]
    out = {}
    for i, name in enumerate(MIX_NAMES):
        xx = x + delta * mu[i]
        adj = jnp.tanh(xx @ A[i]) @ B[i]      # low-rank data-dependent term
        out[name] = x + delta * (mu[i] + adj)
    return out


def _decay(params, xw, dt):
    """w_t in (0, 1): exp(-exp(w0 + lora(x))) per channel."""
    lora = jnp.tanh(xw @ params["w_A"].astype(dt)) @ params["w_B"].astype(dt)
    return jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32)
                            + lora.astype(jnp.float32)))


def _group_norm(x, scale, nh, p, eps=1e-5):
    """Per-head layer norm over the head dim (RWKV ln_x)."""
    shape = x.shape
    xh = x.reshape(*shape[:-1], nh, p).astype(jnp.float32)
    mean = xh.mean(axis=-1, keepdims=True)
    var = xh.var(axis=-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    xh = xh.reshape(shape)
    return xh * scale.astype(jnp.float32)


def time_mix(params, x, cfg: ModelConfig, state=None, x_prev_in=None):
    """Sequential form. x: [B, S, d]. state: [B, nh, P, P] (or None -> zeros).
    Returns (y [B, S, d], state_out, x_last [B, d])."""
    b, s, d = x.shape
    p = cfg.ssm_head_dim
    nh = d // p
    dt = cdtype(cfg)
    x_prev = jnp.concatenate(
        [jnp.zeros((b, 1, d), x.dtype) if x_prev_in is None
         else x_prev_in[:, None, :], x[:, :-1]], axis=1)
    mixed = _mix_inputs(params, x, x_prev, dt)
    r = (mixed["r"] @ params["wr"].astype(dt)).reshape(b, s, nh, p)
    k = (mixed["k"] @ params["wk"].astype(dt)).reshape(b, s, nh, p)
    v = (mixed["v"] @ params["wv"].astype(dt)).reshape(b, s, nh, p)
    g = jax.nn.silu(mixed["g"] @ params["wg"].astype(dt))
    w = _decay(params, mixed["w"], dt).reshape(b, s, nh, p)    # fp32
    u = params["u"].astype(jnp.float32)                        # [nh, p]

    def step(S, inputs):
        rt, kt, vt, wt = inputs          # [b, nh, p] each; wt fp32
        kv = kt[..., :, None].astype(jnp.float32) * vt[..., None, :].astype(jnp.float32)
        # y = r . (S + diag(u) k^T v)
        St = S + u[None, :, :, None] * kv
        yt = jnp.einsum("bhp,bhpq->bhq", rt.astype(jnp.float32), St)
        S_new = wt[..., :, None] * S + kv
        return S_new, yt

    S0 = jnp.zeros((b, nh, p, p), jnp.float32) if state is None else state
    xs = (
        r.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        w.transpose(1, 0, 2, 3),
    )
    S_out, ys = jax.lax.scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)              # fp32
    y = _group_norm(y, params["ln_x"], nh, p)
    y = (y * g.astype(jnp.float32)).astype(dt)
    return y @ params["wo"].astype(dt), S_out, x[:, -1, :]


def time_mix_chunked(params, x, cfg: ModelConfig, chunk: int = 64, state=None,
                     x_prev_in=None):
    """Chunked parallel form (paper-technique tie-in: chunk the time axis).

    Within a chunk the contribution of in-chunk tokens is computed with masked
    matmuls (MXU-shaped); across chunks the state S is carried recurrently. For
    decay w_t the in-chunk cumulative products D realize diag(w) products.
    Mathematically identical to ``time_mix`` (tested)."""
    b, s, d = x.shape
    p = cfg.ssm_head_dim
    nh = d // p
    dt = cdtype(cfg)
    if s % chunk:
        pad = chunk - s % chunk
        x_padded = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    else:
        pad = 0
        x_padded = x
    sp = x_padded.shape[1]
    x_prev = jnp.concatenate(
        [jnp.zeros((b, 1, d), x.dtype) if x_prev_in is None
         else x_prev_in[:, None, :], x_padded[:, :-1]], axis=1)
    mixed = _mix_inputs(params, x_padded, x_prev, dt)
    r = (mixed["r"] @ params["wr"].astype(dt)).reshape(b, sp, nh, p)
    k = (mixed["k"] @ params["wk"].astype(dt)).reshape(b, sp, nh, p)
    v = (mixed["v"] @ params["wv"].astype(dt)).reshape(b, sp, nh, p)
    g = jax.nn.silu(mixed["g"] @ params["wg"].astype(dt))
    w = _decay(params, mixed["w"], dt).reshape(b, sp, nh, p)
    u = params["u"].astype(jnp.float32)
    if pad:
        # padded steps must not touch the carried state: decay 1, contribution 0
        valid = (jnp.arange(sp) < s)[None, :, None, None]
        w = jnp.where(valid, w, 1.0)
        k = jnp.where(valid, k, 0.0)

    nc = sp // chunk
    rc = r.reshape(b, nc, chunk, nh, p).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    kc = k.reshape(b, nc, chunk, nh, p).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    vc = v.reshape(b, nc, chunk, nh, p).transpose(1, 0, 3, 2, 4).astype(jnp.float32)
    wc = w.reshape(b, nc, chunk, nh, p).transpose(1, 0, 3, 2, 4)  # [nc,b,nh,c,p]

    logw = jnp.log(jnp.maximum(wc, 1e-30))
    cum_incl = jnp.cumsum(logw, axis=3)              # prod w_1..w_t (inclusive)
    cum_excl = cum_incl - logw                       # prod w_1..w_{t-1} (exclusive)
    total = cum_incl[:, :, :, -1:, :]                # prod over whole chunk

    def chunk_step(S, inputs):
        rt, kt, vt, ce, ci, tot = inputs
        # decay-adjusted keys/queries for cross-token terms:
        #   y_t += r_t [ sum_{j<t} (prod_{j<i<=t-1} w_i) k_j^T v_j ] + u-bonus term
        r_dec = rt * jnp.exp(ce)                     # r_t * prod_{i<t} w_i
        k_dec = kt * jnp.exp(-ci)                    # k_j / prod_{i<=j} w_i
        # in-chunk pairwise (strictly lower triangular: j < t)
        att = jnp.einsum("bhtp,bhjp->bhtj", r_dec, k_dec)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhtj,bhjq->bhtq", att, vt)
        # u-bonus diagonal term: r_t . (u k_t) v_t
        diag = jnp.einsum("bhtp,bhtp->bht", rt, u[None, :, None, :] * kt)
        y_intra += diag[..., None] * vt
        # inter-chunk: state contribution
        y_inter = jnp.einsum("bhtp,bhpq->bhtq", r_dec, S)
        # state update: S' = diag(prod w) S + sum_j (prod_{j<i} w_i ... ) k_j^T v_j
        k_tail = kt * jnp.exp(tot - ci)              # prod_{j<i<=C} w_i
        S_new = jnp.exp(tot).squeeze(2)[..., :, None] * S + jnp.einsum(
            "bhjp,bhjq->bhpq", k_tail, vt)
        return S_new, y_intra + y_inter

    S0 = jnp.zeros((b, nh, p, p), jnp.float32) if state is None else state
    S_out, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, cum_excl, cum_incl, total))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, sp, d)
    y = _group_norm(y, params["ln_x"], nh, p)
    y = (y * g.astype(jnp.float32)).astype(dt)
    y = (y @ params["wo"].astype(dt))[:, :s]
    return y, S_out, x_padded[:, s - 1, :]


def channel_mix(params, x, cfg: ModelConfig, x_prev_in=None):
    """RWKV squared-ReLU FFN with token shift. Returns (y, x_last)."""
    b, s, d = x.shape
    dt = cdtype(cfg)
    x_prev = jnp.concatenate(
        [jnp.zeros((b, 1, d), x.dtype) if x_prev_in is None
         else x_prev_in[:, None, :], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * params["cm_mu_k"].astype(dt)
    xr = x + (x_prev - x) * params["cm_mu_r"].astype(dt)
    h = jnp.square(jax.nn.relu(xk @ params["cm_k"].astype(dt)))
    y = jax.nn.sigmoid(xr @ params["cm_r"].astype(dt)) * (h @ params["cm_v"].astype(dt))
    return y, x[:, -1, :]
