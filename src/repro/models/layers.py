"""Shared layer primitives: RMSNorm, RoPE, SwiGLU MLP, embeddings.

All modules are (init_fn, apply_fn) pairs over plain-dict param pytrees. Compute
runs in ``cfg.compute_dtype`` (bf16 by default) with fp32 master params and fp32
normalization statistics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(cfg: ModelConfig, d: int | None = None):
    return {"scale": jnp.ones(d or cfg.d_model, pdtype(cfg))}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable int32)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    return {
        "w1": jax.random.normal(k1, (d, ff), pdtype(cfg)) * s_in,   # gate
        "w3": jax.random.normal(k2, (d, ff), pdtype(cfg)) * s_in,   # up
        "w2": jax.random.normal(k3, (ff, d), pdtype(cfg)) * s_out,  # down
    }


def mlp(params, x, cfg: ModelConfig):
    dt = cdtype(cfg)
    h = jax.nn.silu(x @ params["w1"].astype(dt)) * (x @ params["w3"].astype(dt))
    return h @ params["w2"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "embedding": jax.random.normal(
            k1, (cfg.vocab_size, cfg.d_model), pdtype(cfg)
        ) * (cfg.d_model ** -0.5),
        "head": jax.random.normal(
            k2, (cfg.d_model, cfg.vocab_size), pdtype(cfg)
        ) * (cfg.d_model ** -0.5),
    }


def embed(params, tokens, cfg: ModelConfig):
    return params["embedding"].astype(cdtype(cfg))[tokens]


def unembed(params, x, cfg: ModelConfig):
    logits = (x @ params["head"].astype(cdtype(cfg))).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# modality frontend stubs ([vlm]/[audio]: precomputed patch/frame embeddings)
# ---------------------------------------------------------------------------


def frontend_project_init(key, cfg: ModelConfig, frontend_dim: int):
    """Stub frontend: a single linear projection from precomputed embeddings
    (vision patches / audio frames) into d_model. The actual encoder is out of
    scope per the assignment ("the modality frontend is a STUB")."""
    return {
        "proj": jax.random.normal(key, (frontend_dim, cfg.d_model), pdtype(cfg))
        * (frontend_dim ** -0.5)
    }


def frontend_project(params, embeds, cfg: ModelConfig):
    return (embeds.astype(cdtype(cfg)) @ params["proj"].astype(cdtype(cfg)))
