"""GQA attention: RoPE, causal masking, sliding windows, KV caches.

Two implementations of the same math:
  * ``flash_attention`` — pure-JAX doubly-chunked online-softmax (lax.scan over KV
    chunks inside a sequential map over Q chunks). This is the paper's Chunk1
    streaming order at the XLA level: Q/accumulator stationary, KV streamed. It is
    the path the dry-run lowers (CPU backend can't compile Mosaic kernels), and its
    HLO cost_analysis is what §Roofline reads.
  * ``repro.kernels.chunked_attention`` — the Pallas twin for real TPUs, validated
    against the same oracle in tests.

Causal work-skipping: the KV loop runs only up to the last chunk a Q block can see
(dynamic ``fori_loop`` bound), so prefill does ~S^2/2 work, not S^2 — and a sliding
window also *starts* the loop at the first visible chunk, making SWA prefill
O(S * W) (this is what makes mixtral's long_500k cell sub-quadratic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, cdtype, pdtype

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    so = (h * hd) ** -0.5
    return {
        "wq": jax.random.normal(k1, (d, h, hd), pdtype(cfg)) * s,
        "wk": jax.random.normal(k2, (d, hkv, hd), pdtype(cfg)) * s,
        "wv": jax.random.normal(k3, (d, hkv, hd), pdtype(cfg)) * s,
        "wo": jax.random.normal(k4, (h, hd, d), pdtype(cfg)) * so,
    }


def qkv(params, x, cfg: ModelConfig, positions):
    dt = cdtype(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(params, o, cfg: ModelConfig):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(cdtype(cfg)))


# ---------------------------------------------------------------------------
# flash attention (pure JAX, chunk-streamed)
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    q_offset: int = 0, cast_free: bool = False) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Sk, Hkv, D]. Returns [B, Sq, H, D].

    ``q_offset``: global position of q[0] relative to k[0] (prefill: 0)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    scale = 1.0 / (d ** 0.5)
    q_chunk = min(q_chunk, sq) or sq
    kv_chunk = min(kv_chunk, sk) or sk
    # pad ragged tails to chunk multiples; padded K positions are masked below,
    # padded Q rows are sliced off the output
    sq_orig = sq
    sq_pad = -(-sq // q_chunk) * q_chunk
    sk_pad = -(-sk // kv_chunk) * kv_chunk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    sk_valid = sk
    sq, sk = sq_pad, sk_pad
    nq = sq // q_chunk
    nk = sk // kv_chunk
    qg = q.reshape(b, sq, hkv, g, d)

    def q_block(qi: int):
        """One Q chunk. ``qi`` is a static Python int, so the visible-KV bounds
        are static -> reverse-mode differentiable AND causal/window work-skipping
        is preserved (the KV scan only covers visible chunks)."""
        q_blk = qg[:, qi * q_chunk : (qi + 1) * q_chunk]
        if not cast_free:
            q_blk = q_blk.astype(jnp.float32)
        q0 = q_offset + qi * q_chunk
        qpos = q0 + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            # [b, hkv, g, qc, kc] — cast_free keeps operands in their storage
            # dtype and asks the MXU for fp32 accumulation instead of
            # materializing fp32 copies of the KV stream (§Perf lever)
            if cast_free:
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                    preferred_element_type=jnp.float32,
                ) * scale
            else:
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", q_blk, k_blk.astype(jnp.float32)
                ) * scale
            mask = kpos[None, :] < sk_valid
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            mask = jnp.broadcast_to(mask, (q_chunk, kv_chunk))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            if cast_free:
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32,
                )
            else:
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32)
                )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, q_chunk), jnp.float32),
            jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32),
        )
        # causal: no KV chunk beyond this Q block's last row is visible
        hi = min((q0 + q_chunk + kv_chunk - 1) // kv_chunk, nk) if causal else nk
        # sliding window: no KV chunk entirely before (first q row - window)
        lo = max((q0 - window + 1) // kv_chunk, 0) if window else 0
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, jnp.arange(lo, max(hi, lo + 1), dtype=jnp.int32))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        # [b, hkv, g, qc, d] -> [b, qc, h, d]
        return o.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, d)

    out = jnp.concatenate([q_block(qi) for qi in range(nq)], axis=1) if nq > 1 \
        else q_block(0)
    return out[:, :sq_orig].astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Naive oracle for flash_attention (tests only)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) / (d ** 0.5)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# block-level entry points
# ---------------------------------------------------------------------------


def attn_forward(params, x, cfg: ModelConfig, positions):
    """Training / prefill self-attention over the full sequence."""
    q, k, v = qkv(params, x, cfg, positions)
    o = flash_attention(
        q, k, v, causal=True, window=cfg.sliding_window,
        q_chunk=cfg.q_chunk or q.shape[1], kv_chunk=cfg.attn_chunk or k.shape[1],
        cast_free=cfg.cast_free_attention,
    )
    return out_proj(params, o, cfg)


def attn_prefill(params, x, cfg: ModelConfig, positions, cache_len: int):
    """Prefill: returns (y, (k_cache, v_cache)) with caches padded to cache_len.

    For sliding-window attention the cache is a ring buffer of size
    min(cache_len, window) (the capacity feature: the KV working set is bounded)."""
    q, k, v = qkv(params, x, cfg, positions)
    o = flash_attention(
        q, k, v, causal=True, window=cfg.sliding_window,
        q_chunk=cfg.q_chunk or q.shape[1], kv_chunk=cfg.attn_chunk or k.shape[1],
        cast_free=cfg.cast_free_attention,
    )
    y = out_proj(params, o, cfg)
    b, s, hkv, hd = k.shape
    eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    kc = jnp.zeros((b, eff, hkv, hd), k.dtype)
    vc = jnp.zeros((b, eff, hkv, hd), v.dtype)
    if cfg.sliding_window and s > eff:
        # keep the last `eff` tokens, ring-aligned so slot = pos % eff
        tail_k, tail_v = k[:, -eff:], v[:, -eff:]
        pos_tail = positions[:, -eff:] if positions.ndim == 2 else \
            jnp.broadcast_to(positions[-eff:], (b, eff))
        slots = (pos_tail % eff).astype(jnp.int32)
        kc = kc.at[jnp.arange(b)[:, None], slots].set(tail_k)
        vc = vc.at[jnp.arange(b)[:, None], slots].set(tail_v)
    else:
        n = min(s, eff)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k[:, :n], 0, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v[:, :n], 0, 1)
    return y, (kc, vc)


def attn_decode(params, x, cfg: ModelConfig, cache_k, cache_v, pos):
    """One-token decode. x: [B, 1, d]; pos: int32[B] current position (0-based).
    Returns (y [B, 1, d], (cache_k, cache_v) updated)."""
    b = x.shape[0]
    dt = cdtype(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    s_cache = cache_k.shape[1]
    slot = (pos % s_cache).astype(jnp.int32) if cfg.sliding_window else pos
    cache_k = cache_k.at[jnp.arange(b), slot].set(k[:, 0])
    cache_v = cache_v.at[jnp.arange(b), slot].set(v[:, 0])
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hkv
    if cfg.cast_free_attention:
        # storage-dtype operands + fp32 MXU accumulation: no fp32 copy of the
        # KV cache is ever materialized in HBM (§Perf lever)
        qg = q.reshape(b, hkv, g, hd)
        scores = jnp.einsum(
            "bhgd,bshd->bhgs", qg, cache_k,
            preferred_element_type=jnp.float32,
        ) / (hd ** 0.5)
    else:
        qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
        scores = jnp.einsum(
            "bhgd,bshd->bhgs", qg, cache_k.astype(jnp.float32)
        ) / (hd ** 0.5)
    # valid cache slots: <= pos, and within the window for SWA
    if cfg.sliding_window:
        # slot i holds position p iff p % s_cache == i and p <= pos, p > pos - window
        slot_ids = jnp.arange(s_cache)[None, :]
        newest = pos[:, None] - ((pos[:, None] - slot_ids) % s_cache)
        valid = (newest >= 0) & (newest > pos[:, None] - cfg.sliding_window)
    else:
        valid = jnp.arange(s_cache)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    if cfg.cast_free_attention:
        o = jnp.einsum("bhgs,bshd->bhgd", p.astype(cache_v.dtype), cache_v,
                       preferred_element_type=jnp.float32)
    else:
        o = jnp.einsum("bhgs,bshd->bhgd", p, cache_v.astype(jnp.float32))
    o = o.reshape(b, 1, h, hd).astype(x.dtype)
    return out_proj(params, o, cfg), (cache_k, cache_v)
