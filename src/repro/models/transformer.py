"""Decoder assembly for all four families (dense / moe / ssm / hybrid).

Entry points (pure functions over param pytrees):
  init_params(key, cfg)                  -> params (use jax.eval_shape for abstract)
  forward(params, batch, cfg)            -> (logits, aux)     [training path]
  loss_fn(params, batch, cfg)            -> (loss, metrics)
  prefill(params, batch, cfg, cache_len) -> (last_logits, cache)
  decode_step(params, cache, tokens, cfg)-> (logits, cache)   [serve_step body]

Homogeneous stacks (dense/moe/ssm) are scanned over stacked layer params with
rematerialization, so a 95-layer model lowers to one compact scanned HLO body.
The zamba2 hybrid uses a Python-level loop (38 layers) because its shared
attention block breaks homogeneity (one weight set reused at every site).

Modality stubs: configs with ``frontend != "none"`` accept ``batch["embeds"]``
(precomputed patch/frame embeddings) instead of token ids, projected by the stub
frontend (DESIGN.md §5).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import attention as att
from repro.models import layers as ly
from repro.models import mamba2, moe, rwkv6

RWKV_CHUNK = 32   # fp32-safe chunk for the rwkv6 chunked-parallel form
SSD_CHUNK = 64


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        k1, k2 = jax.random.split(key)
        p = {
            "norm1": ly.rmsnorm_init(cfg),
            "attn": att.attn_init(k1, cfg),
            "norm2": ly.rmsnorm_init(cfg),
        }
        if cfg.family == "moe":
            p["moe"] = moe.moe_init(k2, cfg)
        else:
            p["mlp"] = ly.mlp_init(k2, cfg)
        return p
    if cfg.family == "ssm":       # rwkv6
        k1, _ = jax.random.split(key)
        return {
            "norm1": ly.rmsnorm_init(cfg),
            "rwkv": rwkv6.rwkv_init(k1, cfg),
            "norm2": ly.rmsnorm_init(cfg),
        }
    if cfg.family == "hybrid":    # mamba2 blocks (+ shared attn at top level)
        k1, _ = jax.random.split(key)
        return {
            "norm1": ly.rmsnorm_init(cfg),
            "mamba": mamba2.mamba_init(k1, cfg),
        }
    raise ValueError(cfg.family)


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    params = {
        "embed": ly.embed_init(keys[1], cfg),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
        "final_norm": ly.rmsnorm_init(cfg),
    }
    if cfg.family == "hybrid" and cfg.attn_every:
        k1, k2 = jax.random.split(keys[2])
        params["shared_attn"] = {
            "norm1": ly.rmsnorm_init(cfg),
            "attn": att.attn_init(k1, cfg),
            "norm2": ly.rmsnorm_init(cfg),
            "mlp": ly.mlp_init(k2, cfg),
        }
    if cfg.frontend != "none":
        params["frontend"] = ly.frontend_project_init(
            keys[3], cfg, frontend_dim=frontend_dim(cfg)
        )
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def frontend_dim(cfg: ModelConfig) -> int:
    return {"vision_stub": 1024, "audio_stub": 128}.get(cfg.frontend, 0)


# ---------------------------------------------------------------------------
# input embedding
# ---------------------------------------------------------------------------


def _embed_input(params, batch, cfg: ModelConfig):
    if cfg.frontend != "none" and "embeds" in batch:
        return ly.frontend_project(params["frontend"], batch["embeds"], cfg)
    return ly.embed(params["embed"], batch["tokens"], cfg)


def _hybrid_sites(cfg: ModelConfig):
    """Layer indices after which the shared attention block runs."""
    if not cfg.attn_every:
        return ()
    return tuple(range(cfg.attn_every - 1, cfg.n_layers, cfg.attn_every))


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig):
    """Apply the configured rematerialization policy to a layer body."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, prevent_cse=False)


def _maybe_precast(tree, cfg: ModelConfig):
    """§Perf lever: cast fp32 master params to the compute dtype ONCE, outside
    the layer scan, so FSDP weight all-gathers inside the scan move bf16 (half
    the collective bytes). Baseline (off) gathers fp32 then casts per layer."""
    if not cfg.precast_params:
        return tree
    dt = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, tree)


def forward(params, batch, cfg: ModelConfig):
    """Returns (logits [B, S, vocab] fp32, aux dict)."""
    from repro.parallel import constraints as con

    h = _embed_input(params, batch, cfg)
    h = con.hidden(h, cfg)
    b, s, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    params = dict(params, layers=_maybe_precast(params["layers"], cfg))
    if "shared_attn" in params:
        params = dict(params,
                      shared_attn=_maybe_precast(params["shared_attn"], cfg))

    if cfg.family in ("dense", "moe"):
        def body(carry, lp):
            x, aux = carry
            y = att.attn_forward(lp["attn"], ly.rmsnorm(lp["norm1"], x), cfg, pos)
            x = con.hidden(x + y, cfg)
            if cfg.family == "moe":
                y2, a = moe.moe_apply(lp["moe"], ly.rmsnorm(lp["norm2"], x), cfg)
                aux = aux + a
            else:
                y2 = ly.mlp(lp["mlp"], ly.rmsnorm(lp["norm2"], x), cfg)
            return (con.hidden(x + y2, cfg), aux), None

        body = _remat(body, cfg)
        (h, aux_loss), _ = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                        params["layers"])
        aux = {"moe_aux": aux_loss / max(cfg.n_layers, 1)}

    elif cfg.family == "ssm":
        def body(carry, lp):
            x, aux = carry
            y, _, _ = rwkv6.time_mix_chunked(
                lp["rwkv"], ly.rmsnorm(lp["norm1"], x), cfg, chunk=RWKV_CHUNK)
            x = x + y
            y2, _ = rwkv6.channel_mix(lp["rwkv"], ly.rmsnorm(lp["norm2"], x), cfg)
            return (x + y2, aux), None

        body = _remat(body, cfg)
        (h, _), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["layers"])
        aux = {}

    elif cfg.family == "hybrid":
        sites = set(_hybrid_sites(cfg))

        def mamba_layer(x, lp):
            y, _, _ = mamba2.ssd_chunked(
                lp["mamba"], ly.rmsnorm(lp["norm1"], x), cfg, chunk=SSD_CHUNK)
            return x + y

        def shared_block(x):
            sp = params["shared_attn"]
            x = x + att.attn_forward(sp["attn"], ly.rmsnorm(sp["norm1"], x), cfg, pos)
            return x + ly.mlp(sp["mlp"], ly.rmsnorm(sp["norm2"], x), cfg)

        mamba_layer = _remat(mamba_layer, cfg)
        shared_block = _remat(shared_block, cfg)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            h = mamba_layer(h, lp)
            if i in sites:
                h = shared_block(h)
        aux = {}
    else:
        raise ValueError(cfg.family)

    h = ly.rmsnorm(params["final_norm"], h)
    return con.logits(ly.unembed(params["embed"], h, cfg), cfg), aux


def loss_fn(params, batch, cfg: ModelConfig, aux_weight: float = 0.01):
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    total = loss + aux_weight * aux.get("moe_aux", 0.0)
    return total, {"loss": loss, **aux}


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int, abstract=False):
    """Empty decode cache pytree for this family."""
    mk = (lambda shape, dtype: jax.ShapeDtypeStruct(shape, dtype)) if abstract \
        else (lambda shape, dtype: jnp.zeros(shape, dtype))
    cdt = jnp.dtype(cfg.compute_dtype)
    L, b = cfg.n_layers, batch_size
    cache = {"pos": mk((b,), jnp.int32)}
    if cfg.family in ("dense", "moe"):
        eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        cache["k"] = mk((L, b, eff, cfg.n_kv_heads, cfg.head_dim), cdt)
        cache["v"] = mk((L, b, eff, cfg.n_kv_heads, cfg.head_dim), cdt)
    elif cfg.family == "ssm":
        p = cfg.ssm_head_dim
        nh = cfg.d_model // p
        cache["S"] = mk((L, b, nh, p, p), jnp.float32)
        cache["x_att"] = mk((L, b, cfg.d_model), cdt)
        cache["x_cm"] = mk((L, b, cfg.d_model), cdt)
    elif cfg.family == "hybrid":
        d_inner, p, nh, n = mamba2.mamba_dims(cfg)
        conv_ch = d_inner + 2 * n
        cache["h"] = mk((L, b, nh, p, n), jnp.float32)
        cache["conv"] = mk((L, b, mamba2.CONV_W - 1, conv_ch), cdt)
        n_sites = len(_hybrid_sites(cfg))
        eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        cache["k"] = mk((max(n_sites, 1), b, eff, cfg.n_kv_heads, cfg.head_dim), cdt)
        cache["v"] = mk((max(n_sites, 1), b, eff, cfg.n_kv_heads, cfg.head_dim), cdt)
    return cache


def prefill(params, batch, cfg: ModelConfig, cache_len: int):
    """Run the prompt, return (next-token logits [B, vocab], cache).

    With uneven right-padded prompts, ``batch["lengths"]`` (int32[B], true
    prompt lengths) selects each sequence's logits at its own last real token
    instead of the padded final position; without it, the last position is
    used for every sequence (uniform-length prompts)."""
    from repro.parallel import constraints as con

    h = _embed_input(params, batch, cfg)
    h = con.hidden(h, cfg)
    b, s, _ = h.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = init_cache(cfg, b, cache_len)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    params = dict(params, layers=_maybe_precast(params["layers"], cfg))
    if "shared_attn" in params:
        params = dict(params,
                      shared_attn=_maybe_precast(params["shared_attn"], cfg))

    if cfg.family in ("dense", "moe"):
        def body(x, lp):
            y, (ck, cv) = att.attn_prefill(
                lp["attn"], ly.rmsnorm(lp["norm1"], x), cfg, pos, cache_len)
            x = con.hidden(x + y, cfg)
            if cfg.family == "moe":
                y2, _ = moe.moe_apply(lp["moe"], ly.rmsnorm(lp["norm2"], x), cfg)
            else:
                y2 = ly.mlp(lp["mlp"], ly.rmsnorm(lp["norm2"], x), cfg)
            return con.hidden(x + y2, cfg), (ck, cv)

        h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
        cache["k"], cache["v"] = ks, vs

    elif cfg.family == "ssm":
        def body(x, lp):
            y, S, xa = rwkv6.time_mix_chunked(
                lp["rwkv"], ly.rmsnorm(lp["norm1"], x), cfg, chunk=RWKV_CHUNK)
            x = x + y
            xn = ly.rmsnorm(lp["norm2"], x)
            y2, xc = rwkv6.channel_mix(lp["rwkv"], xn, cfg)
            return x + y2, (S, xa, xc)

        h, (S, xa, xc) = jax.lax.scan(body, h, params["layers"])
        cache["S"], cache["x_att"], cache["x_cm"] = S, xa, xc

    elif cfg.family == "hybrid":
        sites = _hybrid_sites(cfg)
        hs, convs, ks, vs = [], [], [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            y, hstate, cstate = mamba2.ssd_chunked(
                lp["mamba"], ly.rmsnorm(lp["norm1"], h), cfg, chunk=SSD_CHUNK)
            h = h + y
            hs.append(hstate)
            convs.append(cstate)
            if i in sites:
                sp = params["shared_attn"]
                y, (ck, cv) = att.attn_prefill(
                    sp["attn"], ly.rmsnorm(sp["norm1"], h), cfg, pos, cache_len)
                h = h + y
                h = h + ly.mlp(sp["mlp"], ly.rmsnorm(sp["norm2"], h), cfg)
                ks.append(ck)
                vs.append(cv)
        cache["h"] = jnp.stack(hs)
        cache["conv"] = jnp.stack(convs)
        if ks:
            cache["k"], cache["v"] = jnp.stack(ks), jnp.stack(vs)

    h = ly.rmsnorm(params["final_norm"], h)
    lengths = batch.get("lengths") if isinstance(batch, dict) else None
    if lengths is None:
        h_last = h[:, -1:]
    else:
        last = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, s - 1)
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)
    logits = ly.unembed(params["embed"], h_last, cfg)
    return logits[:, 0], cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One token for every sequence. tokens: int32[B, 1].
    Returns (logits [B, vocab] fp32, updated cache)."""
    h = ly.embed(params["embed"], tokens, cfg)
    b = tokens.shape[0]
    pos = cache["pos"]
    params = dict(params, layers=_maybe_precast(params["layers"], cfg))
    if "shared_attn" in params:
        params = dict(params,
                      shared_attn=_maybe_precast(params["shared_attn"], cfg))

    if cfg.family in ("dense", "moe"):
        def body(x, inputs):
            lp, ck, cv = inputs
            y, (ck, cv) = att.attn_decode(
                lp["attn"], ly.rmsnorm(lp["norm1"], x), cfg, ck, cv, pos)
            x = x + y
            if cfg.family == "moe":
                y2, _ = moe.moe_apply(lp["moe"], ly.rmsnorm(lp["norm2"], x), cfg)
            else:
                y2 = ly.mlp(lp["mlp"], ly.rmsnorm(lp["norm2"], x), cfg)
            return x + y2, (ck, cv)

        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                             cache["v"]))
        cache = dict(cache, k=ks, v=vs)

    elif cfg.family == "ssm":
        def body(x, inputs):
            lp, S, xa, xc = inputs
            y, S2, xa2 = rwkv6.time_mix(
                lp["rwkv"], ly.rmsnorm(lp["norm1"], x), cfg, state=S, x_prev_in=xa)
            x = x + y
            xn = ly.rmsnorm(lp["norm2"], x)
            y2, xc2 = rwkv6.channel_mix(lp["rwkv"], xn, cfg, x_prev_in=xc)
            return x + y2, (S2, xa2, xc2)

        h, (S, xa, xc) = jax.lax.scan(
            body, h, (params["layers"], cache["S"], cache["x_att"], cache["x_cm"]))
        cache = dict(cache, S=S, x_att=xa, x_cm=xc)

    elif cfg.family == "hybrid":
        sites = _hybrid_sites(cfg)
        hs, convs, ks, vs = [], [], [], []
        si = 0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            y, hstate, cstate = mamba2.ssd_scan(
                lp["mamba"], ly.rmsnorm(lp["norm1"], h), cfg,
                state=cache["h"][i], conv_state=cache["conv"][i])
            h = h + y
            hs.append(hstate)
            convs.append(cstate)
            if i in sites:
                sp = params["shared_attn"]
                y, (ck, cv) = att.attn_decode(
                    sp["attn"], ly.rmsnorm(sp["norm1"], h), cfg,
                    cache["k"][si], cache["v"][si], pos)
                h = h + y
                h = h + ly.mlp(sp["mlp"], ly.rmsnorm(sp["norm2"], h), cfg)
                ks.append(ck)
                vs.append(cv)
                si += 1
        cache = dict(cache, h=jnp.stack(hs), conv=jnp.stack(convs))
        if ks:
            cache = dict(cache, k=jnp.stack(ks), v=jnp.stack(vs))

    h = ly.rmsnorm(params["final_norm"], h)
    logits = ly.unembed(params["embed"], h, cfg)
    cache = dict(cache, pos=pos + 1)
    return logits[:, 0], cache
