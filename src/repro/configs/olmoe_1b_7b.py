"""OLMoE-1B-7B [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert
vocab=50304, 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab_size=50304, n_experts=64, top_k=8, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="olmoe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=256, n_experts=8, top_k=2, capacity_factor=4.0,
    q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
