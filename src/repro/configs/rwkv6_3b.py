"""RWKV-6 (Finch) 3B [ssm] — 32L d_model=2560 attn-free d_ff=8960 vocab=65536,
data-dependent decay. [arXiv:2404.05892; hf]

Attention-free: O(1) decode state (per-head matrix states), which is why the
long_500k cell RUNS for this arch. Head size 64 -> 40 heads."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", ssm_family="rwkv6",
    n_layers=32, d_model=2560, d_ff=8960, vocab_size=65536,
    ssm_head_dim=64,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm", ssm_family="rwkv6",
    n_layers=2, d_model=64, d_ff=128, vocab_size=256,
    ssm_head_dim=16, compute_dtype="float32",
)
