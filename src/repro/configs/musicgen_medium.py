"""MusicGen-medium [audio] — 48L d_model=1536 24H (kv=24 -> MHA) d_ff=6144
vocab=2048, decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only; the EnCodec frontend is a STUB — input_specs() provides
precomputed frame embeddings (dim 128) for training, and decode operates over the
2048-entry codebook vocabulary."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, rope_theta=10000.0, frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=6, d_ff=96,
    vocab_size=128, frontend="audio_stub",
    q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
