"""Llama-3.2-1B [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab_size=256, q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
