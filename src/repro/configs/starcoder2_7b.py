"""StarCoder2-7B [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, GQA + RoPE. [arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab_size=49152, rope_theta=100000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=4, d_ff=192,
    vocab_size=256, q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
