"""Mixtral-8x22B [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]

SWA window: 4096 (Mixtral lineage). The sliding window makes prefill O(S*W) and
bounds the decode KV cache by W — this is why the long_500k cell RUNS for this
arch (sub-quadratic) while pure full-attention archs skip it."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, n_experts=8, top_k=2, sliding_window=4096,
    rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
    vocab_size=256, n_experts=4, top_k=2, capacity_factor=4.0,
    sliding_window=24, q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
