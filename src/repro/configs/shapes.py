"""The four assigned input shapes and per-(arch x shape) input specs.

Shapes (per the assignment):
  train_4k     seq 4,096   global_batch 256   -> lowers train_step
  prefill_32k  seq 32,768  global_batch 32    -> lowers prefill
  decode_32k   seq 32,768  global_batch 128   -> lowers serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; REQUIRES sub-quadratic
                                                 attention. Runs for SSM / hybrid /
                                                 sliding-window archs; full-attention
                                                 archs SKIP (recorded per cell).

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — no allocation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SHAPE_IDS = tuple(SHAPES)


def is_subquadratic(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0


def skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    """None if the (arch, shape) cell runs; otherwise why it is skipped."""
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not is_subquadratic(cfg):
        return (f"{cfg.name} is pure full-attention; long_500k requires "
                "sub-quadratic attention (see DESIGN.md §5)")
    return None


def scale_shape(spec: ShapeSpec, seq_len=None, global_batch=None) -> ShapeSpec:
    """Reduced variants for smoke tests."""
    return ShapeSpec(spec.name, spec.kind, seq_len or spec.seq_len,
                     global_batch or spec.global_batch)


def batch_specs(cfg: ModelConfig, spec: ShapeSpec):
    """ShapeDtypeStructs of the data batch for a train/prefill shape."""
    b, s = spec.global_batch, spec.seq_len
    if cfg.frontend != "none":
        out = {
            "embeds": jax.ShapeDtypeStruct(
                (b, s, tf.frontend_dim(cfg)), jnp.dtype(cfg.compute_dtype)),
        }
    else:
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if spec.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape_name: str):
    """Everything a lowering needs for this cell, as abstract values.

    train:   {"batch": {...}}
    prefill: {"batch": {...}, "cache_len": int}
    decode:  {"cache": <abstract cache pytree>, "tokens": [B, 1] int32}
    """
    spec = SHAPES[shape_name] if isinstance(shape_name, str) else shape_name
    reason = skip_reason(cfg, spec.name)
    if reason:
        raise ValueError(f"cell skipped: {reason}")
    if spec.kind == "train":
        return {"batch": batch_specs(cfg, spec)}
    if spec.kind == "prefill":
        return {"batch": batch_specs(cfg, spec), "cache_len": spec.seq_len}
    if spec.kind == "decode":
        cache = tf.init_cache(cfg, spec.global_batch, spec.seq_len, abstract=True)
        return {
            "cache": cache,
            "tokens": jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32),
        }
    raise ValueError(spec.kind)
