"""Architecture config registry: ``--arch <id>`` resolution.

Each module defines CONFIG (the exact published configuration) and SMOKE (a reduced
same-family configuration for CPU tests). ``get_config(name, smoke=...)`` resolves
either. Input shapes live in repro.configs.shapes.
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "olmoe_1b_7b",
    "mixtral_8x22b",
    "deepseek_67b",
    "llama3_2_1b",
    "minitron_4b",
    "starcoder2_7b",
    "llava_next_mistral_7b",
    "musicgen_medium",
    "rwkv6_3b",
    "zamba2_1p2b",
)

# accept dashed spellings from the assignment table
ALIASES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-67b": "deepseek_67b",
    "llama3.2-1b": "llama3_2_1b",
    "minitron-4b": "minitron_4b",
    "starcoder2-7b": "starcoder2_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "musicgen-medium": "musicgen_medium",
    "rwkv6-3b": "rwkv6_3b",
    "zamba2-1.2b": "zamba2_1p2b",
}


def canonical(name: str) -> str:
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise ValueError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
