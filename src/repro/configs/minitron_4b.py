"""Minitron-4B [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000, pruned nemotron. [arXiv:2407.14679; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab_size=256000, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, d_ff=96,
    vocab_size=512, q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
