"""LLaVA-NeXT (Mistral-7B backbone) [vlm] — 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000, anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Backbone only per the assignment; the vision tower is a STUB — input_specs()
provides precomputed CLIP-large patch embeddings (dim 1024) which a single linear
projector maps into d_model."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, rope_theta=1000000.0, frontend="vision_stub",
)

SMOKE = ModelConfig(
    name="llava-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab_size=256, frontend="vision_stub",
    q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
