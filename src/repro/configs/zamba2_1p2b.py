"""Zamba2-1.2B [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
ssm_state=64, Mamba2 blocks + shared attention blocks. [arXiv:2411.15242; hf]

The shared transformer block (one weight set) runs every 6 Mamba2 layers.
Decode state is O(1) per mamba layer + O(S) KV only at the 6 shared-attn sites,
keeping long_500k decode linear — the cell RUNS."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", ssm_family="mamba2",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_state=64, ssm_head_dim=64, attn_every=6,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", ssm_family="mamba2",
    n_layers=4, d_model=64, n_heads=8, n_kv_heads=8, d_ff=128,
    vocab_size=256, ssm_state=8, ssm_head_dim=16, attn_every=2,
    q_chunk=16, attn_chunk=16, compute_dtype="float32",
)
