"""Fault-tolerant checkpointing: atomic manifests, keep-k, elastic resharding.

Layout:  <dir>/step_<N>/
            manifest.json        tree structure + shapes + dtypes + step
            arr_<i>.npy          one file per leaf (host-gathered)
         <dir>/step_<N>.tmp/     staging; atomic os.replace on completion

Fault-tolerance properties (unit-tested):
  * atomicity — a partially-written checkpoint is never visible (tmp + rename);
    restore always reads the newest *complete* step.
  * elasticity — arrays are saved unsharded (host-gathered) and restored with
    ``jax.device_put(..., sharding)`` for whatever mesh the restart runs on; a
    512-chip checkpoint restores onto 256 chips (mesh-reshape resume).
  * preemption — CheckpointManager installs a SIGTERM handler that flags a final
    save at the next step boundary (the train loop checks ``should_save_now``).
  * retention — keep_last_k garbage-collects old steps after a successful save.

On multi-host pods each leaf would be written as per-process shards with a
process-0 manifest merge; the single-process layout here is the degenerate case
of that scheme (documented in DESIGN.md §6).
"""

from __future__ import annotations

import contextlib
import json
import os
import shutil
import signal
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree, keep_last_k: int = 3) -> str:
    """Atomically persist a pytree. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": int(step),
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "leaves": [],
    }
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append({"index": i, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)          # atomic visibility
    _gc(directory, keep_last_k)
    return final


def _gc(directory: str, keep_last_k: int):
    steps = sorted(_complete_steps(directory))
    for s in steps[:-keep_last_k] if keep_last_k else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def _complete_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if (name.startswith("step_") and not name.endswith(".tmp")
                and os.path.exists(os.path.join(directory, name,
                                                "manifest.json"))):
            out.append(int(name[len("step_"):]))
    return out


def latest_step(directory: str):
    steps = _complete_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``tree_like`` (values ignored). With
    ``shardings`` (a matching pytree of NamedSharding) each leaf is placed
    sharded — this is the elastic-restart path: the saved mesh is irrelevant."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(flat_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected "
            f"{len(flat_like)} — structure changed?")
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat_like))
    out = []
    for i, (like, sh) in enumerate(zip(flat_like, flat_sh)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {like.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Save cadence + preemption handling + straggler bookkeeping for the loop."""

    def __init__(self, directory: str, every_steps: int = 100,
                 keep_last_k: int = 3, install_sigterm: bool = True):
        self.directory = directory
        self.every_steps = every_steps
        self.keep_last_k = keep_last_k
        self._preempted = False
        if install_sigterm:
            # ValueError: not on the main thread (tests)
            with contextlib.suppress(ValueError):
                signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, _signum, _frame):  # pragma: no cover - signal path
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def should_save_now(self, step: int) -> bool:
        return self._preempted or (step > 0 and step % self.every_steps == 0)

    def save(self, step: int, tree) -> str:
        return save_checkpoint(self.directory, step, tree, self.keep_last_k)

    # -- async saves: snapshot on the caller's thread (device_get only), write
    #    files in the background so training never blocks on the filesystem.
    def save_async(self, step: int, tree) -> None:
        self.wait()   # one in-flight save at a time (ordering + atomicity)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree, self.keep_last_k),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        t = getattr(self, "_thread", None)
        if t is not None and t.is_alive():
            t.join()

    def restore_or_none(self, tree_like, shardings=None):
        if latest_step(self.directory) is None:
            return None
        return restore_checkpoint(self.directory, tree_like, shardings=shardings)
