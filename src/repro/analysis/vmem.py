"""VMEM footprint auditor: traced resident bytes vs the planner byte model.

For one backend core traced at one (plan, envelope) the auditor sums, per
``pallas_call``, the bytes of every kernel operand resident in fast memory:

* **blocked inputs** — BlockSpec-staged operands (the stationary piece, the
  fused ``C_prev`` blocks); SMEM scalar-prefetch operands and ``ANY``-space
  (slow-memory) refs are excluded — they are precisely what the streaming
  schedule keeps *out* of VMEM;
* **outputs** — the persistent accumulator blocks;
* **scratch** — the double-buffer slots (both of them: that is the point of
  the two-slot pipeline) and any VMEM workspace; semaphores excluded;
* an **alias credit**: the fused-``C_prev`` convention means each output
  block is initialized from a same-shaped input block and the two are never
  both live, so one matching input block's bytes are credited back per
  output block;
* the **peak intermediate** of the kernel body, counted only for backends
  whose byte model carries a nonzero ``workspace`` term (the ESC expand
  buffer, the hash tables) — for dense-slab kernels the MXU feeds from the
  staged blocks and the model deliberately prices no workspace. Functional
  *ref-update and ref-read images* are excluded: a scatter into the CSR
  accumulator traces as a fresh ``(c_pad + 1,)`` array (the column plus the
  overflow sentinel slot) that the compiler in-places into the
  already-priced ref, and loading a blocked input's field traces as a fresh
  array the size of that ref — so intermediates no larger than one
  already-priced ref of their dtype (plus one element) are not workspace.

The audit asserts the spec's registered ``byte_model`` **dominates** the
traced footprint: ``model.fast_bytes_needed >= traced_total``. An
undercounting model is exactly the bug class PR 3 fixed dynamically
(planner fast-memory undercounts) — this pass proves its absence at trace
time for every backend x geometry in the corpus.

The scan backend registers no byte model (the planner does not dispatch to
it on byte grounds); for it the auditor reports the largest ``lax.scan``
carry as an informational measurement instead of a domination check.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis.jaxpr_tools import (
    aval_bytes, find_eqns, iter_eqns, kernel_jaxpr, kernel_operands,
    pallas_calls, unwrap, vmem_resident,
)


@dataclasses.dataclass(frozen=True)
class VmemAudit:
    """Traced fast-memory accounting of one core at one geometry."""

    traced_bytes: float          # peak resident VMEM the trace witnesses
    model_bytes: float | None    # byte model's claim (None: no model)
    blocked_in_bytes: float
    output_bytes: float
    scratch_bytes: float
    alias_credit_bytes: float
    workspace_bytes: float       # counted peak intermediate (0 if excluded)
    scan_carry_bytes: float      # largest scan carry (scan backend info)
    n_pallas_calls: int

    @property
    def dominated(self) -> bool | None:
        """model >= traced; None when there is no model to check."""
        if self.model_bytes is None:
            return None
        return self.model_bytes >= self.traced_bytes


def _alias_credit(in_avals, out_avals) -> float:
    """Bytes of input blocks structurally aliased by output blocks: for each
    output, one unclaimed input with identical (shape, dtype) — the fused
    C_prev init. Greedy, so a missing partner simply earns no credit."""
    pool = [(tuple(a.shape), str(a.dtype), aval_bytes(a)) for a in in_avals]
    credit = 0.0
    for out in out_avals:
        key = (tuple(out.shape), str(out.dtype))
        for ix, (shape, dtype, nbytes) in enumerate(pool):
            if (shape, dtype) == key:
                credit += nbytes
                pool.pop(ix)
                break
    return credit


def _update_image_floors(ref_avals) -> dict:
    """Per dtype: bytes of the largest already-priced ref plus one element —
    the size of a functional update image of that ref (the accumulator
    scatter's ``(c_pad + 1,)`` buffer) or of a whole-ref *read* image (a
    blocked input's field materialized as an array value, e.g. the
    stationary CSR data the merge body loads). Intermediates at or below
    the floor are in-placed updates or reads of refs the audit already
    counts, not workspace."""
    floors = {}
    for aval in ref_avals:
        dtype = getattr(aval, "dtype", None)
        if dtype is None:
            continue
        key = str(dtype)
        size = aval_bytes(aval) + np.dtype(dtype).itemsize
        floors[key] = max(floors.get(key, 0), size)
    return floors


def _workspace_intermediate_bytes(kjaxpr, ref_avals) -> float:
    """Largest kernel-body intermediate that is genuine workspace (the ESC
    expand buffer, the hash tables): bigger than any in-place update image
    of the already-priced output/scratch refs."""
    floors = _update_image_floors(ref_avals)
    worst = 0
    for eqn in iter_eqns(kjaxpr):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            nbytes = aval_bytes(aval)
            if nbytes > floors.get(str(getattr(aval, "dtype", "")), 0):
                worst = max(worst, nbytes)
    return float(worst)


def _scan_carry_bytes(traced) -> float:
    worst = 0
    for eqn in find_eqns(unwrap(traced), "scan"):
        num_carry = eqn.params.get("num_carry", 0)
        num_consts = eqn.params.get("num_consts", 0)
        carry = eqn.invars[num_consts:num_consts + num_carry]
        worst = max(worst, sum(aval_bytes(v.aval) for v in carry))
    return float(worst)


def audit_vmem(traced, model=None, *,
               count_workspace: bool | None = None) -> VmemAudit:
    """Audit one traced core (``jax.make_jaxpr`` output) against a
    :class:`~repro.core.planner.BackendFastModel` (or None).

    ``count_workspace`` forces the peak-intermediate term on or off; by
    default it follows ``model.workspace_bytes > 0``.
    """
    if count_workspace is None:
        count_workspace = bool(model is not None
                               and model.workspace_bytes > 0)
    blocked_in = out_bytes = scratch = credit = workspace = 0.0
    peak = 0.0
    calls = pallas_calls(traced)
    for eqn in calls:
        ops = kernel_operands(eqn)
        in_avals = [a for _, a in ops["inputs"] if vmem_resident(a)]
        out_avals = [a for _, a in ops["outputs"]]
        scratch_avals = [a for _, a in ops["scratch"] if vmem_resident(a)]
        c_in = float(sum(aval_bytes(a) for a in in_avals))
        c_out = float(sum(aval_bytes(a) for a in out_avals))
        c_scratch = float(sum(aval_bytes(a) for a in scratch_avals))
        c_credit = _alias_credit(in_avals, out_avals)
        c_work = (_workspace_intermediate_bytes(
                      kernel_jaxpr(eqn),
                      in_avals + out_avals + scratch_avals)
                  if count_workspace else 0.0)
        total = c_in + c_out + c_scratch - c_credit + c_work
        if total > peak:
            peak = total
            blocked_in, out_bytes, scratch = c_in, c_out, c_scratch
            credit, workspace = c_credit, c_work
    return VmemAudit(
        traced_bytes=peak,
        model_bytes=(float(model.fast_bytes_needed)
                     if model is not None else None),
        blocked_in_bytes=blocked_in,
        output_bytes=out_bytes,
        scratch_bytes=scratch,
        alias_credit_bytes=credit,
        workspace_bytes=workspace,
        scan_carry_bytes=_scan_carry_bytes(traced),
        n_pallas_calls=len(calls),
    )
