"""Audit orchestration: every registered backend x algorithm x geometry.

One :func:`audit_all` call drives the whole static verifier:

* the **schedule model** simulation (:mod:`repro.analysis.dma`) replays the
  shared two-slot arithmetic over a sweep of launch lengths — once, since
  every streaming kernel imports the same ``repro.kernels.dma_schedule``;
* per (backend, algorithm, corpus case): the spec's ``audit_trace`` stages
  the instance at its envelope, ``jax.make_jaxpr`` abstract-traces the core
  (no device execution), and the trace feeds the VMEM domination audit, the
  structural DMA checks, and the while-bound checks;
* the **retrace-leak** pass stages the case and its structural-subset twin
  at the shared (union) envelope and demands byte-identical jaxprs.

The output is a JSON-able report dict; ``tools/audit_backends.py`` is the
CLI wrapper and the ``static-audit`` CI job fails on any violation.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.analysis import corpus
from repro.analysis.dma import (
    check_dma_structure, check_while_bounds, simulate_schedule,
)
from repro.analysis.retrace import check_retrace
from repro.analysis.vmem import audit_vmem
from repro.core import backend_registry

# launch lengths the schedule simulation sweeps: 1 (prime-only), the parity
# boundary cases, and enough steady-state steps to cover any corpus plan
# (thirds-of-thirds launches never exceed 9 linear steps per batch row).
SCHEDULE_SWEEP = tuple(range(1, 13))


@dataclasses.dataclass(frozen=True)
class Violation:
    """One auditor finding, locatable to (analysis, backend, algorithm,
    case)."""

    analysis: str      # "vmem" | "dma" | "while" | "retrace" | "schedule"
    backend: str
    algorithm: str
    case: str
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _expected_while_bound(spec, target) -> int | None:
    """The hash backend's probe loops must bake the planner-derived table
    size as their static bound; other backends carry no expectation."""
    if spec.name != "hash":
        return None
    from repro.kernels.hash_accum_spgemm import probe_step_bound

    return probe_step_bound(target.meta["table_size"])


def _case_envelope(spec, A, B, plan):
    from repro.core.chunking import instance_envelope

    block = spec.block_size if spec.needs_block_caps else None
    return instance_envelope(A, B, plan, block_size=block)


def audit_backend_case(spec, algorithm: str, case_name: str, A, B,
                       retrace: bool = True):
    """All analyses for one (backend, algorithm, instance). Returns
    ``(record, violations)``: a JSON-able measurement record and the list
    of :class:`Violation`."""
    plan = corpus.make_plan(algorithm, A, B)
    env = _case_envelope(spec, A, B, plan)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    violations = []

    def flag(analysis, messages):
        violations.extend(
            Violation(analysis, spec.name, algorithm, case_name, m)
            for m in messages)

    model = spec.byte_model(plan, env) if spec.byte_model is not None else None
    vaudit = audit_vmem(traced, model)
    if vaudit.dominated is False:
        flag("vmem", [
            f"byte model undercounts the traced VMEM footprint: model "
            f"claims {vaudit.model_bytes:.0f} B but the trace stages "
            f"{vaudit.traced_bytes:.0f} B (blocked-in "
            f"{vaudit.blocked_in_bytes:.0f} + out {vaudit.output_bytes:.0f} "
            f"+ scratch {vaudit.scratch_bytes:.0f} - alias credit "
            f"{vaudit.alias_credit_bytes:.0f} + workspace "
            f"{vaudit.workspace_bytes:.0f})"])
    flag("dma", check_dma_structure(traced))
    flag("while", check_while_bounds(
        traced, expected_bound=_expected_while_bound(spec, target)))

    if retrace:
        A2, B2 = corpus.retrace_pair(A, B)
        plan2 = corpus.make_plan(algorithm, A2, B2)
        env_shared = env.union(_case_envelope(spec, A2, B2, plan2))
        t1 = spec.audit_trace(A, B, plan, env_shared.c_pad, env_shared)
        t2 = spec.audit_trace(A2, B2, plan, env_shared.c_pad, env_shared)
        flag("retrace", check_retrace(t1, t2))

    record = {
        "backend": spec.name,
        "algorithm": algorithm,
        "case": case_name,
        "vmem": dataclasses.asdict(vaudit),
        "dominated": vaudit.dominated,
        "n_pallas_calls": vaudit.n_pallas_calls,
        "n_violations": len(violations),
    }
    return record, violations


def audit_all(backends=None, algorithms=None, cases=None,
              retrace: bool = True) -> dict:
    """Run the full static audit. Returns a JSON-able report dict with
    ``records`` (per backend x algorithm x case measurements),
    ``violations``, ``skipped`` (non-auditable backends), and ``ok``."""
    backend_registry.ensure_registered()
    names = list(backends) if backends else list(backend_registry.all_backends())
    algorithms = list(algorithms) if algorithms else list(backend_registry.ALGORITHMS)
    case_names = list(cases) if cases else list(corpus.CASES)

    violations = []
    for total in SCHEDULE_SWEEP:
        violations.extend(
            Violation("schedule", "*", "*", f"total={total}", m)
            for m in simulate_schedule(total))

    records, skipped = [], []
    for name in names:
        spec = backend_registry.get(name)
        if not spec.supports_audit:
            skipped.append({"backend": name,
                            "reason": "no audit_trace (host-loop oracle has "
                                      "no jitted core)"})
            continue
        for case_name in case_names:
            A, B = corpus.build_case(case_name)
            for algorithm in algorithms:
                record, v = audit_backend_case(
                    spec, algorithm, case_name, A, B, retrace=retrace)
                records.append(record)
                violations.extend(v)

    return {
        "schedule_sweep": list(SCHEDULE_SWEEP),
        "backends": names,
        "algorithms": algorithms,
        "cases": case_names,
        "records": records,
        "skipped": skipped,
        "violations": [v.to_dict() for v in violations],
        "ok": not violations,
    }
