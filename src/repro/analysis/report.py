"""Audit orchestration: every registered backend x algorithm x geometry.

One :func:`audit_all` call drives the whole static verifier:

* the **schedule model** simulation (:mod:`repro.analysis.dma`) replays the
  shared two-slot arithmetic over a sweep of launch lengths — once, since
  every streaming kernel imports the same ``repro.kernels.dma_schedule``;
* per (backend, algorithm, corpus case): the spec's ``audit_trace`` stages
  the instance at its envelope, ``jax.make_jaxpr`` abstract-traces the core
  (no device execution), and the trace feeds the VMEM domination audit, the
  structural DMA checks, the while-bound checks, the **copy-event flow
  equality** pass (:mod:`repro.analysis.traffic` — traced bytes must equal
  the spec's declared per-copy model and tie to the executors'
  ``ChunkStats``), the **DMA interleaving model checker**
  (:mod:`repro.analysis.interleave` — every async-completion order of the
  two-slot schedule is hazard-free, or a minimal counterexample), and the
  **Mosaic preflight lint** (:mod:`repro.analysis.mosaic_lint` — error
  diagnostics fail the audit, warnings/infos ride along in the record);
* the **retrace-leak** pass stages the case and its structural-subset twin
  at the shared (union) envelope and demands byte-identical jaxprs.

``analyses`` subsets the per-trace passes (the CLI's ``--analyses`` flag:
the fast lane smokes one analysis without paying for the rest); the
schedule sweep runs whenever ``dma`` or ``interleave`` is selected.

The output is a JSON-able report dict; ``tools/audit_backends.py`` is the
CLI wrapper and the ``static-audit`` CI job fails on any violation.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.analysis import corpus
from repro.analysis.dma import (
    check_dma_structure, check_while_bounds, simulate_schedule,
)
from repro.analysis.interleave import check_interleave
from repro.analysis.mosaic_lint import check_lint
from repro.analysis.retrace import check_retrace
from repro.analysis.traffic import check_traffic
from repro.analysis.vmem import audit_vmem
from repro.core import backend_registry

# launch lengths the schedule simulation sweeps: 1 (prime-only), the parity
# boundary cases, and enough steady-state steps to cover any corpus plan
# (thirds-of-thirds launches never exceed 9 linear steps per batch row).
SCHEDULE_SWEEP = tuple(range(1, 13))

# every per-trace analysis audit_backend_case can run, in run order.
ANALYSES = ("vmem", "dma", "while", "traffic", "interleave", "lint",
            "retrace")


@dataclasses.dataclass(frozen=True)
class Violation:
    """One auditor finding, locatable to (analysis, backend, algorithm,
    case)."""

    analysis: str      # one of ANALYSES, or "schedule" for the sweep
    backend: str
    algorithm: str
    case: str
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _expected_while_bound(spec, target) -> int | None:
    """The hash backend's probe loops must bake the planner-derived table
    size as their static bound; other backends carry no expectation."""
    if spec.name != "hash":
        return None
    from repro.kernels.hash_accum_spgemm import probe_step_bound

    return probe_step_bound(target.meta["table_size"])


def _case_envelope(spec, A, B, plan):
    from repro.core.chunking import instance_envelope

    block = spec.block_size if spec.needs_block_caps else None
    return instance_envelope(A, B, plan, block_size=block)


def normalize_analyses(analyses) -> tuple:
    """Validate/default an analysis subset (``None`` = all)."""
    if analyses is None:
        return ANALYSES
    selected = tuple(analyses)
    unknown = [a for a in selected if a not in ANALYSES]
    if unknown:
        raise ValueError(
            f"unknown analyses {unknown}; available: {list(ANALYSES)}")
    return selected


def audit_backend_case(spec, algorithm: str, case_name: str, A, B,
                       retrace: bool = True, analyses=None):
    """All selected analyses for one (backend, algorithm, instance).
    Returns ``(record, violations)``: a JSON-able measurement record and
    the list of :class:`Violation`. ``retrace=False`` is shorthand for
    dropping ``"retrace"`` from the selection."""
    analyses = normalize_analyses(analyses)
    plan = corpus.make_plan(algorithm, A, B)
    env = _case_envelope(spec, A, B, plan)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    violations = []

    def flag(analysis, messages):
        violations.extend(
            Violation(analysis, spec.name, algorithm, case_name, m)
            for m in messages)

    record = {
        "backend": spec.name,
        "algorithm": algorithm,
        "case": case_name,
        "analyses": list(analyses),
    }

    if "vmem" in analyses:
        model = (spec.byte_model(plan, env)
                 if spec.byte_model is not None else None)
        vaudit = audit_vmem(traced, model)
        if vaudit.dominated is False:
            flag("vmem", [
                f"byte model undercounts the traced VMEM footprint: model "
                f"claims {vaudit.model_bytes:.0f} B but the trace stages "
                f"{vaudit.traced_bytes:.0f} B (blocked-in "
                f"{vaudit.blocked_in_bytes:.0f} + out "
                f"{vaudit.output_bytes:.0f} + scratch "
                f"{vaudit.scratch_bytes:.0f} - alias credit "
                f"{vaudit.alias_credit_bytes:.0f} + workspace "
                f"{vaudit.workspace_bytes:.0f})"])
        record["vmem"] = dataclasses.asdict(vaudit)
        record["dominated"] = vaudit.dominated
        record["n_pallas_calls"] = vaudit.n_pallas_calls

    if "dma" in analyses:
        flag("dma", check_dma_structure(traced))
    if "while" in analyses:
        flag("while", check_while_bounds(
            traced, expected_bound=_expected_while_bound(spec, target)))

    if "traffic" in analyses:
        if spec.supports_traffic:
            expected = spec.traffic_model(
                A, B, plan, env.c_pad, env, target.meta)
            tv, tinfo = check_traffic(
                traced, expected,
                scalar_args=target.meta.get("scalar_args", ()))
            flag("traffic", tv)
            record["traffic"] = tinfo
        else:
            record["traffic"] = {
                "checked": False,
                "reason": "no traffic_model registered (device-resident "
                          "core: stats are a replay oracle by design)"}

    if "interleave" in analyses:
        iv, iinfo = check_interleave(traced)
        flag("interleave", iv)
        record["interleave"] = iinfo

    if "lint" in analyses:
        lv, linfo = check_lint(traced)
        flag("lint", lv)
        record["lint"] = linfo

    if retrace and "retrace" in analyses:
        A2, B2 = corpus.retrace_pair(A, B)
        plan2 = corpus.make_plan(algorithm, A2, B2)
        env_shared = env.union(_case_envelope(spec, A2, B2, plan2))
        t1 = spec.audit_trace(A, B, plan, env_shared.c_pad, env_shared)
        t2 = spec.audit_trace(A2, B2, plan, env_shared.c_pad, env_shared)
        flag("retrace", check_retrace(t1, t2))

    record["n_violations"] = len(violations)
    return record, violations


def audit_all(backends=None, algorithms=None, cases=None,
              retrace: bool = True, analyses=None) -> dict:
    """Run the full static audit. Returns a JSON-able report dict with
    ``records`` (per backend x algorithm x case measurements),
    ``violations``, ``skipped`` (non-auditable backends), and ``ok``.
    ``analyses`` subsets the per-trace passes (see :data:`ANALYSES`)."""
    backend_registry.ensure_registered()
    names = list(backends) if backends else list(backend_registry.all_backends())
    algorithms = list(algorithms) if algorithms else list(backend_registry.ALGORITHMS)
    case_names = list(cases) if cases else list(corpus.CASES)
    analyses = normalize_analyses(analyses)

    violations = []
    if "dma" in analyses or "interleave" in analyses:
        for total in SCHEDULE_SWEEP:
            violations.extend(
                Violation("schedule", "*", "*", f"total={total}", m)
                for m in simulate_schedule(total))

    records, skipped = [], []
    for name in names:
        spec = backend_registry.get(name)
        if not spec.supports_audit:
            skipped.append({"backend": name,
                            "reason": "no audit_trace (host-loop oracle has "
                                      "no jitted core)"})
            continue
        for case_name in case_names:
            A, B = corpus.build_case(case_name)
            for algorithm in algorithms:
                record, v = audit_backend_case(
                    spec, algorithm, case_name, A, B, retrace=retrace,
                    analyses=analyses)
                records.append(record)
                violations.extend(v)

    return {
        "schedule_sweep": list(SCHEDULE_SWEEP),
        "backends": names,
        "algorithms": algorithms,
        "cases": case_names,
        "analyses": list(analyses),
        "records": records,
        "skipped": skipped,
        "violations": [v.to_dict() for v in violations],
        "ok": not violations,
    }
