"""Static backend verifier: abstract-traces every registered backend core
(``jax.make_jaxpr`` on envelope-shaped inputs, no device execution) and runs
six analyses — VMEM footprint vs the planner byte models, DMA double-buffer
schedule structure, copy-event flow equality against the declared traffic
models, exhaustive DMA interleaving model checking, Mosaic-lowerability
preflight lint, and retrace-leak detection. See ``docs/static_analysis.md``
and ``tools/audit_backends.py`` (the CLI / CI entry point)."""

from repro.analysis.dma import (
    check_dma_structure, check_while_bounds, collect_dma_events,
    simulate_schedule,
)
from repro.analysis.interleave import (
    Counterexample, Op, build_program, check_interleave, explore,
)
from repro.analysis.mosaic_lint import (
    LintDiagnostic, check_lint, lint_pallas_call, lint_traced,
)
from repro.analysis.report import (
    ANALYSES, Violation, audit_all, audit_backend_case, normalize_analyses,
)
from repro.analysis.retrace import check_retrace, diff_summary, trace_text
from repro.analysis.traffic import check_traffic, traced_flows
from repro.analysis.vmem import VmemAudit, audit_vmem

__all__ = [
    "ANALYSES",
    "Counterexample",
    "LintDiagnostic",
    "Op",
    "VmemAudit",
    "Violation",
    "audit_all",
    "audit_backend_case",
    "audit_vmem",
    "build_program",
    "check_dma_structure",
    "check_interleave",
    "check_lint",
    "check_retrace",
    "check_traffic",
    "check_while_bounds",
    "collect_dma_events",
    "diff_summary",
    "explore",
    "lint_pallas_call",
    "lint_traced",
    "normalize_analyses",
    "simulate_schedule",
    "trace_text",
    "traced_flows",
]
