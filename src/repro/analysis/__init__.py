"""Static backend verifier: abstract-traces every registered backend core
(``jax.make_jaxpr`` on envelope-shaped inputs, no device execution) and runs
three analyses — VMEM footprint vs the planner byte models, DMA double-buffer
schedule structure, and retrace-leak detection. See ``docs/static_analysis.md``
and ``tools/audit_backends.py`` (the CLI / CI entry point)."""

from repro.analysis.dma import (
    check_dma_structure, check_while_bounds, collect_dma_events,
    simulate_schedule,
)
from repro.analysis.report import (
    Violation, audit_all, audit_backend_case,
)
from repro.analysis.retrace import check_retrace, diff_summary, trace_text
from repro.analysis.vmem import VmemAudit, audit_vmem

__all__ = [
    "VmemAudit",
    "Violation",
    "audit_all",
    "audit_backend_case",
    "audit_vmem",
    "check_dma_structure",
    "check_retrace",
    "check_while_bounds",
    "collect_dma_events",
    "diff_summary",
    "simulate_schedule",
    "trace_text",
]
