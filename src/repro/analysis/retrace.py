"""Retrace-leak detector: same envelope, same jaxpr — or a value leaked.

A :class:`~repro.sparse.csr.GeometryEnvelope` is the compile key: two
instances staged to one envelope must produce byte-identical traces of a
backend core, otherwise some Python value derived from the instance *data*
(an nnz count, a float, a host-computed table size) leaked into the trace —
the silent-retrace bug class the conformance suite's ``TRACE_COUNTS``
deltas only catch per-test, caught here structurally by diffing the jaxprs
themselves.

The staging contract is the spec's ``audit_trace``: both instances are
staged at the *shared* envelope (exactly what the batched executors do), so
any aval difference is itself a staging bug and reported as such before the
jaxpr diff runs.
"""

from __future__ import annotations

import itertools

import jax


def trace_text(target) -> str:
    """Canonical text of one TraceTarget's jaxpr (abstract trace only).

    ``make_jaxpr`` names variables deterministically from a fresh counter
    per trace, so two structurally identical traces print identically.
    """
    return str(jax.make_jaxpr(target.fn)(*target.args))


def diff_summary(text_a: str, text_b: str, context: int = 2,
                 max_lines: int = 12) -> list:
    """First divergence between two jaxpr texts, a few lines of context."""
    lines_a, lines_b = text_a.splitlines(), text_b.splitlines()
    for ix, (la, lb) in enumerate(itertools.zip_longest(lines_a, lines_b)):
        if la != lb:
            lo = max(0, ix - context)
            out = [f"first divergence at jaxpr line {ix + 1}:"]
            for j in range(lo, min(ix + context + 1, max(len(lines_a),
                                                         len(lines_b)))):
                a = lines_a[j] if j < len(lines_a) else "<absent>"
                b = lines_b[j] if j < len(lines_b) else "<absent>"
                marker = ">>" if j == ix else "  "
                out.append(f"{marker} A| {a.strip()}")
                out.append(f"{marker} B| {b.strip()}")
                if len(out) >= max_lines:
                    break
            return out
    return []


def check_retrace(target_a, target_b) -> list:
    """Violations if two same-envelope TraceTargets diverge.

    Checks staged avals first (a staging bug masquerades as a leak), then
    diffs the traced jaxprs textually.
    """
    shapes_a = jax.tree_util.tree_map(
        lambda x: (getattr(x, "shape", ()), str(getattr(x, "dtype", ""))),
        target_a.args)
    shapes_b = jax.tree_util.tree_map(
        lambda x: (getattr(x, "shape", ()), str(getattr(x, "dtype", ""))),
        target_b.args)
    if shapes_a != shapes_b:
        return ["staged operand avals differ between same-envelope "
                f"instances: {shapes_a} vs {shapes_b} — envelope-driven "
                "staging is broken for this backend"]
    text_a, text_b = trace_text(target_a), trace_text(target_b)
    if text_a == text_b:
        return []
    detail = "; ".join(diff_summary(text_a, text_b))
    return ["same-envelope instances trace to different jaxprs — a "
            "Python value from the instance data leaked into the compile "
            f"key ({detail})"]
