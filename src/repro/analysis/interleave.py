"""DMA interleaving model checker: prove the double-buffer schedule safe
under *every* completion order of its async copies.

``analysis/dma.py`` replays one linear order of the slot schedule — copies
complete exactly when waited on. Real DMA is asynchronous: a started copy
may land at any later point, and the schedule is only correct if **no**
completion order can make a step read a slot before its copy has landed or
let a new copy overwrite a slot that is still in flight. This module checks
that exhaustively:

* the program (:func:`build_program`) is the kernel's per-step op sequence
  — prime ``start``, prefetch ``start``, semaphore ``wait``, slot ``read``
  — emitted from the same :class:`~repro.kernels.dma_schedule.SlotSchedule`
  arithmetic the kernels call, once per streamed element and once per
  buffer field (the CSR backends stream three fields per element);
* :func:`explore` walks every interleaving consistent with that program
  order: from each state either the next program op executes (if enabled)
  or any in-flight copy completes. States — ``(pc, in-flight copies, slot
  contents, semaphore counts)`` — are memoized, and the two-slot schedule
  keeps the reachable set tiny (tens of states per streamed element);
* hazards surface as a **minimal counterexample**: the BFS is
  breadth-first over transitions, so the first violation found is a
  shortest event trace, formatted step by step for the report.

Hazards checked: a ``start`` targeting a slot/field with a copy still in
flight (overwrite-in-flight), a ``read`` of a slot/field with a copy still
in flight (read-before-landing), a ``read`` observing the wrong element
(stale contents — the wait consumed a semaphore signal for a *different*
copy), and a ``wait`` no pending copy can ever satisfy (deadlock).
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.analysis.dma import collect_dma_events
from repro.analysis.jaxpr_tools import kernel_jaxpr, pallas_calls
from repro.kernels.dma_schedule import TWO_SLOT


@dataclasses.dataclass(frozen=True)
class Op:
    """One program event. ``kind`` in {"start", "wait", "read"}; ``slot``
    and ``field`` address the double-buffer cell; ``elem`` is the streamed
    element the op moves/consumes (for ``wait`` it is the element the
    schedule believes the signal belongs to)."""

    kind: str
    slot: int
    field: int
    elem: int

    def describe(self) -> str:
        verb = {"start": "start copy of elem",
                "wait": "wait on sem for elem",
                "read": "read elem"}[self.kind]
        return (f"{verb} {self.elem} "
                f"{'into' if self.kind == 'start' else 'from'} "
                f"slot {self.slot} field {self.field}")


def build_program(total: int, schedule=TWO_SLOT, n_fields: int = 1) -> list:
    """The streaming kernel's op sequence for ``total`` elements under
    ``schedule`` — the exact per-step order the kernels emit: prime start
    (step 0 only), prefetch start, wait, read, each replicated per field."""
    ops = []
    for lin in range(total):
        if schedule.is_prime_step(lin):
            for f in range(n_fields):
                ops.append(Op("start", int(schedule.prime_slot()), f, lin))
        if schedule.has_prefetch(lin, total):
            for f in range(n_fields):
                ops.append(
                    Op("start", int(schedule.prefetch_slot(lin)), f, lin + 1))
        rs = int(schedule.read_slot(lin))
        for f in range(n_fields):
            ops.append(Op("wait", rs, f, lin))
        for f in range(n_fields):
            ops.append(Op("read", rs, f, lin))
    return ops


@dataclasses.dataclass(frozen=True)
class Counterexample:
    """A violating interleaving: the hazard, plus the shortest event trace
    reaching it (program ops interleaved with ``complete ...`` DMA-landing
    events)."""

    hazard: str
    trace: tuple

    def describe(self) -> str:
        lines = [f"hazard: {self.hazard}", "shortest interleaving:"]
        lines += [f"  {i + 1}. {step}" for i, step in enumerate(self.trace)]
        return "\n".join(lines)


def _trace_back(parents, state, last_step):
    steps = [last_step]
    while state is not None:
        prev, step = parents[state]
        if step is not None:
            steps.append(step)
        state = prev
    return tuple(reversed(steps))


def explore(ops, n_slots: int, n_fields: int = 1,
            max_states: int = 200_000) -> Counterexample | None:
    """Exhaustive interleaving search. Returns ``None`` when every
    completion order is hazard-free, else the shortest counterexample.

    State: ``(pc, in_flight, contents, sems)`` where ``in_flight`` is the
    set of started-but-unlanded copies ``(slot, field, elem)``, ``contents``
    maps each slot/field cell to the element it holds (-1 = garbage), and
    ``sems`` counts unconsumed completion signals per cell. Transitions:
    complete any in-flight copy (land its element, bump the cell's
    semaphore), or execute ``ops[pc]`` when enabled (``wait`` needs a
    signal). BFS + memoization make the first hazard found minimal.
    """
    empty = tuple(-1 for _ in range(n_slots * n_fields))
    zeros = tuple(0 for _ in range(n_slots * n_fields))
    init = (0, frozenset(), empty, zeros)
    parents = {init: (None, None)}
    queue = collections.deque([init])
    cell = lambda s, f: s * n_fields + f  # noqa: E731
    while queue:
        if len(parents) > max_states:
            raise RuntimeError(
                f"interleaving state space exceeded {max_states} states — "
                "not a two-slot-shaped schedule")
        state = queue.popleft()
        pc, in_flight, contents, sems = state
        # transition family 1: any in-flight copy lands
        for copy in in_flight:
            slot, field, elem = copy
            c = cell(slot, field)
            nxt = (pc, in_flight - {copy},
                   tuple(elem if i == c else v
                         for i, v in enumerate(contents)),
                   tuple(s + 1 if i == c else s
                         for i, s in enumerate(sems)))
            if nxt not in parents:
                parents[nxt] = (state,
                                f"complete copy of elem {elem} into "
                                f"slot {slot} field {field}")
                queue.append(nxt)
        if pc >= len(ops):
            continue
        # transition family 2: the next program op executes
        op = ops[pc]
        c = cell(op.slot, op.field)
        here = {cp for cp in in_flight if cp[0] == op.slot and cp[1] == op.field}
        if op.kind == "start":
            if here:
                victim = sorted(here)[0]
                return Counterexample(
                    f"{op.describe()} overwrites slot {op.slot} field "
                    f"{op.field} while the copy of elem {victim[2]} is "
                    "still in flight",
                    _trace_back(parents, state, op.describe()))
            nxt = (pc + 1, in_flight | {(op.slot, op.field, op.elem)},
                   contents, sems)
        elif op.kind == "wait":
            if sems[c] == 0:
                if not here:
                    return Counterexample(
                        f"{op.describe()} can never be satisfied: no copy "
                        f"to slot {op.slot} field {op.field} is in flight "
                        "and its semaphore is zero (deadlock)",
                        _trace_back(parents, state, op.describe()))
                continue  # blocked; only completions can move this state on
            nxt = (pc + 1, in_flight, contents,
                   tuple(s - 1 if i == c else s for i, s in enumerate(sems)))
        else:  # read
            if here:
                victim = sorted(here)[0]
                return Counterexample(
                    f"{op.describe()} races the in-flight copy of elem "
                    f"{victim[2]} into the same slot",
                    _trace_back(parents, state, op.describe()))
            if contents[c] != op.elem:
                seen = ("garbage (never written)" if contents[c] == -1
                        else f"elem {contents[c]}")
                return Counterexample(
                    f"{op.describe()} observes {seen} — stale slot contents",
                    _trace_back(parents, state, op.describe()))
            nxt = (pc + 1, in_flight, contents, sems)
        if nxt not in parents:
            parents[nxt] = (state, op.describe())
            queue.append(nxt)
    return None


def streamed_shapes(traced) -> list:
    """Per-``pallas_call`` streaming shape ``(total, n_fields)`` derived
    from the trace: ``n_fields`` = number of distinct VMEM buffers targeted
    by ``dma_start`` inside the kernel, ``total`` = grid size (one streamed
    element per linear step). Calls with no hand-rolled DMA yield no entry."""
    shapes = []
    for eqn in pallas_calls(traced):
        kj = kernel_jaxpr(eqn)
        bufs = []
        for kind, dst, _src in collect_dma_events(kj):
            if kind == "start" and dst not in bufs:
                bufs.append(dst)
        if not bufs:
            continue
        grid = tuple(int(g) for g in eqn.params["grid_mapping"].grid)
        total = int(np.prod(grid, dtype=np.int64)) if grid else 1
        shapes.append((total, len(bufs)))
    return shapes


def check_interleave(traced, schedule=TWO_SLOT) -> tuple:
    """Model-check every hand-DMA'd ``pallas_call`` of a traced core under
    ``schedule``. Returns ``(violations, info)``: each violation is a
    formatted minimal counterexample; ``info`` summarizes the exploration
    (streams checked, states visited is implicit in success)."""
    violations, streams = [], []
    for total, n_fields in streamed_shapes(traced):
        # cap the modeled stream: the schedule is periodic in the slot
        # count, so hazards reachable at all are reachable within a few
        # periods; modeling min(total, 6) elements keeps the program short
        # without losing coverage (6 >= 3 full two-slot periods).
        modeled = min(total, 6)
        ops = build_program(modeled, schedule, n_fields)
        cex = explore(ops, int(schedule.n_slots), n_fields)
        streams.append({"total": total, "modeled": modeled,
                        "n_fields": n_fields,
                        "ok": cex is None})
        if cex is not None:
            violations.append(cex.describe())
    info = {"checked": True, "streams": streams}
    return violations, info
