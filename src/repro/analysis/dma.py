"""DMA schedule checker: proves the two-slot double buffer is race-free.

Two complementary passes over one backend core:

**Host simulation** (:func:`simulate_schedule`) replays the slot arithmetic
of ``repro.kernels.dma_schedule`` — the module the kernels themselves import
— over every linear grid step of the audited launch and asserts the
pipeline invariants concretely: the step-``j`` prefetch of element ``j+1``
never targets the slot step ``j`` is reading (slot parity), a slot is never
overwritten before its previous element was consumed, every read consumes a
copy that was started *and* waited on, and every streamed element is copied
and read exactly once. Because the kernels take their slot indices from the
same functions, simulating the module is simulating the kernels.

**Jaxpr structure** (:func:`check_dma_structure`) walks the traced kernel
body and verifies what the simulation cannot see — that the lowered program
actually contains the schedule: every ``dma_start`` targets a VMEM scratch
buffer (the double buffer), each stream buffer receives exactly
``n_slots`` starts (the warm-up prime plus the steady-state prefetch path),
and a matching ``dma_wait`` on that buffer precedes its first read in
program order. ``dma_start`` eqns live inside the ``pl.when`` cond
branches, so the walker threads variable identity through branch invars
positionally (a cond eqn's invars after the predicate map one-to-one onto
its branch jaxprs' invars).

The while-loop pass (:func:`check_while_bounds`) closes the hash kernel's
probe-termination contract: every ``while`` in an audited kernel must carry
a static comparison literal (a derivable step bound), and for the hash
backend that literal must equal
``probe_step_bound(planner.hash_table_slots(...))`` of the audited
envelope — the table the planner sized is the loop bound the kernel baked.
"""

from __future__ import annotations

import jax

from repro.analysis.jaxpr_tools import (
    is_literal, kernel_jaxpr, kernel_operands, pallas_calls, unwrap,
    while_loop_bounds,
)
from repro.kernels.dma_schedule import TWO_SLOT


def simulate_schedule(total: int, schedule=TWO_SLOT) -> list:
    """Replay the double-buffer schedule over ``total`` linear grid steps.

    Returns a list of violation strings (empty = race-free). ``schedule`` is
    any object with the :class:`repro.kernels.dma_schedule.SlotSchedule`
    surface — the production ``TWO_SLOT`` by default, or a deliberately
    broken one (the negative fixtures).
    """
    violations = []
    # per-slot state: (element, waited, consumed) or None (never written)
    slots = [None] * schedule.n_slots
    copied = set()
    read = set()

    def start(step, elem, slot, what):
        if not 0 <= slot < schedule.n_slots:
            violations.append(
                f"step {step}: {what} targets slot {slot} outside the "
                f"{schedule.n_slots}-slot buffer")
            return
        state = slots[slot]
        if state is not None and not state[2]:
            violations.append(
                f"step {step}: {what} of element {elem} overwrites slot "
                f"{slot} holding unconsumed element {state[0]}")
        if elem in copied:
            violations.append(
                f"step {step}: element {elem} copied twice")
        copied.add(elem)
        slots[slot] = (elem, False, False)

    for lin in range(total):
        if schedule.is_prime_step(lin):
            start(lin, 0, schedule.prime_slot(), "warm-up copy")
        if schedule.has_prefetch(lin, total):
            pslot = schedule.prefetch_slot(lin)
            if pslot == schedule.read_slot(lin):
                violations.append(
                    f"step {lin}: prefetch of element {lin + 1} targets "
                    f"slot {pslot}, the slot this step reads — "
                    "write-after-read race")
            start(lin, lin + 1, pslot, "prefetch")
        rslot = schedule.read_slot(lin)
        if not 0 <= rslot < schedule.n_slots or slots[rslot] is None:
            violations.append(
                f"step {lin}: reads slot {rslot}, which holds no element")
            continue
        elem, _, consumed = slots[rslot]
        if elem != lin:
            violations.append(
                f"step {lin}: reads slot {rslot} holding element {elem}, "
                f"expected element {lin}")
        if consumed:
            violations.append(
                f"step {lin}: re-reads already-consumed element {elem}")
        # the kernels wait on exactly the slot they read, every step
        slots[rslot] = (elem, True, True)
        read.add(elem)

    missing = set(range(total)) - read
    if missing:
        violations.append(
            f"elements never streamed: {sorted(missing)[:8]}"
            f"{'...' if len(missing) > 8 else ''}")
    return violations


def _resolve(env: dict, var):
    if is_literal(var):
        return var
    return env.get(var, var)


def collect_dma_events(kjaxpr) -> list:
    """(kind, dst_var, src_var) events of one kernel body, in program order,
    with ``dma_start`` destinations resolved through cond-branch and pjit
    invar mappings back to kernel-invar identity. Kinds: ``"start"``,
    ``"wait"``, ``"get"`` (dst = the ref being read, src = None).
    """
    events = []

    def walk(jaxpr, env):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in ("dma_start", "dma_wait"):
                flat = [_resolve(env, v) for v in eqn.invars]
                parts = jax.tree_util.tree_unflatten(eqn.params["tree"], flat)
                src, _, dst = parts[0], parts[1], parts[2]
                kind = "start" if name == "dma_start" else "wait"
                events.append((kind, dst, src))
            elif name == "get":
                events.append(("get", _resolve(env, eqn.invars[0]), None))
            elif name == "cond":
                for branch in eqn.params["branches"]:
                    body = unwrap(branch)
                    sub_env = {
                        lv: _resolve(env, ov)
                        for lv, ov in zip(body.invars, eqn.invars[1:])
                    }
                    walk(body, sub_env)
            elif name == "pjit":
                body = unwrap(eqn.params["jaxpr"])
                sub_env = {
                    lv: _resolve(env, ov)
                    for lv, ov in zip(body.invars, eqn.invars)
                }
                walk(body, sub_env)

    walk(kjaxpr, {})
    return events


def check_dma_structure(traced, *, n_slots: int = TWO_SLOT.n_slots) -> list:
    """Structural double-buffer checks on every pallas_call of a traced core.

    Returns violation strings. Cores without DMA eqns (the scan backend, the
    BSR kernel — their staging is BlockSpec-driven) pass vacuously.
    """
    violations = []
    for call_ix, eqn in enumerate(pallas_calls(traced)):
        kj = kernel_jaxpr(eqn)
        ops = kernel_operands(eqn)
        scratch_vars = {v for v, _ in ops["scratch"]}
        events = collect_dma_events(kj)
        starts = [e for e in events if e[0] == "start"]
        if not starts:
            continue
        where = f"pallas_call #{call_ix}"
        buffers = {}
        for _, dst, _src in starts:
            buffers.setdefault(dst, 0)
            buffers[dst] += 1
            if dst not in scratch_vars:
                violations.append(
                    f"{where}: dma_start destination {dst} is not a scratch "
                    "operand — stream buffers must be VMEM scratch")
        for dst, n in buffers.items():
            if n != n_slots:
                violations.append(
                    f"{where}: stream buffer {dst} receives {n} dma_start "
                    f"paths, expected {n_slots} (warm-up prime + prefetch)")
        # a wait on the buffer must precede its first read, program order
        for dst in buffers:
            waited = False
            for kind, ref, _src in events:
                if kind == "wait" and ref is dst:
                    waited = True
                if kind == "get" and ref is dst:
                    if not waited:
                        violations.append(
                            f"{where}: stream buffer {dst} is read before "
                            "any dma_wait on it — unsynchronized read")
                    break
        # every started copy must be waited on somewhere
        waited_bufs = {ref for kind, ref, _ in events if kind == "wait"}
        for dst in buffers:
            if dst not in waited_bufs:
                violations.append(
                    f"{where}: stream buffer {dst} has dma_starts but no "
                    "dma_wait — the copy is never synchronized")
    return violations


def check_while_bounds(traced, *, expected_bound: int | None = None) -> list:
    """Every ``while`` in the traced core must carry a static comparison
    literal in its cond (a derivable step bound); with ``expected_bound``
    (the hash backend: ``probe_step_bound(hash_table_slots(...))`` of the
    audited envelope) that literal must be present among the candidates of
    every probe loop."""
    violations = []
    bounds = while_loop_bounds(traced)
    for ix, candidates in enumerate(bounds):
        if not candidates:
            violations.append(
                f"while-loop #{ix}: no static comparison literal in its "
                "cond — bound not derivable, loop may not terminate")
        elif expected_bound is not None and expected_bound not in candidates:
            violations.append(
                f"while-loop #{ix}: cond literals {sorted(candidates)} do "
                f"not include the planner-derived bound {expected_bound} "
                "(probe_step_bound of hash_table_slots)")
    if expected_bound is not None and not bounds:
        violations.append(
            "no while-loop found, but the backend's probe loops were "
            "expected (hash kernel)")
    return violations
