"""Jaxpr-walking utilities for the static backend auditor.

Everything here operates on the output of ``jax.make_jaxpr`` — abstract
traces, no device execution. The walkers are duck-typed (``.eqns`` /
``.jaxpr`` attributes) rather than isinstance-checked against jax internals,
so they survive the ``jax.core`` module reshuffles across versions.

Conventions this module encodes (verified against jax 0.4.37 Pallas
lowerings, which ``repro/kernels/_compat.py`` pins around):

* a ``pallas_call`` eqn carries the kernel body as ``params["jaxpr"]`` and a
  ``grid_mapping`` whose operand counts slice the kernel invars into
  ``[scalar-prefetch | inputs | outputs | scratch]``;
* kernel invars are memory-ref avals with a ``memory_space`` attribute —
  ``None`` means a blocked operand staged into VMEM, explicit VMEM scratch
  says so, ``ANY`` is slow (HBM) memory, SMEM and semaphores are the scalar
  and sync spaces the VMEM accounting must exclude;
* ``dma_start``/``dma_wait`` eqn params carry a ``tree`` whose unflattened
  invars are ``(src, src_transforms, dst, dst_transforms, dst_sem, ...)``;
* sub-jaxprs hide inside eqn params as jaxprs, closed jaxprs, or tuples of
  closed jaxprs (``cond`` branches) — ``subjaxprs`` finds them all.
"""

from __future__ import annotations

import numpy as np


def _as_jaxprs(val):
    """Yield every (possibly closed) jaxpr reachable from one eqn param."""
    if hasattr(val, "eqns"):                     # Jaxpr
        yield val
    elif hasattr(val, "jaxpr") and hasattr(getattr(val, "jaxpr"), "eqns"):
        yield val.jaxpr                          # ClosedJaxpr
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _as_jaxprs(item)


def subjaxprs(jaxpr):
    """Immediate sub-jaxprs of every eqn (cond branches, pjit bodies, scan
    bodies, pallas kernel bodies, ...)."""
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            yield from _as_jaxprs(val)


def iter_eqns(jaxpr):
    """All eqns of a jaxpr, depth-first through every sub-jaxpr."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _as_jaxprs(val):
                yield from iter_eqns(sub)


def find_eqns(jaxpr, primitive_name: str) -> list:
    """Every eqn (recursively) whose primitive has the given name."""
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == primitive_name]


def unwrap(traced):
    """The plain Jaxpr of a ``jax.make_jaxpr`` result (ClosedJaxpr)."""
    return traced.jaxpr if hasattr(traced, "jaxpr") else traced


def aval_bytes(aval) -> int:
    """Byte footprint of one array aval (0 for non-array avals)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64) * np.dtype(dtype).itemsize)


def memory_space_of(aval) -> str:
    """Canonical lowercase memory-space tag of a kernel operand aval.

    ``"blocked"`` = no explicit space (a BlockSpec-staged operand, resident
    in VMEM while its block is live); otherwise the lowercased space name
    (``"vmem"``, ``"smem"``, ``"any"``, ``"semaphore"``, ...).
    """
    space = getattr(aval, "memory_space", None)
    if space is None:
        return "blocked"
    name = str(space).lower()
    for tag in ("semaphore", "smem", "vmem", "any"):
        if tag in name:
            return tag
    return name


def vmem_resident(aval) -> bool:
    """Whether a kernel operand aval occupies fast (VMEM) memory: blocked
    operands and explicit VMEM scratch yes; SMEM scalars, semaphores, and
    ``ANY``-space (slow/HBM) refs no."""
    return memory_space_of(aval) in ("blocked", "vmem")


def pallas_calls(jaxpr) -> list:
    """Every pallas_call eqn reachable from a traced core."""
    return find_eqns(unwrap(jaxpr), "pallas_call")


def kernel_jaxpr(pallas_eqn):
    """The kernel-body jaxpr of a pallas_call eqn."""
    return next(iter(_as_jaxprs(pallas_eqn.params["jaxpr"])))


def kernel_operands(pallas_eqn) -> dict:
    """Kernel invars sliced by role via the grid mapping's operand counts.

    Returns ``{"index": [...], "inputs": [...], "outputs": [...],
    "scratch": [...]}`` of (var, aval) pairs in kernel-invar order.
    """
    gm = pallas_eqn.params["grid_mapping"]
    body = kernel_jaxpr(pallas_eqn)
    invars = list(body.invars)
    n_idx = gm.num_index_operands
    n_in = gm.num_inputs
    n_out = gm.num_outputs
    n_scratch = gm.num_scratch_operands
    if n_idx + n_in + n_out + n_scratch != len(invars):
        raise ValueError(
            f"grid mapping operand counts {n_idx}+{n_in}+{n_out}+{n_scratch} "
            f"do not cover the {len(invars)} kernel invars")
    pairs = [(v, v.aval) for v in invars]
    return {
        "index": pairs[:n_idx],
        "inputs": pairs[n_idx:n_idx + n_in],
        "outputs": pairs[n_idx + n_in:n_idx + n_in + n_out],
        "scratch": pairs[n_idx + n_in + n_out:],
    }


def max_intermediate_bytes(jaxpr) -> int:
    """Largest single intermediate array materialized anywhere in a jaxpr
    (recursively): the peak *temporary* the compiler cannot shrink below —
    for the accumulator kernels, the hash tables / ESC expand buffer their
    byte models carry as the ``workspace`` term."""
    worst = 0
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            worst = max(worst, aval_bytes(getattr(var, "aval", None)))
    return worst


def is_literal(var) -> bool:
    """jax Literal (inline constant) vs Var."""
    return hasattr(var, "val")


def int_literals(eqn) -> list:
    """Integer literal operands of one eqn."""
    out = []
    for var in eqn.invars:
        if is_literal(var):
            val = var.val
            if isinstance(val, (int, np.integer)):
                out.append(int(val))
            elif isinstance(val, np.ndarray) and val.ndim == 0 \
                    and np.issubdtype(val.dtype, np.integer):
                out.append(int(val))
    return out


def while_loop_bounds(jaxpr) -> list:
    """For every ``while`` eqn (recursively): the set of integer literals
    appearing in comparison eqns of its cond jaxpr — the candidate static
    step bounds. A while whose cond has no such literal is unbounded as far
    as static analysis can tell."""
    results = []
    for weqn in find_eqns(unwrap(jaxpr), "while"):
        cond = next(iter(_as_jaxprs(weqn.params["cond_jaxpr"])))
        candidates = set()
        for eqn in iter_eqns(cond):
            if eqn.primitive.name in ("lt", "le", "gt", "ge"):
                candidates.update(int_literals(eqn))
        results.append(candidates)
    return results
