"""Geometry corpus for the static backend auditor.

Mirrors the adversarial geometry classes of the cross-backend conformance
suite (empty rows, skew, zero chunks, single-column B, duplicate-heavy
structure, dense rows, wide sparse output) at **distinct dimensions and
seeds** so auditing never warms the jit caches whose first-trace deltas the
conformance suite pins exactly. Everything here is host-side numpy; the
auditor only ever abstract-traces the staged instances.

Also provides the retrace pair: a second instance that is a *structural
subset* of the first (every other stored entry kept, values rescaled), so
the first instance's envelope dominates both and staging them at the shared
envelope must yield byte-identical jaxprs.
"""

from __future__ import annotations

import numpy as np

from repro.core.planner import ChunkPlan
from repro.sparse.csr import CSR, csr_from_dense, csr_to_dense


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def _random_dense(rng, rows: int, cols: int, density: float) -> np.ndarray:
    mask = rng.random((rows, cols)) < density
    vals = rng.standard_normal((rows, cols)).astype(np.float32)
    return np.where(mask, vals, 0.0).astype(np.float32)


def _case_empty_rows(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 13, 10, 0.4)
    a[0] = 0.0
    a[5] = 0.0
    a[12] = 0.0
    b = _random_dense(rng, 10, 8, 0.3)
    return a, b


def _case_skewed_rows(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 11, 14, 0.06)
    a[4] = rng.standard_normal(14).astype(np.float32)  # one dense row
    b = _random_dense(rng, 14, 9, 0.3)
    return a, b


def _case_all_zero_chunk(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 9, 12, 0.3)
    b = _random_dense(rng, 12, 7, 0.35)
    b[4:8] = 0.0  # the middle B-chunk vanishes
    return a, b


def _case_single_col_b(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 8, 11, 0.4)
    b = _random_dense(rng, 11, 1, 0.5)
    return a, b


def _case_all_zero_b(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 7, 9, 0.4)
    b = np.zeros((9, 5), dtype=np.float32)
    return a, b


def _case_wide_sparse_output(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 9, 11, 0.12)
    b = _random_dense(rng, 11, 40, 0.05)
    return a, b


def _case_duplicate_heavy(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 11, 8, 0.2)
    a[:, :3] = rng.standard_normal((11, 3)).astype(np.float32)
    b = _random_dense(rng, 8, 9, 0.25)
    b[:3] = rng.standard_normal((3, 9)).astype(np.float32)
    return a, b


def _case_dense_row(seed):
    rng = _rng(seed)
    a = _random_dense(rng, 9, 7, 0.2)
    a[3] = rng.standard_normal(7).astype(np.float32)
    b = _random_dense(rng, 7, 10, 0.3)
    b[0] = rng.standard_normal(10).astype(np.float32)
    return a, b


# name -> (builder, seed). Seeds 211+ and dims deliberately disjoint from
# the conformance CASES (seeds 101-108/207/303) and the trace-count
# geometry (21x19x13): the audit must not pre-trace pinned geometries.
CASES = {
    "empty_rows": (_case_empty_rows, 211),
    "skewed_rows": (_case_skewed_rows, 212),
    "all_zero_chunk": (_case_all_zero_chunk, 213),
    "single_col_b": (_case_single_col_b, 214),
    "all_zero_b": (_case_all_zero_b, 215),
    "wide_sparse_output": (_case_wide_sparse_output, 216),
    "duplicate_heavy": (_case_duplicate_heavy, 217),
    "dense_row": (_case_dense_row, 218),
}

# the cheap-but-representative subset the fast test lane audits; the CLI /
# static-audit CI job runs the full corpus.
FAST_CASES = ("skewed_rows", "all_zero_chunk", "wide_sparse_output")


def build_case(name: str) -> tuple:
    """(A, B) CSR pair for one corpus case."""
    builder, seed = CASES[name]
    a, b = builder(seed)
    return csr_from_dense(a), csr_from_dense(b)


def _thirds(n: int) -> tuple:
    if n < 3:
        return (0, n)
    return (0, n // 3, 2 * n // 3, n)


def make_plan(algorithm: str, A: CSR, B: CSR) -> ChunkPlan:
    """The conformance-style plan: knl keeps A whole, chunked algorithms
    split both operands into thirds (cost fields are irrelevant to
    tracing)."""
    p_ac = (0, A.n_rows) if algorithm == "knl" else _thirds(A.n_rows)
    return ChunkPlan(algorithm, p_ac, _thirds(B.n_rows), 0.0, 0.0)


def structural_subset(M: CSR, seed: int = 0) -> CSR:
    """A second instance dominated by ``M``'s geometry: every other stored
    entry kept (so per-row nnz can only shrink), surviving values rescaled.
    Same shape, different data — the retrace pair."""
    dense = np.asarray(csr_to_dense(M))
    rows, cols = np.nonzero(dense)
    keep = np.zeros_like(dense, dtype=bool)
    keep[rows[::2], cols[::2]] = True
    rng = _rng(900 + seed)
    scale = (0.25 + rng.random(dense.shape)).astype(dense.dtype)
    return csr_from_dense(np.where(keep, dense * scale, 0.0).astype(dense.dtype))


def retrace_pair(A: CSR, B: CSR) -> tuple:
    """(A2, B2): structural subsets of (A, B) for the retrace-leak check."""
    return structural_subset(A, seed=1), structural_subset(B, seed=2)
