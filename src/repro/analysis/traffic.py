"""Copy-event flow equality: the traced jaxpr's data movement must equal
the backend's declared per-copy event model **exactly** — not dominate it.

The paper's cost model is a stream of ``copy2Fast``/``copy2Slow`` events;
the executors report that stream as :class:`~repro.core.chunking.ChunkStats`
and the planner prices plans from the same arithmetic. Nothing at runtime
ties those host-side models to the bytes the staged programs actually move,
so this pass closes the loop statically, in three layers:

1. **Traced reconstruction** (:func:`traced_flows`): walk every
   ``pallas_call`` of an abstract-traced core and rebuild, per operand, the
   ordered list of copy-event byte sizes over the whole launch grid:

   * *blocked* operands (BlockSpec-staged) — replay the operand's index map
     over the grid in row-major order (the pipeline's iteration order) with
     the staged scalar-prefetch values bound; a copy event fires whenever
     the map's start indices change between consecutive steps (the pipeline
     reuses a resident block otherwise), at the kernel ref's block bytes;
   * *streamed* (``ANY``-space) operands — the hand-DMA'd path: find the
     VMEM scratch buffer their ``dma_start`` events target and charge one
     slot-sized copy per linear grid step (warm-up prime + per-step
     prefetch, the ``kernels/dma_schedule.py`` arithmetic);
   * *outputs* — one writeback event per run of distinct block indices
     (same transition replay as blocked inputs).

2. **Flow equality** (:func:`check_traffic`): the reconstruction must equal
   the spec's :class:`~repro.core.backend_registry.ExpectedTraffic`
   operand-for-operand and event-for-event; any divergence produces a
   per-event diff naming the operand, the event index, and both byte
   streams.

3. **Stats tie**: same-key expected flows merge event-wise (the three CSR
   field operands of one logical staging sum into the single event the
   executors log) and the merged multiset must equal the
   ``ChunkStats.per_copy_in/out`` the backend reports — so the numbers the
   benches plot are, provably, the bytes the kernels move. A spec may
   declare a documented ``stats_exempt`` reason (the BSR executor's
   per-pair host staging) — recorded, not flagged.
"""

from __future__ import annotations

import collections
import itertools

import numpy as np

from repro.analysis.dma import collect_dma_events
from repro.analysis.jaxpr_tools import (
    aval_bytes, kernel_jaxpr, kernel_operands, memory_space_of, pallas_calls,
)


def _grid_steps(grid):
    """Row-major enumeration of the launch grid (last dim fastest — the
    Pallas pipeline's iteration order, matching the kernels' ``lin``
    linearization)."""
    return itertools.product(*[range(int(g)) for g in grid])


def _block_events(bm, grid, scalar_args, nbytes: float) -> tuple:
    """Copy events of one blocked operand: replay the index map over the
    grid; an event fires at every start-index transition (first step
    included). ``compute_start_indices_interpret`` evaluates data-dependent
    maps (the BSR slot-table lookups) given the concrete scalar-prefetch
    operands."""
    events, prev = [], None
    for idx in _grid_steps(grid):
        start = tuple(
            int(x) for x in bm.compute_start_indices_interpret(
                idx, *scalar_args))
        if start != prev:
            events.append(float(nbytes))
        prev = start
    return tuple(events)


def traced_call_flows(eqn, scalar_args=()) -> dict:
    """Per-operand copy-event flows of one ``pallas_call`` eqn.

    Returns ``{"in": [(label, events), ...], "out": [...],
    "notes": [...]}`` with operands in spec order. ``notes`` collects
    structural surprises (an ``ANY`` operand never DMA'd, a block-mapping
    count mismatch) that the caller should surface as violations.
    """
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    total = int(np.prod(grid, dtype=np.int64)) if grid else 1
    ops = kernel_operands(eqn)
    kj = kernel_jaxpr(eqn)
    bms = list(gm.block_mappings)
    n_in, n_out = len(ops["inputs"]), len(ops["outputs"])
    notes = []
    if len(bms) != n_in + n_out:
        notes.append(
            f"grid mapping carries {len(bms)} block mappings for "
            f"{n_in} inputs + {n_out} outputs")
        return {"in": [], "out": [], "notes": notes}
    dma = collect_dma_events(kj)
    in_flows = []
    for i, (var, aval) in enumerate(ops["inputs"]):
        space = memory_space_of(aval)
        label = f"in#{i}({space})"
        if space == "any":
            starts = [d for d in dma if d[0] == "start" and d[2] is var]
            if not starts:
                notes.append(
                    f"{label}: ANY-space operand is never dma_start'ed — "
                    "a streamed operand the kernel does not stream")
                in_flows.append((label, ()))
                continue
            buf_aval = getattr(starts[0][1], "aval", None)
            n_slots = int(buf_aval.shape[0])
            slot_bytes = aval_bytes(buf_aval) / n_slots
            # one slot copy per linear grid step: prime + per-step prefetch
            in_flows.append((label, (float(slot_bytes),) * total))
        else:
            in_flows.append((label, _block_events(
                bms[i], grid, scalar_args, aval_bytes(aval))))
    out_flows = []
    for j, (_var, aval) in enumerate(ops["outputs"]):
        label = f"out#{j}"
        out_flows.append((label, _block_events(
            bms[n_in + j], grid, scalar_args, aval_bytes(aval))))
    return {"in": in_flows, "out": out_flows, "notes": notes}


def traced_flows(traced, scalar_args=()) -> list:
    """Per-``pallas_call`` operand flows of a traced core (see
    :func:`traced_call_flows`)."""
    return [traced_call_flows(eqn, scalar_args)
            for eqn in pallas_calls(traced)]


def _fmt_events(events, limit: int = 6) -> str:
    shown = ", ".join(f"{e:.0f}" for e in events[:limit])
    more = f", ...({len(events)} total)" if len(events) > limit else ""
    return f"[{shown}{more}]"


def _diff_flow(direction: str, op, label: str, events: tuple) -> str | None:
    """One per-event diff line, or None when the flows match exactly."""
    expected = tuple(float(e) for e in op.events)
    if events == expected:
        return None
    head = (f"{direction} operand {label} (model key {op.key!r}): traced "
            f"{len(events)} copy events {_fmt_events(events)} vs model "
            f"{len(expected)} events {_fmt_events(expected)}")
    for ix, (t, e) in enumerate(zip(events, expected)):
        if t != e:
            return (f"{head}; first divergence at event {ix}: traced "
                    f"{t:.0f} B vs model {e:.0f} B")
    return f"{head}; streams agree up to the shorter length"


def _merged_events(ops) -> tuple:
    """Same-key flows merged event-wise: the k-th event of every operand
    sharing a key sums into one k-th merged event (three CSR fields staging
    together are one ChunkStats copy)."""
    merged, order, errors = {}, [], []
    for op in ops:
        if op.key not in merged:
            merged[op.key] = [float(e) for e in op.events]
            order.append(op.key)
        else:
            cur = merged[op.key]
            if len(cur) != len(op.events):
                errors.append(
                    f"model flows sharing key {op.key!r} differ in event "
                    f"count ({len(cur)} vs {len(op.events)}) — they cannot "
                    "merge into one ChunkStats event stream")
                continue
            merged[op.key] = [a + float(b) for a, b in zip(cur, op.events)]
    events = [e for key in order for e in merged[key]]
    return events, errors


def _diff_multiset(direction: str, merged: list, stats: tuple) -> list:
    got = collections.Counter(round(e, 6) for e in merged)
    want = collections.Counter(round(float(e), 6) for e in stats)
    if got == want:
        return []
    missing = sorted((want - got).elements())
    extra = sorted((got - want).elements())
    return [
        f"{direction} stats tie broken: merged model flow has "
        f"{len(merged)} events summing {sum(merged):.0f} B but the "
        f"executors' ChunkStats log {len(stats)} events summing "
        f"{sum(float(e) for e in stats):.0f} B"
        + (f"; stats events absent from the flow: {_fmt_events(missing)}"
           if missing else "")
        + (f"; flow events absent from the stats: {_fmt_events(extra)}"
           if extra else "")
    ]


def check_traffic(traced, expected, *, scalar_args=()) -> tuple:
    """Flow-equality audit of one traced core against its
    :class:`~repro.core.backend_registry.ExpectedTraffic`.

    Returns ``(violations, info)``: violation strings (empty = the traced
    movement equals the model exactly and ties to the reported stats) and a
    JSON-able summary for the report record.
    """
    violations = []
    calls = pallas_calls(traced)
    info = {"checked": True, "n_pallas_calls": len(calls),
            "stats_exempt": expected.stats_exempt}
    if len(calls) != 1:
        violations.append(
            f"traffic model describes one staged launch but the trace "
            f"contains {len(calls)} pallas_calls")
        return violations, info
    flows = traced_call_flows(calls[0], scalar_args)
    violations.extend(flows["notes"])
    for direction, traced_side, model_side in (
            ("slow->fast", flows["in"], expected.in_ops),
            ("fast->slow", flows["out"], expected.out_ops)):
        if len(traced_side) != len(model_side):
            violations.append(
                f"{direction}: trace has {len(traced_side)} operands but "
                f"the model declares {len(model_side)}")
            continue
        for (label, events), op in zip(traced_side, model_side):
            diff = _diff_flow(direction, op, label, events)
            if diff:
                violations.append(diff)
    info["in_bytes"] = sum(e for _, ev in flows["in"] for e in ev)
    info["out_bytes"] = sum(e for _, ev in flows["out"] for e in ev)
    info["in_events"] = sum(len(ev) for _, ev in flows["in"])
    info["out_events"] = sum(len(ev) for _, ev in flows["out"])
    if expected.stats_exempt is None:
        for direction, ops, stats in (("slow->fast", expected.in_ops,
                                       expected.stats_in),
                                      ("fast->slow", expected.out_ops,
                                       expected.stats_out)):
            merged, errors = _merged_events(ops)
            violations.extend(errors)
            violations.extend(_diff_multiset(direction, merged, stats))
    return violations, info
