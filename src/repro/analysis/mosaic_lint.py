"""Mosaic-lowerability preflight lint: structural checks on the pallas
kernel bodies, run on the abstract trace before any TPU is involved.

The interpreter (``interpret=True``) executes anything jaxpr-shaped, so a
kernel can pass the whole CPU suite and still fail to lower through Mosaic
on hardware. The ROADMAP explicitly distrusts the ESC sort/scatter bodies
and the hash-probe ``while_loop`` for this reason. This lint encodes the
known structural rules from the Pallas/TPU guide as per-kernel diagnostics:

* **primitive census** — host-callback primitives are errors (they cannot
  exist inside a Mosaic kernel); the ESC/hash data-movement primitives
  (``sort``, ``scatter*``, ``gather``, ``cumsum``) are warnings naming the
  untrusted lanes; anything outside the audited allowlist is an info-level
  note so new primitives get reviewed, not silently trusted;
* **tile alignment** — VMEM block shapes want lane = multiples of 128 and
  sublane = multiples of the dtype's min tile (8 for 4-byte, 16 for
  2-byte, 32 for 1-byte types); rank-1 VMEM refs lower via implicit
  reshapes. Misalignment costs padding/relayout, not correctness, and the
  test-corpus geometries are deliberately tiny — so these are warnings;
* **static loop bounds** — a ``while`` whose cond contains no integer
  comparison literal has no statically evident trip bound: an error, since
  the planner's cost model (and Mosaic's unrolling decisions) need one;
* **dtype rules** — float64 values are errors (no TPU lowering under the
  repo's f32 envelope), int64 a warning (x32 mode truncates);
* **dot shape** — ``dot_general`` without a ``preferred_element_type`` is
  a warning (MXU accumulation dtype left implicit);
* **scalar prefetch** — grid index operands must be int32 SMEM refs
  (errors otherwise: Mosaic places scalar prefetch in SMEM);
* **1-D iota** — rank-1 ``iota`` needs a relayout on TPU (warning; the
  guide's recommended form is 2-D ``broadcasted_iota``).

Only **error**-severity diagnostics become audit violations; warnings and
infos ride along in the report and the CI lint artifact so the on-TPU
validation work has a precise worklist.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis.jaxpr_tools import (
    int_literals, iter_eqns, kernel_jaxpr, kernel_operands, memory_space_of,
    pallas_calls,
)

SEVERITIES = ("error", "warning", "info")

# primitives that can never appear inside a Mosaic kernel: they re-enter
# the host runtime mid-kernel.
DISALLOWED = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "infeed", "outfeed",
})

# primitives the ROADMAP flags as untrusted on TPU until validated on
# hardware: the ESC sort/scatter pipeline and the hash-probe machinery.
SUSPECT = frozenset({
    "sort", "scatter", "scatter-add", "scatter-max", "scatter-min",
    "gather", "cumsum",
})

# the audited census of every primitive the four auditable backends stage
# today (probed over the corpus), plus close arithmetic/structural
# neighbours known to lower. Anything outside -> info diagnostic.
ALLOWED = frozenset({
    "add", "and", "broadcast_in_dim", "concatenate", "cond",
    "convert_element_type", "div", "dma_start", "dma_wait", "dot_general",
    "dynamic_slice", "dynamic_update_slice", "eq", "ge", "get", "gt",
    "iota", "le", "le_to", "lt", "lt_to", "max", "min", "mul", "ne",
    "neg", "not", "or", "pad", "pjit", "program_id", "reduce_and",
    "reduce_max", "reduce_min", "reduce_or", "reduce_sum", "rem",
    "reshape", "scan", "select_n", "sign", "slice", "squeeze", "sub",
    "swap", "transpose", "while", "xor",
}) | SUSPECT

# minimum sublane multiple per dtype itemsize (lane is always 128).
LANE = 128
SUBLANE = {4: 8, 2: 16, 1: 32}


@dataclasses.dataclass(frozen=True)
class LintDiagnostic:
    """One structured finding. ``where`` locates it (call index, operand or
    primitive); ``check`` names the rule for filtering/artifact grouping."""

    severity: str
    check: str
    where: str
    message: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def describe(self) -> str:
        return f"[{self.severity}] {self.check} @ {self.where}: {self.message}"


def _tile_diags(where: str, aval, out: list) -> None:
    shape = tuple(getattr(aval, "shape", ()) or ())
    dtype = np.dtype(getattr(aval, "dtype", np.float32))
    if not shape:
        return
    if len(shape) == 1:
        out.append(LintDiagnostic(
            "warning", "tile-alignment", where,
            f"rank-1 VMEM ref of shape {shape} lowers via implicit "
            "relayout; prefer a (sublane, lane) 2-D shape"))
        return
    sublane_min = SUBLANE.get(dtype.itemsize, 8)
    lane, sublane = shape[-1], shape[-2]
    if lane % LANE:
        out.append(LintDiagnostic(
            "warning", "tile-alignment", where,
            f"lane dim {lane} of block shape {shape} is not a multiple of "
            f"{LANE} — Mosaic pads each block to the full lane width"))
    if sublane % sublane_min:
        out.append(LintDiagnostic(
            "warning", "tile-alignment", where,
            f"sublane dim {sublane} of block shape {shape} is not a "
            f"multiple of {sublane_min} (min tile for {dtype.name})"))


def _dtype_diags(where: str, aval, out: list) -> None:
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return
    dt = np.dtype(dtype)
    if dt == np.float64:
        out.append(LintDiagnostic(
            "error", "dtype", where,
            "float64 value in a kernel body — no TPU lowering under the "
            "f32 compute envelope"))
    elif dt == np.int64:
        out.append(LintDiagnostic(
            "warning", "dtype", where,
            "int64 value in a kernel body — x32 lowering truncates"))


def lint_pallas_call(eqn, where: str = "pallas_call#0") -> list:
    """All diagnostics of one ``pallas_call`` eqn's kernel body + operands."""
    diags = []
    ops = kernel_operands(eqn)
    for i, (_var, aval) in enumerate(ops["index"]):
        loc = f"{where}/index#{i}"
        space = memory_space_of(aval)
        dtype = np.dtype(getattr(aval, "dtype", np.float32))
        if space != "smem":
            diags.append(LintDiagnostic(
                "error", "scalar-prefetch", loc,
                f"scalar-prefetch operand lives in {space!r}, not SMEM — "
                "Mosaic requires prefetch scalars in SMEM"))
        if dtype.kind != "i" or dtype.itemsize > 4:
            diags.append(LintDiagnostic(
                "error", "scalar-prefetch", loc,
                f"scalar-prefetch operand has dtype {dtype.name}; Mosaic "
                "prefetches int32 scalars"))
    for group in ("inputs", "outputs", "scratch"):
        for i, (_var, aval) in enumerate(ops[group]):
            space = memory_space_of(aval)
            loc = f"{where}/{group}#{i}"
            if space in ("blocked", "vmem"):
                _tile_diags(loc, aval, diags)
    kj = kernel_jaxpr(eqn)
    seen = set()
    for keqn in iter_eqns(kj):
        name = keqn.primitive.name
        loc = f"{where}/{name}"
        if name in DISALLOWED:
            diags.append(LintDiagnostic(
                "error", "primitive-allowlist", loc,
                "host-callback primitive inside a kernel body — cannot "
                "lower through Mosaic"))
        elif name in SUSPECT and name not in seen:
            diags.append(LintDiagnostic(
                "warning", "primitive-allowlist", loc,
                "ESC/hash data-movement primitive — the ROADMAP flags this "
                "lane as unvalidated on TPU hardware"))
        elif name not in ALLOWED and name not in seen:
            diags.append(LintDiagnostic(
                "info", "primitive-allowlist", loc,
                "primitive outside the audited allowlist — review its "
                "Mosaic support before trusting this lane on TPU"))
        seen.add(name)
        if name == "while":
            cond = keqn.params["cond_jaxpr"].jaxpr
            bounds = set()
            for ceqn in iter_eqns(cond):
                if ceqn.primitive.name in ("lt", "le", "gt", "ge"):
                    bounds.update(int_literals(ceqn))
            if not bounds:
                diags.append(LintDiagnostic(
                    "error", "static-bounds", loc,
                    "while loop whose cond has no integer comparison "
                    "literal — no statically evident trip bound"))
        if name == "dot_general" and \
                keqn.params.get("preferred_element_type") is None:
            diags.append(LintDiagnostic(
                "warning", "dot-accumulation", loc,
                "dot_general without preferred_element_type — MXU "
                "accumulation dtype left implicit"))
        if name == "iota":
            aval = keqn.outvars[0].aval
            if len(getattr(aval, "shape", ())) < 2:
                diags.append(LintDiagnostic(
                    "warning", "iota-rank", loc,
                    f"rank-{len(aval.shape)} iota of shape {aval.shape} — "
                    "TPU wants 2-D broadcasted_iota"))
        for var in keqn.outvars:
            _dtype_diags(loc, getattr(var, "aval", None), diags)
    return diags


def lint_traced(traced) -> list:
    """All diagnostics across every ``pallas_call`` of a traced core."""
    diags = []
    for ci, eqn in enumerate(pallas_calls(traced)):
        diags.extend(lint_pallas_call(eqn, f"pallas_call#{ci}"))
    return diags


def check_lint(traced) -> tuple:
    """Audit entry: ``(violations, info)``. Violations are the error-level
    diagnostics' descriptions; ``info`` carries every diagnostic (dicts)
    plus per-severity counts for the report and the CI artifact."""
    diags = lint_traced(traced)
    counts = {sev: 0 for sev in SEVERITIES}
    for d in diags:
        counts[d.severity] += 1
    violations = [d.describe() for d in diags if d.severity == "error"]
    info = {"checked": True, "counts": counts,
            "diagnostics": [d.to_dict() for d in diags]}
    return violations, info
