"""Bender et al.'s four chunkability properties (paper §2), quantified for
SpGEMM on the bench problems:

 (1) memory boundedness        — arithmetic intensity vs machine balance
 (2) scratch-pad decomposable  — planner finds a partition where every chunk
                                 fits an 1/8-size fast window
 (3) cache chunking insufficient — L2-capacity miss fraction still high
 (4) staged-data reuse         — mean uses of each staged B row
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, BENCH_SIZES
from repro.core.kkmem import spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL
from repro.core.planner import plan_knl, row_bytes_csr
from repro.sparse import multigrid


def run():
    for prob, n in BENCH_SIZES.items():
        A, R, P = multigrid.problem(prob, n)
        for tag, (L, Rt) in {"AxP": (A, P), "RxA": (R, A)}.items():
            ws = spgemm_symbolic_host(L, Rt)
            st = analyze(L, Rt)
            bytes_touched = L.nbytes() + Rt.nbytes() + ws.c_nnz * 12.0
            intensity = ws.flops / bytes_touched
            balance = KNL.flops_peak / KNL.slow.bandwidth_Bps
            emit(f"chunkability/{prob}/{tag}/1_mem_bound", 0.0,
                 f"AI={intensity:.2f}_vs_balance={balance:.1f}")
            size_b = float(row_bytes_csr(Rt).sum())
            plan = plan_knl(L, Rt, fast_limit_bytes=size_b / 8)
            ok = all(
                row_bytes_csr(Rt)[s:e].sum() <= size_b / 8 * 1.01 or e - s == 1
                for s, e in zip(plan.p_b[:-1], plan.p_b[1:]))
            emit(f"chunkability/{prob}/{tag}/2_decomposable", 0.0,
                 f"{plan.n_b}chunks_fit={ok}")
            l2_miss = st.miss_fraction_bytes(1 << 20)
            emit(f"chunkability/{prob}/{tag}/3_cache_insufficient", 0.0,
                 f"L2miss={l2_miss:.3f}")
            nnz_a = float(np.asarray(L.indptr)[-1])
            reuse = nnz_a / max(Rt.n_rows, 1)
            emit(f"chunkability/{prob}/{tag}/4_reuse", 0.0,
                 f"{reuse:.2f}_uses_per_staged_row")
