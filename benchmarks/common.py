"""Shared benchmark machinery.

Every benchmark prints ``name,us_per_call,derived`` CSV rows:
  * us_per_call — real median wall-clock of the JAX computation on this CPU
    (algorithmic work is real; only the *memory-system* behaviour is modeled).
  * derived     — the paper-comparable number (modeled GFLOP/s, speedup, count),
    produced by the calibrated two-level memory model (repro.core.memory_model).

The paper's absolute GFLOP/s need its machines; what we reproduce exactly are
its DECISIONS and RELATIVE effects (EXPERIMENTS.md maps each row to the paper
claim it validates).
"""

from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str | float):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def emit_compare(name: str, us_base: float, us_new: float):
    """Emit a measured base-vs-new comparison; derived = real speedup."""
    speedup = us_base / us_new if us_new > 0 else float("inf")
    emit(name, us_new, f"{speedup:.2f}x_vs_base({us_base:.1f}us)")


def timeit(fn, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall-clock microseconds; blocks on JAX async dispatch."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# problem sizes tuned to finish in seconds on CPU while keeping the paper's
# structure (R short+wide, A regular, nnz/row = 7/13/27/81)
BENCH_SIZES = {
    "laplace3d": 14,
    "bigstar2d": 44,
    "brick3d": 11,
    "elasticity": 7,
}
