"""Serving throughput: SpGEMMService (bucketed vmapped batches) vs naive
per-instance dispatch, across batch sizes and mixed-structure workloads.

Each workload submits ``n`` small C = A x B requests two ways:

  * service — queue everything into ``SpGEMMService``, one ``flush()``: one
    vmapped-scan execution per geometry bucket microbatch;
  * naive   — a Python loop of per-instance ``chunked_spgemm`` calls (the
    dispatch pattern the service replaces).

Mixed workloads draw sparsity densities from a small set, so instances differ
in structure — the heterogeneous-batch case that needs geometry envelopes.

Every row is measured in two regimes, because they answer different questions:

  * ``fresh`` — a wave of never-seen matrices after one cold warmup wave.
    Fresh structures mean fresh padded geometries: the naive path retraces
    per new geometry while the service's quantized buckets absorb them, so
    this regime measures exactly the per-multiply setup amortization the
    service exists for (it flatters the service on purpose — that's the
    effect, not an artifact).
  * ``warm``  — re-serving the *identical* requests, all compiles cached on
    both sides: pure steady-state dispatch + execution. At tiny CPU sizes
    the service loses here (vmap lanes serialize on CPU and envelope/
    microbatch padding is wasted work); the regime keeps the fresh numbers
    honest.

The service runs with its serving optimizations on: buffer donation into the
bucket-owned jitted cores and tail-width learning (warmup waves repeat until
a wave compiles nothing, so learned widths are warm before timing starts).
``run`` asserts every warm row reports ``compiles == 0`` — a warm-regime
compile means the executable cache leaked, and the regime's numbers would be
lies.

Output is a single JSON document on stdout (machine-checkable; CI smoke runs
``--smoke`` and asserts it parses), with per-row/per-regime service/naive
microseconds, requests-per-second throughput, and speedup, plus top-level
``fresh_speedup``/``warm_speedup`` medians that ``tools/bench_trajectory.py``
tracks per commit.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core.chunking import chunked_spgemm
from repro.core.planner import ChunkPlan
from repro.serve.spgemm_service import SpGEMMService
from repro.sparse.csr import csr_from_dense


def _random_csr(rng, m, n, density):
    d = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return csr_from_dense(d.astype(np.float32))


def _requests(rng, n, dim, densities):
    out = []
    for i in range(n):
        d = densities[i % len(densities)]
        out.append((_random_csr(rng, dim, dim, d), _random_csr(rng, dim, dim, d)))
    return out


def _serve_service(service, reqs):
    t0 = time.perf_counter()
    for A, B in reqs:
        service.submit(A, B)
    responses = service.flush()
    return (time.perf_counter() - t0) * 1e6, responses


def _serve_naive(reqs, plan):
    t0 = time.perf_counter()
    outs = []
    for A, B in reqs:
        C, _ = chunked_spgemm(A, B, plan)
        outs.append(C)
    jax.block_until_ready([(C.indptr, C.indices, C.data) for C in outs])
    return (time.perf_counter() - t0) * 1e6, outs


def run(dim: int, batch_sizes, densities_by_workload, max_batch: int,
        quantum: int, seed: int = 0, retrace_budget: int = 16) -> dict:
    half = dim // 2
    plan = ChunkPlan("knl", (0, dim), (0, half, dim), 0.0, 0.0)
    rows = []
    for workload, densities in densities_by_workload.items():
        for n in batch_sizes:
            rng = np.random.default_rng(seed)
            service = SpGEMMService(plan, quantum=quantum, max_batch=max_batch,
                                    retrace_budget=retrace_budget,
                                    donate_buffers=True,
                                    learn_tail_widths=True)
            # warmup waves (not reported) until one compiles nothing: first
            # compiles on both sides, plus any tail widths the service learns
            # from this workload's flush pattern
            warmup = _requests(rng, n, dim, densities)
            for _ in range(6):
                compiles0 = service.stats.compiles
                _serve_service(service, warmup)
                if service.stats.compiles == compiles0:
                    break
            _serve_naive(warmup, plan)
            # fresh regime: never-seen structures -> new geometries; the
            # naive path retraces per geometry, the service's buckets don't
            timed = _requests(rng, n, dim, densities)
            compiles0 = service.stats.compiles
            fresh_service_us, fresh_responses = _serve_service(service, timed)
            fresh_naive_us, _ = _serve_naive(timed, plan)
            fresh_compiles = service.stats.compiles - compiles0
            assert len(fresh_responses) == n
            # warm regime: identical requests again, zero compiles anywhere
            compiles1 = service.stats.compiles
            warm_service_us, warm_responses = _serve_service(service, timed)
            warm_naive_us, _ = _serve_naive(timed, plan)
            warm_compiles = service.stats.compiles - compiles1
            # the warm regime's whole claim is "all executables cached": a
            # compile here means the cache leaked and the timing is a lie
            assert warm_compiles == 0, (
                f"warm regime compiled {warm_compiles}x "
                f"(workload={workload}, n={n})")
            for regime, service_us, naive_us, responses, compiles in (
                    ("fresh", fresh_service_us, fresh_naive_us,
                     fresh_responses, fresh_compiles),
                    ("warm", warm_service_us, warm_naive_us,
                     warm_responses, warm_compiles)):
                rows.append({
                    "workload": workload,
                    "regime": regime,
                    "n_requests": n,
                    "service_us": round(service_us, 1),
                    "naive_us": round(naive_us, 1),
                    "service_rps": round(n / (service_us * 1e-6), 1),
                    "naive_rps": round(n / (naive_us * 1e-6), 1),
                    "speedup": round(naive_us / service_us, 3),
                    "buckets": service.n_buckets,
                    "compiles": compiles,
                    "mean_latency_us": round(
                        1e6 * sum(r.latency_s for r in responses) / n, 1),
                })
    def _median_speedup(regime):
        v = sorted(r["speedup"] for r in rows if r["regime"] == regime)
        return round(v[len(v) // 2], 3) if v else 0.0

    # top-level scalars flow verbatim into BENCH_trajectory.json summaries,
    # so the warm-regime gap is tracked per commit
    return {
        "bench": "spgemm_serving",
        "dim": dim,
        "max_batch": max_batch,
        "quantum": quantum,
        "retrace_budget": retrace_budget,
        "fresh_speedup": _median_speedup("fresh"),
        "warm_speedup": _median_speedup("warm"),
        "rows": rows,
    }


def run_suite():
    """Driver entry point (``python -m benchmarks.run serving``): a small
    serving sweep emitted as the driver's ``name,us_per_call,derived`` CSV
    rows. The standalone ``main()`` JSON document remains the primary output
    (CI smoke-parses it); this lane makes serving reachable from the same
    driver as every paper table/figure."""
    from benchmarks.common import emit

    report = run(dim=24, batch_sizes=[3, 6], max_batch=4, quantum=32,
                 densities_by_workload={"uniform": [0.2],
                                        "mixed": [0.08, 0.25]})
    for row in report["rows"]:
        emit(
            f"serving/{row['workload']}/{row['regime']}/n={row['n_requests']}"
            f"[buckets={row['buckets']}]",
            row["service_us"],
            f"{row['speedup']}x_vs_naive({row['service_rps']}rps)",
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, still valid JSON)")
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=None)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--quantum", type=int, default=32)
    ap.add_argument("--retrace-budget", type=int, default=16,
                    help="bound on distinct compiled geometry buckets")
    args = ap.parse_args()

    if args.smoke:
        dim = args.dim or 16
        batch_sizes = args.batch_sizes or [2, 3, 5]
        workloads = {"uniform": [0.2], "mixed": [0.1, 0.3]}
    else:
        dim = args.dim or 48
        batch_sizes = args.batch_sizes or [4, 8, 16]
        workloads = {"uniform": [0.15],
                     "mixed": [0.05, 0.1, 0.2, 0.3]}
    report = run(dim, batch_sizes, workloads, args.max_batch, args.quantum,
                 retrace_budget=args.retrace_budget)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
