"""Pallas kernel microbenches (interpret mode: correctness-path timing only) +
the TPU roofline estimates for the kernels' target shapes.

Wall-clock here measures the interpret-mode path on CPU (NOT TPU performance);
the derived column is the modeled VMEM-chunked execution time on TPU v5e from
the memory model — HBM->VMEM streaming at 819 GB/s overlapped with MXU work at
197 TFLOP/s, the Pallas pipeline's double-buffering assumption.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.memory_model import TPU_V5E
from repro.kernels import ops
from repro.sparse.bsr import bsr_from_dense


def _tpu_time(flops: float, bytes_moved: float) -> float:
    return max(flops / TPU_V5E.flops_peak, bytes_moved / TPU_V5E.copy_bandwidth_Bps)


def run():
    rng = np.random.default_rng(0)

    # BSR SpGEMM at a bench-scale shape
    m = k = n = 256
    bs = 16
    da = (rng.random((m, k)) < 0.12) * rng.standard_normal((m, k))
    db = (rng.random((k, n)) < 0.12) * rng.standard_normal((k, n))
    A = bsr_from_dense(da.astype(np.float32), bs)
    B = bsr_from_dense(db.astype(np.float32), bs)
    from repro.kernels.bsr_spgemm import bsr_spgemm_symbolic
    meta = bsr_spgemm_symbolic(A, B)
    us = timeit(lambda: ops.bsr_spgemm(A, B, meta=meta), repeats=2)
    moved = (meta.nc_pad * meta.u_max * 2 * bs * bs * 4)      # staged blocks
    emit("kernel/bsr_spgemm/256x256x256_bs16", us,
         f"tpu_est={_tpu_time(meta.flops, moved)*1e6:.2f}us")

    # grouped matmul at an MoE-like shape (tiny)
    e, kdim, ndim = 8, 128, 128
    sizes = rng.integers(0, 64, e).tolist()
    x = jnp.asarray(rng.standard_normal((sum(sizes), kdim)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((e, kdim, ndim)).astype(np.float32))
    us = timeit(lambda: ops.grouped_matmul(x, w, sizes, bt=32, bn=64, bk=64)[0],
                repeats=2)
    flops = 2 * sum(sizes) * kdim * ndim
    moved = w.size * 4 + x.size * 4
    emit("kernel/grouped_matmul/moe8e", us,
         f"tpu_est={_tpu_time(flops, moved)*1e6:.2f}us")

    # decode attention at a small cache
    b, hkv, g, d, s = 2, 4, 4, 64, 1024
    q = jnp.asarray(rng.standard_normal((b, hkv, g, d)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    lengths = jnp.asarray([s, s // 3], jnp.int32)
    us = timeit(lambda: ops.decode_attention(q, kc, vc, lengths, bs_kv=256),
                repeats=2)
    flops = 4 * b * hkv * g * s * d
    moved = kc.size * 4 * 2
    emit("kernel/decode_attention/s1024", us,
         f"tpu_est={_tpu_time(flops, moved)*1e6:.2f}us")
