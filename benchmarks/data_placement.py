"""Paper Table 3: selective data placement — pin one of A, B, C to slow memory.

Validates the paper's central DP observation: B_Pin collapses performance
(7x-29x on the GPU), A_Pin/C_Pin are mild when those operands are small, and DP
(B fast, rest slow) recovers most of all-fast performance on the KNL (§3.2.1).
"""

from __future__ import annotations


from benchmarks.common import emit, timeit, BENCH_SIZES
from repro.core.kkmem import spgemm, spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL, P100
from repro.core.placement import Placement, placement_cost, dp_recommendation
from repro.sparse import multigrid

PLACEMENTS = {
    "HBM": Placement("fast", "fast", "fast"),
    "A_Pin": Placement("slow", "fast", "fast"),
    "B_Pin": Placement("fast", "slow", "fast"),
    "C_Pin": Placement("fast", "fast", "slow"),
    "HostPin": Placement("slow", "slow", "slow"),
    "DP": Placement("slow", "fast", "slow"),
}


def run():
    for prob, n in BENCH_SIZES.items():
        A, R, P = multigrid.problem(prob, n)
        for tag, (L, Rt) in {"RxA": (R, A), "AxP": (A, P)}.items():
            ws = spgemm_symbolic_host(L, Rt)
            st = analyze(L, Rt)
            us = timeit(lambda L=L, Rt=Rt, ws=ws: spgemm(L, Rt, ws.c_pad),
                        repeats=3)
            for mode, pl in PLACEMENTS.items():
                cost = placement_cost(P100, pl, L, Rt, ws.c_nnz * 12.0, ws.flops,
                                      st)
                emit(f"table3/gpu/{prob}/{tag}/{mode}", us,
                     f"{cost.gflops(ws.flops):.3f}")
            # KNL DP recovery (§3.2.1 + Figs 9/10)
            for mode in ("HBM", "HostPin", "DP"):
                cost = placement_cost(KNL, PLACEMENTS[mode], L, Rt,
                                      ws.c_nnz * 12.0, ws.flops, st)
                emit(f"fig9_10/knl/{prob}/{tag}/{mode}", us,
                     f"{cost.gflops(ws.flops):.3f}")
            rec = dp_recommendation(
                P100, L.nbytes(), Rt.nbytes(), ws.c_nnz * 12.0)
            emit(f"table3/gpu/{prob}/{tag}/recommended", 0.0,
                 f"B={rec.B}")
