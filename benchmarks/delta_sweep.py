"""Paper Table 2: Elasticity R / A times random RHS with increasing delta.

Claims validated: the DDR/HBM gap shrinks as delta grows (spatial locality /
prefetch amortization); L1-proxy misses fall with delta; R x RHS gaps exceed
A x RHS gaps at equal delta."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.kkmem import spgemm, spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL
from repro.core.placement import ALL_FAST, ALL_SLOW, placement_cost
from repro.sparse import generators, multigrid

DELTAS = (1, 4, 16, 64)   # 256 takes ~12 s/call on CPU for the same conclusion


def run():
    A, R, P = multigrid.problem("elasticity", 6)
    for tag, L in {"RxRHS": R, "AxRHS": A}.items():
        for delta in DELTAS:
            rhs = generators.random_uniform_degree(
                L.n_cols, L.n_cols, delta, seed=delta)
            ws = spgemm_symbolic_host(L, rhs)
            st = analyze(L, rhs)
            us = timeit(lambda L=L, r=rhs, ws=ws: spgemm(L, r, ws.c_pad),
                        repeats=3)
            fast = placement_cost(KNL, ALL_FAST, L, rhs, ws.c_nnz * 12.0,
                                  ws.flops, st)
            slow = placement_cost(KNL, ALL_SLOW, L, rhs, ws.c_nnz * 12.0,
                                  ws.flops, st)
            l1 = st.miss_fraction_bytes(32 << 10)
            l2 = st.miss_fraction_bytes(1 << 20)
            emit(f"table2/{tag}/delta{delta}/DDR", us,
                 f"{slow.gflops(ws.flops):.3f}")
            emit(f"table2/{tag}/delta{delta}/HBM", us,
                 f"{fast.gflops(ws.flops):.3f}")
            emit(f"table2/{tag}/delta{delta}/L1miss", 0.0, f"{l1:.4f}")
            emit(f"table2/{tag}/delta{delta}/L2miss", 0.0, f"{l2:.4f}")
