"""§Roofline table: aggregate the dry-run artifacts into the per-(arch x shape)
roofline report (reads reports/dryrun/*/*.json written by launch/dryrun.py)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(report_dir: str = "reports/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*", "*.json"))):
        with open(path) as f:
            r = json.load(f)
        mesh = os.path.basename(os.path.dirname(path))
        if r.get("status") == "skip":
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}", 0.0, "SKIP")
            continue
        if r.get("status") != "ok":
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}", 0.0, "FAIL")
            continue
        frac = r.get("roofline_fraction", 0.0)
        emit(
            f"roofline/{mesh}/{r['arch']}/{r['shape']}",
            max(r.get("t_compute", 0), r.get("t_memory", 0),
                r.get("t_collective", 0)) * 1e6,
            f"bottleneck={r['bottleneck']};frac={frac:.3f};"
            f"rho={r.get('rho', 1):.1f};temp_GiB={r.get('temp_bytes', 0)/2**30:.1f}",
        )
        rows.append(r)
    if not rows:
        emit("roofline/NO_REPORTS_FOUND_run_dryrun_first", 0.0, "n/a")
