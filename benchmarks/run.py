"""Benchmark driver: one suite per paper table/figure (plus executor and
serving lanes).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table2 fig11   # a subset

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py for the
semantics of each column). A SUITES value is ``module`` (whose ``run()`` is
called) or ``module:function`` for lanes that live inside a bigger module."""

from __future__ import annotations

import sys
import time

SUITES = {
    "fig3_4_6_7": "benchmarks.memory_modes",      # KNL + GPU memory modes
    "table2": "benchmarks.delta_sweep",           # delta sweep
    "table3": "benchmarks.data_placement",        # selective placement (+Figs 9/10)
    "fig12_13": "benchmarks.chunking_bench",      # chunked algorithms (+Alg 1)
    "triangle_counting": "benchmarks.triangle_counting",  # Fig 11 + Table 4
    "chunkability": "benchmarks.chunkability",    # Bender properties
    "kernels": "benchmarks.kernels_bench",        # Pallas kernel microbenches
    "roofline": "benchmarks.roofline_table",      # §Roofline aggregation
    "serving": "benchmarks.spgemm_serving:run_suite",   # SpGEMMService vs naive
    "scan_vs_loop": "benchmarks.chunking_bench:run_loop_vs_scan",
    "scan_vs_pallas": "benchmarks.chunking_bench:run_csv_scan_vs_pallas",
    "accumulator_shootout":
        "benchmarks.chunking_bench:run_csv_accumulator_shootout",
    "bsr_blocking": "benchmarks.chunking_bench:run_csv_bsr_blocking",
}


def _resolve(spec: str):
    mod_name, _, fn_name = spec.partition(":")
    mod = __import__(mod_name, fromlist=["run"])
    return getattr(mod, fn_name or "run")


def main() -> None:
    args = sys.argv[1:]
    picks = args if args else list(SUITES)
    print("name,us_per_call,derived")
    for name in picks:
        if name not in SUITES:
            print(f"# unknown suite {name!r}; have {list(SUITES)}", file=sys.stderr)
            continue
        fn = _resolve(SUITES[name])
        t0 = time.time()
        print(f"# --- {name} ({SUITES[name]}) ---")
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
