"""Paper Alg 1 + Figs 12/13: chunked SpGEMM vs whole-problem placements.

KNL (Alg 1): chunk B through an 8 GiB fast window for R x A (the only case the
paper finds chunking profitable on KNL) and report modeled GFLOP/s including the
copy cost, vs DDR and HBM.

GPU (Figs 12/13): Chunk8 / Chunk16 (fast window of 8/16 "GiB" scaled to bench
size) with the Alg-4 planner choosing the streaming order; derived speedup vs
host-pinned — the paper reports 3.1x-14.7x.

Executor lanes: ``run_loop_vs_scan`` (host loop vs device-resident lax.scan,
CSV rows), ``run_scan_vs_pallas`` (scan vs the explicitly double-buffered
Pallas backend), ``run_accumulator_shootout`` (the three-way dense-slab
vs ESC-sparse vs hash-probe accumulator comparison across an output-density
sweep, with all three planner fast-memory models and the ``backend="auto"``
pick per row), and ``run_bsr_blocking`` (the blocked MXU-tile accumulator vs
the entry-level ones across a blockiness sweep — where the auto dispatch
starts and stops selecting ``backend="bsr"``). The JSON lanes power
``python benchmarks/chunking_bench.py [--smoke] [--lane ...]``, which prints
one JSON document (the ``BENCH_chunking.json`` schema:
``{"bench": ..., "rows": [...]}``) that CI smoke-parses like the serving
bench.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import emit, emit_compare, timeit, BENCH_SIZES
from repro.core.chunking import chunked_spgemm, default_c_pad
from repro.core.kkmem import spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL, P100
from repro.core.placement import ALL_FAST, ALL_SLOW, placement_cost
from repro.core.planner import plan_chunks, plan_knl, row_bytes_csr
from repro.sparse import multigrid


def _modeled_chunk_gflops(system, _plan, stats, ws, st, A, B) -> float:
    """Kernel runs at fast-memory speed; staged copies pay the copy engine."""
    nnz_a = float(np.asarray(A.indptr)[-1])
    from repro.core.memory_model import spgemm_cost

    kernel = spgemm_cost(
        system, bytes_A=A.nbytes(), bytes_B=B.nbytes(), bytes_C=ws.c_nnz * 12.0,
        flops=ws.flops, b_row_reads=nnz_a, b_row_bytes=st.avg_b_row_bytes,
        b_miss_fraction=st.miss_fraction_bytes(1 << 20),
        place_A="fast", place_B="fast", place_C="fast",
        copy_bytes=stats.copy_bytes)
    return kernel.gflops(ws.flops)


def run():
    for prob in ("laplace3d", "elasticity"):
        n = BENCH_SIZES[prob]
        A, R, P = multigrid.problem(prob, n)
        # --- KNL Alg 1 on R x A ------------------------------------------------
        ws = spgemm_symbolic_host(R, A)
        st = analyze(R, A)
        size_b = float(row_bytes_csr(A).sum())
        for frac, label in ((0.5, "Chunk-half"), (0.25, "Chunk-quarter")):
            plan = plan_knl(R, A, fast_limit_bytes=size_b * frac)
            C, stats = chunked_spgemm(R, A, plan)
            us = timeit(lambda R=R, A=A, p=plan: chunked_spgemm(R, A, p),
                        repeats=2)
            g = _modeled_chunk_gflops(KNL, plan, stats, ws, st, R, A)
            emit(f"alg1/knl/{prob}/RxA/{label}", us, f"{g:.3f}")
        ddr = placement_cost(KNL, ALL_SLOW, R, A, ws.c_nnz * 12.0, ws.flops, st)
        hbm = placement_cost(KNL, ALL_FAST, R, A, ws.c_nnz * 12.0, ws.flops, st)
        emit(f"alg1/knl/{prob}/RxA/DDR", 0.0, f"{ddr.gflops(ws.flops):.3f}")
        emit(f"alg1/knl/{prob}/RxA/HBM", 0.0, f"{hbm.gflops(ws.flops):.3f}")

        # --- GPU Figs 12/13 ----------------------------------------------------
        for tag, (L, Rt) in {"AxP": (A, P), "RxA": (R, A)}.items():
            ws = spgemm_symbolic_host(L, Rt)
            st = analyze(L, Rt)
            total = float(row_bytes_csr(L).sum() + row_bytes_csr(Rt).sum()
                          + ws.c_nnz * 12.0)
            pinned = placement_cost(P100, ALL_SLOW, L, Rt, ws.c_nnz * 12.0,
                                    ws.flops, st)
            for frac, label in ((0.5, "Chunk16"), (0.25, "Chunk8")):
                crb = np.full(L.n_rows, max(ws.c_nnz / L.n_rows, 1.0) * 12.0)
                plan = plan_chunks(L, Rt, crb, P100,
                                   fast_limit_bytes=total * frac)
                C, stats = chunked_spgemm(L, Rt, plan)
                us = timeit(lambda L=L, Rt=Rt, p=plan: chunked_spgemm(L, Rt, p),
                            repeats=2)
                g = _modeled_chunk_gflops(P100, plan, stats, ws, st, L, Rt)
                speedup = g / pinned.gflops(ws.flops)
                emit(f"fig12_13/gpu/{prob}/{tag}/{label}"
                     f"[{plan.algorithm};ac={plan.n_ac};b={plan.n_b}]",
                     us, f"{speedup:.2f}x_vs_pinned")

    # The executor comparison sweeps (loop vs scan, scan vs pallas) are their
    # own driver lanes — `scan_vs_loop` / `scan_vs_pallas` in
    # benchmarks.run.SUITES — so a full `python -m benchmarks.run` covers
    # them exactly once.


def run_loop_vs_scan():
    from repro.core.planner import ChunkPlan

    prob = "laplace3d"
    A, R, P = multigrid.problem(prob, BENCH_SIZES[prob])

    cases = []
    # 1-D B streaming (Alg 1) at two fast-window sizes
    for frac, label in ((0.5, "knl-half"), (0.125, "knl-eighth")):
        cases.append((plan_knl(A, P, fast_limit_bytes=P.nbytes() * frac),
                      label))
    # 2-D plans: both streaming orders on an explicit 3x4 partition
    n_a, n_b = A.n_rows, P.n_rows
    p_ac = tuple(int(v) for v in np.linspace(0, n_a, 4))
    p_b = tuple(int(v) for v in np.linspace(0, n_b, 5))
    for alg in ("chunk1", "chunk2"):
        cases.append((ChunkPlan(alg, p_ac, p_b, 0.0, 0.0), f"{alg}-3x4"))

    for plan, label in cases:
        c_pad = default_c_pad(A, P, plan)
        us_loop = timeit(lambda plan=plan, c_pad=c_pad: chunked_spgemm(
            A, P, plan, c_pad, backend="loop"), repeats=3)
        us_scan = timeit(lambda plan=plan, c_pad=c_pad: chunked_spgemm(
            A, P, plan, c_pad, backend="scan"), repeats=3)
        emit_compare(
            f"scan_vs_loop/{prob}/AxP/{label}"
            f"[{plan.algorithm};ac={plan.n_ac};b={plan.n_b}]",
            us_loop, us_scan)


def run_scan_vs_pallas(smoke: bool = False) -> dict:
    """Scan (XLA-scheduled transfers) vs Pallas (explicit double-buffered
    prefetch) on the same plans, as a machine-checkable JSON report.

    On CPU the Pallas path runs in interpret mode over *densified* staged
    pieces, so the absolute numbers only validate plumbing; the lane exists so
    the comparison harness (and its JSON schema) is exercised continuously and
    ready for real-TPU runs, where the dense slabs hit the MXU and the DMA
    overlap is the paper's measured effect.
    """
    from repro.core.planner import ChunkPlan

    prob = "laplace3d"
    size = 5 if smoke else 8
    A, R, P = multigrid.problem(prob, size)
    n_a, n_b = A.n_rows, P.n_rows

    cases = [(plan_knl(A, P, fast_limit_bytes=P.nbytes() * 0.4), "knl-chunks")]
    p_ac = tuple(int(v) for v in np.linspace(0, n_a, 3))
    p_b = tuple(int(v) for v in np.linspace(0, n_b, 4))
    for alg in ("chunk1", "chunk2"):
        cases.append((ChunkPlan(alg, p_ac, p_b, 0.0, 0.0), f"{alg}-2x3"))

    repeats = 2 if smoke else 3
    rows = []
    for plan, label in cases:
        c_pad = default_c_pad(A, P, plan)
        # plan-derived stats are deterministic: take them from the warmup
        # call instead of re-executing after the timed runs
        _, stats_scan = chunked_spgemm(A, P, plan, c_pad, backend="scan")
        _, stats_pallas = chunked_spgemm(A, P, plan, c_pad, backend="pallas")
        us_scan = timeit(lambda plan=plan, c_pad=c_pad: chunked_spgemm(
            A, P, plan, c_pad, backend="scan"), repeats=repeats)
        us_pallas = timeit(lambda plan=plan, c_pad=c_pad: chunked_spgemm(
            A, P, plan, c_pad, backend="pallas"), repeats=repeats)
        rows.append({
            "case": f"{prob}/AxP/{label}",
            "algorithm": plan.algorithm,
            "n_ac": plan.n_ac,
            "n_b": plan.n_b,
            "scan_us": round(us_scan, 1),
            "pallas_us": round(us_pallas, 1),
            "pallas_vs_scan": round(us_scan / us_pallas, 3) if us_pallas
            else float("inf"),
            "scan_copy_bytes": stats_scan.copy_bytes,
            "pallas_copy_bytes": stats_pallas.copy_bytes,
        })
    from repro.kernels.ranged_spgemm import default_interpret

    return {
        "bench": "chunking_scan_vs_pallas",
        "problem": prob,
        "size": size,
        "interpret_mode": default_interpret(),
        "rows": rows,
    }


def run_csv_scan_vs_pallas():
    """The scan-vs-pallas lane as driver CSV rows (JSON stays in ``main``)."""
    report = run_scan_vs_pallas()
    for row in report["rows"]:
        emit(f"scan_vs_pallas/{row['case']}"
             f"[{row['algorithm']};ac={row['n_ac']};b={row['n_b']}]",
             row["pallas_us"], f"{row['pallas_vs_scan']}x_vs_scan")


def run_accumulator_shootout(smoke: bool = False) -> dict:
    """Three-way accumulator comparison — dense-slab Pallas vs ESC
    sparse-output vs hash-probe — across an output-density sweep, as a
    machine-checkable JSON report (the PR-4 ``dense_vs_sparse_accum`` lane
    grown a third column).

    Fixed (A, plan, n_cols); B's density sweeps so nnz(C) / (m * n) sweeps.
    Each row carries the three measured runtimes *and* the three planner
    fast-memory models (``planned_stats_dense_slab`` / ``planned_stats_sparse``
    / ``planned_stats_hash``): on CPU interpret mode the runtimes only
    validate plumbing, but the byte models are backend truth on any hardware.
    ``byte_winner`` is the per-row argmin — asserted identical to what
    ``backend="auto"`` resolves (``select_accumulator_backend``), so the lane
    continuously measures the crossover densities the auto dispatch is
    trusted with; the ``crossover`` block reports the largest swept density
    at which each pairwise comparison still favors the compressed side.
    """
    from repro.core.chunking import instance_envelope
    from repro.core.planner import (
        ChunkPlan, backend_fast_models, select_accumulator_backend,
    )
    from repro.core.symbolic import strip_output_caps
    from repro.sparse.csr import csr_from_dense

    rng = np.random.default_rng(17)
    m, k, n = (40, 36, 96) if smoke else (96, 80, 256)
    b_densities = (0.003, 0.01, 0.05, 0.25) if smoke else (
        0.002, 0.005, 0.01, 0.03, 0.08, 0.25)
    a = ((rng.random((m, k)) < 0.08) * rng.standard_normal((m, k)))
    A = csr_from_dense(a.astype(np.float32))
    p_ac = tuple(int(v) for v in np.linspace(0, m, 3))
    p_b = tuple(int(v) for v in np.linspace(0, k, 4))
    plan = ChunkPlan("chunk1", p_ac, p_b, 0.0, 0.0)

    repeats = 2 if smoke else 3
    rows = []
    for db in b_densities:
        b = ((rng.random((k, n)) < db) * rng.standard_normal((k, n)))
        B = csr_from_dense(b.astype(np.float32))
        # one symbolic expansion per row: caps feed c_pad, the envelope, and
        # the exact output density (strips partition all rows, so their nnz
        # sums to nnz(C))
        caps = strip_output_caps(A, B, plan.p_ac)
        c_pad = caps.c_pad
        c_nnz = sum(caps.strip_nnz)
        env = instance_envelope(A, B, plan, caps=caps)
        models = backend_fast_models(plan, env)
        auto_pick = select_accumulator_backend(plan, env)
        row = {
            "case": f"synthetic/{m}x{k}x{n}/db={db}",
            "c_density": round(c_nnz / float(m * n), 5),
        }
        for backend in ("pallas", "sparse", "hash"):
            us = timeit(lambda be=backend, B=B, c_pad=c_pad: chunked_spgemm(
                A, B, plan, c_pad, backend=be), repeats=repeats)
            row[f"{backend}_us"] = round(us, 1)
            row[f"{backend}_fast_bytes"] = models[backend].fast_bytes_needed
        row["byte_winner"] = min(
            ("pallas", "sparse", "hash"),
            key=lambda be, row=row: row[f"{be}_fast_bytes"])
        row["auto_backend"] = auto_pick
        assert auto_pick == row["byte_winner"], (
            f"auto dispatch disagrees with the byte argmin at {row['case']}")
        row["sparse_vs_dense_bytes"] = round(
            row["sparse_fast_bytes"] / row["pallas_fast_bytes"], 3)
        row["hash_vs_dense_bytes"] = round(
            row["hash_fast_bytes"] / row["pallas_fast_bytes"], 3)
        row["hash_vs_esc_bytes"] = round(
            row["hash_fast_bytes"] / row["sparse_fast_bytes"], 3)
        rows.append(row)
    from repro.kernels.sparse_accum_spgemm import default_interpret

    def crossover(wins):
        """Largest swept C density at which ``wins(row)`` still holds."""
        winning = [r["c_density"] for r in rows if wins(r)]
        return max(winning) if winning else None

    return {
        "bench": "chunking_accumulator_shootout",
        "problem": f"synthetic/{m}x{k}x{n}",
        "interpret_mode": default_interpret(),
        "crossover": {
            # ESC byte model below the dense slab's
            "sparse_vs_dense_c_density": crossover(
                lambda r: r["sparse_vs_dense_bytes"] < 1.0),
            # hash byte model below the dense slab's
            "hash_vs_dense_c_density": crossover(
                lambda r: r["hash_vs_dense_bytes"] < 1.0),
            # hash byte model below ESC's (the shrunken-workspace claim)
            "hash_vs_esc_c_density": crossover(
                lambda r: r["hash_vs_esc_bytes"] < 1.0),
        },
        "byte_winner_by_density": {
            str(r["c_density"]): r["byte_winner"] for r in rows
        },
        "rows": rows,
    }


def run_csv_accumulator_shootout():
    """The accumulator-shootout lane as driver CSV rows."""
    report = run_accumulator_shootout()
    for row in report["rows"]:
        emit(f"accumulator_shootout/{row['case']}"
             f"[c_density={row['c_density']}]",
             row["hash_us"],
             f"winner={row['byte_winner']};"
             f"hash_vs_esc={row['hash_vs_esc_bytes']}x_bytes")


def run_bsr_blocking(smoke: bool = False) -> dict:
    """Blocked (BSR/MXU-tile) vs entry-level accumulators across a
    *blockiness* sweep, as a machine-checkable JSON report.

    Fixed shape and roughly fixed nnz; what sweeps is how that nnz is
    organized — from dense block-diagonal 8x8 tiles (blockiness 1.0, every
    staged piece a handful of MXU tiles) to fully scattered singles
    (blockiness 0.0, every entry its own mostly-empty tile). Each row
    carries the measured runtimes, every registered accumulator's planner
    fast-memory model under the block-capped envelope, and the
    ``backend="auto"`` pick — asserted equal to the byte argmin, and pinned
    to ``bsr`` on the blockiest row / to an entry-level backend on the
    fully scattered row. That crossover is the lane's product: the planner
    prices the zero-padding waste of blocked staging honestly, so auto only
    selects the MXU-shaped backend where block structure amortizes it.
    """
    from repro.core.chunking import instance_envelope
    from repro.core.kkmem import spgemm_dense_oracle
    from repro.core.planner import (
        ChunkPlan, backend_fast_models, select_accumulator_backend,
    )
    from repro.sparse.csr import csr_from_dense, csr_to_dense

    bs = 8
    m = 64 if smoke else 128
    budget = (m // bs) // 2        # nnz budget in dense-block units: half the
    rng = np.random.default_rng(23)  # diagonal, so scatter stays truly sparse

    def blocky(frac: float):
        """Block-diagonal dense tiles for ``frac`` of the nnz budget, the
        remainder scattered as entry-level singles."""
        n_blocks = round(frac * budget)
        d = np.zeros((m, m), np.float32)
        for i in range(n_blocks):
            s = i * bs
            d[s:s + bs, s:s + bs] = rng.standard_normal((bs, bs))
        scatter = (budget - n_blocks) * bs * bs
        if scatter:
            idx = rng.choice(m * m, size=scatter, replace=False)
            d.flat[idx] = rng.standard_normal(scatter)
        return csr_from_dense(d)

    plan = ChunkPlan("knl", (0, m), (0, m // 2, m), 0.0, 0.0)
    repeats = 2 if smoke else 3
    rows = []
    for frac in (1.0, 0.5, 0.0):
        A, B = blocky(frac), blocky(frac)
        env = instance_envelope(A, B, plan, block_size=bs)
        models = backend_fast_models(plan, env)
        auto_pick = select_accumulator_backend(plan, env)
        row = {"case": f"synthetic/{m}x{m}x{m}/blockiness={frac}",
               "blockiness": frac,
               "nnz_a": int(np.asarray(A.indptr)[-1])}
        for backend, model in models.items():
            row[f"{backend}_fast_bytes"] = model.fast_bytes_needed
        for backend in ("pallas", "hash", "bsr"):
            kw = {"block_size": bs} if backend == "bsr" else {}
            C, _ = chunked_spgemm(A, B, plan, backend=backend, **kw)
            us = timeit(lambda be=backend, k=kw, A=A, B=B: chunked_spgemm(
                A, B, plan, backend=be, **k), repeats=repeats)
            row[f"{backend}_us"] = round(us, 1)
        # the blocked backend must stay correct at every blockiness
        assert np.allclose(np.asarray(csr_to_dense(C)),
                           np.asarray(spgemm_dense_oracle(A, B)), atol=1e-4)
        row["byte_winner"] = min(models, key=lambda be, models=models:
                                 models[be].fast_bytes_needed)
        row["auto_backend"] = auto_pick
        assert auto_pick == row["byte_winner"], (
            f"auto dispatch disagrees with the byte argmin at {row['case']}")
        rows.append(row)
    assert rows[0]["byte_winner"] == "bsr", \
        "block-diagonal tiles must price the blocked backend cheapest"
    assert rows[-1]["byte_winner"] != "bsr", \
        "scattered singles must price the blocked backend out"
    from repro.kernels.ranged_spgemm import default_interpret

    return {
        "bench": "chunking_bsr_blocking",
        "problem": f"synthetic/{m}x{m}x{m}",
        "block_size": bs,
        "interpret_mode": default_interpret(),
        "byte_winner_by_blockiness": {
            str(r["blockiness"]): r["byte_winner"] for r in rows
        },
        "rows": rows,
    }


def run_csv_bsr_blocking():
    """The BSR blocking lane as driver CSV rows."""
    report = run_bsr_blocking()
    for row in report["rows"]:
        emit(f"bsr_blocking/{row['case']}[nnz_a={row['nnz_a']}]",
             row["bsr_us"],
             f"winner={row['byte_winner']};"
             f"bsr_vs_pallas_bytes="
             f"{round(row['bsr_fast_bytes'] / row['pallas_fast_bytes'], 3)}x")


JSON_LANES = {
    "scan_vs_pallas": run_scan_vs_pallas,
    "accumulator_shootout": run_accumulator_shootout,
    "bsr_blocking": run_bsr_blocking,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, still valid JSON)")
    ap.add_argument("--lane", choices=sorted(JSON_LANES),
                    default="scan_vs_pallas",
                    help="which JSON lane to print")
    args = ap.parse_args()
    print(json.dumps(JSON_LANES[args.lane](smoke=args.smoke), indent=2))


if __name__ == "__main__":
    main()
