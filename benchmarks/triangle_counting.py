"""Paper Fig 11 + Table 4: triangle counting on three graph classes.

Real wall-clock of the masked L x L SpGEMM on synthetic graphs mirroring the
paper's classes (graph500-RMAT / social-powerlaw / web-crawl-ish banded), plus
the L1/L2 locality proxies of Table 4 and the paper's claim that memory modes
barely matter for this kernel (derived gap HBM vs DDR)."""

from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core.kkmem import spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL
from repro.core.placement import ALL_FAST, ALL_SLOW, placement_cost
from repro.core.triangle import count_triangles
from repro.sparse import graphs

GRAPHS = {
    "g500_s10": lambda: graphs.rmat(10, 8, seed=1),
    "social_powerlaw": lambda: graphs.powerlaw(2048, 8, seed=2),
    "web_like": lambda: graphs.rmat(10, 4, a=0.45, b=0.25, c=0.15, seed=3),
}


def run():
    for name, make in GRAPHS.items():
        G = make()
        L = graphs.lower_triangular_degree_sorted(G)
        tri = float(count_triangles(L))
        us = timeit(lambda L=L: count_triangles(L), repeats=2)
        emit(f"fig11/{name}/count", us, f"{tri:.0f}")
        ws = spgemm_symbolic_host(L, L)
        st = analyze(L, L)
        l1 = st.miss_fraction_bytes(32 << 10)
        l2 = st.miss_fraction_bytes(1 << 20)
        emit(f"table4/{name}/L1miss", 0.0, f"{l1:.4f}")
        emit(f"table4/{name}/L2miss", 0.0, f"{l2:.4f}")
        fast = placement_cost(KNL, ALL_FAST, L, L, ws.c_nnz * 12.0, ws.flops, st)
        slow = placement_cost(KNL, ALL_SLOW, L, L, ws.c_nnz * 12.0, ws.flops, st)
        emit(f"fig11/{name}/hbm_ddr_gap", 0.0,
             f"{slow.total / fast.total:.3f}")
