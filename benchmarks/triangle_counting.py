"""Paper Fig 11 + Table 4: triangle counting on three graph classes.

Real wall-clock of the masked L x L SpGEMM on synthetic graphs mirroring the
paper's classes (graph500-RMAT / social-powerlaw / web-crawl-ish banded), as
a machine-checkable JSON lane: the *fused* chunked path (mask applied inside
the hash accumulator's merge — ``repro.core.triangle.count_triangles``)
against the unfused ``kkmem.spgemm``-then-sort-merge baseline
(``count_triangles_kkmem``), plus the L1/L2 locality proxies of Table 4 and
the paper's claim that memory modes barely matter for this kernel (the
derived HBM-vs-DDR gap).

Timing discipline: the host symbolic phase runs ONCE per graph outside every
timed region — its workspace capacity feeds the baseline's numeric phase and
the derived placement costs — and the fused path's plan + masked caps are
likewise precomputed, so both timed callables are numeric-only.

``python -m benchmarks.triangle_counting [--smoke] [--lane ...]`` prints the
JSON report; the driver's ``triangle_counting`` suite wraps it as CSV rows.
"""

from __future__ import annotations

import argparse
import json
import statistics

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.kkmem import spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL
from repro.core.placement import ALL_FAST, ALL_SLOW, placement_cost
from repro.core.triangle import (
    count_triangles, count_triangles_dense, count_triangles_kkmem,
)
from repro.sparse import graphs

GRAPHS = {
    "g500_s10": lambda: graphs.rmat(10, 8, seed=1),
    "social_powerlaw": lambda: graphs.powerlaw(2048, 8, seed=2),
    "web_like": lambda: graphs.rmat(10, 4, a=0.45, b=0.25, c=0.15, seed=3),
}

SMOKE_GRAPHS = {
    "g500_s8": lambda: graphs.rmat(8, 8, seed=1),
    "social_powerlaw": lambda: graphs.powerlaw(512, 8, seed=2),
    "web_like": lambda: graphs.rmat(8, 4, a=0.45, b=0.25, c=0.15, seed=3),
}


def run_triangle_counting(smoke: bool = False) -> dict:
    """The triangle-counting lane as a JSON report (Fig 11 + Table 4)."""
    from repro.core import backend_registry
    from repro.core.planner import plan_knl
    from repro.core.symbolic import masked_output_caps
    from repro.kernels.ranged_spgemm import default_interpret

    backend = backend_registry.masked_backends()[0]
    repeats = 2 if smoke else 3
    rows = []
    for name, make in (SMOKE_GRAPHS if smoke else GRAPHS).items():
        G = make()
        L = graphs.lower_triangular_degree_sorted(G)
        # Host precomputations, all OUTSIDE the timed regions: one symbolic
        # workspace reused by the baseline's numeric phase and the derived
        # placement costs, one plan + masked caps for the fused path.
        ws = spgemm_symbolic_host(L, L)
        plan = plan_knl(L, L, float("inf"))
        caps = masked_output_caps(L, plan.p_ac)

        tri = float(count_triangles(L, plan=plan, backend=backend, caps=caps))
        tri_base = float(count_triangles_kkmem(L, c_pad=ws.c_pad))
        assert tri == tri_base, (
            f"{name}: fused count {tri} != unfused baseline {tri_base}")
        assert tri == float(count_triangles_dense(L)), (
            f"{name}: fused count {tri} disagrees with the dense oracle")

        chunked_us = timeit(
            lambda L=L, plan=plan, caps=caps: count_triangles(
                L, plan=plan, backend=backend, caps=caps),
            repeats=repeats)
        kkmem_us = timeit(
            lambda L=L, c=ws.c_pad: count_triangles_kkmem(L, c_pad=c),
            repeats=repeats)

        st = analyze(L, L)
        fast = placement_cost(KNL, ALL_FAST, L, L, ws.c_nnz * 12.0,
                              ws.flops, st)
        slow = placement_cost(KNL, ALL_SLOW, L, L, ws.c_nnz * 12.0,
                              ws.flops, st)
        rows.append({
            "graph": name,
            "n": L.n_rows,
            "nnz_l": int(np.asarray(L.indptr)[-1]),
            "triangles": tri,
            "chunked_us": round(chunked_us, 1),
            "kkmem_us": round(kkmem_us, 1),
            "chunked_vs_kkmem": round(kkmem_us / chunked_us, 3),
            "l1_miss": round(float(st.miss_fraction_bytes(32 << 10)), 4),
            "l2_miss": round(float(st.miss_fraction_bytes(1 << 20)), 4),
            "hbm_ddr_gap": round(slow.total / fast.total, 3),
        })
    return {
        "bench": "triangle_counting",
        "backend": backend,
        "interpret_mode": default_interpret(),
        "smoke": smoke,
        # lane-level scalar so tools/bench_trajectory.py keeps it verbatim
        "chunked_vs_kkmem_speedup": round(statistics.median(
            r["chunked_vs_kkmem"] for r in rows), 3),
        "rows": rows,
    }


def run():
    """The triangle lane as driver CSV rows (Fig 11 + Table 4 names)."""
    report = run_triangle_counting()
    for row in report["rows"]:
        emit(f"fig11/{row['graph']}/count", row["chunked_us"],
             f"{row['triangles']:.0f}")
        emit(f"fig11/{row['graph']}/kkmem_baseline", row["kkmem_us"],
             f"speedup={row['chunked_vs_kkmem']}x")
        emit(f"table4/{row['graph']}/L1miss", 0.0, f"{row['l1_miss']:.4f}")
        emit(f"table4/{row['graph']}/L2miss", 0.0, f"{row['l2_miss']:.4f}")
        emit(f"fig11/{row['graph']}/hbm_ddr_gap", 0.0,
             f"{row['hbm_ddr_gap']:.3f}")


JSON_LANES = {
    "triangle_counting": run_triangle_counting,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, still valid JSON)")
    ap.add_argument("--lane", choices=sorted(JSON_LANES),
                    default="triangle_counting",
                    help="which JSON lane to print")
    args = ap.parse_args()
    print(json.dumps(JSON_LANES[args.lane](smoke=args.smoke), indent=2))


if __name__ == "__main__":
    main()
