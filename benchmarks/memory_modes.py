"""Paper Figures 3/4 (KNL) and 6/7 (GPU): KKMEM across memory modes.

For each problem x {A x P, R x A} x machine x memory mode, we run the real
numeric phase (wall-clock) and derive the modeled GFLOP/s under that mode's
placement. Modes:
  KNL: HBM (all fast), DDR (all slow), Cache16/Cache8 (hardware cache of the
       given capacity in front of DDR: miss fraction from the reuse-distance
       profile at that capacity).
  GPU: HBM, HostPinned (all slow), UVM (cache-mode analogue with the paper's
       observed ~30% management overhead when resident; pinned performance when
       the problem exceeds HBM).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit, BENCH_SIZES
from repro.core.kkmem import spgemm, spgemm_symbolic_host
from repro.core.locality import analyze
from repro.core.memory_model import KNL, P100, spgemm_cost
from repro.core.placement import ALL_FAST, ALL_SLOW
from repro.sparse import multigrid

GiB = float(1 << 30)


def _modeled_gflops(system, A, B, ws, st, place: str, cache_bytes: float | None
                    ) -> float:
    """GFLOP/s under a placement or a hardware-cache mode. The on-core cache is
    scaled to the paper's problem:cache ratio (repro.core.placement docstring)."""
    from repro.core.placement import paper_scale_cache

    nnz_a = float(np.asarray(A.indptr)[-1])
    core_cache = paper_scale_cache(A, B, ws.c_nnz * 12.0)
    if cache_bytes is None:
        miss = st.miss_fraction_bytes(core_cache)
        pl = ALL_FAST if place == "fast" else ALL_SLOW
        cost = spgemm_cost(
            system, bytes_A=A.nbytes(), bytes_B=B.nbytes(), bytes_C=ws.c_nnz * 12.0,
            flops=ws.flops, b_row_reads=nnz_a, b_row_bytes=st.avg_b_row_bytes,
            b_miss_fraction=miss, place_A=pl.A, place_B=pl.B, place_C=pl.C)
    else:
        # hardware cache mode: the HBM-cache (16/8 GB scaled by the same ratio
        # as the problem) front-ends DDR; accesses missing IT go to slow memory
        scale = (A.nbytes() + B.nbytes() + ws.c_nnz * 12.0) / (33.0 * GiB)
        hw_cache = max(cache_bytes * scale, core_cache)
        miss = st.miss_fraction_bytes(hw_cache)
        cost = spgemm_cost(
            system, bytes_A=A.nbytes(), bytes_B=B.nbytes(), bytes_C=ws.c_nnz * 12.0,
            flops=ws.flops, b_row_reads=nnz_a, b_row_bytes=st.avg_b_row_bytes,
            b_miss_fraction=miss, place_A="slow", place_B="slow", place_C="slow")
        # hits are served at fast-memory speed
        hit_cost = spgemm_cost(
            system, bytes_A=A.nbytes(), bytes_B=B.nbytes(), bytes_C=ws.c_nnz * 12.0,
            flops=ws.flops, b_row_reads=nnz_a, b_row_bytes=st.avg_b_row_bytes,
            b_miss_fraction=st.miss_fraction_bytes(core_cache) - miss
            if st.miss_fraction_bytes(core_cache) > miss else 0.0,
            place_A="slow", place_B="fast", place_C="slow")
        total = max(cost.t_A + cost.t_C + cost.t_B + hit_cost.t_B,
                    cost.t_compute)
        return ws.flops / total / 1e9
    return cost.gflops(ws.flops)


def run():
    for prob, n in BENCH_SIZES.items():
        A, R, P = multigrid.problem(prob, n)
        for tag, (L, Rt) in {"AxP": (A, P), "RxA": (R, A)}.items():
            ws = spgemm_symbolic_host(L, Rt)
            st = analyze(L, Rt)
            us = timeit(lambda L=L, Rt=Rt, ws=ws: spgemm(L, Rt, ws.c_pad),
                        repeats=3)
            # KNL modes (Figs 3/4)
            for mode, args in {
                "HBM": ("fast", None), "DDR": ("slow", None),
                "Cache16": ("slow", 16 * GiB * 0.9),
                "Cache8": ("slow", 8 * GiB * 0.9),
            }.items():
                g = _modeled_gflops(KNL, L, Rt, ws, st, *args)
                emit(f"fig3_4/knl/{prob}/{tag}/{mode}", us, f"{g:.3f}")
            # GPU modes (Figs 6/7)
            fits = (L.nbytes() + Rt.nbytes() + ws.c_nnz * 12.0) \
                <= P100.fast.capacity_bytes
            hbm = _modeled_gflops(P100, L, Rt, ws, st, "fast", None)
            pin = _modeled_gflops(P100, L, Rt, ws, st, "slow", None)
            uvm = hbm * 0.45 if fits else pin   # paper: UVM <=30-45% of HBM,
            emit(f"fig6_7/gpu/{prob}/{tag}/HBM", us, f"{hbm:.3f}")
            emit(f"fig6_7/gpu/{prob}/{tag}/Pinned", us, f"{pin:.3f}")
            emit(f"fig6_7/gpu/{prob}/{tag}/UVM", us, f"{uvm:.3f}")
