import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

"""Pipeline-parallel correctness proof (forward + gradient vs sequential).
Run by tests/test_pipeline.py as a subprocess (needs >1 placeholder device)."""

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import mesh_kwargs
from repro.parallel.pipeline import (
    pipeline_forward, sequential_reference, split_stages, pad_layers_identity,
)


def body_fn(lp, x):
    """A pre-norm residual MLP block (shape-preserving)."""
    h = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    y = jnp.tanh(h @ lp["w1"]) @ lp["w2"]
    return x + y


def main():
    n_stages, n_layers, t_micro, mb, d = 4, 8, 6, 3, 16
    rng = np.random.default_rng(0)
    stacked = {
        "w1": jnp.asarray(rng.standard_normal((n_layers, d, 2 * d)) * 0.2,
                          jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((n_layers, 2 * d, d)) * 0.2,
                          jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((t_micro, mb, d)), jnp.float32)
    mesh = jax.make_mesh((n_stages,), ("stage",), **mesh_kwargs(1))

    want = sequential_reference(stacked, x, body_fn)
    staged = split_stages(stacked, n_stages)
    with mesh:
        got = jax.jit(
            lambda p, m: pipeline_forward(p, m, body_fn, mesh))(staged, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    print("PIPELINE_FWD_OK")

    # identity padding: 6 real layers padded to 8
    stacked6 = jax.tree.map(lambda a: a[:6], stacked)
    want6 = sequential_reference(stacked6, x, body_fn)
    padded = pad_layers_identity(stacked6, 6, 8)
    with mesh:
        got6 = jax.jit(
            lambda p, m: pipeline_forward(p, m, body_fn, mesh))(
                split_stages(padded, n_stages), x)
    np.testing.assert_allclose(np.asarray(got6), np.asarray(want6), atol=1e-5)
    print("PIPELINE_PAD_OK")

    # gradients: AD through ppermute == GPipe backward
    def loss_pipe(p):
        with mesh:
            return jnp.sum(pipeline_forward(
                split_stages(p, n_stages), x, body_fn, mesh) ** 2)

    def loss_seq(p):
        return jnp.sum(sequential_reference(p, x, body_fn) ** 2)

    g1 = jax.grad(loss_pipe)(stacked)
    g2 = jax.grad(loss_seq)(stacked)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    print("PIPELINE_GRAD_OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
