import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Elastic-restart proof: save a checkpoint sharded on one mesh, restore it on a
DIFFERENT mesh, verify values. Run by tests/test_checkpoint.py (slow)."""

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import mesh_kwargs


def main():
    tmp = tempfile.mkdtemp(prefix="elastic_")
    mesh_a = jax.make_mesh((8,), ("data",), **mesh_kwargs(1))
    mesh_b = jax.make_mesh((4, 2), ("data", "model"), **mesh_kwargs(2))
    rng = np.random.default_rng(0)
    host = {"w": rng.standard_normal((64, 32)).astype(np.float32),
            "b": rng.standard_normal((16,)).astype(np.float32)}
    sharded_a = {
        "w": jax.device_put(host["w"], NamedSharding(mesh_a, P("data", None))),
        "b": jax.device_put(host["b"], NamedSharding(mesh_a, P("data"))),
    }
    save_checkpoint(tmp, 7, sharded_a)

    sh_b = {
        "w": NamedSharding(mesh_b, P("data", "model")),
        "b": NamedSharding(mesh_b, P(("data", "model"))),
    }
    restored, step = restore_checkpoint(tmp, jax.eval_shape(lambda: sharded_a),
                                        shardings=sh_b)
    assert step == 7
    for k in host:
        np.testing.assert_allclose(np.asarray(restored[k]), host[k])
        assert restored[k].sharding.mesh.shape == {"data": 4, "model": 2}, (
            restored[k].sharding)
    print("ELASTIC_OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
