"""Static backend auditor CLI: abstract-trace every registered backend and
verify the planner byte models, the DMA double-buffer schedule, copy-event
flow equality against the declared traffic models, exhaustive DMA
interleaving safety, Mosaic-lowerability preflight lint, and the retrace
(compile-key) contract — no device execution.

Registry-driven: the backend roster, the analyses, and the geometry corpus
all come from ``repro.analysis``; a newly registered backend is audited with
zero changes here (the add-a-backend checklist in ``docs/backends.md``
requires this tool to pass).

    PYTHONPATH=src python tools/audit_backends.py \
        [--json bench-artifacts/static_audit.json] \
        [--lint-json bench-artifacts/mosaic_lint.json] \
        [--backends sparse,hash] [--algorithms chunk1] [--cases fast] \
        [--analyses traffic,lint] [--no-retrace] [--subprocess-checks]

``--analyses`` subsets the per-trace passes (vmem, dma, while, traffic,
interleave, lint, retrace) so the fast lane can smoke a single analysis;
``--lint-json`` writes every lint diagnostic (all severities, not just the
audit-failing errors) as a standalone artifact for the on-TPU validation
worklist. ``--subprocess-checks`` additionally runs the multi-device proof
scripts (``tools/elastic_check.py``, ``tools/pipeline_check.py``) in
subprocesses and asserts their OK markers — the fast-CI home of checks
otherwise only exercised by the nightly ``slow`` test lane.

Exit status 0 iff every analysis (and every requested subprocess check)
passed; the JSON reports are written either way.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

SUBPROCESS_CHECKS = (
    ("elastic_check.py", ("ELASTIC_OK",)),
    ("pipeline_check.py",
     ("PIPELINE_FWD_OK", "PIPELINE_PAD_OK", "PIPELINE_GRAD_OK")),
)


def run_subprocess_checks(timeout: int = 900) -> list:
    """Run the multi-device proof scripts; each entry reports the script,
    its exit code, and any missing OK markers."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    results = []
    for script, markers in SUBPROCESS_CHECKS:
        proc = subprocess.run(
            [sys.executable, os.path.join(ROOT, "tools", script)],
            capture_output=True, text=True, env=env, timeout=timeout)
        missing = [m for m in markers if m not in proc.stdout]
        results.append({
            "script": script,
            "returncode": proc.returncode,
            "missing_markers": missing,
            "ok": proc.returncode == 0 and not missing,
            "tail": (proc.stdout + proc.stderr)[-2000:],
        })
    return results


def _csv(value):
    return [v for v in value.split(",") if v] if value else None


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--json", metavar="PATH",
                        help="write the full JSON report here")
    parser.add_argument("--backends", type=_csv, default=None,
                        help="comma-separated backend subset (default: all "
                             "registered)")
    parser.add_argument("--algorithms", type=_csv, default=None,
                        help="comma-separated algorithm subset "
                             "(knl,chunk1,chunk2)")
    parser.add_argument("--cases", default=None,
                        help="comma-separated corpus cases, or 'fast' for "
                             "the quick subset (default: full corpus)")
    parser.add_argument("--analyses", type=_csv, default=None,
                        help="comma-separated analysis subset (vmem,dma,"
                             "while,traffic,interleave,lint,retrace); "
                             "default: all")
    parser.add_argument("--lint-json", metavar="PATH",
                        help="write all Mosaic lint diagnostics (every "
                             "severity) here as a standalone artifact")
    parser.add_argument("--no-retrace", action="store_true",
                        help="skip the retrace-leak pass (halves trace work)")
    parser.add_argument("--subprocess-checks", action="store_true",
                        help="also run tools/elastic_check.py and "
                             "tools/pipeline_check.py and require their OK "
                             "markers")
    args = parser.parse_args(argv)

    from repro.analysis import audit_all
    from repro.analysis.corpus import FAST_CASES

    cases = (list(FAST_CASES) if args.cases == "fast" else _csv(args.cases))
    report = audit_all(backends=args.backends, algorithms=args.algorithms,
                       cases=cases, retrace=not args.no_retrace,
                       analyses=args.analyses)

    ok = report["ok"]
    if args.subprocess_checks:
        checks = run_subprocess_checks()
        report["subprocess_checks"] = checks
        ok = ok and all(c["ok"] for c in checks)
        for c in checks:
            status = "OK" if c["ok"] else "FAIL"
            print(f"subprocess {c['script']}: {status}")
            if not c["ok"]:
                print(c["tail"])

    dominated = sum(1 for r in report["records"] if r.get("dominated"))
    lint_counts = {"error": 0, "warning": 0, "info": 0}
    for r in report["records"]:
        for sev, n in r.get("lint", {}).get("counts", {}).items():
            lint_counts[sev] += n
    print(f"audited {len(report['records'])} (backend, algorithm, case) "
          f"traces over backends={report['backends']} "
          f"analyses={report['analyses']}; "
          f"{dominated} byte-model domination checks passed; "
          f"lint {lint_counts['error']}E/{lint_counts['warning']}W/"
          f"{lint_counts['info']}I; "
          f"{len(report['skipped'])} backend(s) skipped "
          f"({', '.join(s['backend'] for s in report['skipped']) or 'none'})")
    for v in report["violations"]:
        print(f"VIOLATION [{v['analysis']}] {v['backend']}/{v['algorithm']}"
              f"/{v['case']}: {v['message']}")

    def _write_json(path, payload, label):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"{label} written to {path}")

    if args.json:
        _write_json(args.json, report, "report")
    if args.lint_json:
        lint_report = {
            "counts": lint_counts,
            "diagnostics": [
                dict(d, backend=r["backend"], algorithm=r["algorithm"],
                     case=r["case"])
                for r in report["records"]
                for d in r.get("lint", {}).get("diagnostics", [])
            ],
        }
        _write_json(args.lint_json, lint_report, "lint diagnostics")

    print("STATIC_AUDIT_OK" if ok else "STATIC_AUDIT_FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
