"""Append bench-lane JSON reports to the committed trajectory file.

CI runs every smoke JSON lane, tees each report to ``bench-artifacts/``
(uploaded as workflow artifacts), then runs this tool to fold a compact
summary of each report into ``BENCH_trajectory.json`` — the committed,
append-only record of how the lanes' headline numbers move across commits.
Artifacts hold the full per-row data for a few weeks; the trajectory file
holds the durable curve.

Stdlib-only and idempotent: an (sha, lane) pair already present is skipped,
so re-runs (workflow retries, local invocations) never duplicate entries,
and any duplicates an older tool version managed to log are dropped
(first occurrence wins) whenever the file is rewritten. ``--sha`` defaults
to the repo's current HEAD (10-hex short form), so local runs stamp real
commits instead of placeholders.

    python tools/bench_trajectory.py [--sha <sha>] [--date ISO] \
        [--out BENCH_trajectory.json] report.json [report2.json ...]
"""

from __future__ import annotations

import argparse
import json
import statistics
import subprocess
import sys
from datetime import datetime, timezone
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def current_sha() -> str:
    """HEAD of the repo this tool lives in, 10-hex short form (matching the
    CI invocation's ``${GITHUB_SHA::10}``)."""
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "--short=10", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError) as exc:
        raise SystemExit(
            f"cannot derive --sha from git ({exc}); pass --sha explicitly"
        ) from exc
    return proc.stdout.strip()


def summarize(report: dict) -> dict:
    """Compact lane summary: lane-level scalar fields verbatim, per-row
    numeric metrics reduced to medians. Bounded regardless of row count."""
    rows = report.get("rows", [])
    summary = {k: v for k, v in report.items()
               if k != "rows" and not isinstance(v, list)}
    metrics = {}
    for row in rows:
        for k, v in row.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                metrics.setdefault(k, []).append(float(v))
    summary["n_rows"] = len(rows)
    summary["row_medians"] = {
        k: round(statistics.median(vs), 3) for k, vs in sorted(metrics.items())
    }
    return summary


def normalize_entries(entries: list) -> list:
    """Drop duplicate (sha, lane) pairs, first occurrence wins — the repair
    pass for files an older (dedupe-free) tool version appended to."""
    seen, out = set(), []
    for entry in entries:
        key = (entry.get("sha"), entry.get("lane"))
        if key in seen:
            continue
        seen.add(key)
        out.append(entry)
    return out


def append_entries(out_path: Path, sha: str, date: str,
                   reports: list) -> list:
    """Fold reports into the trajectory file; returns the appended entries."""
    if out_path.exists():
        doc = json.loads(out_path.read_text())
    else:
        doc = {"entries": []}
    if "entries" not in doc or not isinstance(doc["entries"], list):
        raise SystemExit(f"{out_path}: not a trajectory file (no entries list)")
    deduped = normalize_entries(doc["entries"])
    repaired = len(deduped) != len(doc["entries"])
    doc["entries"] = deduped
    seen = {(e.get("sha"), e.get("lane")) for e in doc["entries"]}
    added = []
    for report in reports:
        lane = report.get("bench")
        if not lane:
            raise SystemExit("report has no 'bench' lane name")
        if (sha, lane) in seen:
            continue
        entry = {"sha": sha, "date": date, "lane": lane,
                 "summary": summarize(report)}
        doc["entries"].append(entry)
        seen.add((sha, lane))
        added.append(entry)
    if added or repaired:
        out_path.write_text(json.dumps(doc, indent=2) + "\n")
    return added


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("reports", nargs="+", type=Path,
                    help="bench-lane JSON report files")
    ap.add_argument("--sha", default=None,
                    help="commit the reports measure (default: this repo's "
                         "HEAD, 10-hex short form)")
    ap.add_argument("--date", default=None,
                    help="ISO date of the measurement (default: now, UTC)")
    ap.add_argument("--out", type=Path, default=Path("BENCH_trajectory.json"))
    args = ap.parse_args(argv)
    date = args.date or datetime.now(timezone.utc).strftime("%Y-%m-%d")
    sha = args.sha or current_sha()
    reports = [json.loads(p.read_text()) for p in args.reports]
    added = append_entries(args.out, sha, date, reports)
    for e in added:
        print(f"appended {e['lane']} @ {e['sha']}")
    if not added:
        print("nothing to append (all (sha, lane) pairs already recorded)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
