"""KV-cache placement planner (paper DP/Alg-4 applied to serving)."""

import pytest

from repro.configs import get_config
from repro.serve.kv_planner import plan_kv_cache, kv_cache_bytes


def test_cache_bytes_scale_with_context():
    cfg = get_config("llama3_2_1b")
    small = kv_cache_bytes(cfg, 8, 2048)
    big = kv_cache_bytes(cfg, 8, 32768)
    assert big == pytest.approx(small * 16, rel=0.01)


def test_swa_cache_is_window_bounded():
    mix = get_config("mixtral_8x22b")
    a = kv_cache_bytes(mix, 1, 32768)
    b = kv_cache_bytes(mix, 1, 524288)
    assert a == b   # ring cache: bounded by the 4096 window


def test_ssm_cache_is_constant():
    rwkv = get_config("rwkv6_3b")
    assert kv_cache_bytes(rwkv, 1, 1024) == kv_cache_bytes(rwkv, 1, 524288)


def test_plan_whole_fast_when_small():
    cfg = get_config("llama3_2_1b")
    plan = plan_kv_cache(cfg, batch=8, cache_len=4096, n_devices=8)
    assert plan.algorithm == "whole_fast"
    assert plan.per_step_copy_s == 0.0


def test_plan_demotes_aux_before_cache():
    cfg = get_config("llama3_2_1b")
    # big aux state forces a decision; cache+weights still fit -> DP
    plan = plan_kv_cache(cfg, batch=64, cache_len=32768, n_devices=1,
                         aux_bytes=12e9)
    assert plan.algorithm in ("dp", "chunk_stream")
    if plan.algorithm == "dp":
        assert plan.weights_bytes + plan.cache_bytes <= plan.hbm_bytes


def test_plan_streams_when_oversized():
    cfg = get_config("deepseek_67b")
    # 67B weights on ONE device cannot fit: must stream
    plan = plan_kv_cache(cfg, batch=128, cache_len=32768, n_devices=1)
    assert plan.algorithm == "chunk_stream"
    assert plan.per_step_copy_s > 0
    # sharded over 256 devices the same deployment fits
    plan2 = plan_kv_cache(cfg, batch=128, cache_len=32768, n_devices=256)
    assert plan2.algorithm == "whole_fast"
