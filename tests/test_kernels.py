"""Pallas kernel sweeps (interpret=True) against the pure-jnp oracles.

Per instructions: sweep shapes/dtypes and assert_allclose against ref.py."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.sparse.bsr import bsr_from_dense, bsr_to_dense
from repro.kernels import ops, ref
from conftest import random_dense, assert_close


def sprand_bsr(rng, m, n, density, bs, dtype=np.float32):
    d = (random_dense(rng, m, n, density)).astype(dtype)
    return bsr_from_dense(d, bs)


# ---------------------------------------------------------------------------
# bsr_spgemm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bs", [8, 16, 32])
@pytest.mark.parametrize("shape", [(64, 64, 64), (128, 64, 96), (32, 96, 64)])
def test_bsr_spgemm_shapes(rng, bs, shape):
    m, k, n = shape
    A = sprand_bsr(rng, m, k, 0.15, bs)
    B = sprand_bsr(rng, k, n, 0.15, bs)
    C = ops.bsr_spgemm(A, B)
    assert_close(bsr_to_dense(C), ref.bsr_spgemm_ref(A, B), atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_spgemm_dtypes(rng, dtype):
    A = bsr_from_dense(jnp.asarray(random_dense(rng, 64, 64, 0.2), dtype), 8)
    B = bsr_from_dense(jnp.asarray(random_dense(rng, 64, 64, 0.2), dtype), 8)
    C = ops.bsr_spgemm(A, B)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    assert_close(bsr_to_dense(C), ref.bsr_spgemm_ref(A, B), atol=tol, rtol=tol)


def test_bsr_spgemm_skip_zero_equivalence(rng):
    A = sprand_bsr(rng, 48, 48, 0.2, 8)
    B = sprand_bsr(rng, 48, 48, 0.2, 8)
    c1 = ops.bsr_spgemm(A, B, skip_zero=True)
    c2 = ops.bsr_spgemm(A, B, skip_zero=False)
    assert_close(bsr_to_dense(c1), bsr_to_dense(c2), atol=1e-5)


def test_bsr_spgemm_empty(rng):
    A = bsr_from_dense(np.zeros((32, 32), np.float32), 8)
    B = sprand_bsr(rng, 32, 32, 0.3, 8)
    C = ops.bsr_spgemm(A, B)
    assert np.allclose(np.asarray(bsr_to_dense(C)), 0.0)


# ---------------------------------------------------------------------------
# bsr_spmm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bs,nf,bn", [(8, 128, 128), (16, 256, 128), (8, 64, 64)])
def test_bsr_spmm_shapes(rng, bs, nf, bn):
    A = sprand_bsr(rng, 8 * bs, 6 * bs, 0.2, bs)
    x = jnp.asarray(random_dense(rng, 6 * bs, nf, 1.0))
    y = ops.bsr_spmm(A, x, bn=bn)
    assert_close(y, ref.bsr_spmm_ref(A, x), atol=1e-3)


# ---------------------------------------------------------------------------
# grouped_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sizes", [[37, 0, 91, 12], [1, 1, 1, 1], [128], [0, 64]])
def test_grouped_matmul_ragged(rng, sizes):
    e, k, n = len(sizes), 64, 96
    t = sum(sizes)
    x = jnp.asarray(random_dense(rng, max(t, 1), k, 1.0))[:t]
    w = jnp.asarray(rng.standard_normal((e, k, n)).astype(np.float32))
    y, offs = ops.grouped_matmul(x, w, sizes, bt=32, bn=32, bk=32)
    tg = np.repeat(np.arange(e), sizes)
    want = np.asarray(ref.grouped_matmul_ref(x, w, jnp.asarray(tg))) if t else None
    src = 0
    for g in range(e):
        got = np.asarray(y[offs[g] : offs[g] + sizes[g]])
        if sizes[g]:
            assert_close(got, want[src : src + sizes[g]], atol=1e-3)
        src += sizes[g]


# ---------------------------------------------------------------------------
# chunked (flash-decoding) attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,bs_kv", [(128, 32), (256, 64), (64, 64)])
@pytest.mark.parametrize("g", [1, 4])
def test_decode_attention_shapes(rng, s, bs_kv, g):
    b, hkv, d = 3, 2, 32
    q = jnp.asarray(rng.standard_normal((b, hkv, g, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    lengths = jnp.asarray([s, s // 2, 1], jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, bs_kv=bs_kv)
    assert_close(o, ref.decode_attention_ref(q, k, v, lengths), atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 3e-2)])
def test_decode_attention_dtypes(rng, dtype, tol):
    b, hkv, g, d, s = 2, 2, 2, 32, 128
    q = jnp.asarray(rng.standard_normal((b, hkv, g, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    lengths = jnp.asarray([s, 77], jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, bs_kv=32)
    assert_close(np.asarray(o, np.float32),
                 np.asarray(ref.decode_attention_ref(q, k, v, lengths), np.float32),
                 atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# flash prefill attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,bq,bk,h,hkv,window", [
    (128, 32, 32, 8, 2, 0),
    (128, 32, 32, 8, 2, 48),
    (64, 16, 32, 6, 3, 0),
    (256, 64, 64, 4, 4, 0),
])
def test_flash_prefill_shapes(rng, s, bq, bk, h, hkv, window):
    from repro.models import attention as att

    b, d = 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    o = ops.flash_prefill(q, k, v, bq=bq, bk=bk, window=window)
    want = att.attention_ref(q, k, v, causal=True, window=window)
    assert_close(o, want, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 4e-2)])
def test_flash_prefill_dtypes(rng, dtype, tol):
    from repro.models import attention as att

    b, s, h, hkv, d = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    o = ops.flash_prefill(q, k, v, bq=32, bk=32)
    want = att.attention_ref(q, k, v, causal=True)
    assert_close(np.asarray(o, np.float32), np.asarray(want, np.float32),
                 atol=tol, rtol=tol)
