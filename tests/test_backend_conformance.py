"""Cross-backend differential conformance suite.

One seeded geometry matrix — empty rows, skewed rows, all-zero chunks,
single-column B, all-zero B, wide-but-sparse outputs — runs through **every**
``chunked_spgemm`` backend and is asserted allclose to the loop oracle at
matched ``c_pad`` (scan additionally bitwise, which ``assert_close`` at tiny
atol effectively witnesses via identical float schedules). The backend lists
are **derived from the registry** (``repro.core.backend_registry``): a new
backend's one registration call enrolls it in the whole matrix — correctness
guarantees come from this suite, not per-backend ad-hoc tests — and the
registry-completeness test pins the expected roster so an accidentally
dropped registration fails here, not in production dispatch.

The trace-count section pins the *exact* ``TRACE_COUNTS`` deltas of every
backend across repeat / same-envelope / new-envelope calls, so a silent
retrace regression (a geometry-dependent Python value smuggled into a jitted
signature, a cache-busting non-hashable static) fails the fast lane instead
of showing up as a serving-latency cliff.

Determinism: every case is seeded and the matrix is pure-parametrize, so two
runs of this file must produce identical reports — CI runs it twice and
diffs (the determinism job in .github/workflows/ci.yml).
"""

import numpy as np
import pytest

from repro.core import backend_registry
from repro.core.chunk_stream import TRACE_COUNTS, chunked_spgemm_batched
from repro.core.chunking import (
    batch_envelope, chunked_spgemm, default_c_pad, instance_envelope,
)
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.planner import ChunkPlan, select_accumulator_backend
from repro.core.symbolic import spgemm_structure_host, strip_output_caps
from repro.sparse.csr import csr_from_dense, csr_to_dense
from repro.serve.spgemm_service import SpGEMMService
from conftest import assert_close, random_csr, random_dense

# registry-derived backend matrix: registering a BackendSpec enrolls the
# backend in every test below; nothing is named by hand
BACKENDS = [*backend_registry.all_backends(), "auto"]
BATCHED_BACKENDS = [*backend_registry.batched_backends(), "auto"]
ALGORITHMS = ["knl", "chunk1", "chunk2"]


def _block_size_for(backend: str) -> int | None:
    """The envelope block edge a backend needs (None for non-block backends
    and for auto, whose resolve under uncapped envelopes never picks one)."""
    if backend == "auto":
        return None
    spec = backend_registry.get(backend)
    return spec.block_size if spec.needs_block_caps else None


def test_registry_completeness():
    """The registration contract: the expected roster in priority order,
    every spec covering every algorithm, batched + trace-keyed except the
    loop oracle, byte models on every accumulator, a block edge on every
    block backend. A dropped or malformed registration fails here, not as a
    cryptic dispatch error."""
    specs = backend_registry.specs()
    assert [s.name for s in specs] == ["loop", "scan", "pallas", "sparse",
                                       "hash", "bsr"]
    for s in specs:
        assert set(backend_registry.ALGORITHMS) <= set(s.executors), s.name
        if s.name == "loop":
            assert not s.supports_batched
        else:
            assert s.supports_batched, s.name
            assert s.trace_key and s.trace_key_batched, s.name
        if s.is_accumulator:
            assert s.byte_model is not None, s.name
        if s.needs_block_caps:
            assert s.block_size, s.name
    assert backend_registry.batched_backends() == ("scan", "pallas", "sparse",
                                                   "hash", "bsr")
    assert tuple(s.name for s in backend_registry.accumulator_specs()) == (
        "pallas", "sparse", "hash", "bsr")
    with pytest.raises(ValueError, match="unknown backend"):
        backend_registry.get("nope")


def _thirds(n: int) -> tuple:
    if n < 3:
        return (0, n)
    return (0, n // 3, 2 * n // 3, n)


def _case_empty_rows(rng):
    """A with structurally empty rows at both ends and mid-strip."""
    a = random_dense(rng, 14, 11, 0.4)
    a[0] = a[5] = a[6] = a[13] = 0.0
    return csr_from_dense(a), random_csr(rng, 11, 9, 0.3)


def _case_skewed_rows(rng):
    """One fully dense A row among near-empty ones (skewed staging caps)."""
    a = random_dense(rng, 12, 16, 0.05)
    a[7] = rng.standard_normal(16).astype(np.float32)
    return csr_from_dense(a), random_csr(rng, 16, 10, 0.3)


def _case_all_zero_chunk(rng):
    """The middle B chunk of the thirds partition is structurally empty."""
    b = random_dense(rng, 15, 8, 0.4)
    b[5:10] = 0.0
    return random_csr(rng, 10, 15, 0.3), csr_from_dense(b)


def _case_single_col_b(rng):
    return random_csr(rng, 9, 12, 0.4), random_csr(rng, 12, 1, 0.5)


def _case_all_zero_b(rng):
    """C is structurally empty (every backend must produce an all-zero C)."""
    return random_csr(rng, 8, 10, 0.4), csr_from_dense(np.zeros((10, 6),
                                                                np.float32))


def _case_wide_sparse_output(rng):
    """Wide C at low density — the sparse backend's home turf."""
    return random_csr(rng, 10, 12, 0.12), random_csr(rng, 12, 48, 0.04)


def _case_duplicate_heavy(rng):
    """Every A entry hits one of three hot B rows: duplicate (row, col)
    products pile onto the same hash slots and neighboring keys chain off
    each other — the linear-probe collision stressor. The thirds partition
    of B also leaves chunks 1 and 2 structurally empty."""
    a = np.zeros((12, 9), np.float32)
    a[:, :3] = random_dense(rng, 12, 3, 0.9)
    b = np.zeros((9, 10), np.float32)
    b[:3] = random_dense(rng, 3, 10, 0.8)
    return csr_from_dense(a), csr_from_dense(b)


def _case_dense_row(rng):
    """One fully dense C row: ``c_max_row_nnz == n_cols``, so the hash
    table's occupancy hits its exact capacity bound (the table-full
    boundary — every probe chain in that row terminates only because the
    symbolic bound is exact)."""
    a = random_dense(rng, 10, 8, 0.2)
    a[4] = rng.standard_normal(8).astype(np.float32)     # dense A row
    b = random_dense(rng, 8, 12, 0.3)
    b[0] = rng.standard_normal(12).astype(np.float32)    # dense B row
    A, B = csr_from_dense(a), csr_from_dense(b)
    # the case exists for this boundary; pin it so a seed drift can't
    # silently soften the geometry
    assert spgemm_structure_host(A, B).c_max_row_nnz == B.n_cols
    return A, B


CASES = {
    "empty_rows": (_case_empty_rows, 101),
    "skewed_rows": (_case_skewed_rows, 102),
    "all_zero_chunk": (_case_all_zero_chunk, 103),
    "single_col_b": (_case_single_col_b, 104),
    "all_zero_b": (_case_all_zero_b, 105),
    "wide_sparse_output": (_case_wide_sparse_output, 106),
    "duplicate_heavy": (_case_duplicate_heavy, 107),
    "dense_row": (_case_dense_row, 108),
}


def _plan(algorithm: str, A, B) -> ChunkPlan:
    p_ac = (0, A.n_rows) if algorithm == "knl" else _thirds(A.n_rows)
    return ChunkPlan(algorithm, p_ac, _thirds(B.n_rows), 0.0, 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_backend_matches_loop_oracle(case, algorithm, backend):
    build, seed = CASES[case]
    A, B = build(np.random.default_rng(seed))
    plan = _plan(algorithm, A, B)
    c_pad = default_c_pad(A, B, plan)
    Cl, sl = chunked_spgemm(A, B, plan, c_pad, backend="loop")
    Cb, sb = chunked_spgemm(A, B, plan, c_pad, backend=backend)
    assert Cb.shape == (A.n_rows, B.n_cols)
    assert_close(csr_to_dense(Cb), csr_to_dense(Cl), atol=1e-4,
                 msg=f"{case}/{algorithm}/{backend} vs loop oracle")
    assert_close(csr_to_dense(Cl), spgemm_dense_oracle(A, B), atol=1e-4)
    # every backend runs the plan's exact multiply schedule
    assert sb.kernel_calls == sl.kernel_calls
    assert len(sb.per_copy_in) > 0 and sb.copy_in_bytes > 0


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_batched_hetero_conformance(algorithm, backend):
    """Heterogeneous-structure batches (mixed densities plus one structurally
    empty A instance) through every batched backend, against the
    per-instance loop oracle at the batch envelope's c_pad."""
    rng = np.random.default_rng(207)
    As = [random_csr(rng, 18, 15, d) for d in (0.10, 0.30)]
    As.append(csr_from_dense(np.zeros((18, 15), np.float32)))
    Bs = [random_csr(rng, 15, 13, d) for d in (0.15, 0.25, 0.35)]
    plan = _plan(algorithm, As[0], Bs[0])
    env = batch_envelope(As, Bs, plan)
    out, _ = chunked_spgemm_batched(As, Bs, plan, backend=backend)
    assert len(out) == len(As)
    for A, B, Cb in zip(As, Bs, out):
        Cl, _ = chunked_spgemm(A, B, plan, c_pad=env.c_pad, backend="loop")
        assert_close(csr_to_dense(Cb), csr_to_dense(Cl), atol=1e-4,
                     msg=f"hetero/{algorithm}/{backend}")


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
def test_service_conformance(backend):
    """The full serving path (bucketing, envelope quantization, microbatch
    padding) stays oracle-correct for every backend."""
    rng = np.random.default_rng(303)
    As = [random_csr(rng, 12, 10, d) for d in (0.1, 0.2, 0.3, 0.15)]
    Bs = [random_csr(rng, 10, 8, d) for d in (0.2, 0.3, 0.1, 0.25)]
    svc = SpGEMMService(fast_limit_bytes=1500.0, backend=backend, max_batch=2)
    ids = [svc.submit(A, B) for A, B in zip(As, Bs)]
    responses = svc.flush()
    assert [r.req_id for r in responses] == ids
    for r, A, B in zip(responses, As, Bs):
        assert_close(csr_to_dense(r.C), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg=f"service/{backend}")


# ---------------------------------------------------------------------------
# trace-count regression: exact deltas per backend
# ---------------------------------------------------------------------------

# TRACE_COUNTS key of each backend's jitted core ({alg} formats in) — pulled
# from the registry, so a registration's trace keys are what gets pinned
TRACE_KEYS = {s.name: s.trace_key for s in backend_registry.specs()
              if s.trace_key}
TRACE_KEYS_BATCHED = {s.name: s.trace_key_batched
                      for s in backend_registry.specs()
                      if s.trace_key_batched}


def _trace_key(backend: str, algorithm: str, plan, env) -> str:
    """The TRACE_COUNTS key a chunked_spgemm call will bump. ``auto`` is
    resolved the way the dispatcher resolves it — through the planner byte
    models — so the pin also witnesses that auto's resolution is the
    deterministic function of (plan, envelope) it claims to be."""
    if backend == "auto":
        backend = select_accumulator_backend(plan, env)
    return TRACE_KEYS[backend].format(alg=algorithm)


def _trace_geometry(rng, m=21, k=19, n=13, da=0.25, db=0.3):
    """Sizes unique to this module so the session-global jit cache cannot
    have seen the padded geometry before the first measured call."""
    return random_csr(rng, m, k, da), random_csr(rng, k, n, db)


@pytest.mark.parametrize("backend", [*TRACE_KEYS, "auto"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_trace_counts_exact(algorithm, backend):
    """first call = exactly one trace of the backend core; repeat and
    same-envelope (new values, same padded geometry) = exactly zero; a new
    envelope = exactly one more."""
    # deterministic per-combination seed (str hashing is process-salted)
    seed = 1000 + 10 * ALGORITHMS.index(algorithm) + BACKENDS.index(backend)
    rng = np.random.default_rng(seed)
    A1, B1 = _trace_geometry(rng)
    plan = _plan(algorithm, A1, B1)
    c_pad = default_c_pad(A1, B1, plan)
    env1 = instance_envelope(A1, B1, plan, c_pad=c_pad)
    key = _trace_key(backend, algorithm, plan, env1)

    before = TRACE_COUNTS[key]
    chunked_spgemm(A1, B1, plan, c_pad, backend=backend)
    assert TRACE_COUNTS[key] - before == 1, "first call must trace once"

    mid = TRACE_COUNTS[key]
    chunked_spgemm(A1, B1, plan, c_pad, backend=backend)     # repeat
    assert TRACE_COUNTS[key] == mid, "repeat call must not retrace"

    # same envelope, different values: rebuild with the same seed's structure
    A1b = csr_from_dense(np.asarray(csr_to_dense(A1)) * 2.0)
    B1b = csr_from_dense(np.asarray(csr_to_dense(B1)) * 0.5)
    assert instance_envelope(A1b, B1b, plan, c_pad=c_pad) == env1
    chunked_spgemm(A1b, B1b, plan, c_pad, backend=backend)
    assert TRACE_COUNTS[key] == mid, "same-envelope call must not retrace"

    # a genuinely new padded geometry: exactly one more trace (of the core
    # auto resolves to *for that geometry* — the winner may change with it)
    A2, B2 = _trace_geometry(rng, m=23, k=20, n=11, da=0.4, db=0.35)
    plan2 = _plan(algorithm, A2, B2)
    c_pad2 = default_c_pad(A2, B2, plan2)
    key2 = _trace_key(backend, algorithm, plan2,
                      instance_envelope(A2, B2, plan2, c_pad=c_pad2))
    mid2 = TRACE_COUNTS[key2]
    chunked_spgemm(A2, B2, plan2, c_pad2, backend=backend)
    assert TRACE_COUNTS[key2] == mid2 + 1, \
        "new envelope must trace exactly once"


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
def test_trace_counts_exact_batched(backend):
    """Batched cores: one trace per (envelope, batch width), zero on repeat
    and on new same-envelope instances, one more when the envelope grows."""
    algorithm = "chunk1"
    rng = np.random.default_rng(2000 + BACKENDS.index(backend))
    As = [random_csr(rng, 22, 17, 0.2) for _ in range(2)]
    Bs = [random_csr(rng, 17, 12, 0.25) for _ in range(2)]
    plan = _plan(algorithm, As[0], Bs[0])
    block = _block_size_for(backend)
    env = batch_envelope(As, Bs, plan, block_size=block)
    resolved = (select_accumulator_backend(plan, env) if backend == "auto"
                else backend)
    key = TRACE_KEYS_BATCHED[resolved].format(alg=algorithm)

    before = TRACE_COUNTS[key]
    chunked_spgemm_batched(As, Bs, plan, envelope=env, backend=backend)
    assert TRACE_COUNTS[key] - before == 1

    mid = TRACE_COUNTS[key]
    chunked_spgemm_batched(As, Bs, plan, envelope=env, backend=backend)
    assert TRACE_COUNTS[key] == mid

    # fresh instances, same bucket envelope: a structural *subset* of the
    # originals (every other entry dropped, values rescaled), so domination
    # holds by construction for any seed
    def subset(m):
        d = np.asarray(csr_to_dense(m))
        keep = np.arange(d.size).reshape(d.shape) % 2 == 0
        return csr_from_dense((d * keep * 1.5).astype(d.dtype))

    As2 = [subset(A) for A in As]
    Bs2 = [subset(B) for B in Bs]
    assert env.dominates(batch_envelope(As2, Bs2, plan))
    chunked_spgemm_batched(As2, Bs2, plan, envelope=env, backend=backend)
    assert TRACE_COUNTS[key] == mid

    # grown envelope (denser batch): exactly one more compile, of the core
    # auto resolves to under the grown envelope
    As3 = [random_csr(rng, 22, 17, 0.5) for _ in range(2)]
    Bs3 = [random_csr(rng, 17, 12, 0.5) for _ in range(2)]
    env3 = env.union(batch_envelope(As3, Bs3, plan, block_size=block))
    resolved3 = (select_accumulator_backend(plan, env3) if backend == "auto"
                 else backend)
    key3 = TRACE_KEYS_BATCHED[resolved3].format(alg=algorithm)
    mid3 = TRACE_COUNTS[key3]
    chunked_spgemm_batched(As3, Bs3, plan, envelope=env3, backend=backend)
    assert TRACE_COUNTS[key3] == mid3 + 1


# ---------------------------------------------------------------------------
# capacity-overflow regression: under-capped launches fail loudly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["sparse", "hash"])
def test_undercapped_c_pad_raises(backend):
    """A c_pad below the realized strip output nnz must be a planner-level
    ValueError naming the geometry — both sparse-output kernels would
    otherwise silently drop the overflow into their bounded scratch (the
    ESC scatter's drop bucket, a full hash table)."""
    rng = np.random.default_rng(401)
    A = random_csr(rng, 12, 10, 0.4)
    B = random_csr(rng, 10, 9, 0.4)
    plan = _plan("chunk1", A, B)
    caps = strip_output_caps(A, B, plan.p_ac)
    bad = max(caps.strip_nnz) - 1
    assert bad > 0
    with pytest.raises(ValueError, match="exceeds the accumulator capacity"):
        chunked_spgemm(A, B, plan, c_pad=bad, backend=backend)
    # the exact symbolic capacity itself (unrounded) must be accepted
    C, _ = chunked_spgemm(A, B, plan, c_pad=max(caps.strip_nnz),
                          backend=backend)
    assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-4)


@pytest.mark.parametrize("backend", ["sparse", "hash"])
def test_undercapped_batched_envelope_raises(backend):
    """The batched path validates every instance against the shared envelope:
    a caller-built envelope whose c_pad undercuts one instance's realized
    output must raise and name the offending instance."""
    import dataclasses

    rng = np.random.default_rng(402)
    As = [random_csr(rng, 12, 10, d) for d in (0.15, 0.45)]
    Bs = [random_csr(rng, 10, 9, d) for d in (0.2, 0.45)]
    plan = _plan("chunk1", As[0], Bs[0])
    env = batch_envelope(As, Bs, plan)
    caps1 = strip_output_caps(As[1], Bs[1], plan.p_ac)
    bad_env = dataclasses.replace(env, c_pad=max(caps1.strip_nnz) - 1)
    with pytest.raises(ValueError, match="batch instance 1"):
        chunked_spgemm_batched(As, Bs, plan, envelope=bad_env,
                               backend=backend)


def test_undercapped_hash_table_raises():
    """The hash-specific cap: an envelope whose c_max_row_nnz undersizes the
    per-row table relative to an instance's realized densest C row must trip
    the row-cap branch of check_output_caps (only reachable batched — the
    unbatched path sizes the table from the exact caps it checks against)."""
    import dataclasses

    rng = np.random.default_rng(403)
    As = [random_csr(rng, 12, 10, 0.5)]
    Bs = [random_csr(rng, 10, 9, 0.5)]
    plan = _plan("chunk1", As[0], Bs[0])
    env = batch_envelope(As, Bs, plan)
    exact = strip_output_caps(As[0], Bs[0], plan.p_ac).c_max_row_nnz
    assert exact > 2    # dense draw: the densest C row has several entries
    bad_env = dataclasses.replace(env, c_max_row_nnz=2)   # 2-slot tables
    with pytest.raises(ValueError, match="hash-table capacity"):
        chunked_spgemm_batched(As, Bs, plan, envelope=bad_env,
                               backend="hash")


# ---------------------------------------------------------------------------
# auto dispatch: provably the minimum-resident-bytes accumulator
# ---------------------------------------------------------------------------


def _auto_geometries(rng):
    """Three geometries whose minimum-byte accumulator provably differs:
    dense narrow C (dense slab wins), wide sparse C with a fat product
    expansion (hash wins), near-diagonal tall operands (tiny ESC expand
    stream beats the row-count-scaled hash tables)."""
    dense_a = csr_from_dense(random_dense(rng, 24, 16, 0.6))
    dense_b = csr_from_dense(random_dense(rng, 16, 12, 0.6))
    dense_plan = ChunkPlan("chunk1", (0, 12, 24), (0, 8, 16), 0.0, 0.0)

    wide_a = csr_from_dense(random_dense(rng, 32, 40, 0.25))
    wide_b = csr_from_dense(random_dense(rng, 40, 512, 0.02))
    wide_plan = ChunkPlan("chunk1", (0, 16, 32), (0, 14, 27, 40), 0.0, 0.0)

    m, k, n = 192, 64, 512
    a = np.zeros((m, k), np.float32)
    a[np.arange(m), np.arange(m) % k] = 1.0
    a[0, :8] = 1.0                      # one denser row: c_max_row_nnz ~ 8
    b = np.zeros((k, n), np.float32)
    b[np.arange(k), (np.arange(k) * 7) % n] = 1.0
    diag_a, diag_b = csr_from_dense(a), csr_from_dense(b)
    diag_plan = ChunkPlan("chunk1", (0, 96, 192), (0, 32, 64), 0.0, 0.0)

    return [("dense_narrow", dense_a, dense_b, dense_plan),
            ("wide_sparse", wide_a, wide_b, wide_plan),
            ("tall_diag", diag_a, diag_b, diag_plan)]


def test_auto_selects_min_resident_bytes_backend():
    """Acceptance: on three geometries with three different byte-model
    winners, ``backend="auto"`` (i) resolves to the argmin of the three
    planner models, (ii) runs exactly that backend's core (trace-counted),
    and (iii) stays oracle-correct. Together the three cases cover every
    accumulator being chosen at least once."""
    from repro.core.planner import backend_fast_models

    rng = np.random.default_rng(500)
    winners = {}
    for name, A, B, plan in _auto_geometries(rng):
        c_pad = default_c_pad(A, B, plan)
        env = instance_envelope(A, B, plan, c_pad=c_pad)
        models = backend_fast_models(plan, env)
        pick = select_accumulator_backend(plan, env)
        assert models[pick].fast_bytes_needed == min(
            m.fast_bytes_needed for m in models.values()), name
        key = TRACE_KEYS[pick].format(alg=plan.algorithm)
        before = TRACE_COUNTS[key]
        C, _ = chunked_spgemm(A, B, plan, c_pad, backend="auto")
        # geometries here are unique to this test, so the resolved core must
        # trace exactly once — auto provably ran the argmin backend
        assert TRACE_COUNTS[key] == before + 1, \
            f"{name}: auto did not run the {pick} core"
        assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg=f"auto/{name}")
        winners[name] = pick
    assert set(winners.values()) == {"pallas", "sparse", "hash"}, winners


# ---------------------------------------------------------------------------
# nightly: larger hash sweep (geometry grid too big for the fast lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_hash_backend_large_sweep(algorithm):
    """Bigger geometries x densities through the hash backend — the probe
    chains and table occupancies the fast-lane cases only sample. Nightly:
    the serial insert loops make these seconds-per-case."""
    rng = np.random.default_rng(600 + ALGORITHMS.index(algorithm))
    for m, k, n, da, db in ((48, 40, 96, 0.15, 0.1), (64, 48, 160, 0.1, 0.05),
                            (40, 56, 64, 0.3, 0.2)):
        A, B = random_csr(rng, m, k, da), random_csr(rng, k, n, db)
        plan = _plan(algorithm, A, B)
        c_pad = default_c_pad(A, B, plan)
        C, _ = chunked_spgemm(A, B, plan, c_pad, backend="hash")
        assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg=f"hash sweep {m}x{k}x{n}/{algorithm}")
