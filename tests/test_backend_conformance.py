"""Cross-backend differential conformance suite.

One seeded geometry matrix — empty rows, skewed rows, all-zero chunks,
single-column B, all-zero B, wide-but-sparse outputs — runs through **every**
``chunked_spgemm`` backend and is asserted allclose to the loop oracle at
matched ``c_pad`` (scan additionally bitwise, which ``assert_close`` at tiny
atol effectively witnesses via identical float schedules). New backends
register in ``BACKENDS``/``BATCHED_BACKENDS`` and inherit the whole matrix:
correctness guarantees come from this suite, not per-backend ad-hoc tests.

The trace-count section pins the *exact* ``TRACE_COUNTS`` deltas of every
backend across repeat / same-envelope / new-envelope calls, so a silent
retrace regression (a geometry-dependent Python value smuggled into a jitted
signature, a cache-busting non-hashable static) fails the fast lane instead
of showing up as a serving-latency cliff.

Determinism: every case is seeded and the matrix is pure-parametrize, so two
runs of this file must produce identical reports — CI runs it twice and
diffs (the determinism job in .github/workflows/ci.yml).
"""

import numpy as np
import pytest

from repro.core.chunk_stream import TRACE_COUNTS, chunked_spgemm_batched
from repro.core.chunking import (
    batch_envelope, chunked_spgemm, default_c_pad, instance_envelope,
)
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.planner import ChunkPlan
from repro.sparse.csr import csr_from_dense, csr_to_dense
from repro.serve.spgemm_service import SpGEMMService
from conftest import assert_close, random_csr, random_dense

# every chunked_spgemm backend; new backends register here (and in
# BATCHED_BACKENDS below when they support chunked_spgemm_batched)
BACKENDS = ["loop", "scan", "pallas", "sparse"]
BATCHED_BACKENDS = ["scan", "pallas", "sparse"]
ALGORITHMS = ["knl", "chunk1", "chunk2"]


def _thirds(n: int) -> tuple:
    if n < 3:
        return (0, n)
    return (0, n // 3, 2 * n // 3, n)


def _case_empty_rows(rng):
    """A with structurally empty rows at both ends and mid-strip."""
    a = random_dense(rng, 14, 11, 0.4)
    a[0] = a[5] = a[6] = a[13] = 0.0
    return csr_from_dense(a), random_csr(rng, 11, 9, 0.3)


def _case_skewed_rows(rng):
    """One fully dense A row among near-empty ones (skewed staging caps)."""
    a = random_dense(rng, 12, 16, 0.05)
    a[7] = rng.standard_normal(16).astype(np.float32)
    return csr_from_dense(a), random_csr(rng, 16, 10, 0.3)


def _case_all_zero_chunk(rng):
    """The middle B chunk of the thirds partition is structurally empty."""
    b = random_dense(rng, 15, 8, 0.4)
    b[5:10] = 0.0
    return random_csr(rng, 10, 15, 0.3), csr_from_dense(b)


def _case_single_col_b(rng):
    return random_csr(rng, 9, 12, 0.4), random_csr(rng, 12, 1, 0.5)


def _case_all_zero_b(rng):
    """C is structurally empty (every backend must produce an all-zero C)."""
    return random_csr(rng, 8, 10, 0.4), csr_from_dense(np.zeros((10, 6),
                                                                np.float32))


def _case_wide_sparse_output(rng):
    """Wide C at low density — the sparse backend's home turf."""
    return random_csr(rng, 10, 12, 0.12), random_csr(rng, 12, 48, 0.04)


CASES = {
    "empty_rows": (_case_empty_rows, 101),
    "skewed_rows": (_case_skewed_rows, 102),
    "all_zero_chunk": (_case_all_zero_chunk, 103),
    "single_col_b": (_case_single_col_b, 104),
    "all_zero_b": (_case_all_zero_b, 105),
    "wide_sparse_output": (_case_wide_sparse_output, 106),
}


def _plan(algorithm: str, A, B) -> ChunkPlan:
    p_ac = (0, A.n_rows) if algorithm == "knl" else _thirds(A.n_rows)
    return ChunkPlan(algorithm, p_ac, _thirds(B.n_rows), 0.0, 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_backend_matches_loop_oracle(case, algorithm, backend):
    build, seed = CASES[case]
    A, B = build(np.random.default_rng(seed))
    plan = _plan(algorithm, A, B)
    c_pad = default_c_pad(A, B, plan)
    Cl, sl = chunked_spgemm(A, B, plan, c_pad, backend="loop")
    Cb, sb = chunked_spgemm(A, B, plan, c_pad, backend=backend)
    assert Cb.shape == (A.n_rows, B.n_cols)
    assert_close(csr_to_dense(Cb), csr_to_dense(Cl), atol=1e-4,
                 msg=f"{case}/{algorithm}/{backend} vs loop oracle")
    assert_close(csr_to_dense(Cl), spgemm_dense_oracle(A, B), atol=1e-4)
    # every backend runs the plan's exact multiply schedule
    assert sb.kernel_calls == sl.kernel_calls
    assert len(sb.per_copy_in) > 0 and sb.copy_in_bytes > 0


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_batched_hetero_conformance(algorithm, backend):
    """Heterogeneous-structure batches (mixed densities plus one structurally
    empty A instance) through every batched backend, against the
    per-instance loop oracle at the batch envelope's c_pad."""
    rng = np.random.default_rng(207)
    As = [random_csr(rng, 18, 15, d) for d in (0.10, 0.30)]
    As.append(csr_from_dense(np.zeros((18, 15), np.float32)))
    Bs = [random_csr(rng, 15, 13, d) for d in (0.15, 0.25, 0.35)]
    plan = _plan(algorithm, As[0], Bs[0])
    env = batch_envelope(As, Bs, plan)
    out, _ = chunked_spgemm_batched(As, Bs, plan, backend=backend)
    assert len(out) == len(As)
    for A, B, Cb in zip(As, Bs, out):
        Cl, _ = chunked_spgemm(A, B, plan, c_pad=env.c_pad, backend="loop")
        assert_close(csr_to_dense(Cb), csr_to_dense(Cl), atol=1e-4,
                     msg=f"hetero/{algorithm}/{backend}")


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
def test_service_conformance(backend):
    """The full serving path (bucketing, envelope quantization, microbatch
    padding) stays oracle-correct for every backend."""
    rng = np.random.default_rng(303)
    As = [random_csr(rng, 12, 10, d) for d in (0.1, 0.2, 0.3, 0.15)]
    Bs = [random_csr(rng, 10, 8, d) for d in (0.2, 0.3, 0.1, 0.25)]
    svc = SpGEMMService(fast_limit_bytes=1500.0, backend=backend, max_batch=2)
    ids = [svc.submit(A, B) for A, B in zip(As, Bs)]
    responses = svc.flush()
    assert [r.req_id for r in responses] == ids
    for r, A, B in zip(responses, As, Bs):
        assert_close(csr_to_dense(r.C), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg=f"service/{backend}")


# ---------------------------------------------------------------------------
# trace-count regression: exact deltas per backend
# ---------------------------------------------------------------------------

# TRACE_COUNTS key of each backend's unbatched jitted core ({alg} formats in)
TRACE_KEYS = {"scan": "{alg}", "pallas": "{alg}_pallas",
              "sparse": "{alg}_sparse"}
TRACE_KEYS_BATCHED = {"scan": "{alg}_batched", "pallas": "{alg}_pallas_batched",
                      "sparse": "{alg}_sparse_batched"}


def _trace_geometry(rng, m=21, k=19, n=13, da=0.25, db=0.3):
    """Sizes unique to this module so the session-global jit cache cannot
    have seen the padded geometry before the first measured call."""
    return random_csr(rng, m, k, da), random_csr(rng, k, n, db)


@pytest.mark.parametrize("backend", ["scan", "pallas", "sparse"])
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_trace_counts_exact(algorithm, backend):
    """first call = exactly one trace of the backend core; repeat and
    same-envelope (new values, same padded geometry) = exactly zero; a new
    envelope = exactly one more."""
    key = TRACE_KEYS[backend].format(alg=algorithm)
    # deterministic per-combination seed (str hashing is process-salted)
    seed = 1000 + 10 * ALGORITHMS.index(algorithm) + BACKENDS.index(backend)
    rng = np.random.default_rng(seed)
    A1, B1 = _trace_geometry(rng)
    plan = _plan(algorithm, A1, B1)
    c_pad = default_c_pad(A1, B1, plan)

    before = TRACE_COUNTS[key]
    chunked_spgemm(A1, B1, plan, c_pad, backend=backend)
    assert TRACE_COUNTS[key] - before == 1, "first call must trace once"

    mid = TRACE_COUNTS[key]
    chunked_spgemm(A1, B1, plan, c_pad, backend=backend)     # repeat
    assert TRACE_COUNTS[key] == mid, "repeat call must not retrace"

    # same envelope, different values: rebuild with the same seed's structure
    A1b = csr_from_dense(np.asarray(csr_to_dense(A1)) * 2.0)
    B1b = csr_from_dense(np.asarray(csr_to_dense(B1)) * 0.5)
    env1 = instance_envelope(A1, B1, plan, c_pad=c_pad)
    assert instance_envelope(A1b, B1b, plan, c_pad=c_pad) == env1
    chunked_spgemm(A1b, B1b, plan, c_pad, backend=backend)
    assert TRACE_COUNTS[key] == mid, "same-envelope call must not retrace"

    # a genuinely new padded geometry: exactly one more trace
    A2, B2 = _trace_geometry(rng, m=23, k=20, n=11, da=0.4, db=0.35)
    plan2 = _plan(algorithm, A2, B2)
    chunked_spgemm(A2, B2, plan2, default_c_pad(A2, B2, plan2),
                   backend=backend)
    assert TRACE_COUNTS[key] == mid + 1, "new envelope must trace exactly once"


@pytest.mark.parametrize("backend", BATCHED_BACKENDS)
def test_trace_counts_exact_batched(backend):
    """Batched cores: one trace per (envelope, batch width), zero on repeat
    and on new same-envelope instances, one more when the envelope grows."""
    algorithm = "chunk1"
    key = TRACE_KEYS_BATCHED[backend].format(alg=algorithm)
    rng = np.random.default_rng(2000 + BACKENDS.index(backend))
    As = [random_csr(rng, 22, 17, 0.2) for _ in range(2)]
    Bs = [random_csr(rng, 17, 12, 0.25) for _ in range(2)]
    plan = _plan(algorithm, As[0], Bs[0])
    env = batch_envelope(As, Bs, plan)

    before = TRACE_COUNTS[key]
    chunked_spgemm_batched(As, Bs, plan, envelope=env, backend=backend)
    assert TRACE_COUNTS[key] - before == 1

    mid = TRACE_COUNTS[key]
    chunked_spgemm_batched(As, Bs, plan, envelope=env, backend=backend)
    assert TRACE_COUNTS[key] == mid

    # fresh instances, same bucket envelope: served by the compiled program
    As2 = [random_csr(rng, 22, 17, 0.1) for _ in range(2)]
    Bs2 = [random_csr(rng, 17, 12, 0.15) for _ in range(2)]
    assert env.dominates(batch_envelope(As2, Bs2, plan))
    chunked_spgemm_batched(As2, Bs2, plan, envelope=env, backend=backend)
    assert TRACE_COUNTS[key] == mid

    # grown envelope (denser batch): exactly one more compile
    As3 = [random_csr(rng, 22, 17, 0.5) for _ in range(2)]
    Bs3 = [random_csr(rng, 17, 12, 0.5) for _ in range(2)]
    env3 = env.union(batch_envelope(As3, Bs3, plan))
    chunked_spgemm_batched(As3, Bs3, plan, envelope=env3, backend=backend)
    assert TRACE_COUNTS[key] == mid + 1
