"""Every example's ``main`` must actually run (tiny sizes, in-process).

The examples are the repo's executable documentation; they historically
rotted against API changes (multigrid_spgemm predated the backend dispatch),
so each one is smoke-run here. Heavy end-to-end drivers (LM train/serve) run
in the nightly slow lane; the SpGEMM-centric ones stay in the fast lane at
sizes chosen to finish in seconds.
"""

import sys

import pytest


def test_quickstart_main(capsys):
    from examples import quickstart

    quickstart.main()
    out = capsys.readouterr().out
    assert "chunked == unchunked == oracle" in out
    assert "Alg-1 chunking ok" in out


def test_multigrid_spgemm_main_all_backends(capsys):
    """The paper driver through every chunked_spgemm backend at tiny size."""
    from examples import multigrid_spgemm

    multigrid_spgemm.main(["--problem", "laplace3d", "--size", "5",
                           "--backends", "all"])
    out = capsys.readouterr().out
    for backend in multigrid_spgemm.ALL_BACKENDS:
        assert f"/{backend:6s}:" in out, f"backend {backend} did not run"
    for backend in ("sparse", "hash"):
        assert f"pipeline@1.00/{backend:6s}:" in out, \
            f"fused R(AP) pipeline did not run through {backend}"
        assert f"pipeline@0.25/{backend:6s}:" in out
    assert "correct=False" not in out


def test_multigrid_spgemm_rejects_unknown_backend():
    from examples import multigrid_spgemm

    with pytest.raises(SystemExit):
        multigrid_spgemm.main(["--problem", "laplace3d", "--size", "5",
                               "--backends", "nope"])


def test_triangle_count_main(monkeypatch, capsys):
    from examples import triangle_count

    monkeypatch.setattr(sys, "argv",
                        ["triangle_count.py", "--scale", "7"])
    triangle_count.main()
    out = capsys.readouterr().out
    assert "triangles =" in out
    assert "fused/hash" in out, "masked hash backend did not run"
    assert "agrees: True" in out
    assert "dense oracle agrees: True" in out


@pytest.mark.slow
def test_serve_lm_main(monkeypatch, capsys):
    from examples import serve_lm

    monkeypatch.setattr(sys, "argv",
                        ["serve_lm.py", "--batch", "2",
                         "--max-new-tokens", "4"])
    serve_lm.main()
    out = capsys.readouterr().out
    assert "generated" in out


@pytest.mark.slow
def test_train_lm_main(monkeypatch, capsys, tmp_path):
    from examples import train_lm

    monkeypatch.setattr(sys, "argv",
                        ["train_lm.py", "--steps", "2", "--d-model", "64",
                         "--layers", "1", "--seq-len", "32",
                         "--batch-size", "2", "--microbatches", "1",
                         "--ckpt-dir", str(tmp_path / "ckpt")])
    train_lm.main()
    out = capsys.readouterr().out
    assert "finished" in out
