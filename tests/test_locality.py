"""Reuse-distance machinery vs brute force + triangle counting."""

import numpy as np
from conftest import given, settings, st

from repro.core.locality import stack_distances, analyze, b_access_trace
from repro.core.triangle import count_triangles, count_triangles_dense
from repro.sparse import graphs, multigrid


def brute_stack_distance(trace):
    out = []
    last = {}
    for t, r in enumerate(trace):
        if r not in last:
            out.append(-1)
        else:
            out.append(len(set(trace[last[r] + 1 : t])))
        last[r] = t
    return out


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 12), min_size=1, max_size=80))
def test_stack_distance_vs_brute_force(trace):
    got = stack_distances(np.asarray(trace), 13)
    want = brute_stack_distance(trace)
    np.testing.assert_array_equal(got, want)


def test_miss_fraction_monotone_in_capacity():
    A, R, P = multigrid.problem("laplace3d", 6)
    st_ = analyze(R, A)
    fracs = [st_.miss_fraction(c) for c in (1, 4, 16, 64, 256, 4096)]
    assert all(a >= b for a, b in zip(fracs, fracs[1:]))
    assert fracs[-1] >= st_.n_cold / st_.n_accesses


def test_access_trace_is_a_columns():
    A, R, P = multigrid.problem("laplace3d", 4)
    trace = b_access_trace(R)
    assert trace.size == int(np.asarray(R.indptr)[-1])


@settings(max_examples=4, deadline=None)
@given(st.integers(6, 8), st.integers(3, 6), st.integers(0, 10_000))
def test_triangle_count_property(scale, ef, seed):
    G = graphs.rmat(scale, ef, seed=seed)
    L = graphs.lower_triangular_degree_sorted(G)
    got = float(count_triangles(L))
    want = float(count_triangles_dense(L))
    assert abs(got - want) < 1e-3
