"""§Perf levers must never change results — only layouts/dtypes of transport.

These are the regression tests behind EXPERIMENTS.md §Perf: every lever (and the
local-dispatch MoE rewrite) is checked for numerical equivalence against the
baseline path on CPU.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as tf
from repro.models import moe

KEY = jax.random.PRNGKey(0)

BASE = ModelConfig(name="t", family="moe", n_layers=2, d_model=64, d_ff=96,
                   vocab_size=128, n_heads=8, n_kv_heads=2, n_experts=4, top_k=2,
                   capacity_factor=8.0, q_chunk=16, attn_chunk=16,
                   compute_dtype="float32")


def _batch(rng):
    toks = jnp.asarray(rng.integers(0, BASE.vocab_size, (2, 32)), jnp.int32)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("lever", [
    {"precast_params": True},
    {"cast_free_attention": True},
    {"shard_activations": True, "dp_axes": (), "tp_axis": ""},  # no-op w/o mesh
    {"precast_params": True, "cast_free_attention": True,
     "shard_activations": True},
    {"remat_policy": "dots"},
])
def test_lever_preserves_forward(rng, lever):
    cfg = dataclasses.replace(BASE, **lever)
    params = tf.init_params(KEY, BASE)
    batch = _batch(rng)
    l0, _ = tf.forward(params, batch, BASE)
    l1, _ = tf.forward(params, batch, cfg)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


def test_lever_preserves_grads(rng):
    cfg = dataclasses.replace(BASE, precast_params=True,
                              cast_free_attention=True, remat_policy="dots")
    params = tf.init_params(KEY, BASE)
    batch = _batch(rng)
    g0 = jax.grad(lambda p: tf.loss_fn(p, batch, BASE)[0])(params)
    g1 = jax.grad(lambda p: tf.loss_fn(p, batch, cfg)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_lever_preserves_decode(rng):
    cfg = dataclasses.replace(BASE, precast_params=True,
                              cast_free_attention=True)
    params = tf.init_params(KEY, BASE)
    batch = _batch(rng)
    logits, _ = tf.forward(params, batch, cfg)
    toks = batch["tokens"]
    lg, cache = tf.prefill(params, {"tokens": toks[:, :28]}, cfg, cache_len=32)
    errs = [np.abs(np.asarray(lg) - np.asarray(logits[:, 27])).max()]
    for t in range(28, 32):
        lg, cache = tf.decode_step(params, cache, toks[:, t : t + 1], cfg)
        errs.append(np.abs(np.asarray(lg) - np.asarray(logits[:, t])).max())
    assert max(errs) < 2e-2


def test_local_dispatch_row_independence(rng):
    """Per-row dispatch: each batch row's output is independent of the others
    (the property that makes batch sharding propagate)."""
    cfg = dataclasses.replace(BASE, capacity_factor=8.0)
    p = moe.moe_init(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((3, 16, cfg.d_model)).astype(np.float32))
    y_all, _ = moe.moe_apply(p, x, cfg)
    for i in range(3):
        y_one, _ = moe.moe_apply(p, x[i : i + 1], cfg)
        np.testing.assert_allclose(np.asarray(y_all[i]), np.asarray(y_one[0]),
                                   atol=1e-5)
