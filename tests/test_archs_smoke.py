"""Deliverable (f): per-architecture smoke tests.

Every assigned architecture instantiates a REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and no NaNs. The
FULL configs are exercised only via the dry-run (abstract, no allocation)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as tf
from repro.train.optim import TrainConfig
from repro.train.step import make_train_step, init_opt_state

# the arch zoo is ~4 min of compile-heavy smoke on CPU — nightly/full-lane
# material; the fast CI lane covers the model stack via test_models.py
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    b, s = 2, 32
    params = tf.init_params(KEY, cfg)
    batch = SyntheticLM(cfg, b, s, seed=1).batch(0)
    batch = jax.tree.map(jnp.asarray, batch)

    logits, _ = tf.forward(params, batch, cfg)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any(), f"{arch}: NaN logits"

    tcfg = TrainConfig(microbatches=2, total_steps=10, warmup_steps=2)
    step = make_train_step(cfg, tcfg)
    opt = init_opt_state(cfg, tcfg, params)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: bad loss"
    assert np.isfinite(float(metrics["grad_norm"])), f"{arch}: bad grad norm"
    # params actually changed
    delta = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))), params, p2)
    assert jax.tree.reduce(max, delta, 0.0) > 0, f"{arch}: no update"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_full_config_loads(arch):
    """FULL config: abstract init only (no allocation), sane dims."""
    cfg = get_config(arch, smoke=False)
    ap = tf.abstract_params(cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(ap))
    assert n > 1e8, f"{arch}: suspiciously small ({n})"
    if cfg.n_heads:
        assert cfg.d_model == cfg.n_heads * cfg.head_dim


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    """Serve path smoke: prefill (embeds for [vlm]/[audio] frontends, token ids
    otherwise) + 4 greedy decode steps for EVERY assigned architecture."""
    cfg = get_config(arch, smoke=True)
    params = tf.init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    if cfg.frontend != "none":
        batch = {"embeds": jnp.asarray(
            rng.standard_normal((2, 12, tf.frontend_dim(cfg))), jnp.float32)}
    else:
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)}
    lg, cache = tf.prefill(params, batch, cfg, cache_len=32)
    assert lg.shape == (2, cfg.vocab_size)
    for _ in range(4):
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        lg, cache = tf.decode_step(params, cache, nxt, cfg)
    assert np.isfinite(np.asarray(lg)).all()
