"""SpGEMMService: bucketed batched serving over chunked_spgemm_batched.

Contracts: correct results for mixed-structure workloads, at most one compile
per (geometry bucket, microbatch ladder width) pair (TRACE_COUNTS on the
batched cores), zero retraces for repeat traffic at already-seen widths, a
retrace budget that folds new geometries into existing buckets instead of
compiling more programs, and flush tails that execute at the smallest ladder
width that fits instead of paying for max_batch multiplies.
"""

import numpy as np
import pytest

from repro.core.chunk_stream import TRACE_COUNTS
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.planner import ChunkPlan, plan_knl
from repro.serve.spgemm_service import SpGEMMService
from repro.sparse.csr import csr_to_dense
from conftest import assert_close, random_csr


def _mixed_workload(rng, n, dim, densities):
    return [(random_csr(rng, dim, dim, densities[i % len(densities)]),
             random_csr(rng, dim, dim, densities[i % len(densities)]))
            for i in range(n)]


def test_service_mixed_structures_correct_and_one_compile_per_bucket():
    """Fast-lane heterogeneous case: mixed densities through one knl plan."""
    rng = np.random.default_rng(0)
    dim = 24
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=3, retrace_budget=8)
    assert svc.widths == [1, 2, 3]
    reqs = _mixed_workload(rng, 7, dim, [0.08, 0.25])
    before = TRACE_COUNTS["knl_batched"]
    ids = [svc.submit(A, B) for A, B in reqs]
    out = svc.flush()
    assert [r.req_id for r in out] == sorted(ids)
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
        assert resp.latency_s >= resp.exec_s > 0.0
        assert resp.stats.copy_in_bytes > 0
        # tails pad to the smallest ladder width that fits, never more
        assert resp.padded_batch == min(
            w for w in svc.widths if w >= resp.batch_size)
    # <= 1 compile per (bucket, ladder width), and the accounting agrees
    new = TRACE_COUNTS["knl_batched"] - before
    widths_total = sum(len(w) for *_rest, w in svc.bucket_summaries())
    assert new == svc.stats.compiles <= widths_total
    for *_rest, compiles, _execs, _served, widths in svc.bucket_summaries():
        assert compiles <= len(widths)
    # repeat traffic hitting the same structures *and* the same microbatch
    # widths: zero retraces
    mid = TRACE_COUNTS["knl_batched"]
    for A, B in _mixed_workload(rng, 7, dim, [0.08, 0.25]):
        svc.submit(A, B)
    out2 = svc.flush()
    assert len(out2) == 7
    assert TRACE_COUNTS["knl_batched"] == mid
    assert svc.pending == 0


def test_service_retrace_budget_folds_geometries():
    """With budget=2, many distinct structures still serve correctly through
    at most 2 compiled buckets (envelopes grow by union instead)."""
    rng = np.random.default_rng(7)
    dim = 24
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=8, max_batch=2, retrace_budget=2)
    reqs = _mixed_workload(rng, 8, dim, [0.03, 0.1, 0.2, 0.3, 0.4])
    for A, B in reqs:
        svc.submit(A, B)
    assert svc.n_buckets <= 2
    assert svc.stats.budget_merges > 0 and svc.stats.budget_overflows == 0
    out = svc.flush()
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)


def test_service_flush_tail_uses_ladder_width():
    """A short flush tail executes at the smallest ladder width that fits —
    a 1-request flush runs 1 multiply, not max_batch — and the padded width
    is visible in the response."""
    rng = np.random.default_rng(1)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=4, retrace_budget=4)
    assert svc.widths == [1, 2, 4]
    A, B = random_csr(rng, dim, dim, 0.2), random_csr(rng, dim, dim, 0.2)
    svc.submit(A, B)
    (resp,) = svc.flush()
    assert resp.batch_size == 1 and resp.padded_batch == 1
    assert svc.stats.padded_requests == 0
    # 5 identical requests: one full microbatch + a width-1 tail, no padding
    for _ in range(5):
        svc.submit(A, B)
    out = svc.flush()
    assert sorted(r.padded_batch for r in out) == [1, 4, 4, 4, 4]
    assert svc.stats.padded_requests == 0
    # 3 requests land on ladder width 4 with exactly one padded slot
    for _ in range(3):
        svc.submit(A, B)
    out = svc.flush()
    assert all(r.padded_batch == 4 and r.batch_size == 3 for r in out)
    assert svc.stats.padded_requests == 1
    # trace bound: compiles <= (bucket, width) pairs seen
    for *_rest, compiles, _execs, _served, widths in svc.bucket_summaries():
        assert compiles <= len(widths)


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_service_pallas_backend(algorithm):
    """backend="pallas": every bucket executable picks up the double-buffered
    prefetching kernel unchanged — oracle-correct results, compile accounting
    on the pallas batched trace keys, scan cores untouched."""
    rng = np.random.default_rng(9)
    dim = 20
    p_ac = (0, dim) if algorithm == "knl" else (0, dim // 2, dim)
    plan = ChunkPlan(algorithm, p_ac, (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=2, retrace_budget=8,
                        backend="pallas")
    counter = f"{algorithm}_pallas_batched"
    scan_counter = f"{algorithm}_batched"
    before, scan_before = TRACE_COUNTS[counter], TRACE_COUNTS[scan_counter]
    reqs = _mixed_workload(rng, 5, dim, [0.1, 0.3])
    for A, B in reqs:
        svc.submit(A, B)
    out = svc.flush()
    assert len(out) == 5
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    assert TRACE_COUNTS[counter] - before == svc.stats.compiles > 0
    assert TRACE_COUNTS[scan_counter] == scan_before
    for *_rest, compiles, _execs, _served, widths in svc.bucket_summaries():
        assert compiles <= len(widths)


def test_service_rejects_unknown_backend():
    plan = ChunkPlan("knl", (0, 8), (0, 8), 0.0, 0.0)
    with pytest.raises(ValueError, match="backend"):
        SpGEMMService(plan, backend="nope")


def test_service_requires_plan_or_limit_and_plans_itself():
    with pytest.raises(ValueError):
        SpGEMMService()
    rng = np.random.default_rng(3)
    dim = 20
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    limit = float(B.nbytes()) * 0.4
    svc = SpGEMMService(fast_limit_bytes=limit, max_batch=2)
    svc.submit(A, B)
    (resp,) = svc.flush()
    assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    # the derived plan matches what plan_knl would choose
    assert resp.bucket_key[1][0] == plan_knl(A, B, limit).algorithm == "knl"


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_service_large_mixed_sweep(algorithm):
    """Nightly sweep: bigger mixed-structure workloads across all three
    algorithms and several flush waves; every response matches the oracle and
    buckets never recompile after their first wave."""
    rng = np.random.default_rng(42)
    dim = 48
    p_ac = (0, dim) if algorithm == "knl" else (0, dim // 3, dim)
    plan = ChunkPlan(algorithm, p_ac, (0, dim // 3, 2 * dim // 3, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=64, max_batch=4, retrace_budget=6)
    counter = f"{algorithm}_batched"
    densities = [0.02, 0.08, 0.15, 0.25]
    n_widths = len(svc.widths)
    for _wave in range(3):
        reqs = _mixed_workload(rng, 10, dim, densities)
        traces0 = TRACE_COUNTS[counter]
        merges0 = svc.stats.budget_merges
        pairs0 = sum(len(w) for *_r, w in svc.bucket_summaries())
        for A, B in reqs:
            svc.submit(A, B)
        out = svc.flush()
        for (A, B), resp in zip(reqs, out):
            assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B),
                         atol=1e-3)
        # compiles this wave are bounded by the genuinely new (geometry,
        # ladder width) pairs plus envelope-growing merges (which retrace
        # already-seen widths once under the grown envelope)
        new_traces = TRACE_COUNTS[counter] - traces0
        pairs1 = sum(len(w) for *_r, w in svc.bucket_summaries())
        assert new_traces <= max(pairs1 - pairs0, 0) + n_widths * (
            svc.stats.budget_merges - merges0)
    # lifetime: every bucket compiled at most once per (envelope epoch, width)
    assert svc.stats.compiles <= n_widths * (svc.stats.buckets_created
                                             + svc.stats.budget_merges)
    assert svc.stats.served == 30
