"""SpGEMMService: bucketed batched serving over chunked_spgemm_batched.

Contracts: correct results for mixed-structure workloads, at most one compile
per (geometry bucket, microbatch ladder width) pair (TRACE_COUNTS on the
batched cores), zero retraces for repeat traffic at already-seen widths, a
retrace budget that folds new geometries into existing buckets instead of
compiling more programs, and flush tails that execute at the smallest ladder
width that fits instead of paying for max_batch multiplies.
"""

import numpy as np
import pytest

from repro.core.chunk_stream import TRACE_COUNTS
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.planner import ChunkPlan, plan_knl
from repro.serve.spgemm_service import SpGEMMService
from repro.sparse.csr import csr_to_dense
from conftest import assert_close, random_csr


def _mixed_workload(rng, n, dim, densities):
    return [(random_csr(rng, dim, dim, densities[i % len(densities)]),
             random_csr(rng, dim, dim, densities[i % len(densities)]))
            for i in range(n)]


def test_service_mixed_structures_correct_and_one_compile_per_bucket():
    """Fast-lane heterogeneous case: mixed densities through one knl plan."""
    rng = np.random.default_rng(0)
    dim = 24
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=3, retrace_budget=8)
    assert svc.widths == [1, 2, 3]
    reqs = _mixed_workload(rng, 7, dim, [0.08, 0.25])
    before = TRACE_COUNTS["knl_batched"]
    ids = [svc.submit(A, B) for A, B in reqs]
    out = svc.flush()
    assert [r.req_id for r in out] == sorted(ids)
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
        assert resp.latency_s >= resp.exec_s > 0.0
        assert resp.stats.copy_in_bytes > 0
        # tails pad to the smallest ladder width that fits, never more
        assert resp.padded_batch == min(
            w for w in svc.widths if w >= resp.batch_size)
    # <= 1 compile per (bucket, ladder width), and the accounting agrees
    new = TRACE_COUNTS["knl_batched"] - before
    widths_total = sum(len(w) for *_rest, w in svc.bucket_summaries())
    assert new == svc.stats.compiles <= widths_total
    for *_rest, compiles, _execs, _served, widths in svc.bucket_summaries():
        assert compiles <= len(widths)
    # repeat traffic hitting the same structures *and* the same microbatch
    # widths: zero retraces
    mid = TRACE_COUNTS["knl_batched"]
    for A, B in _mixed_workload(rng, 7, dim, [0.08, 0.25]):
        svc.submit(A, B)
    out2 = svc.flush()
    assert len(out2) == 7
    assert TRACE_COUNTS["knl_batched"] == mid
    assert svc.pending == 0


def test_service_retrace_budget_folds_geometries():
    """With budget=2, many distinct structures still serve correctly through
    at most 2 compiled buckets (envelopes grow by union instead)."""
    rng = np.random.default_rng(7)
    dim = 24
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=8, max_batch=2, retrace_budget=2)
    reqs = _mixed_workload(rng, 8, dim, [0.03, 0.1, 0.2, 0.3, 0.4])
    for A, B in reqs:
        svc.submit(A, B)
    assert svc.n_buckets <= 2
    assert svc.stats.budget_merges > 0 and svc.stats.budget_overflows == 0
    out = svc.flush()
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)


def test_service_flush_tail_uses_ladder_width():
    """A short flush tail executes at the smallest ladder width that fits —
    a 1-request flush runs 1 multiply, not max_batch — and the padded width
    is visible in the response."""
    rng = np.random.default_rng(1)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=4, retrace_budget=4)
    assert svc.widths == [1, 2, 4]
    A, B = random_csr(rng, dim, dim, 0.2), random_csr(rng, dim, dim, 0.2)
    svc.submit(A, B)
    (resp,) = svc.flush()
    assert resp.batch_size == 1 and resp.padded_batch == 1
    assert svc.stats.padded_requests == 0
    # 5 identical requests: one full microbatch + a width-1 tail, no padding
    for _ in range(5):
        svc.submit(A, B)
    out = svc.flush()
    assert sorted(r.padded_batch for r in out) == [1, 4, 4, 4, 4]
    assert svc.stats.padded_requests == 0
    # 3 requests land on ladder width 4 with exactly one padded slot
    for _ in range(3):
        svc.submit(A, B)
    out = svc.flush()
    assert all(r.padded_batch == 4 and r.batch_size == 3 for r in out)
    assert svc.stats.padded_requests == 1
    # trace bound: compiles <= (bucket, width) pairs seen
    for *_rest, compiles, _execs, _served, widths in svc.bucket_summaries():
        assert compiles <= len(widths)


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_service_pallas_backend(algorithm):
    """backend="pallas": every bucket executable picks up the double-buffered
    prefetching kernel unchanged — oracle-correct results, compile accounting
    on the pallas batched trace keys, scan cores untouched."""
    rng = np.random.default_rng(9)
    dim = 20
    p_ac = (0, dim) if algorithm == "knl" else (0, dim // 2, dim)
    plan = ChunkPlan(algorithm, p_ac, (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=2, retrace_budget=8,
                        backend="pallas")
    counter = f"{algorithm}_pallas_batched"
    scan_counter = f"{algorithm}_batched"
    before, scan_before = TRACE_COUNTS[counter], TRACE_COUNTS[scan_counter]
    reqs = _mixed_workload(rng, 5, dim, [0.1, 0.3])
    for A, B in reqs:
        svc.submit(A, B)
    out = svc.flush()
    assert len(out) == 5
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    assert TRACE_COUNTS[counter] - before == svc.stats.compiles > 0
    assert TRACE_COUNTS[scan_counter] == scan_before
    for *_rest, compiles, _execs, _served, widths in svc.bucket_summaries():
        assert compiles <= len(widths)


def test_service_rejects_unknown_backend():
    plan = ChunkPlan("knl", (0, 8), (0, 8), 0.0, 0.0)
    with pytest.raises(ValueError, match="backend"):
        SpGEMMService(plan, backend="nope")


def test_service_requires_plan_or_limit_and_plans_itself():
    with pytest.raises(ValueError):
        SpGEMMService()
    rng = np.random.default_rng(3)
    dim = 20
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    limit = float(B.nbytes()) * 0.4
    svc = SpGEMMService(fast_limit_bytes=limit, max_batch=2)
    svc.submit(A, B)
    (resp,) = svc.flush()
    assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    # the derived plan matches what plan_knl would choose
    assert resp.bucket_key[1][0] == plan_knl(A, B, limit).algorithm == "knl"


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_service_large_mixed_sweep(algorithm):
    """Nightly sweep: bigger mixed-structure workloads across all three
    algorithms and several flush waves; every response matches the oracle and
    buckets never recompile after their first wave."""
    rng = np.random.default_rng(42)
    dim = 48
    p_ac = (0, dim) if algorithm == "knl" else (0, dim // 3, dim)
    plan = ChunkPlan(algorithm, p_ac, (0, dim // 3, 2 * dim // 3, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=64, max_batch=4, retrace_budget=6)
    counter = f"{algorithm}_batched"
    densities = [0.02, 0.08, 0.15, 0.25]
    n_widths = len(svc.widths)
    for _wave in range(3):
        reqs = _mixed_workload(rng, 10, dim, densities)
        traces0 = TRACE_COUNTS[counter]
        merges0 = svc.stats.budget_merges
        pairs0 = sum(len(w) for *_r, w in svc.bucket_summaries())
        for A, B in reqs:
            svc.submit(A, B)
        out = svc.flush()
        for (A, B), resp in zip(reqs, out):
            assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B),
                         atol=1e-3)
        # compiles this wave are bounded by the genuinely new (geometry,
        # ladder width) pairs plus envelope-growing merges (which retrace
        # already-seen widths once under the grown envelope)
        new_traces = TRACE_COUNTS[counter] - traces0
        pairs1 = sum(len(w) for *_r, w in svc.bucket_summaries())
        assert new_traces <= max(pairs1 - pairs0, 0) + n_widths * (
            svc.stats.budget_merges - merges0)
    # lifetime: every bucket compiled at most once per (envelope epoch, width)
    assert svc.stats.compiles <= n_widths * (svc.stats.buckets_created
                                             + svc.stats.budget_merges)
    assert svc.stats.served == 30


# ---------------------------------------------------------------------------
# continuous-batching serving: async API, SLO, admission, eviction
# ---------------------------------------------------------------------------


def _banded_csr(dim, k, val=1.0):
    """Deterministic CSR with exactly ``k`` nonzeros (cols 0..k-1) per row.

    Pairs built as ``(banded(ka), banded(kb))`` with ``ka`` increasing and
    ``kb`` decreasing give pairwise *incomparable* instance envelopes (the A
    caps grow while the B/strip/output caps shrink), so each pair lands in
    its own bucket instead of a dominated hit — the deterministic scaffolding
    the eviction/priority/dominator tests below stand on.
    """
    from repro.sparse.csr import csr_from_dense

    d = np.zeros((dim, dim), np.float32)
    d[:, :k] = val
    return csr_from_dense(d)


def test_service_compile_exec_split():
    """compile_s carries the cold-trace cost; exec_s never does. The second
    flush of the same (bucket, width) reports compile_s == 0.0 exactly."""
    rng = np.random.default_rng(5)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=2, retrace_budget=4)
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    svc.submit(A, B)
    svc.submit(A, B)
    out = svc.flush()
    assert all(r.compile_s > 0.0 for r in out)       # cold: warmup paid here
    assert all(r.exec_s > 0.0 for r in out)
    assert svc.stats.compile_s > 0.0
    # warm wave: identical geometry and width — no trace, no compile time
    before = TRACE_COUNTS["knl_batched"]
    svc.submit(A, B)
    svc.submit(A, B)
    out2 = svc.flush()
    assert TRACE_COUNTS["knl_batched"] == before
    assert all(r.compile_s == 0.0 for r in out2)
    assert all(r.exec_s > 0.0 for r in out2)


def test_service_tightest_dominator_minimizes_padding():
    """A request dominated by several buckets lands in the one with minimal
    staged bytes (least padding waste), and the waste is accounted."""
    dim = 12
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=1, max_batch=1, retrace_budget=8)
    # two incomparable buckets: A-heavy (ka=4, kb=1) and B-heavy (ka=1, kb=4)
    svc.submit(_banded_csr(dim, 4, 2.0), _banded_csr(dim, 1, 3.0))
    svc.submit(_banded_csr(dim, 1, 2.0), _banded_csr(dim, 4, 3.0))
    assert svc.n_buckets == 2 and svc.stats.dominated_hits == 0
    envs = [b[0] for b in svc.bucket_summaries()]
    tight = min(envs, key=lambda e: e.staged_nbytes())
    # (ka=1, kb=1) is dominated by both; must resolve into the tighter one
    A_s, B_s = _banded_csr(dim, 1, 5.0), _banded_csr(dim, 1, 7.0)
    svc.submit(A_s, B_s)
    assert svc.n_buckets == 2 and svc.stats.dominated_hits == 1
    assert svc.stats.dominated_padding_bytes > 0
    out = svc.drain()
    assert out[-1].bucket_key[0] == tight
    for (A, B), resp in zip(
            [(_banded_csr(dim, 4, 2.0), _banded_csr(dim, 1, 3.0)),
             (_banded_csr(dim, 1, 2.0), _banded_csr(dim, 4, 3.0)),
             (A_s, B_s)], out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)


def test_service_sentinel_tail_padding():
    """Flush tails pad with envelope-shaped *empty* sentinels, not a replay
    of a live request; padded outputs never reach responses."""
    rng = np.random.default_rng(11)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=4, retrace_budget=4)
    reqs = [(random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3))
            for _ in range(3)]
    ids = [svc.submit(A, B) for A, B in reqs]
    out = svc.flush()
    assert [r.req_id for r in out] == ids          # only real requests answered
    assert all(r.batch_size == 3 and r.padded_batch == 4 for r in out)
    assert svc.stats.padded_requests == 1
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    (bucket,) = svc._buckets.values()
    A0, B0 = bucket.sentinel                       # cached by the padded flush
    assert int(A0.indptr[-1]) == 0 and int(B0.indptr[-1]) == 0
    assert A0.shape == bucket.envelope.a_shape
    assert B0.shape == bucket.envelope.b_shape


def test_service_bounded_eviction_and_refault():
    """With eviction enabled, the retrace budget is a hard working-set bound:
    more distinct geometries than budget end with n_buckets <= budget, idle
    buckets evicted LRU-first, and an evicted geometry that returns refaults
    (recompiles exactly once)."""
    dim = 12
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=1, max_batch=1, retrace_budget=3,
                        eviction_hysteresis=0)
    pairs = [(_banded_csr(dim, i + 1, float(i + 1)),
              _banded_csr(dim, 6 - i, 1.0)) for i in range(6)]
    before = TRACE_COUNTS["knl_batched"]
    for A, B in pairs:
        svc.submit(A, B)
        (resp,) = svc.drain()
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
        assert svc.n_buckets <= 3
    assert svc.n_buckets == 3
    assert svc.stats.buckets_created == 6
    assert svc.stats.evictions == 3 and svc.stats.refaults == 0
    assert svc.stats.budget_merges == 0 and svc.stats.budget_overflows == 0
    # one compile per bucket created (single ladder width), and the eviction
    # bound holds with equality: compiles == budget + evictions
    new = TRACE_COUNTS["knl_batched"] - before
    assert new == svc.stats.compiles == svc.stats.buckets_created
    assert svc.stats.compiles <= svc.retrace_budget + svc.stats.evictions
    # geometry 0 was evicted: its return is a refault (recompiles once) ...
    A, B = pairs[0]
    svc.submit(A, B)
    (resp,) = svc.drain()
    assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    assert svc.stats.refaults == 1 and svc.stats.evictions == 4
    assert TRACE_COUNTS["knl_batched"] - before == 7
    # ... and is then resident: an immediate repeat is a free exact hit
    svc.submit(A, B)
    svc.drain()
    assert TRACE_COUNTS["knl_batched"] - before == 7
    assert svc.stats.buckets_created == 7 and svc.n_buckets == 3


def test_service_poll_slo_and_priority():
    """poll() only flushes due buckets (full microbatch or SLO breach) and
    walks them oldest-deadline-first, not dict insertion order."""
    import time as _time

    dim = 12
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    # no SLO: a partial queue is not due
    svc = SpGEMMService(plan, quantum=1, max_batch=2, retrace_budget=8)
    a_pair = (_banded_csr(dim, 4, 2.0), _banded_csr(dim, 1, 3.0))
    b_pair = (_banded_csr(dim, 1, 2.0), _banded_csr(dim, 4, 3.0))
    svc.submit(*a_pair)
    assert svc.poll() == [] and svc.pending == 1
    svc.submit(*a_pair)                         # queue reaches max_batch
    out = svc.poll()
    assert [r.req_id for r in out] == [0, 1] and svc.pending == 0
    assert svc.stats.slo_flushes == 0
    # SLO service: bucket A is *older in the dict*, bucket B has the *older
    # queued request* — poll must execute B first
    svc2 = SpGEMMService(plan, quantum=1, max_batch=4, retrace_budget=8,
                         slo_s=0.0)
    svc2.submit(*a_pair)
    svc2.submit(*b_pair)
    svc2.drain()                                # both buckets exist, idle
    svc2.submit(*b_pair)                        # req 2: oldest deadline
    svc2.submit(*a_pair)                        # req 3: newer, earlier bucket
    _time.sleep(0.01)
    out = svc2.poll()
    assert [r.req_id for r in out] == [2, 3]    # execution order, B first
    assert svc2.stats.slo_flushes == 2


def test_service_admission_shed_and_flush():
    from repro.serve.spgemm_service import AdmissionError

    rng = np.random.default_rng(13)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    svc = SpGEMMService(plan, max_batch=4, max_pending=2, admission="shed")
    svc.submit(A, B)
    svc.submit(A, B)
    with pytest.raises(AdmissionError):
        svc.submit(A, B)
    assert svc.stats.shed == 1 and svc.pending == 2
    assert len(svc.drain()) == 2
    # admission="flush" makes room by draining the oldest-deadline bucket;
    # its responses surface through the futures and the next poll/drain
    svc2 = SpGEMMService(plan, max_batch=4, max_pending=2, admission="flush")
    f0 = svc2.submit(A, B)
    f1 = svc2.submit(A, B)
    f2 = svc2.submit(A, B)
    assert svc2.stats.admission_flushes == 1 and svc2.stats.shed == 0
    assert f0.done() and f1.done() and not f2.done()
    assert svc2.pending == 1
    out = svc2.poll()                          # carries the forced responses
    assert [r.req_id for r in out] == [0, 1]
    resp2 = f2.result()                        # drains the remaining request
    assert resp2.req_id == 2 and f2.done()
    assert_close(csr_to_dense(resp2.C), spgemm_dense_oracle(A, B), atol=1e-3)


def test_service_future_api():
    """submit() returns a future that *is* the request id (int subclass)."""
    rng = np.random.default_rng(17)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, max_batch=2)
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    fut = svc.submit(A, B)
    assert fut == 0 and isinstance(fut, int) and not fut.done()
    resp = fut.result()                        # forces the drain
    assert fut.done() and resp.req_id == fut
    assert fut.result() is resp                # idempotent once resolved
    assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)


def test_service_learned_tail_width():
    """A recurring flush-tail size earns an exact ladder width: one compile,
    zero padding for that tail thereafter."""
    rng = np.random.default_rng(19)
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=4, retrace_budget=4,
                        learn_tail_widths=True, tail_learn_threshold=2)
    assert svc.widths == [1, 2, 4]
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    for _ in range(3):
        svc.submit(A, B)
    out = svc.flush()
    assert all(r.padded_batch == 4 for r in out)   # first time: pad to 4
    assert svc.stats.padded_requests == 1
    for _ in range(3):
        svc.submit(A, B)
    out = svc.flush()                              # threshold hit: exact width
    assert svc.widths == [1, 2, 3, 4] and svc.stats.learned_widths == 1
    assert all(r.padded_batch == 3 for r in out)
    assert svc.stats.padded_requests == 1          # no new padding
    before = TRACE_COUNTS["knl_batched"]
    for _ in range(3):
        svc.submit(A, B)
    svc.flush()                                    # learned width is warm now
    assert TRACE_COUNTS["knl_batched"] == before


def test_service_adaptive_quantum():
    """Churny families coarsen their envelope quantum; stable families
    tighten it back."""
    dim = 16
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=8, max_batch=1, retrace_budget=32,
                        adapt_quantum=True)
    # 16 pairwise-incomparable geometries: every submit is a bucket miss
    for i in range(16):
        svc.submit(_banded_csr(dim, i + 1, 1.0), _banded_csr(dim, 16 - i, 1.0))
    (q,) = svc._family_quanta.values()
    assert q == 16                                 # churny: coarsened 8 -> 16
    # 16 repeats of one geometry: at most the first is a miss, the rest hit
    A, B = _banded_csr(dim, 1, 1.0), _banded_csr(dim, 16, 1.0)
    for _ in range(16):
        svc.submit(A, B)
    (q,) = svc._family_quanta.values()
    assert q == 8                                  # stable: tightened back


def test_service_replan_lagging_buckets():
    """Observed latency feeds back into planning: a bucket over the SLO gets
    a coarser streamed-B partition, queued work is re-routed, and future
    submits pick up the override."""
    rng = np.random.default_rng(23)
    dim = 18
    plan = ChunkPlan("knl", (0, dim), (0, 6, 12, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=2, retrace_budget=8)
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    with pytest.raises(ValueError):
        svc.replan_lagging_buckets()               # no SLO anywhere
    svc.submit(A, B)
    svc.drain()                                    # sets the bucket's ewma
    svc.submit(A, B)                               # queued under the old plan
    assert svc.replan_lagging_buckets(slo_s=0.0) == 1
    assert svc.stats.replans == 1 and svc.pending == 1
    out = svc.drain()                              # re-routed request runs
    assert out[0].bucket_key[1] == ("knl", (0, dim), (0, 12, dim))
    assert_close(csr_to_dense(out[0].C), spgemm_dense_oracle(A, B), atol=1e-3)
    # the override sticks for future planning of the same plan key
    A2, B2 = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    svc.submit(A2, B2)
    out = svc.drain()
    assert out[0].bucket_key[1] == ("knl", (0, dim), (0, 12, dim))
