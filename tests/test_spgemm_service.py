"""SpGEMMService: bucketed batched serving over chunked_spgemm_batched.

Contracts: correct results for mixed-structure workloads, at most one compile
per geometry bucket (TRACE_COUNTS on the batched scan cores), zero retraces
for repeat traffic, and a retrace budget that folds new geometries into
existing buckets instead of compiling more programs.
"""

import numpy as np
import pytest

from repro.core.chunk_stream import TRACE_COUNTS
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.planner import ChunkPlan, plan_knl
from repro.serve.spgemm_service import SpGEMMService
from repro.sparse.csr import csr_to_dense
from conftest import assert_close, random_csr


def _mixed_workload(rng, n, dim, densities):
    return [(random_csr(rng, dim, dim, densities[i % len(densities)]),
             random_csr(rng, dim, dim, densities[i % len(densities)]))
            for i in range(n)]


def test_service_mixed_structures_correct_and_one_compile_per_bucket():
    """Fast-lane heterogeneous case: mixed densities through one knl plan."""
    rng = np.random.default_rng(0)
    dim = 24
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=32, max_batch=3, retrace_budget=8)
    reqs = _mixed_workload(rng, 7, dim, [0.08, 0.25])
    before = TRACE_COUNTS["knl_batched"]
    ids = [svc.submit(A, B) for A, B in reqs]
    out = svc.flush()
    assert [r.req_id for r in out] == sorted(ids)
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
        assert resp.latency_s >= resp.exec_s > 0.0
        assert resp.stats.copy_in_bytes > 0
    # <= 1 compile per geometry bucket, and the service's own accounting agrees
    new = TRACE_COUNTS["knl_batched"] - before
    assert new == svc.stats.compiles <= svc.n_buckets
    for _env, _alg, compiles, _execs, _served in svc.bucket_summaries():
        assert compiles <= 1
    # repeat traffic with the same structures: zero retraces
    mid = TRACE_COUNTS["knl_batched"]
    for A, B in _mixed_workload(rng, 4, dim, [0.08, 0.25]):
        svc.submit(A, B)
    out2 = svc.flush()
    assert len(out2) == 4
    assert TRACE_COUNTS["knl_batched"] == mid
    assert svc.pending == 0


def test_service_retrace_budget_folds_geometries():
    """With budget=2, many distinct structures still serve correctly through
    at most 2 compiled buckets (envelopes grow by union instead)."""
    rng = np.random.default_rng(7)
    dim = 24
    plan = ChunkPlan("knl", (0, dim), (0, dim // 2, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=8, max_batch=2, retrace_budget=2)
    reqs = _mixed_workload(rng, 8, dim, [0.03, 0.1, 0.2, 0.3, 0.4])
    for A, B in reqs:
        svc.submit(A, B)
    assert svc.n_buckets <= 2
    assert svc.stats.budget_merges > 0 and svc.stats.budget_overflows == 0
    out = svc.flush()
    for (A, B), resp in zip(reqs, out):
        assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)


def test_service_requires_plan_or_limit_and_plans_itself():
    with pytest.raises(ValueError):
        SpGEMMService()
    rng = np.random.default_rng(3)
    dim = 20
    A, B = random_csr(rng, dim, dim, 0.3), random_csr(rng, dim, dim, 0.3)
    limit = float(B.nbytes()) * 0.4
    svc = SpGEMMService(fast_limit_bytes=limit, max_batch=2)
    svc.submit(A, B)
    (resp,) = svc.flush()
    assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B), atol=1e-3)
    # the derived plan matches what plan_knl would choose
    assert resp.bucket_key[1][0] == plan_knl(A, B, limit).algorithm == "knl"


@pytest.mark.slow
@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_service_large_mixed_sweep(algorithm):
    """Nightly sweep: bigger mixed-structure workloads across all three
    algorithms and several flush waves; every response matches the oracle and
    buckets never recompile after their first wave."""
    rng = np.random.default_rng(42)
    dim = 48
    p_ac = (0, dim) if algorithm == "knl" else (0, dim // 3, dim)
    plan = ChunkPlan(algorithm, p_ac, (0, dim // 3, 2 * dim // 3, dim), 0.0, 0.0)
    svc = SpGEMMService(plan, quantum=64, max_batch=4, retrace_budget=6)
    counter = f"{algorithm}_batched"
    densities = [0.02, 0.08, 0.15, 0.25]
    for wave in range(3):
        reqs = _mixed_workload(rng, 10, dim, densities)
        traces0 = TRACE_COUNTS[counter]
        created0 = svc.stats.buckets_created
        merges0 = svc.stats.budget_merges
        for A, B in reqs:
            svc.submit(A, B)
        out = svc.flush()
        for (A, B), resp in zip(reqs, out):
            assert_close(csr_to_dense(resp.C), spgemm_dense_oracle(A, B),
                         atol=1e-3)
        # compiles this wave are bounded by the geometries that are genuinely
        # new to it: freshly created buckets plus envelope-growing merges
        new_traces = TRACE_COUNTS[counter] - traces0
        assert new_traces <= (svc.stats.buckets_created - created0
                              + svc.stats.budget_merges - merges0)
    # lifetime: every bucket compiled at most once per envelope it has had
    assert svc.stats.compiles <= (svc.stats.buckets_created
                                  + svc.stats.budget_merges)
    assert svc.stats.served == 30
