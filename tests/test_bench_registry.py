"""Bench-driver registry hygiene: every lane listed exactly once, JSON lanes
wired through the driver, and the CI-parsed lanes present under the names the
workflow invokes — the drift this guards against is a renamed lane leaving a
stale SUITES entry (double-run) or none (silently dropped from full runs).
"""

import collections
import pathlib

from benchmarks.chunking_bench import JSON_LANES
from benchmarks.run import SUITES, _resolve


def test_suites_list_every_lane_exactly_once():
    """Lane names are unique by dict construction; the drift that can happen
    is two names pointing at the same module:function (one lane run twice
    per full sweep)."""
    specs = collections.Counter(SUITES.values())
    dupes = {spec: n for spec, n in specs.items() if n > 1}
    assert not dupes, f"lanes registered more than once: {dupes}"


def test_every_suite_spec_resolves():
    for name, spec in SUITES.items():
        fn = _resolve(spec)
        assert callable(fn), f"{name}: {spec} did not resolve to a callable"


def test_json_lanes_have_driver_entries():
    """Each chunking JSON lane (what `--lane` and the CI smoke parse run)
    also runs under a full `python -m benchmarks.run` via a CSV wrapper."""
    for lane in JSON_LANES:
        assert lane in SUITES, f"JSON lane {lane!r} missing from run.SUITES"
    assert "accumulator_shootout" in JSON_LANES
    assert "bsr_blocking" in JSON_LANES
    assert "dense_vs_sparse_accum" not in SUITES, \
        "stale pre-shootout lane name still registered"


def test_ci_smokes_every_json_lane():
    """Registry-driven CI gating: the workflow must carry a smoke step (and
    artifact capture) for every registered JSON lane, so registering a lane
    without wiring its CI gate fails the fast test lane — the same
    add-one-registration contract the backend registry gives executors."""
    ci = (pathlib.Path(__file__).resolve().parents[1]
          / ".github" / "workflows" / "ci.yml").read_text()
    for lane in JSON_LANES:
        if lane == "scan_vs_pallas":
            continue                     # the workflow's default (laneless) run
        assert f"--lane {lane}" in ci, \
            f"JSON lane {lane!r} has no smoke step in .github/workflows/ci.yml"
    assert "upload-artifact" in ci, "bench artifacts are not uploaded by CI"
    assert "bench_trajectory" in ci, \
        "bench trajectory persistence step missing from CI"
