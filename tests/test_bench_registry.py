"""Bench-driver registry hygiene: every lane listed exactly once, JSON lanes
wired through the driver, and the CI-parsed lanes present under the names the
workflow invokes — the drift this guards against is a renamed lane leaving a
stale SUITES entry (double-run) or none (silently dropped from full runs).
"""

import collections
import pathlib

from benchmarks.chunking_bench import JSON_LANES as CHUNKING_LANES
from benchmarks.run import SUITES, _resolve
from benchmarks.triangle_counting import JSON_LANES as TRIANGLE_LANES

JSON_LANES = {**CHUNKING_LANES, **TRIANGLE_LANES}


def test_json_lane_names_globally_unique():
    """`--lane` names double as bench-artifact filenames and trajectory keys,
    so two modules must never register the same lane name."""
    overlap = set(CHUNKING_LANES) & set(TRIANGLE_LANES)
    assert not overlap, f"lane names registered by two modules: {overlap}"


def test_suites_list_every_lane_exactly_once():
    """Lane names are unique by dict construction; the drift that can happen
    is two names pointing at the same module:function (one lane run twice
    per full sweep)."""
    specs = collections.Counter(SUITES.values())
    dupes = {spec: n for spec, n in specs.items() if n > 1}
    assert not dupes, f"lanes registered more than once: {dupes}"


def test_every_suite_spec_resolves():
    for name, spec in SUITES.items():
        fn = _resolve(spec)
        assert callable(fn), f"{name}: {spec} did not resolve to a callable"


def test_json_lanes_have_driver_entries():
    """Each JSON lane (what `--lane` and the CI smoke parse run) also runs
    under a full `python -m benchmarks.run` via a CSV wrapper."""
    for lane in JSON_LANES:
        assert lane in SUITES, f"JSON lane {lane!r} missing from run.SUITES"
    assert "accumulator_shootout" in JSON_LANES
    assert "bsr_blocking" in JSON_LANES
    assert "triangle_counting" in JSON_LANES
    assert "dense_vs_sparse_accum" not in SUITES, \
        "stale pre-shootout lane name still registered"
    assert "fig11" not in SUITES, \
        "stale pre-JSON-lane triangle suite name still registered"


def test_ci_smokes_every_json_lane():
    """Registry-driven CI gating: the workflow must carry a smoke step (and
    artifact capture) for every registered JSON lane, so registering a lane
    without wiring its CI gate fails the fast test lane — the same
    add-one-registration contract the backend registry gives executors."""
    ci = (pathlib.Path(__file__).resolve().parents[1]
          / ".github" / "workflows" / "ci.yml").read_text()
    for lane in JSON_LANES:
        if lane == "scan_vs_pallas":
            continue                     # the workflow's default (laneless) run
        assert f"--lane {lane}" in ci, \
            f"JSON lane {lane!r} has no smoke step in .github/workflows/ci.yml"
    assert "upload-artifact" in ci, "bench artifacts are not uploaded by CI"
    assert "bench_trajectory" in ci, \
        "bench trajectory persistence step missing from CI"


def test_triangle_speedup_is_lane_level_scalar():
    """The chunked-vs-kkmem speedup must survive trajectory summarization
    verbatim, which `tools/bench_trajectory.py` only guarantees for
    lane-level non-list scalars — run the smoke lane and summarize it."""
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "tools"))
    from bench_trajectory import summarize

    from benchmarks.triangle_counting import run_triangle_counting

    report = run_triangle_counting(smoke=True)
    assert report["bench"] == "triangle_counting"
    assert isinstance(report["chunked_vs_kkmem_speedup"], float)
    assert report["chunked_vs_kkmem_speedup"] > 0
    assert report["rows"], "smoke lane emitted no rows"
    summary = summarize(report)
    assert summary["chunked_vs_kkmem_speedup"] == \
        report["chunked_vs_kkmem_speedup"]
