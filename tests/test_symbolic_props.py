"""Property tests: the symbolic phase's output caps are *exact*.

Both sparse-output backends (ESC and hash) size fixed-capacity VMEM scratch —
the CSR accumulator at ``c_pad``/``c_nnz_cap``, the per-row hash tables at
``hash_table_slots(c_max_row_nnz)`` — from ``repro.core.symbolic``. Their
no-overflow guarantee is exactly the claim tested here: the symbolic counts
equal the **realized** output structure of the loop oracle (and of an
independent boolean-pattern product, which is immune to numeric
cancellation), across random ``csr_pair`` draws. Follows the
``tests/conftest.py`` hypothesis-optional pattern: with hypothesis absent the
``@given(csr_pair())`` tests run over the seeded parametrize fallback.
"""

import numpy as np

from repro.core.chunking import chunked_spgemm, default_c_pad
from repro.core.planner import ChunkPlan, hash_table_slots
from repro.core.symbolic import (
    _round_up, spgemm_structure_host, strip_output_caps,
)
from repro.sparse.csr import csr_to_dense
from conftest import csr_pair, given, settings


def _pattern_structure(A, B):
    """Independent structural oracle: boolean pattern product (cancellation-
    proof, unlike a value product)."""
    pa = np.asarray(csr_to_dense(A)) != 0
    pb = np.asarray(csr_to_dense(B)) != 0
    pc = pa.astype(np.int64) @ pb.astype(np.int64) > 0
    return pc.sum(axis=1)


def _thirds(n):
    return (0, n) if n < 3 else (0, n // 3, 2 * n // 3, n)


@settings(deadline=None, max_examples=20)
@given(csr_pair())
def test_symbolic_structure_matches_pattern_product(pair):
    """per_row_nnz / c_nnz / c_max_row_nnz are exactly the boolean-pattern
    product's realized structure — no over- or under-estimate."""
    A, B = pair
    s = spgemm_structure_host(A, B)
    per_row = _pattern_structure(A, B)
    np.testing.assert_array_equal(np.asarray(s.per_row_nnz), per_row)
    assert s.c_nnz == int(per_row.sum())
    assert s.c_max_row_nnz == (int(per_row.max()) if per_row.size else 0)


@settings(deadline=None, max_examples=10)
@given(csr_pair(max_dim=16))
def test_symbolic_structure_matches_loop_oracle(pair):
    """The loop executor's realized output structure (its CSR keeps every
    structural entry, even value-cancelled ones) equals the symbolic counts
    row for row — the invariant that makes the fixed-capacity accumulators
    overflow-free."""
    A, B = pair
    plan = ChunkPlan("chunk1", _thirds(A.n_rows), _thirds(B.n_rows), 0.0, 0.0)
    C, _ = chunked_spgemm(A, B, plan, default_c_pad(A, B, plan),
                          backend="loop")
    realized = np.asarray(C.indptr[1:]) - np.asarray(C.indptr[:-1])
    s = spgemm_structure_host(A, B)
    np.testing.assert_array_equal(realized, np.asarray(s.per_row_nnz))


@settings(deadline=None, max_examples=20)
@given(csr_pair())
def test_strip_output_caps_exact_partial_sums(pair):
    """strip_output_caps is the symbolic structure re-expressed per strip:
    strip nnz are exact partial sums (so they total c_nnz), c_pad is the
    rounded largest strip, c_nnz_cap the rounded total, and the hash-table
    sizing from c_max_row_nnz always covers the densest realized row."""
    A, B = pair
    p_ac = _thirds(A.n_rows)
    caps = strip_output_caps(A, B, p_ac)
    s = spgemm_structure_host(A, B)
    per_row = np.asarray(s.per_row_nnz)
    expected = tuple(int(per_row[lo:hi].sum())
                     for lo, hi in zip(p_ac[:-1], p_ac[1:]))
    assert caps.strip_nnz == expected
    assert sum(caps.strip_nnz) == s.c_nnz
    assert caps.c_pad == _round_up(max(caps.strip_nnz), 64)
    assert caps.c_nnz_cap == _round_up(s.c_nnz, 64)
    assert caps.c_max_row_nnz == s.c_max_row_nnz
    slots = hash_table_slots(caps.c_max_row_nnz)
    assert slots >= max(caps.c_max_row_nnz, 1)
    assert slots & (slots - 1) == 0
