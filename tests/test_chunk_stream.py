"""Scan and Pallas executors (repro.core.chunk_stream) vs the loop oracle.

The contract: for every algorithm and every plan, the device-resident scan
executor produces the *identical* CSR (structure and values, bit-for-bit) and
the *identical* modeled per-copy byte event sequence as the host-driven loop,
while compiling its chunk loop O(1) times regardless of the chunk count. The
Pallas double-buffered backend accumulates densely (explicit DMA prefetch of
the streamed operand), so its contract is allclose to the oracle at matched
``c_pad`` — same O(1) trace bound, its own per-copy event model
(``planned_stats_pallas``).
"""

import numpy as np
import pytest

from repro.core.chunk_stream import (
    TRACE_COUNTS, chunk_gpu1_pallas, chunk_gpu1_scan, chunk_gpu2_pallas,
    chunk_gpu2_scan, chunk_knl_pallas, chunk_knl_scan, chunked_spgemm_batched,
    planned_stats_pallas,
)
from repro.core.chunking import (
    batch_envelope, chunk_gpu1, chunk_gpu2, chunk_knl, chunked_spgemm,
    instance_envelope,
)
from repro.core.kkmem import spgemm_dense_oracle, spgemm_symbolic_host
from repro.core.planner import ChunkPlan, plan_knl
from repro.sparse import multigrid
from repro.sparse.csr import csr_from_dense, csr_to_dense
from conftest import assert_close, csr_pair_cases, random_csr

LOOP = {"knl": chunk_knl, "chunk1": chunk_gpu1, "chunk2": chunk_gpu2}
SCAN = {"knl": chunk_knl_scan, "chunk1": chunk_gpu1_scan, "chunk2": chunk_gpu2_scan}
PALLAS = {"knl": chunk_knl_pallas, "chunk1": chunk_gpu1_pallas,
          "chunk2": chunk_gpu2_pallas}


def _random_plan(algorithm, A, B, rng):
    """A random-but-valid plan: contiguous row partitions of A/C and B."""
    def cuts(n, max_parts):
        k = int(rng.integers(1, max_parts + 1))
        inner = sorted(set(rng.integers(1, n, size=k - 1).tolist())) if n > 1 else []
        return tuple([0] + inner + [n])

    p_ac = (0, A.n_rows) if algorithm == "knl" else cuts(A.n_rows, 4)
    p_b = cuts(B.n_rows, 4)
    return ChunkPlan(algorithm, p_ac, p_b, copy_bytes=0.0, fast_bytes_needed=0.0)


def _assert_same_csr(Cl, Cs):
    assert Cl.shape == Cs.shape
    np.testing.assert_array_equal(np.asarray(Cl.indptr), np.asarray(Cs.indptr))
    np.testing.assert_array_equal(np.asarray(Cl.indices), np.asarray(Cs.indices))
    np.testing.assert_array_equal(np.asarray(Cl.data), np.asarray(Cs.data))


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_scan_matches_loop_random_plans(algorithm):
    """Property: identical CSRs and identical per-copy byte events across
    random matrices x random plans."""
    rng = np.random.default_rng(7)
    for i, (A, B) in enumerate(csr_pair_cases(n_examples=5, max_dim=18, seed=3)):
        plan = _random_plan(algorithm, A, B, rng)
        c_pad = spgemm_symbolic_host(A, B).c_pad
        Cl, sl = LOOP[algorithm](A, B, plan, c_pad)
        Cs, ss = SCAN[algorithm](A, B, plan, c_pad)
        _assert_same_csr(Cl, Cs)
        assert sl.per_copy_in == ss.per_copy_in, f"case {i}"
        assert sl.per_copy_out == ss.per_copy_out, f"case {i}"
        assert sl.copy_in_bytes == ss.copy_in_bytes
        assert sl.copy_out_bytes == ss.copy_out_bytes
        assert sl.kernel_calls == ss.kernel_calls
        assert_close(csr_to_dense(Cs), spgemm_dense_oracle(A, B), atol=1e-3,
                     msg=f"case {i}")


@pytest.mark.parametrize("algorithm", ["chunk1", "chunk2"])
def test_scan_matches_loop_2d_plans(algorithm):
    """Both 2-D streaming orders on a real multigrid problem."""
    A, R, P = multigrid.problem("brick3d", 5)
    ws = spgemm_symbolic_host(A, P)
    n_a, n_b = A.n_rows, P.n_rows
    plan = ChunkPlan(algorithm,
                     (0, n_a // 3, 2 * n_a // 3, n_a),
                     (0, n_b // 4, n_b // 2, n_b),
                     copy_bytes=0.0, fast_bytes_needed=0.0)
    Cl, sl = LOOP[algorithm](A, P, plan, ws.c_pad)
    Cs, ss = SCAN[algorithm](A, P, plan, ws.c_pad)
    _assert_same_csr(Cl, Cs)
    assert sl.per_copy_in == ss.per_copy_in
    assert sl.per_copy_out == ss.per_copy_out
    assert_close(csr_to_dense(Cs), spgemm_dense_oracle(A, P), atol=1e-4)


def test_dispatcher_backends_agree():
    A, R, P = multigrid.problem("laplace3d", 6)
    plan = plan_knl(A, P, fast_limit_bytes=P.nbytes() * 0.3)
    assert plan.n_b >= 2
    Cl, sl = chunked_spgemm(A, P, plan, backend="loop")
    Cs, ss = chunked_spgemm(A, P, plan, backend="scan")
    Cp, sp = chunked_spgemm(A, P, plan, backend="pallas")
    _assert_same_csr(Cl, Cs)
    assert sl.copy_bytes == ss.copy_bytes
    assert_close(csr_to_dense(Cp), csr_to_dense(Cl), atol=1e-4)
    assert sp.kernel_calls == sl.kernel_calls
    with pytest.raises(ValueError):
        chunked_spgemm(A, P, plan, backend="nope")


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_three_way_backends_agree_random_plans(algorithm):
    """Loop, scan, and Pallas backends on the same random matrices x random
    plans (seeded-parametrize pattern — runs without hypothesis): scan is
    bitwise-equal to loop, Pallas is allclose at matched c_pad (dense
    accumulation reorders the float adds), all three match the dense oracle."""
    rng = np.random.default_rng(23)
    for i, (A, B) in enumerate(csr_pair_cases(n_examples=4, max_dim=14,
                                              seed=29)):
        plan = _random_plan(algorithm, A, B, rng)
        c_pad = spgemm_symbolic_host(A, B).c_pad
        Cl, sl = LOOP[algorithm](A, B, plan, c_pad)
        Cs, ss = SCAN[algorithm](A, B, plan, c_pad)
        Cp, sp = PALLAS[algorithm](A, B, plan, c_pad)
        _assert_same_csr(Cl, Cs)
        ref = spgemm_dense_oracle(A, B)
        assert_close(csr_to_dense(Cp), csr_to_dense(Cl), atol=1e-3,
                     msg=f"case {i}")
        assert_close(csr_to_dense(Cp), ref, atol=1e-3, msg=f"case {i}")
        # same multiply schedule, pallas' own staging event model
        assert sp.kernel_calls == sl.kernel_calls == ss.kernel_calls
        assert len(sp.per_copy_in) >= plan.n_b       # every chunk staged
        assert sp.copy_in_bytes > 0


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_pallas_compiles_once_per_geometry(algorithm):
    """<= 2 traces of the pallas core regardless of chunk count, zero on a
    second run with the same padded geometry."""
    A, R, P = multigrid.problem("brick3d", 5)
    ws = spgemm_symbolic_host(A, P)
    n_a, n_b = A.n_rows, P.n_rows
    p_ac = (0, n_a) if algorithm == "knl" else tuple(
        int(v) for v in np.linspace(0, n_a, 5))
    p_b = tuple(int(v) for v in np.linspace(0, n_b, 7))   # 6 B chunks
    plan = ChunkPlan(algorithm, p_ac, p_b, 0.0, 0.0)
    key = f"{algorithm}_pallas"
    before = TRACE_COUNTS[key]
    C, _ = PALLAS[algorithm](A, P, plan, ws.c_pad)
    assert TRACE_COUNTS[key] - before <= 2
    assert_close(csr_to_dense(C), spgemm_dense_oracle(A, P), atol=1e-4)
    mid = TRACE_COUNTS[key]
    PALLAS[algorithm](A, P, plan, ws.c_pad)   # same geometry: cache hit
    assert TRACE_COUNTS[key] == mid


def test_planned_stats_pallas_event_model():
    """The pallas event model: dense slab per (strip, chunk) pair, stationary
    operand staged once per outer step, C_prev fetched once, and — unlike the
    loop/scan model — Chunk2 partials never bounce to slow memory."""
    plan2 = ChunkPlan("chunk2", (0, 4, 8), (0, 3, 6, 9), 0.0, 0.0)
    st2 = planned_stats_pallas(plan2, slab_nbytes=100, a_stage_nbytes=10,
                               c_stage_nbytes=1)
    assert st2.kernel_calls == 6                  # n_ac * n_b
    assert st2.per_copy_in.count(100.0) == 3      # each chunk staged once
    assert st2.per_copy_in.count(10.0) == 6       # strips streamed per chunk
    assert st2.per_copy_in.count(2.0) == 1        # whole C block, one fetch
    assert st2.per_copy_out == [2.0]              # single final writeback
    plan1 = ChunkPlan("chunk1", (0, 4, 8), (0, 3, 6, 9), 0.0, 0.0)
    st1 = planned_stats_pallas(plan1, 100, 10, 1)
    assert st1.kernel_calls == 6
    assert st1.per_copy_in.count(100.0) == 6      # chunks streamed per strip
    assert st1.per_copy_in.count(10.0) == 2       # each strip staged once
    assert st1.per_copy_out == [1.0, 1.0]
    plank = ChunkPlan("knl", (0, 8), (0, 3, 6, 9), 0.0, 0.0)
    stk = planned_stats_pallas(plank, 100, 10, 1)
    assert stk.kernel_calls == 3
    assert stk.per_copy_in.count(100.0) == 3
    with pytest.raises(ValueError):
        planned_stats_pallas(ChunkPlan("nope", (0, 8), (0, 8), 0.0, 0.0),
                             1, 1, 1)


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_scan_compiles_once_per_algorithm(algorithm):
    """<= 2 compilations of the chunk loop regardless of the chunk count, and
    zero recompilation on a second run with the same padded geometry."""
    A, R, P = multigrid.problem("brick3d", 5)
    ws = spgemm_symbolic_host(A, P)
    n_a, n_b = A.n_rows, P.n_rows
    p_ac = (0, n_a) if algorithm == "knl" else tuple(
        int(v) for v in np.linspace(0, n_a, 5))
    p_b = tuple(int(v) for v in np.linspace(0, n_b, 7))   # 6 B chunks
    plan = ChunkPlan(algorithm, p_ac, p_b, 0.0, 0.0)
    before_w = TRACE_COUNTS[algorithm]
    before_b = TRACE_COUNTS[f"{algorithm}_body"]
    SCAN[algorithm](A, P, plan, ws.c_pad)
    assert TRACE_COUNTS[algorithm] - before_w <= 2
    assert TRACE_COUNTS[f"{algorithm}_body"] - before_b <= 2
    mid_w = TRACE_COUNTS[algorithm]
    mid_b = TRACE_COUNTS[f"{algorithm}_body"]
    SCAN[algorithm](A, P, plan, ws.c_pad)   # same geometry: cache hit
    assert TRACE_COUNTS[algorithm] == mid_w
    assert TRACE_COUNTS[f"{algorithm}_body"] == mid_b


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_batched_heterogeneous_structures(algorithm):
    """Regression: instances differing in sparsity *structure* (nnz,
    max_row_nnz) used to crash csr_stack with 'uniform padded geometry';
    the batch envelope must repad them into one program whose per-instance
    results are bitwise-identical to the unbatched scan executor."""
    rng = np.random.default_rng(5)
    # the original repro: 32x32 at 10% vs 20% density, 2-chunk plan
    As = [random_csr(rng, 32, 32, d) for d in (0.10, 0.20, 0.05)]
    Bs = [random_csr(rng, 32, 32, d) for d in (0.10, 0.20, 0.30)]
    p_ac = (0, 32) if algorithm == "knl" else (0, 13, 32)
    plan = ChunkPlan(algorithm, p_ac, (0, 16, 32), 0.0, 0.0)
    env = batch_envelope(As, Bs, plan)
    for A, B in zip(As, Bs):
        assert env.dominates(instance_envelope(A, B, plan))
    Cs_list, _ = chunked_spgemm_batched(As, Bs, plan)
    assert len(Cs_list) == 3
    for A, B, Cb in zip(As, Bs, Cs_list):
        Ci, _ = chunked_spgemm(A, B, plan, c_pad=env.c_pad)
        _assert_same_csr(Ci, Cb)
        assert_close(csr_to_dense(Cb), spgemm_dense_oracle(A, B), atol=1e-3)


def test_batched_same_structure_unchanged():
    """Same-structure batches must keep the pre-envelope behavior bitwise:
    the batch envelope degenerates to every instance's own geometry."""
    rng = np.random.default_rng(13)
    base_a = (rng.random((20, 16)) < 0.25) * 1.0
    base_b = (rng.random((16, 18)) < 0.25) * 1.0
    As = [csr_from_dense((base_a * rng.standard_normal(base_a.shape))
                         .astype(np.float32)) for _ in range(3)]
    Bs = [csr_from_dense((base_b * rng.standard_normal(base_b.shape))
                         .astype(np.float32)) for _ in range(3)]
    plan = ChunkPlan("knl", (0, 20), (0, 6, 11, 16), 0.0, 0.0)
    env = batch_envelope(As, Bs, plan)
    assert env == instance_envelope(As[0], Bs[0], plan, c_pad=env.c_pad)
    Cs_list, stats = chunked_spgemm_batched(As, Bs, plan)
    for A, B, Cb in zip(As, Bs, Cs_list):
        Cs, ss = chunk_knl_scan(A, B, plan, env.c_pad)
        _assert_same_csr(Cs, Cb)
        assert ss.per_copy_in == stats.per_copy_in
        assert ss.per_copy_out == stats.per_copy_out


def test_batched_rejects_mismatched_shapes_and_conflicting_c_pad():
    rng = np.random.default_rng(3)
    A1, B1 = random_csr(rng, 8, 8, 0.3), random_csr(rng, 8, 8, 0.3)
    A2, B2 = random_csr(rng, 9, 8, 0.3), random_csr(rng, 8, 8, 0.3)
    plan = ChunkPlan("knl", (0, 8), (0, 4, 8), 0.0, 0.0)
    with pytest.raises(ValueError, match="share shapes"):
        chunked_spgemm_batched([A1, A2], [B1, B2], plan)
    env = batch_envelope([A1], [B1], plan)
    with pytest.raises(ValueError, match="c_pad"):
        chunked_spgemm_batched([A1], [B1], plan, c_pad=env.c_pad + 1,
                               envelope=env)
    # an undersized caller envelope (e.g. stale bucket applied to a denser
    # batch) must fail loudly, never silently truncate
    A3, B3 = random_csr(rng, 8, 8, 0.9), random_csr(rng, 8, 8, 0.9)
    with pytest.raises(ValueError):
        chunked_spgemm_batched([A3], [B3], plan, envelope=env)


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_batched_matches_per_instance_loop(algorithm):
    """vmapped scan over instances sharing one structure == per-instance loop."""
    rng = np.random.default_rng(11)
    base_a = (rng.random((20, 16)) < 0.25) * 1.0
    base_b = (rng.random((16, 18)) < 0.25) * 1.0
    As, Bs = [], []
    for _ in range(3):
        As.append(csr_from_dense(
            (base_a * rng.standard_normal(base_a.shape)).astype(np.float32)))
        Bs.append(csr_from_dense(
            (base_b * rng.standard_normal(base_b.shape)).astype(np.float32)))
    c_pad = max(spgemm_symbolic_host(A, B).c_pad for A, B in zip(As, Bs))
    p_ac = (0, 20) if algorithm == "knl" else (0, 7, 20)
    plan = ChunkPlan(algorithm, p_ac, (0, 6, 11, 16), 0.0, 0.0)
    Cs_list, stats = chunked_spgemm_batched(As, Bs, plan, c_pad=c_pad)
    assert len(Cs_list) == 3
    for A, B, Cb in zip(As, Bs, Cs_list):
        Cl, sl = LOOP[algorithm](A, B, plan, c_pad)
        _assert_same_csr(Cl, Cb)
        assert sl.per_copy_in == stats.per_copy_in
        assert sl.per_copy_out == stats.per_copy_out


@pytest.mark.parametrize("algorithm", ["knl", "chunk1", "chunk2"])
def test_batched_pallas_heterogeneous_structures(algorithm):
    """The pallas backend serves heterogeneous-structure batches through one
    kernel launch (batch = leading grid dim): oracle-correct per instance,
    O(1) traces per geometry, zero retrace on a repeat batch."""
    rng = np.random.default_rng(5)
    As = [random_csr(rng, 24, 20, d) for d in (0.10, 0.25, 0.05)]
    Bs = [random_csr(rng, 20, 22, d) for d in (0.10, 0.20, 0.30)]
    p_ac = (0, 24) if algorithm == "knl" else (0, 11, 24)
    plan = ChunkPlan(algorithm, p_ac, (0, 7, 14, 20), 0.0, 0.0)
    key = f"{algorithm}_pallas_batched"
    before = TRACE_COUNTS[key]
    out, stats = chunked_spgemm_batched(As, Bs, plan, backend="pallas")
    assert len(out) == 3
    for A, B, Cb in zip(As, Bs, out):
        assert_close(csr_to_dense(Cb), spgemm_dense_oracle(A, B), atol=1e-3)
    assert TRACE_COUNTS[key] - before <= 2
    assert stats.kernel_calls == plan.n_ac * plan.n_b
    mid = TRACE_COUNTS[key]
    chunked_spgemm_batched(As, Bs, plan, backend="pallas")
    assert TRACE_COUNTS[key] == mid
    with pytest.raises(ValueError, match="backend"):
        chunked_spgemm_batched(As, Bs, plan, backend="vmapped")


@pytest.mark.parametrize("backend", ["scan", "sparse", "hash"])
def test_make_batched_cores_isolated_caches(backend):
    """``BackendSpec.make_batched_cores`` builds a *fresh* jitted core set:
    each set owns its compile cache (two sets retrace independently, repeat
    calls within a set don't), results match the default-core path, and
    ``donate=True`` cores stay oracle-correct (the staged accumulator stacks
    they consume are freshly allocated per call)."""
    from repro.core import backend_registry

    spec = backend_registry.get(backend)
    rng = np.random.default_rng(21)
    As = [random_csr(rng, 16, 16, d) for d in (0.2, 0.3)]
    Bs = [random_csr(rng, 16, 16, d) for d in (0.2, 0.3)]
    plan = ChunkPlan("knl", (0, 16), (0, 8, 16), 0.0, 0.0)
    counter = spec.trace_key_batched.format(alg="knl")

    def run(cores):
        Cs, _ = chunked_spgemm_batched(As, Bs, plan, backend=backend,
                                       cores=cores)
        for A, B, C in zip(As, Bs, Cs):
            assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-3)

    cores_a = spec.make_batched_cores()
    cores_d = spec.make_batched_cores(donate=True)
    assert set(cores_a) == {"knl", "chunk1", "chunk2"}
    before = TRACE_COUNTS[counter]
    run(cores_a)
    assert TRACE_COUNTS[counter] - before == 1   # set A compiles once
    run(cores_a)
    assert TRACE_COUNTS[counter] - before == 1   # ... and stays warm
    run(cores_d)
    assert TRACE_COUNTS[counter] - before == 2   # fresh set: its own cache
    run(cores_d)
    assert TRACE_COUNTS[counter] - before == 2
