"""Differential + compile-accounting tests for the fused two-hop pipeline.

Three contracts from the pipeline work:

* **Galerkin triple product** — ``C = R x (A x P)`` through the pipeline
  executor equals the dense oracle on all four multigrid ``PROBLEMS``,
  through both the sparse (ESC) and hash chunked backends, on the resident
  and the forced-spill paths; and the resident/spill answers agree with
  each other bitwise in structure (the composed symbolic phase is exact, so
  chunking must never change C's pattern).
* **Masked triangle counts** — the fused masked path equals
  ``count_triangles_dense`` on the three bench graph classes.
* **Compile accounting** — one envelope, one compile: a second identical
  pipeline (or masked triangle) run adds zero ``TRACE_COUNTS`` deltas and
  returns bitwise-identical results.
"""

import numpy as np
import pytest

from repro.core.chunk_stream import TRACE_COUNTS
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.memory_model import P100
from repro.core.pipeline_spgemm import pipeline_spgemm
from repro.core.planner import plan_knl, plan_pipeline
from repro.core.symbolic import masked_output_caps, pipeline_output_caps
from repro.core.triangle import count_triangles, count_triangles_dense
from repro.sparse import graphs, multigrid
from repro.sparse.csr import csr_to_dense

SIZES = {"laplace3d": 4, "bigstar2d": 8, "brick3d": 4, "elasticity": 3}

GRAPHS = {
    "g500_s7": lambda: graphs.rmat(7, 8, seed=1),
    "social_powerlaw": lambda: graphs.powerlaw(256, 8, seed=2),
    "web_like": lambda: graphs.rmat(7, 4, a=0.45, b=0.25, c=0.15, seed=3),
}


def _dense_rap(A, R, P):
    return np.asarray(csr_to_dense(R)) @ np.asarray(spgemm_dense_oracle(A, P))


def _tight_limit(A, P, R, frac):
    return float(A.nbytes() + P.nbytes() + R.nbytes()) * frac


@pytest.mark.parametrize("backend", ["sparse", "hash"])
@pytest.mark.parametrize("name", multigrid.PROBLEMS)
def test_pipeline_matches_dense_oracle(name, backend):
    """R x (A x P) on every problem, default (ample) fast budget."""
    A, R, P = multigrid.problem(name, SIZES[name])
    C, stats = pipeline_spgemm(A, P, R, system=P100, backend=backend)
    np.testing.assert_allclose(np.asarray(csr_to_dense(C)),
                               _dense_rap(A, R, P), atol=1e-4, rtol=1e-5)
    assert stats.spilled is (not stats.plan.t_resident)


@pytest.mark.parametrize("backend", ["sparse", "hash"])
def test_pipeline_chunked_regime_matches_dense_oracle(backend):
    """A fast budget tight enough to force chunked hops (and possibly a
    spilled intermediate) must not change the answer."""
    A, R, P = multigrid.problem("laplace3d", SIZES["laplace3d"])
    limit = _tight_limit(A, P, R, 0.25)
    C, stats = pipeline_spgemm(A, P, R, system=P100,
                               fast_limit_bytes=limit, backend=backend)
    assert "whole_fast" not in (stats.plan.plan1.algorithm,
                                stats.plan.plan2.algorithm)
    np.testing.assert_allclose(np.asarray(csr_to_dense(C)),
                               _dense_rap(A, R, P), atol=1e-4, rtol=1e-5)


def test_pipeline_resident_and_spill_same_structure():
    """The composed symbolic phase is exact, so C's pattern is an invariant
    of the geometry — the resident and spill paths must emit bitwise the
    same structure (values agree to accumulation-order tolerance)."""
    A, R, P = multigrid.problem("bigstar2d", SIZES["bigstar2d"])
    C_ample, s_ample = pipeline_spgemm(A, P, R, system=P100,
                                       backend="sparse")
    C_tight, s_tight = pipeline_spgemm(
        A, P, R, system=P100,
        fast_limit_bytes=_tight_limit(A, P, R, 0.25), backend="sparse")
    assert s_ample.plan.t_resident and not s_tight.plan.t_resident
    np.testing.assert_array_equal(np.asarray(C_ample.indptr),
                                  np.asarray(C_tight.indptr))
    nnz = int(np.asarray(C_ample.indptr)[-1])
    np.testing.assert_array_equal(np.asarray(C_ample.indices)[:nnz],
                                  np.asarray(C_tight.indices)[:nnz])
    np.testing.assert_allclose(np.asarray(C_ample.data)[:nnz],
                               np.asarray(C_tight.data)[:nnz],
                               atol=1e-4, rtol=1e-5)


def test_pipeline_spill_path_reports_spill_traffic():
    A, R, P = multigrid.problem("laplace3d", SIZES["laplace3d"])
    _, stats = pipeline_spgemm(A, P, R, system=P100,
                               fast_limit_bytes=_tight_limit(A, P, R, 0.2),
                               backend="sparse")
    if stats.spilled:
        assert stats.spill_bytes > 0
        assert stats.copy_bytes > stats.hop1.copy_bytes + stats.hop2.copy_bytes
    else:
        assert stats.spill_bytes == 0.0


def test_pipeline_requires_plan_or_system():
    A, R, P = multigrid.problem("laplace3d", SIZES["laplace3d"])
    with pytest.raises(ValueError, match="PipelinePlan or"):
        pipeline_spgemm(A, P, R)


@pytest.mark.parametrize("name", sorted(GRAPHS))
def test_masked_triangle_count_matches_dense(name):
    L = graphs.lower_triangular_degree_sorted(GRAPHS[name]())
    assert float(count_triangles(L)) == float(count_triangles_dense(L))


def test_pipeline_compiles_once_per_envelope():
    """Second identical run: zero new traces, bitwise-identical output."""
    A, R, P = multigrid.problem("laplace3d", SIZES["laplace3d"])
    plan = plan_pipeline(A, P, R, P100,
                         fast_limit_bytes=_tight_limit(A, P, R, 0.25))
    assert "whole_fast" not in (plan.plan1.algorithm, plan.plan2.algorithm)
    caps = pipeline_output_caps(A, P, R, plan.plan1.p_ac, plan.plan2.p_ac)
    C1, _ = pipeline_spgemm(A, P, R, plan, backend="sparse", caps=caps)
    before = dict(TRACE_COUNTS)
    C2, _ = pipeline_spgemm(A, P, R, plan, backend="sparse", caps=caps)
    assert dict(TRACE_COUNTS) == before, \
        "second identical pipeline run retraced a core"
    np.testing.assert_array_equal(np.asarray(C1.indptr),
                                  np.asarray(C2.indptr))
    np.testing.assert_array_equal(np.asarray(C1.indices),
                                  np.asarray(C2.indices))
    np.testing.assert_array_equal(np.asarray(C1.data), np.asarray(C2.data))


def test_masked_triangle_compiles_once_per_envelope():
    L = graphs.lower_triangular_degree_sorted(GRAPHS["g500_s7"]())
    plan = plan_knl(L, L, float("inf"))
    caps = masked_output_caps(L, plan.p_ac)
    t1 = float(count_triangles(L, plan=plan, caps=caps))
    before = dict(TRACE_COUNTS)
    t2 = float(count_triangles(L, plan=plan, caps=caps))
    assert dict(TRACE_COUNTS) == before, \
        "second identical masked triangle run retraced a core"
    assert t1 == t2
    assert any(k.endswith("_hash_masked") for k in before), \
        "masked run never hit a masked hash core"
