"""Shared test fixtures and hypothesis strategies.

``hypothesis`` is optional: hermetic environments don't have it. When absent,
test modules that do ``from conftest import given, settings, st`` degrade
gracefully —

  * ``@given(csr_pair(...))`` (all arguments seeded-example providers) becomes
    a deterministic ``pytest.mark.parametrize`` over a handful of seeded
    (A, B) pairs, so the core SpGEMM properties still run;
  * ``@given(...)`` over generic strategies (``st.lists``/``st.integers``/...)
    auto-skips with an explanatory reason.

NOTE: no XLA_FLAGS here on purpose — tests must see exactly 1 CPU device
(only launch/dryrun.py requests 512 placeholder devices).
"""

import inspect

import jax
import numpy as np
import pytest
import jax.numpy as jnp  # noqa: F401  (re-exported convenience for tests)

from repro.sparse.csr import CSR, csr_from_dense


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables_between_modules():
    """Drop jit caches at module boundaries.

    The full suite compiles hundreds of executables in one process (arch
    smokes, the conformance matrix, every chunked backend); on the 1-CPU CI
    box the accumulated XLA compile state eventually segfaults the CPU
    compiler mid-suite. No module relies on warm caches from a previous
    module — the trace-count pins all measure deltas within a single test.
    """
    yield
    jax.clear_caches()

try:
    # re-exported: test modules import given/settings/st from conftest
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def random_dense(rng, m, n, density):
    d = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return d.astype(np.float32)


def random_csr(rng, m, n, density, pad_extra=0) -> CSR:
    d = random_dense(rng, m, n, density)
    nnz = int((d != 0).sum())
    return csr_from_dense(d, pad_to=nnz + pad_extra)


def csr_pair_cases(n_examples=8, max_dim=24, seed=0):
    """Deterministic (A, B) pairs with compatible inner dims — the seeded
    fallback behind ``csr_pair`` and directly usable with parametrize."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_examples):
        m, k, n = (int(v) for v in rng.integers(1, max_dim + 1, 3))
        da, db = rng.uniform(0.05, 0.6, 2)
        out.append(
            (random_csr(rng, m, k, da, pad_extra=int(rng.integers(0, 8))),
             random_csr(rng, k, n, db, pad_extra=int(rng.integers(0, 8))))
        )
    return out


if HAVE_HYPOTHESIS:

    @st.composite
    def csr_pair(draw, max_dim=24):
        """(A, B) with compatible inner dims for C = A x B."""
        m = draw(st.integers(1, max_dim))
        k = draw(st.integers(1, max_dim))
        n = draw(st.integers(1, max_dim))
        seed = draw(st.integers(0, 2**31 - 1))
        da = draw(st.floats(0.05, 0.6))
        db = draw(st.floats(0.05, 0.6))
        rng = np.random.default_rng(seed)
        return (random_csr(rng, m, k, da, pad_extra=draw(st.integers(0, 7))),
                random_csr(rng, k, n, db, pad_extra=draw(st.integers(0, 7))))

else:

    class _SeededExamples:
        """Concrete examples standing in for a strategy (fallback mode)."""

        def __init__(self, values):
            self.values = values

    def csr_pair(max_dim=24):
        return _SeededExamples(csr_pair_cases(n_examples=6, max_dim=max_dim))

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def given(*strategies, **kwargs):
        if (strategies and not kwargs
                and all(isinstance(s, _SeededExamples) for s in strategies)):
            def deco(fn):
                # hypothesis fills the RIGHTMOST parameters (fixtures precede)
                names = list(inspect.signature(fn).parameters)[-len(strategies):]
                cases = list(zip(*(s.values for s in strategies)))
                if len(names) == 1:
                    cases = [c[0] for c in cases]
                return pytest.mark.parametrize(
                    ",".join(names), cases,
                    ids=[f"seeded{i}" for i in range(len(cases))],
                )(fn)

            return deco
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (property test auto-skipped)"
        )(fn)

    class _StrategyNamespace:
        """Opaque stand-ins so module-level strategy expressions still build."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyNamespace()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_close(a, b, atol=1e-4, rtol=1e-4, msg=""):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    np.testing.assert_allclose(a, b, atol=atol, rtol=rtol, err_msg=msg)
