"""Shared test fixtures and hypothesis strategies.

NOTE: no XLA_FLAGS here on purpose — tests must see exactly 1 CPU device
(only launch/dryrun.py requests 512 placeholder devices).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from hypothesis import strategies as st

from repro.sparse.csr import CSR, csr_from_dense


def random_dense(rng, m, n, density):
    d = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    return d.astype(np.float32)


def random_csr(rng, m, n, density, pad_extra=0) -> CSR:
    d = random_dense(rng, m, n, density)
    nnz = int((d != 0).sum())
    return csr_from_dense(d, pad_to=nnz + pad_extra)


@st.composite
def csr_pair(draw, max_dim=24):
    """(A, B) with compatible inner dims for C = A x B."""
    m = draw(st.integers(1, max_dim))
    k = draw(st.integers(1, max_dim))
    n = draw(st.integers(1, max_dim))
    seed = draw(st.integers(0, 2**31 - 1))
    da = draw(st.floats(0.05, 0.6))
    db = draw(st.floats(0.05, 0.6))
    rng = np.random.default_rng(seed)
    return (random_csr(rng, m, k, da, pad_extra=draw(st.integers(0, 7))),
            random_csr(rng, k, n, db, pad_extra=draw(st.integers(0, 7))))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_close(a, b, atol=1e-4, rtol=1e-4, msg=""):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    np.testing.assert_allclose(a, b, atol=atol, rtol=rtol, err_msg=msg)
