"""KKMEM two-phase SpGEMM: numeric vs dense oracle + the chunk-invariance
property (the paper's central algorithmic invariant)."""

import numpy as np
from conftest import given, settings, st

import jax.numpy as jnp

from repro.core.kkmem import (
    spgemm, spgemm_ranged, spgemm_full, spgemm_symbolic_host, spgemm_dense_oracle,
)
from repro.sparse.csr import CSR, csr_to_dense, csr_select_rows_host
from conftest import csr_pair, assert_close


@settings(max_examples=15, deadline=None)
@given(csr_pair())
def test_spgemm_matches_dense_oracle(pair):
    A, B = pair
    C = spgemm_full(A, B)
    assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(csr_pair())
def test_symbolic_counts_exact(pair):
    A, B = pair
    ws = spgemm_symbolic_host(A, B)
    dense = np.asarray(spgemm_dense_oracle(A, B))
    # structural nnz >= numeric nnz (cancellation can zero entries numerically)
    assert ws.c_nnz >= int((np.abs(dense) > 1e-7).sum())
    # flops = 2 * sum over A nonzeros of matching B row lengths
    a_ptr = np.asarray(A.indptr)
    a_idx = np.asarray(A.indices)[: int(a_ptr[-1])]
    b_len = np.diff(np.asarray(B.indptr))
    assert ws.flops == 2 * int(b_len[a_idx].sum())


@settings(max_examples=10, deadline=None)
@given(csr_pair(max_dim=12), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_chunk_invariance_property(pair, n_chunks, seed):
    """THE paper invariant: any row-partition of B, streamed through the ranged
    fused-multiply-add kernel, yields exactly the unchunked product."""
    A, B = pair
    ws = spgemm_symbolic_host(A, B)
    ref = spgemm_dense_oracle(A, B)
    rng = np.random.default_rng(seed)
    cuts = sorted(set([0, B.n_rows] + rng.integers(
        0, B.n_rows + 1, size=min(n_chunks - 1, B.n_rows)).tolist()))
    C = CSR(jnp.zeros(A.n_rows + 1, jnp.int32), jnp.zeros(ws.c_pad, jnp.int32),
            jnp.zeros(ws.c_pad, A.data.dtype), (A.n_rows, B.n_cols), 0)
    for r0, r1 in zip(cuts[:-1], cuts[1:]):
        if r1 == r0:
            continue
        Bc = csr_select_rows_host(B, r0, r1, pad_to=B.nnz_pad)
        Bc = CSR(Bc.indptr, Bc.indices, Bc.data, Bc.shape, B.max_row_nnz)
        C = spgemm_ranged(A, Bc, r0, r1, C, ws.c_pad)
    assert_close(csr_to_dense(C), ref, atol=1e-3)


def test_spgemm_empty_rows():
    """Rows with no nonzeros and an all-padding matrix behave."""
    A = CSR(jnp.array([0, 0, 0], jnp.int32), jnp.zeros(4, jnp.int32),
            jnp.zeros(4, jnp.float32), (2, 3), 0)
    B = CSR(jnp.array([0, 1, 1, 2], jnp.int32), jnp.array([0, 1, 0, 0], jnp.int32),
            jnp.array([1.0, 2.0, 0.0, 0.0], jnp.float32), (3, 2), 1)
    C = spgemm(A, B, c_pad=8)
    assert np.allclose(np.asarray(csr_to_dense(C)), 0.0)
