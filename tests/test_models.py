"""Model-layer invariants: flash==naive attention, chunked==sequential scans,
MoE==dense oracle, prefill+decode==forward for every family."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import attention as att
from repro.models import rwkv6, mamba2, moe
from repro.models import transformer as tf
from conftest import assert_close

KEY = jax.random.PRNGKey(0)


def dense_cfg(**kw):
    base = {"name": "t", "family": "dense", "n_layers": 2, "d_model": 64,
            "d_ff": 128, "vocab_size": 128, "n_heads": 8, "n_kv_heads": 2,
            "q_chunk": 16, "attn_chunk": 16, "compute_dtype": "float32"}
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [0, 8, 24])
@pytest.mark.parametrize("sq,sk", [(64, 64), (48, 48), (32, 64)])
def test_flash_matches_naive(rng, window, sq, sk):
    b, h, hkv, d = 2, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, sk, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, sk, hkv, d)).astype(np.float32))
    off = sk - sq
    o1 = att.flash_attention(q, k, v, causal=True, window=window,
                             q_chunk=16, kv_chunk=16, q_offset=off)
    o2 = att.attention_ref(q, k, v, causal=True, window=window, q_offset=off)
    assert_close(o1, o2, atol=2e-3)


@pytest.mark.parametrize("chunks", [(16, 16), (32, 8), (8, 32), (64, 64)])
def test_flash_chunk_invariance(rng, chunks):
    """Chunk sizes must not change the result (paper chunk-invariance, attention
    edition)."""
    b, s, h, hkv, d = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)).astype(np.float32))
    qc, kc = chunks
    o1 = att.flash_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    o2 = att.attention_ref(q, k, v)
    assert_close(o1, o2, atol=2e-3)


# ---------------------------------------------------------------------------
# rwkv6 / mamba2 chunked forms
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,chunk", [(48, 16), (50, 16), (32, 32), (7, 32)])
def test_rwkv_chunked_equals_sequential(rng, s, chunk):
    cfg = ModelConfig(name="r", family="ssm", ssm_family="rwkv6", n_layers=1,
                      d_model=64, d_ff=128, vocab_size=64, ssm_head_dim=16,
                      compute_dtype="float32")
    p = rwkv6.rwkv_init(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((2, s, 64)).astype(np.float32)) * 0.5
    y1, s1, _ = rwkv6.time_mix(p, x, cfg)
    y2, s2, _ = rwkv6.time_mix_chunked(p, x, cfg, chunk=chunk)
    assert_close(y1, y2, atol=2e-3)
    assert_close(s1, s2, atol=2e-3)


@pytest.mark.parametrize("s,chunk", [(48, 16), (50, 16), (64, 64), (5, 16)])
def test_mamba_chunked_equals_scan(rng, s, chunk):
    cfg = ModelConfig(name="m", family="hybrid", ssm_family="mamba2", n_layers=1,
                      d_model=32, d_ff=64, vocab_size=64, n_heads=4, n_kv_heads=4,
                      ssm_state=8, ssm_head_dim=16, compute_dtype="float32")
    p = mamba2.mamba_init(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((2, s, 32)).astype(np.float32)) * 0.5
    y1, h1, c1 = mamba2.ssd_scan(p, x, cfg)
    y2, h2, c2 = mamba2.ssd_chunked(p, x, cfg, chunk=chunk)
    assert_close(y1, y2, atol=2e-3)
    assert_close(h1, h2, atol=2e-3)
    assert_close(c1, c2, atol=2e-3)


def test_rwkv_streaming_state(rng):
    cfg = ModelConfig(name="r", family="ssm", ssm_family="rwkv6", n_layers=1,
                      d_model=64, d_ff=128, vocab_size=64, ssm_head_dim=16,
                      compute_dtype="float32")
    p = rwkv6.rwkv_init(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((1, 40, 64)).astype(np.float32)) * 0.5
    y_full, _, _ = rwkv6.time_mix(p, x, cfg)
    ya, sa, xa = rwkv6.time_mix(p, x[:, :25], cfg)
    yb, _, _ = rwkv6.time_mix(p, x[:, 25:], cfg, state=sa, x_prev_in=xa)
    assert_close(jnp.concatenate([ya, yb], 1), y_full, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def test_moe_matches_dense_oracle_when_no_drops(rng):
    cfg = ModelConfig(name="e", family="moe", n_layers=1, d_model=32, d_ff=64,
                      vocab_size=64, n_heads=4, n_kv_heads=4, n_experts=8,
                      top_k=2, capacity_factor=8.0, compute_dtype="float32")
    p = moe.moe_init(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, 32)).astype(np.float32))
    y, aux = moe.moe_apply(p, x, cfg)
    assert_close(y, moe.moe_apply_dense_oracle(p, x, cfg), atol=1e-4)
    assert float(aux) >= 1.0 - 1e-5    # switch aux lower bound at balance


def test_moe_capacity_drops_bounded(rng):
    """With tight capacity some tokens drop; outputs stay finite and the layer
    never amplifies magnitude pathologically."""
    cfg = ModelConfig(name="e", family="moe", n_layers=1, d_model=32, d_ff=64,
                      vocab_size=64, n_heads=4, n_kv_heads=4, n_experts=4,
                      top_k=2, capacity_factor=0.5, compute_dtype="float32")
    p = moe.moe_init(KEY, cfg)
    x = jnp.asarray(rng.standard_normal((2, 32, 32)).astype(np.float32))
    y, _ = moe.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# whole-model: prefill + decode == forward, per family
# ---------------------------------------------------------------------------


FAMILY_CFGS = [
    dense_cfg(name="dense"),
    dense_cfg(name="swa", sliding_window=16),
    ModelConfig(name="moe", family="moe", n_layers=2, d_model=64, d_ff=96,
                vocab_size=128, n_heads=8, n_kv_heads=8, n_experts=8, top_k=2,
                capacity_factor=8.0, q_chunk=16, attn_chunk=16,
                compute_dtype="float32"),
    ModelConfig(name="rwkv", family="ssm", ssm_family="rwkv6", n_layers=2,
                d_model=64, d_ff=128, vocab_size=128, ssm_head_dim=16,
                compute_dtype="float32"),
    ModelConfig(name="zamba", family="hybrid", ssm_family="mamba2", n_layers=4,
                d_model=64, d_ff=128, vocab_size=128, n_heads=8, n_kv_heads=8,
                ssm_state=8, ssm_head_dim=16, attn_every=2, q_chunk=16,
                attn_chunk=16, compute_dtype="float32"),
]


@pytest.mark.parametrize("cfg", FAMILY_CFGS, ids=lambda c: c.name)
def test_decode_matches_forward(rng, cfg):
    b, s = 2, 32
    params = tf.init_params(KEY, cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits, _ = tf.forward(params, {"tokens": toks, "labels": toks}, cfg)
    assert not np.isnan(np.asarray(logits)).any()
    n_pre = s - 4
    lg, cache = tf.prefill(params, {"tokens": toks[:, :n_pre]}, cfg, cache_len=s)
    errs = [np.abs(np.asarray(lg) - np.asarray(logits[:, n_pre - 1])).max()]
    for t in range(n_pre, s):
        lg, cache = tf.decode_step(params, cache, toks[:, t : t + 1], cfg)
        errs.append(np.abs(np.asarray(lg) - np.asarray(logits[:, t])).max())
    assert max(errs) < 2e-2, f"{cfg.name}: {max(errs)}"


@pytest.mark.parametrize("cfg", FAMILY_CFGS, ids=lambda c: c.name)
def test_grads_finite(rng, cfg):
    params = tf.init_params(KEY, cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    g = jax.grad(lambda p: tf.loss_fn(p, batch, cfg)[0])(params)
    norms = jax.tree.map(lambda x: float(jnp.sum(x.astype(jnp.float32) ** 2)), g)
    total = jax.tree.reduce(lambda a, b: a + b, norms, 0.0)
    assert np.isfinite(total) and total > 0


def test_prefill_gathers_logits_at_true_prompt_lengths(rng):
    """Uneven right-padded prompts + batch["lengths"]: each sequence's prefill
    logits must match an unpadded single-prompt prefill — the first generated
    token is predicted from the prompt's true last token, never from padding."""
    cfg = dense_cfg()
    params = tf.init_params(KEY, cfg)
    lens = [9, 16]
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    toks = np.zeros((2, max(lens)), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    lg, _ = tf.prefill(
        params,
        {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens, jnp.int32)},
        cfg, cache_len=32)
    for i, p in enumerate(prompts):
        ref, _ = tf.prefill(params, {"tokens": jnp.asarray(p[None, :])}, cfg,
                            cache_len=32)
        assert_close(lg[i], ref[0], atol=1e-3,
                     msg=f"prompt {i} (len {lens[i]})")
    # without lengths, the padded short prompt reads logits from padding —
    # the pre-fix behavior this test guards against
    lg_bad, _ = tf.prefill(params, {"tokens": jnp.asarray(toks)}, cfg,
                           cache_len=32)
    ref0, _ = tf.prefill(params, {"tokens": jnp.asarray(prompts[0][None, :])},
                         cfg, cache_len=32)
    assert np.abs(np.asarray(lg_bad[0]) - np.asarray(ref0[0])).max() > 1e-3
