"""Sharding rules: every leaf gets a legal spec on both production meshes.

These tests build the 256/512-device meshes ABSTRACTLY via jax.sharding.Mesh over
a numpy array of fake device objects? No — jax requires real devices for
NamedSharding placement, but PartitionSpec *legality* (divisibility) is pure
arithmetic, which is what we check here against mesh shape dicts. The real-mesh
compile check is the dry-run's job (launch/dryrun.py, run as a subprocess in
test_dryrun_subprocess below)."""

import pytest
import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import input_specs, skip_reason
from repro.models import transformer as tf


class FakeMesh:
    """Duck-typed mesh: .axis_names / .shape, enough for the rule arithmetic."""

    def __init__(self, shape_by_axis):
        self.axis_names = tuple(shape_by_axis)
        self.shape = dict(shape_by_axis)


MESHES = {
    "pod16x16": FakeMesh({"data": 16, "model": 16}),
    "pod2x16x16": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_spec_legal(shape, spec, mesh, where):
    assert len(spec) <= len(shape), f"{where}: spec longer than shape"
    used = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            continue
        size = _axis_size(mesh, axes)
        assert dim % size == 0, \
            f"{where}: dim {dim} not divisible by {axes}={size}"
        for a in (axes,) if isinstance(axes, str) else axes:
            assert a not in used, f"{where}: axis {a} used twice"
            used.append(a)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_rules_legal_everywhere(arch, mesh_name, monkeypatch):
    from repro.parallel import sharding as sh

    mesh = MESHES[mesh_name]
    cfg = get_config(arch)
    ap = tf.abstract_params(cfg)

    # patch NamedSharding to capture specs without real devices
    captured = []

    class FakeNS:
        def __init__(self, m, spec):
            captured.append(spec)
            self.spec = spec

    monkeypatch.setattr(sh, "NamedSharding", FakeNS)
    specs = sh.param_shardings(cfg, mesh, ap)

    flat_p, _ = jax.tree_util.tree_flatten_with_path(ap)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, FakeNS))
    assert len(flat_p) == len(flat_s)
    n_sharded = 0
    for (path, leaf), fake in zip(flat_p, flat_s):
        _check_spec_legal(leaf.shape, fake.spec, mesh, f"{arch}:{path}")
        if any(a is not None for a in fake.spec):
            n_sharded += 1
    # the overwhelming majority of parameter BYTES must actually shard
    assert n_sharded >= len(flat_p) // 3, f"{arch}: too few sharded params"


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ["deepseek_67b", "mixtral_8x22b", "rwkv6_3b",
                                  "zamba2_1p2b"])
def test_cache_rules_legal(arch, mesh_name, monkeypatch):
    from repro.parallel import sharding as sh

    mesh = MESHES[mesh_name]
    cfg = get_config(arch)
    captured = []

    class FakeNS:
        def __init__(self, m, spec):
            captured.append(spec)
            self.spec = spec

    monkeypatch.setattr(sh, "NamedSharding", FakeNS)
    for shape_name in ("decode_32k", "long_500k"):
        if skip_reason(cfg, shape_name):
            continue
        specs_in = input_specs(cfg, shape_name)
        out = sh.cache_shardings(cfg, mesh, specs_in["cache"])
        flat_c, _ = jax.tree_util.tree_flatten_with_path(specs_in["cache"])
        flat_s = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, FakeNS))
        for (path, leaf), fake in zip(flat_c, flat_s):
            _check_spec_legal(leaf.shape, fake.spec, mesh,
                              f"{arch}:{shape_name}:{path}")


def test_best_effort_drops_nondivisible():
    from repro.parallel.sharding import best_effort_spec

    mesh = MESHES["pod16x16"]
    spec = best_effort_spec((4, 64), mesh, ["model", "data"])   # 4 % 16 != 0
    assert spec[0] is None and spec[1] == "data"


@pytest.mark.slow
def test_dryrun_subprocess_one_cell():
    """End-to-end: the real dry-run (512 fake devices) compiles one cell."""
    import subprocess, sys, os

    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3_2_1b", "--shape", "decode_32k", "--multi-pod",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), env=env, timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
