"""Sparse containers + generators: roundtrips, structure, padding invariants."""

import numpy as np
import pytest
from conftest import given, settings, st

from repro.sparse.csr import (
    csr_from_dense, csr_to_dense, csr_from_coo, csr_transpose_host,
    csr_select_rows_host, csr_row_of_entry,
)
from repro.sparse.bsr import bsr_from_dense, bsr_to_dense, bsr_from_csr
from repro.sparse import multigrid, generators, graphs
from conftest import random_dense, assert_close


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.integers(1, 30), st.floats(0.0, 0.7),
       st.integers(0, 9), st.integers(0, 2**31 - 1))
def test_csr_dense_roundtrip(m, n, density, pad, seed):
    d = random_dense(np.random.default_rng(seed), m, n, density)
    c = csr_from_dense(d, pad_to=int((d != 0).sum()) + pad)
    assert_close(csr_to_dense(c), d)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 20), st.integers(2, 20), st.floats(0.05, 0.6),
       st.integers(0, 2**31 - 1))
def test_csr_transpose(m, n, density, seed):
    d = random_dense(np.random.default_rng(seed), m, n, density)
    c = csr_from_dense(d)
    assert_close(csr_to_dense(csr_transpose_host(c)), d.T)


def test_csr_row_select_and_entry_rows(rng):
    d = random_dense(rng, 12, 9, 0.4)
    c = csr_from_dense(d, pad_to=int((d != 0).sum()) + 5)
    sub = csr_select_rows_host(c, 3, 9)
    assert_close(csr_to_dense(sub), d[3:9])
    rows = np.asarray(csr_row_of_entry(c))
    nnz = int(c.indptr[-1])
    expect = np.repeat(np.arange(12), np.diff(np.asarray(c.indptr)))
    np.testing.assert_array_equal(rows[:nnz], expect)


def test_csr_from_coo_sums_duplicates():
    c = csr_from_coo([0, 0, 1], [2, 2, 0], [1.0, 2.0, 5.0], (2, 3))
    d = np.asarray(csr_to_dense(c))
    assert d[0, 2] == pytest.approx(3.0)
    assert d[1, 0] == pytest.approx(5.0)


@pytest.mark.parametrize("bs", [2, 4, 8])
def test_bsr_roundtrip(rng, bs):
    d = random_dense(rng, 4 * bs, 6 * bs, 0.2)
    b = bsr_from_dense(d, bs, pad_to=None)
    assert_close(bsr_to_dense(b), d)


def test_bsr_from_csr_pads_shape(rng):
    d = random_dense(rng, 10, 13, 0.3)   # not multiples of 4
    c = csr_from_dense(d)
    b = bsr_from_csr(c, 4)
    assert b.shape == (12, 16)
    assert_close(np.asarray(bsr_to_dense(b))[:10, :13], d)


@pytest.mark.parametrize("name,exp_nnz", [
    ("laplace3d", 7), ("bigstar2d", 13), ("brick3d", 27), ("elasticity", 81)])
def test_multigrid_stencil_widths(name, exp_nnz):
    A, R, P = multigrid.problem(name, 5)
    row_nnz = np.diff(np.asarray(A.indptr))
    assert row_nnz.max() == exp_nnz
    # P = R^T
    assert_close(csr_to_dense(P), np.asarray(csr_to_dense(R)).T)
    # R short and wide
    assert R.shape[0] < R.shape[1]


def test_random_uniform_degree_exact(rng):
    B = generators.random_uniform_degree(40, 60, 7, seed=3)
    np.testing.assert_array_equal(np.diff(np.asarray(B.indptr)), 7)
    # distinct columns per row
    idx = np.asarray(B.indices)
    ptr = np.asarray(B.indptr)
    for i in range(40):
        row = idx[ptr[i]:ptr[i + 1]]
        assert len(set(row.tolist())) == 7


def test_graphs_symmetric_binary():
    G = graphs.rmat(7, 4, seed=1)
    d = np.asarray(csr_to_dense(G))
    np.testing.assert_array_equal(d, d.T)
    assert set(np.unique(d)).issubset({0.0, 1.0})
    assert np.trace(d) == 0
    L = graphs.lower_triangular_degree_sorted(G)
    ld = np.asarray(csr_to_dense(L))
    assert np.allclose(np.triu(ld), 0)


# ---------------------------------------------------------------------------
# geometry envelopes + repadding
# ---------------------------------------------------------------------------


def _env(**kw):
    from repro.sparse.csr import GeometryEnvelope

    base = {"a_shape": (8, 8), "b_shape": (8, 8), "a_nnz_cap": 10,
            "a_max_row_nnz": 3, "b_max_row_nnz": 5, "chunk_rows": 4,
            "chunk_nnz_cap": 7, "strip_rows": 8, "strip_nnz_cap": 10,
            "c_pad": 64, "dtype": "float32"}
    base.update(kw)
    return GeometryEnvelope(**base)


def test_envelope_union_dominates_quantize():
    e1 = _env(chunk_nnz_cap=7, c_pad=64)
    e2 = _env(chunk_nnz_cap=9, c_pad=32, b_max_row_nnz=2)
    u = e1.union(e2)
    assert u.chunk_nnz_cap == 9 and u.c_pad == 64 and u.b_max_row_nnz == 5
    assert u.dominates(e1) and u.dominates(e2)
    assert not e2.dominates(e1)          # c_pad smaller
    assert not e1.dominates(_env(a_shape=(9, 8)))  # shape mismatch
    with pytest.raises(ValueError):
        e1.union(_env(dtype="float64"))
    q = e2.quantized(32)
    assert q.chunk_nnz_cap == 32 and q.c_pad == 32 and q.a_nnz_cap == 32
    assert q.b_max_row_nnz == 2 and q.a_max_row_nnz == 4   # pow2 rounding
    assert q.chunk_rows == e2.chunk_rows                   # plan-derived: exact
    assert q.dominates(e2)
    # quantization is idempotent -> stable bucket keys
    assert q.quantized(32) == q


def test_csr_pad_to_grows_only(rng):
    from repro.sparse.csr import csr_pad_to

    d = random_dense(rng, 5, 6, 0.4)
    m = csr_from_dense(d)
    p = csr_pad_to(m, nnz_cap=m.nnz_pad + 7, rows=9, max_row_nnz=11)
    assert p.nnz_pad == m.nnz_pad + 7 and p.n_rows == 9
    assert p.max_row_nnz == 11 and p.shape[1] == m.shape[1]
    # true content unchanged; appended rows are empty
    assert_close(csr_to_dense(p)[:5], d)
    assert_close(csr_to_dense(p)[5:], np.zeros((4, 6)))
    ptr = np.asarray(p.indptr)
    assert (ptr[6:] == ptr[5]).all()
    with pytest.raises(ValueError):
        csr_pad_to(m, nnz_cap=m.nnz_pad - 1)
    with pytest.raises(ValueError):
        csr_pad_to(m, rows=4)
    with pytest.raises(ValueError):
        # lowering the row-nnz bound would truncate SpGEMM expansion buffers
        csr_pad_to(m, max_row_nnz=m.max_row_nnz - 1)


def test_envelope_staged_nbytes_monotone():
    """staged_nbytes orders envelopes by padding cost: strictly dominating
    envelopes always score strictly higher (the tightest-dominator argmin in
    the serving layer relies on it), and union never shrinks the score."""
    e1 = _env()
    e2 = _env(chunk_nnz_cap=9, c_pad=128, b_max_row_nnz=2)
    u = e1.union(e2)
    assert u.staged_nbytes() >= max(e1.staged_nbytes(), e2.staged_nbytes())
    for grown in (_env(a_nnz_cap=20), _env(strip_nnz_cap=16),
                  _env(chunk_nnz_cap=11), _env(c_pad=100)):
        assert grown.staged_nbytes() > e1.staged_nbytes()
    # wider dtypes pay for every value slot
    assert _env(dtype="float64").staged_nbytes() > e1.staged_nbytes()
