"""Fault tolerance: atomicity, keep-k, elastic resharding, resume determinism."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.mesh import mesh_kwargs
from repro.ckpt.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, CheckpointManager,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32)),
                   "b": jnp.asarray(rng.standard_normal(8).astype(np.float32))},
        "opt": {"mu": jnp.zeros((8, 8)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    restored, step = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_atomicity_partial_write_invisible(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    # simulate a crashed half-finished save: stray .tmp directory
    crash = tmp_path / "step_00000020.tmp"
    crash.mkdir()
    (crash / "arr_0.npy").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 10
    restored, step = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 10
    # an incomplete final dir (no manifest) is also invisible
    bad = tmp_path / "step_00000030"
    bad.mkdir()
    assert latest_step(str(tmp_path)) == 10


def test_keep_last_k(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep_last_k=2)
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [4, 5]


def test_structure_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: {"only": jnp.zeros(3)}))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore places leaves with provided shardings (the mesh-reshape path).
    On 1 device the sharding is degenerate but the code path is identical."""
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    mesh = jax.make_mesh((1,), ("data",), **mesh_kwargs(1))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        t)
    restored, _ = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: t),
                                     shardings=sh)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding is not None


def test_manager_cadence_and_preemption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=10, install_sigterm=False)
    assert not mgr.should_save_now(5)
    assert mgr.should_save_now(10)
    mgr._preempted = True
    assert mgr.should_save_now(1)   # preemption forces a save


def test_resume_determinism(tmp_path):
    """Full-loop: run 8 steps; run 4 + checkpoint + resume 4; same params."""
    from repro.launch.train import train_loop
    from repro.train.optim import TrainConfig
    from repro.configs import get_config

    cfg = get_config("llama3_2_1b", smoke=True)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=8, warmup_steps=1)
    r1 = train_loop(cfg, tcfg, batch_size=2, seq_len=16, steps=8,
                    ckpt_dir=None, log_every=100)
    d1 = str(tmp_path / "resume")
    r2a = train_loop(cfg, tcfg, batch_size=2, seq_len=16, steps=4,
                     ckpt_dir=d1, ckpt_every=4, log_every=100)
    r2b = train_loop(cfg, tcfg, batch_size=2, seq_len=16, steps=8,
                     ckpt_dir=d1, ckpt_every=4, log_every=100)
    assert r2b.resumed_from == 4
    assert r1.last_loss == pytest.approx(r2b.last_loss, rel=1e-5)


@pytest.mark.slow
def test_elastic_restore_across_meshes_subprocess():
    """Save sharded on an 8-way mesh, restore on a 4x2 mesh (different axis
    names AND shape) — the elastic-restart path on real multi-device state."""
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "elastic_check.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout


def test_straggler_watchdog_detects_slow_steps():
    """Inject a 10x-slow step; the EWMA watchdog must flag it."""
    import time as _time

    from repro.launch.train import train_loop
    from repro.train.optim import TrainConfig
    from repro.configs import get_config

    cfg = get_config("llama3_2_1b", smoke=True)
    tcfg = TrainConfig(total_steps=12, warmup_steps=1)

    def hook(step):
        if step == 8:
            _time.sleep(1.5)   # vs ~30ms steady-state steps

    stats = train_loop(cfg, tcfg, batch_size=2, seq_len=16, steps=12,
                       log_every=100, straggler_factor=3.0, _step_hook=hook)
    assert stats.stragglers >= 1


def test_async_save_roundtrip(tmp_path):
    """save_async writes in a background thread; wait() + restore sees the
    complete atomic checkpoint."""
    from repro.ckpt.checkpoint import CheckpointManager

    t = _tree(seed=3)
    mgr = CheckpointManager(str(tmp_path), every_steps=1, install_sigterm=False)
    mgr.save_async(11, t)
    mgr.save_async(12, t)   # implicitly waits for the first
    mgr.wait()
    restored, step = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 12
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
