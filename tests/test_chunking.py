"""Chunk executors (Algs 1-3) + planner (Alg 4): correctness and cost properties."""

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.kkmem import spgemm_symbolic_host, spgemm_dense_oracle
from repro.core.planner import (
    ChunkPlan, plan_chunks, plan_knl, binary_search_partition, partition_cost,
    row_bytes_csr,
)
from repro.core.chunking import chunked_spgemm, chunk_knl, chunk_gpu1, chunk_gpu2
from repro.core.memory_model import P100
from repro.sparse import multigrid
from repro.sparse.csr import csr_to_dense
from conftest import assert_close


@pytest.fixture(scope="module")
def problem():
    A, R, P = multigrid.problem("brick3d", 5)
    return A, P


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=1, max_size=60),
       st.floats(1.0, 2000.0))
def test_binary_search_partition_properties(row_bytes, target):
    rb = np.asarray(row_bytes, np.float64)
    bounds = binary_search_partition(rb, target)
    assert bounds[0] == 0 and bounds[-1] == len(row_bytes)
    assert list(bounds) == sorted(set(bounds))
    for s, e in zip(bounds[:-1], bounds[1:]):
        size = rb[s:e].sum()
        # each chunk fits, unless it is a single oversized row
        assert size <= target or (e - s) == 1


def test_knl_chunking_matches_oracle(problem):
    A, P = problem
    ref = np.asarray(spgemm_dense_oracle(A, P))
    for frac in (0.6, 0.34, 0.15):
        plan = plan_knl(A, P, fast_limit_bytes=P.nbytes() * frac)
        assert plan.n_b >= 2
        C, stats = chunked_spgemm(A, P, plan)
        assert_close(csr_to_dense(C), ref, atol=1e-4)
        assert stats.kernel_calls == plan.n_b


@pytest.mark.parametrize("algorithm", ["chunk1", "chunk2"])
def test_gpu_chunking_matches_oracle(problem, algorithm):
    A, P = problem
    ws = spgemm_symbolic_host(A, P)
    ref = np.asarray(spgemm_dense_oracle(A, P))
    crb = np.full(A.n_rows, max(ws.c_nnz / A.n_rows, 1.0) * 12)
    tiny = (A.nbytes() + P.nbytes() + float(crb.sum())) / 5
    plan = plan_chunks(A, P, crb, P100, fast_limit_bytes=tiny)
    plan = type(plan)(algorithm, plan.p_ac, plan.p_b, plan.copy_bytes,
                      plan.fast_bytes_needed)
    fn = chunk_gpu1 if algorithm == "chunk1" else chunk_gpu2
    C, stats = fn(A, P, plan, c_pad=ws.c_pad)
    assert_close(csr_to_dense(C), ref, atol=1e-4)
    assert stats.kernel_calls == plan.n_ac * plan.n_b


def test_both_orders_same_result(problem, rng):
    """Chunk1 and Chunk2 stream in different orders but must agree exactly."""
    A, P = problem
    ws = spgemm_symbolic_host(A, P)
    crb = np.full(A.n_rows, 12.0)
    plan = plan_chunks(A, P, crb, P100,
                       fast_limit_bytes=(A.nbytes() + P.nbytes()) / 4)
    c1, _ = chunk_gpu1(A, P, plan, c_pad=ws.c_pad)
    c2, _ = chunk_gpu2(A, P, plan, c_pad=ws.c_pad)
    assert_close(csr_to_dense(c1), csr_to_dense(c2), atol=1e-5)


def test_planner_whole_fast_when_it_fits(problem):
    A, P = problem
    crb = np.full(A.n_rows, 12.0)
    plan = plan_chunks(A, P, crb, P100, fast_limit_bytes=1e12)
    assert plan.algorithm == "whole_fast"
    assert plan.n_ac == 1 and plan.n_b == 1


def test_planner_prefers_resident_b(problem):
    """Alg 4: when B fits in the big portion, B stays resident (chunk2)."""
    A, P = problem
    crb = np.full(A.n_rows, 12.0)
    # use the planner's own byte convention (row_bytes_csr = 12 B/entry)
    size_a = float(row_bytes_csr(A).sum())
    size_b = float(row_bytes_csr(P).sum())
    fast = size_b / 0.7   # B fits in the 75% portion
    assert size_a + size_b + crb.sum() > fast  # whole problem does not fit
    plan = plan_chunks(A, P, crb, P100, fast_limit_bytes=fast)
    assert plan.algorithm == "chunk2"
    assert plan.n_b == 1


def test_planner_picks_cheaper_order(problem):
    """When 2-D chunking is forced, Alg 4 must choose the order with the lower
    modeled copy cost (in the planner's own byte units)."""
    A, P = problem
    crb = np.full(A.n_rows, 12.0)
    size_a = float(row_bytes_csr(A).sum())
    size_b = float(row_bytes_csr(P).sum())
    size_c = float(crb.sum())
    tiny = (size_a + size_b + size_c) / 6
    plan = plan_chunks(A, P, crb, P100, fast_limit_bytes=tiny)
    c1 = partition_cost(size_a, size_b, size_c, plan.n_ac, plan.n_b, "chunk1")
    c2 = partition_cost(size_a, size_b, size_c, plan.n_ac, plan.n_b, "chunk2")
    assert plan.copy_bytes == min(c1, c2)
    assert plan.algorithm == ("chunk1" if c1 <= c2 else "chunk2")


def test_copy_cost_formulas():
    # paper §3.3.1:  chunk1 = |A|+|C|+|B|*n_ac ; chunk2 = |B|+|A|*n_b+|C|*(n_b-1)
    assert partition_cost(10, 20, 5, 3, 4, "chunk1") == 10 + 5 + 20 * 3
    assert partition_cost(10, 20, 5, 3, 4, "chunk2") == 20 + 10 * 4 + 5 * 3


def test_chunk_stats_track_copies(problem):
    """Actual staged bytes scale with the planned partition counts."""
    A, P = problem
    ws = spgemm_symbolic_host(A, P)
    plan = plan_knl(A, P, fast_limit_bytes=P.nbytes() / 3)
    _, stats = chunk_knl(A, P, plan, ws.c_pad)
    # B is staged exactly once in total (row-chunks are disjoint), up to padding
    assert stats.copy_in_bytes >= P.nbytes() * 0.9
    assert stats.copy_in_bytes <= P.nbytes() * plan.n_b  # padding slack bound


def test_plan_knl_models_padded_staged_footprint():
    """The executors stage uniformly padded chunks (every chunk padded to the
    largest chunk's nnz and rows), so the planned fast footprint must cover
    the *staged* chunk bytes — summing unpadded per-chunk bytes undercounts
    on skewed row distributions."""
    from repro.core.chunking import b_chunks
    from repro.sparse.csr import csr_from_dense

    rng = np.random.default_rng(2)
    # skewed B: one fully dense row among hundreds of near-empty ones, so the
    # padded chunk envelope (dense-row nnz cap x widest row span) far exceeds
    # any single chunk's unpadded bytes
    n_rows = 256
    dense = (rng.random((n_rows, 48)) < 0.01) * rng.standard_normal((n_rows, 48))
    dense[0] = rng.standard_normal(48)             # one fully dense row
    B = csr_from_dense(dense.astype(np.float32))
    A = csr_from_dense(np.eye(n_rows, dtype=np.float32))
    size_b = float(row_bytes_csr(B).sum())
    for frac in (0.5, 0.3, 0.15):
        plan = plan_knl(A, B, fast_limit_bytes=size_b * frac)
        chunks = b_chunks(B, plan.p_b)
        staged = max(c.nbytes() for c in chunks)
        assert plan.fast_bytes_needed >= staged, (
            f"frac={frac}: modeled {plan.fast_bytes_needed} < staged {staged}"
        )
    # the pre-fix model (max unpadded chunk bytes) genuinely undercounts here
    plan = plan_knl(A, B, fast_limit_bytes=size_b * 0.15)
    unpadded = max(
        float(row_bytes_csr(B)[s:e].sum())
        for s, e in zip(plan.p_b[:-1], plan.p_b[1:])
    )
    staged = max(c.nbytes() for c in b_chunks(B, plan.p_b))
    assert unpadded < staged


def _skewed_csr(rng, n_rows, n_cols, density, dense_rows=1):
    d = (rng.random((n_rows, n_cols)) < density) * rng.standard_normal(
        (n_rows, n_cols))
    d[:dense_rows] = rng.standard_normal((dense_rows, n_cols))
    from repro.sparse.csr import csr_from_dense
    return csr_from_dense(d.astype(np.float32))


def test_plan_chunks_models_padded_staged_footprint():
    """Regression for the Alg-4 planner's fast-memory model: all three
    branches must report the *staged* peak footprint the executors allocate
    (resident operands + padded streamed envelopes). The pre-fix model used
    the densest single row for the streamed term (and, in the 2-D branch,
    reported the limit itself), so skewed rows made plans "fit" while their
    padded strips/chunks did not."""
    from repro.core.chunking import a_strips, b_chunks

    rng = np.random.default_rng(4)
    n = 192
    A = _skewed_csr(rng, n, n, 0.05)
    B = _skewed_csr(rng, n, n, 0.15)
    crb = np.full(n, 12.0)
    a_rows, b_rows = row_bytes_csr(A), row_bytes_csr(B)
    size_a, size_b, size_c = (float(a_rows.sum()), float(b_rows.sum()),
                              float(crb.sum()))
    ac_rows = a_rows + crb

    def staged_ab(plan):
        sa = max(s.nbytes() for s in a_strips(A, plan.p_ac))
        sb = max(c.nbytes() for c in b_chunks(B, plan.p_b))
        return sa, sb

    # branch 1: B resident, stream A/C strips (chunk2, n_b == 1): a limit
    # above size_b / 0.75 (B fits the big portion) but below the whole problem
    fast = (size_b / 0.75 + (size_a + size_b + size_c)) / 2
    plan = plan_chunks(A, B, crb, P100, fast_limit_bytes=fast)
    assert plan.algorithm == "chunk2" and plan.n_b == 1
    sa, sb = staged_ab(plan)
    assert plan.fast_bytes_needed >= size_b + sa
    # pre-fix model: resident B + densest single A/C row — undercounts the
    # padded strip the executors actually stage, so it fails the bound above
    assert size_b + float(ac_rows.max()) < size_b + sa

    # branch 2: A,C resident, stream B chunks (chunk1)
    fast = (size_a + size_c) / 0.7
    assert size_b > 0.75 * fast       # B must not fit the big portion
    plan = plan_chunks(A, B, crb, P100, fast_limit_bytes=fast)
    assert plan.algorithm == "chunk1" and plan.n_ac == 1
    sa, sb = staged_ab(plan)
    assert plan.fast_bytes_needed >= size_a + size_c + sb
    assert size_a + size_c + float(b_rows.max()) < size_a + size_c + sb

    # branch 3: 2-D chunking — the pre-fix model reported the limit `fast`
    # itself; the footprint must instead be the staged strip + chunk peak
    fast = (size_a + size_b + size_c) / 6
    plan = plan_chunks(A, B, crb, P100, fast_limit_bytes=fast)
    assert plan.algorithm in ("chunk1", "chunk2")
    assert plan.n_ac >= 2 and plan.n_b >= 2
    sa, sb = staged_ab(plan)
    assert plan.fast_bytes_needed >= sa + sb
    assert plan.fast_bytes_needed != fast


def test_planned_stats_sparse_lifts_dense_slab_bound(rng):
    """Acceptance: on a wide, sparse-output geometry the dense-slab backend
    model blows a fast-memory limit the plan was meant for, while the
    CSR-native sparse model — scaling with the symbolic nnz caps, not with
    n_cols — fits under it. This is the planner-side statement of why
    backend="sparse" admits larger strips when C is sparse."""
    from conftest import random_dense
    from repro.core.chunking import instance_envelope
    from repro.core.planner import (
        ChunkPlan, planned_stats_dense_slab, planned_stats_sparse,
    )
    from repro.sparse.csr import csr_from_dense

    A = csr_from_dense(random_dense(rng, 64, 64, 0.05))
    B = csr_from_dense(random_dense(rng, 64, 512, 0.01))   # wide, very sparse C
    plan = ChunkPlan("chunk1", (0, 32, 64), (0, 22, 43, 64), 0.0, 0.0)
    env = instance_envelope(A, B, plan)

    dense = planned_stats_dense_slab(plan, env)
    sparse = planned_stats_sparse(plan, env)
    fast_limit = 48 * 1024
    assert dense.fast_bytes_needed > fast_limit
    assert sparse.fast_bytes_needed < dense.fast_bytes_needed
    assert sparse.fast_bytes_needed < fast_limit
    # both models are their components' sum (no hidden terms)
    for model in (dense, sparse):
        assert model.fast_bytes_needed == (
            2 * model.streamed_bytes + model.stationary_bytes
            + model.c_accum_bytes + model.workspace_bytes)
    # chunk2 keeps every strip's accumulator resident: n_ac x the C block
    plan2 = ChunkPlan("chunk2", plan.p_ac, plan.p_b, 0.0, 0.0)
    assert (planned_stats_sparse(plan2, env).c_accum_bytes
            == plan.n_ac * sparse.c_accum_bytes)
    # the sparse model is n_cols-independent at fixed caps: widening B only
    # moves the dense model
    import dataclasses
    wide = dataclasses.replace(env, b_shape=(env.b_shape[0], 4096))
    assert (planned_stats_sparse(plan, wide).fast_bytes_needed
            == sparse.fast_bytes_needed)
    assert (planned_stats_dense_slab(plan, wide).fast_bytes_needed
            > dense.fast_bytes_needed)


def test_replan_for_latency_coarsens_streamed_partition():
    """Latency feedback: drop every other interior boundary of p_b — chunk
    count halves (rounding up), row coverage is preserved, and the modeled
    fast-memory footprint grows accordingly."""
    from repro.core.planner import replan_for_latency

    plan = ChunkPlan("chunk1", (0, 8), (0, 2, 4, 6, 8), 10.0, 100.0)
    p1 = replan_for_latency(plan)
    assert p1.p_b == (0, 4, 8) and p1.n_b == 2
    assert p1.algorithm == plan.algorithm and p1.p_ac == plan.p_ac
    assert p1.fast_bytes_needed > plan.fast_bytes_needed
    p2 = replan_for_latency(p1)
    assert p2.p_b == (0, 8) and p2.n_b == 1
    assert replan_for_latency(p2) is p2          # single chunk: fixed point
    # odd chunk counts round up: 5 -> 3
    odd = ChunkPlan("knl", (0, 4), (0, 1, 2, 3, 4, 5), 0.0, 1.0)
    assert replan_for_latency(odd).p_b == (0, 2, 4, 5)
