"""The bench-trajectory persistence tool: schema, idempotence, CLI contract.

The tool is what CI trusts to keep ``BENCH_trajectory.json`` an append-only,
duplicate-free record; these tests pin the properties that make that safe to
run unattended (re-runs are no-ops, malformed inputs fail loudly, summaries
are bounded) against the committed seed file's actual schema.
"""

import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
TOOL = REPO / "tools" / "bench_trajectory.py"

sys.path.insert(0, str(REPO / "tools"))
from bench_trajectory import (  # noqa: E402
    append_entries, current_sha, normalize_entries, summarize,
)


def _report(lane="chunking_bsr_blocking", n=3):
    return {
        "bench": lane,
        "problem": "synthetic/64x64x64",
        "interpret_mode": True,
        "rows": [{"case": f"r{i}", "bsr_us": 10.0 * (i + 1),
                  "bsr_fast_bytes": 9484, "byte_winner": "bsr"}
                 for i in range(n)],
    }


def test_summarize_is_bounded_and_numeric_median():
    s = summarize(_report(n=5))
    assert s["n_rows"] == 5
    assert s["row_medians"]["bsr_us"] == 30.0
    assert s["row_medians"]["bsr_fast_bytes"] == 9484
    assert "rows" not in s
    assert s["bench"] == "chunking_bsr_blocking"
    # non-numeric row fields never leak into the medians
    assert "case" not in s["row_medians"] and "byte_winner" not in s["row_medians"]


def test_append_idempotent_per_sha_lane(tmp_path):
    out = tmp_path / "traj.json"
    added = append_entries(out, "abc123", "2026-08-08", [_report()])
    assert [e["lane"] for e in added] == ["chunking_bsr_blocking"]
    # same (sha, lane): no-op; new lane under the same sha: appended
    assert append_entries(out, "abc123", "2026-08-08", [_report()]) == []
    added = append_entries(out, "abc123", "2026-08-08",
                           [_report(), _report(lane="chunking_scan_vs_pallas")])
    assert [e["lane"] for e in added] == ["chunking_scan_vs_pallas"]
    doc = json.loads(out.read_text())
    assert len(doc["entries"]) == 2
    # a new sha re-records the same lane (that is the trajectory)
    append_entries(out, "def456", "2026-08-09", [_report()])
    assert len(json.loads(out.read_text())["entries"]) == 3


def test_cli_end_to_end(tmp_path):
    rep = tmp_path / "rep.json"
    rep.write_text(json.dumps(_report()))
    out = tmp_path / "traj.json"
    cmd = [sys.executable, str(TOOL), str(rep), "--sha", "feed01",
           "--date", "2026-08-08", "--out", str(out)]
    r1 = subprocess.run(cmd, capture_output=True, text=True)
    assert r1.returncode == 0 and "appended chunking_bsr_blocking" in r1.stdout
    r2 = subprocess.run(cmd, capture_output=True, text=True)
    assert r2.returncode == 0 and "nothing to append" in r2.stdout
    doc = json.loads(out.read_text())
    assert len(doc["entries"]) == 1
    assert doc["entries"][0]["sha"] == "feed01"


def test_lane_name_required(tmp_path):
    out = tmp_path / "traj.json"
    with pytest.raises(SystemExit, match="no 'bench' lane name"):
        append_entries(out, "abc", "2026-08-08", [{"rows": []}])
    assert not out.exists()


def test_committed_seed_matches_schema():
    doc = json.loads((REPO / "BENCH_trajectory.json").read_text())
    assert isinstance(doc["entries"], list) and doc["entries"]
    for e in doc["entries"]:
        assert {"sha", "date", "lane", "summary"} <= set(e)
        assert e["summary"]["n_rows"] >= 1
        assert isinstance(e["summary"]["row_medians"], dict)


def test_committed_file_has_real_shas_and_no_duplicates():
    """The backfill contract: every committed entry stamps a hex commit sha
    (no 'seed' placeholders) and (sha, lane) pairs are unique."""
    doc = json.loads((REPO / "BENCH_trajectory.json").read_text())
    keys = [(e["sha"], e["lane"]) for e in doc["entries"]]
    assert len(keys) == len(set(keys))
    for sha, _lane in keys:
        assert len(sha) >= 7 and all(c in "0123456789abcdef" for c in sha), sha


def test_default_sha_is_current_head(tmp_path):
    """Without --sha the CLI stamps this repo's HEAD, not a placeholder."""
    head = current_sha()
    assert len(head) >= 10 and all(c in "0123456789abcdef" for c in head)
    rep = tmp_path / "rep.json"
    rep.write_text(json.dumps(_report()))
    out = tmp_path / "traj.json"
    r = subprocess.run(
        [sys.executable, str(TOOL), str(rep), "--date", "2026-08-08",
         "--out", str(out)],
        capture_output=True, text=True, cwd=tmp_path)
    assert r.returncode == 0, r.stderr
    doc = json.loads(out.read_text())
    assert doc["entries"][0]["sha"] == head


def test_append_repairs_preexisting_duplicates(tmp_path):
    """A file an older tool double-logged is normalized on the next append:
    duplicates drop (first wins) even though the new report is a no-op."""
    dup = {"sha": "abc123", "date": "2026-08-08", "lane": "chunking_bsr_blocking",
           "summary": summarize(_report())}
    out = tmp_path / "traj.json"
    out.write_text(json.dumps({"entries": [dup, dict(dup), dict(dup)]}))
    assert normalize_entries([dup, dict(dup)]) == [dup]
    added = append_entries(out, "abc123", "2026-08-08", [_report()])
    assert added == []
    assert len(json.loads(out.read_text())["entries"]) == 1
