"""BSR backend suite: block-size sweep, sentinel/padding contracts, auto pin.

The generic cross-backend matrix (test_backend_conformance, registry-derived)
already runs ``bsr`` at its default block edge (8) through every case,
algorithm, batched-hetero, and service path. This module adds what is
BSR-*specific*:

  * the same full case x algorithm matrix at ``block_size=16`` — together
    with the generic suite this is the bs in {8, 16} sweep, witnessing that
    correctness is block-size independent (caps, staging, and scatter all
    re-derive from ``bs``);
  * the zero-sentinel and padding-row contracts of the kernel
    (``bsr_blocks_with_sentinel`` tamper detection, all-zero padded output
    tiles under an inflated ``nc_pad``, loud envelope-floor overflows);
  * a pinned block-diagonal geometry where ``backend="auto"`` provably
    selects ``bsr`` through the planner byte models — the acceptance witness
    that block-capped envelopes make the blocked backend priceable and
    winnable, while the same geometry without block caps excludes it.
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.chunk_stream import TRACE_COUNTS, chunked_spgemm_batched
from repro.core.chunking import batch_envelope, chunked_spgemm, instance_envelope
from repro.core.kkmem import spgemm_dense_oracle
from repro.core.planner import (
    ChunkPlan, backend_fast_models, select_accumulator_backend,
)
from repro.core.symbolic import bsr_plan_caps
from repro.kernels.bsr_spgemm import bsr_spgemm_blocks, bsr_spgemm_symbolic
from repro.sparse.bsr import bsr_blocks_with_sentinel, bsr_from_dense
from repro.sparse.csr import csr_from_dense, csr_to_dense
from repro.serve.spgemm_service import SpGEMMService
from conftest import assert_close, random_csr
from test_backend_conformance import ALGORITHMS, CASES, _plan


# ---------------------------------------------------------------------------
# block-size sweep: the full conformance matrix again at bs=16
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ALGORITHMS)
@pytest.mark.parametrize("case", sorted(CASES))
def test_bsr_block16_matches_loop_oracle(case, algorithm):
    build, seed = CASES[case]
    A, B = build(np.random.default_rng(seed))
    plan = _plan(algorithm, A, B)
    Cl, sl = chunked_spgemm(A, B, plan, backend="loop")
    Cb, sb = chunked_spgemm(A, B, plan, backend="bsr", block_size=16)
    assert_close(csr_to_dense(Cb), csr_to_dense(Cl), atol=1e-4,
                 msg=f"bsr16/{case}/{algorithm} vs loop oracle")
    assert sb.kernel_calls == sl.kernel_calls


def test_bsr_batched_block16_hetero():
    """Heterogeneous batch under one explicitly block-capped (bs=16) bucket
    envelope, per-instance against the loop oracle."""
    rng = np.random.default_rng(611)
    As = [random_csr(rng, 18, 15, d) for d in (0.1, 0.3)]
    As.append(csr_from_dense(np.zeros((18, 15), np.float32)))
    Bs = [random_csr(rng, 15, 13, d) for d in (0.15, 0.25, 0.35)]
    plan = _plan("chunk2", As[0], Bs[0])
    env = batch_envelope(As, Bs, plan, block_size=16)
    out, _ = chunked_spgemm_batched(As, Bs, plan, envelope=env, backend="bsr")
    for i, (A, B, Cb) in enumerate(zip(As, Bs, out)):
        Cl, _ = chunked_spgemm(A, B, plan, c_pad=env.c_pad, backend="loop")
        assert_close(csr_to_dense(Cb), csr_to_dense(Cl), atol=1e-4,
                     msg=f"bsr16/batched instance {i}")


def test_bsr_service_block16():
    """The serving path with a non-default block edge: the service threads
    its ``block_size`` into every instance envelope, so bucketing keys on
    (and executes under) bs=16 block caps."""
    rng = np.random.default_rng(613)
    As = [random_csr(rng, 12, 10, d) for d in (0.15, 0.3)]
    Bs = [random_csr(rng, 10, 8, d) for d in (0.2, 0.25)]
    svc = SpGEMMService(fast_limit_bytes=1500.0, backend="bsr", max_batch=2,
                        block_size=16)
    ids = [svc.submit(A, B) for A, B in zip(As, Bs)]
    responses = svc.flush()
    assert [r.req_id for r in responses] == ids
    for r, A, B in zip(responses, As, Bs):
        assert_close(csr_to_dense(r.C), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg="bsr16/service")


def test_bsr_batched_requires_block_caps():
    """An explicit envelope without block caps must fail loudly at dispatch,
    not as a shape error deep in staging."""
    rng = np.random.default_rng(617)
    As = [random_csr(rng, 10, 8, 0.3)]
    Bs = [random_csr(rng, 8, 7, 0.3)]
    plan = _plan("chunk1", As[0], Bs[0])
    env = batch_envelope(As, Bs, plan)          # no block_size -> uncapped
    with pytest.raises(ValueError, match="block-capped envelope"):
        chunked_spgemm_batched(As, Bs, plan, envelope=env, backend="bsr")


# ---------------------------------------------------------------------------
# zero-sentinel and padding contracts
# ---------------------------------------------------------------------------


def test_sentinel_rejects_tampered_padding_tail():
    """The kernel's branch-free padding scheme aims every padding slot at the
    appended zero block; a BSR container whose padding tail carries garbage
    would feed nonzero tiles to mis-aimed slots, so the sentinel helper must
    refuse it instead of silently corrupting C."""
    rng = np.random.default_rng(619)
    dense = (rng.random((16, 16)) < 0.3) * rng.standard_normal((16, 16))
    m = bsr_from_dense(dense.astype(np.float32), block_size=8, pad_to=6)
    ok = bsr_blocks_with_sentinel(m)
    assert ok.shape[0] == m.nbl_pad + 1
    assert not np.asarray(ok[-1]).any()
    blocks = np.asarray(m.blocks).copy()
    blocks[-1, 0, 0] = 1.0                       # garbage in the padding tail
    bad = dataclasses.replace(m, blocks=jnp.asarray(blocks))
    with pytest.raises(ValueError, match="zero-sentinel"):
        bsr_blocks_with_sentinel(bad)


def test_kernel_padding_rows_flush_zero_tiles():
    """Under an inflated ``nc_pad`` the table rows past ``n_c_blocks`` are
    all-sentinel, so their grid steps MAC nothing and flush exactly-zero
    tiles — the invariant that makes the consumers' crop-to-``n_c_blocks``
    scatter safe (``c_indices`` past ``n_c`` is 0 and would alias block
    (i, 0) if a consumer ever scattered the tail)."""
    rng = np.random.default_rng(623)
    bs = 8
    da = (rng.random((16, 24)) < 0.4) * rng.standard_normal((16, 24))
    db = (rng.random((24, 16)) < 0.4) * rng.standard_normal((24, 16))
    A = bsr_from_dense(da.astype(np.float32), bs)
    B = bsr_from_dense(db.astype(np.float32), bs)
    meta = bsr_spgemm_symbolic(A, B, nc_pad=32)   # inflated: n_c <= 4 here
    assert meta.n_c_blocks < meta.nc_pad
    assert (meta.a_slots[meta.n_c_blocks:] == A.nbl_pad).all()
    out = bsr_spgemm_blocks(
        bsr_blocks_with_sentinel(A), bsr_blocks_with_sentinel(B),
        jnp.asarray(meta.a_slots), jnp.asarray(meta.b_slots),
        nc_pad=meta.nc_pad, u_max=meta.u_max, bs=bs, interpret=True,
    )
    out = np.asarray(out)
    assert not out[meta.n_c_blocks:].any(), "padding rows must be zero tiles"
    # the real tiles reassemble to the dense product
    ref = da @ db
    got = np.zeros_like(ref)
    ptr = meta.c_indptr
    for i in range(A.mb):
        for e in range(int(ptr[i]), int(ptr[i + 1])):
            j = int(meta.c_indices[e])
            got[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = out[e]
    assert_close(got, ref, atol=1e-4)


def test_dense_last_block_row_regression():
    """The geometry most prone to sentinel/padding aliasing: A's *final*
    block row is fully dense, so its real blocks butt directly against the
    padded tail and the densest C block row is the last one — a mis-aimed
    padding slot or an uncropped scatter would corrupt exactly those rows.
    Pinned against the dense oracle through every chunk order, and the
    sentinel contract re-verified on the padded container itself."""
    rng = np.random.default_rng(641)
    da = np.zeros((24, 16), np.float32)
    da[:8] = ((rng.random((8, 16)) < 0.2)
              * rng.standard_normal((8, 16))).astype(np.float32)
    da[16:] = rng.standard_normal((8, 16)).astype(np.float32)  # dense tail row
    db = ((rng.random((16, 24)) < 0.35)
          * rng.standard_normal((16, 24))).astype(np.float32)
    A, B = csr_from_dense(da), csr_from_dense(db)
    m = bsr_from_dense(da, 8, pad_to=8)       # real blocks end at the tail
    assert int(np.asarray(m.block_indptr)[-1]) < m.nbl_pad
    assert bsr_blocks_with_sentinel(m).shape[0] == m.nbl_pad + 1
    for algorithm in ALGORITHMS:
        plan = _plan(algorithm, A, B)
        Cb, _ = chunked_spgemm(A, B, plan, backend="bsr")
        assert_close(csr_to_dense(Cb), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg=f"dense-last-block-row/{algorithm}")


def test_symbolic_envelope_floor_overflow_raises():
    """Envelope floors that do not dominate the realized block structure must
    raise (the kernel would otherwise silently drop contributor pairs or
    whole C blocks into truncated tables)."""
    rng = np.random.default_rng(627)
    da = rng.standard_normal((16, 16)).astype(np.float32)
    db = rng.standard_normal((16, 16)).astype(np.float32)
    A = bsr_from_dense(da, 8)
    B = bsr_from_dense(db, 8)
    ref = bsr_spgemm_symbolic(A, B)
    assert ref.n_c_blocks == 4 and int(ref.a_slots.max()) >= 0
    with pytest.raises(ValueError, match="do not dominate"):
        bsr_spgemm_symbolic(A, B, nc_pad=ref.n_c_blocks - 1)
    with pytest.raises(ValueError, match="do not dominate"):
        bsr_spgemm_symbolic(A, B, u_max=1)        # dense 2x2 blocks: u == 2


# ---------------------------------------------------------------------------
# pinned auto dispatch: block-diagonal geometry where bsr provably wins
# ---------------------------------------------------------------------------


def _block_diag(rng, nblocks=8, bs=8):
    n = nblocks * bs
    d = np.zeros((n, n), np.float32)
    for i in range(nblocks):
        s = i * bs
        d[s:s + bs, s:s + bs] = rng.standard_normal((bs, bs)).astype(np.float32)
    return csr_from_dense(d)


def test_auto_selects_bsr_on_block_diagonal():
    """64x64 block-diagonal operands with dense 8x8 blocks, block-aligned
    partitions: every staged piece is a handful of MXU tiles while the CSR
    accumulators pay entry-level scratch for 512-nnz strips, so the bsr byte
    model is the strict minimum and ``auto`` must select it. The same
    geometry without block caps prices bsr at infinity and must *not* select
    it — the opt-in contract."""
    rng = np.random.default_rng(631)
    A = _block_diag(rng)
    B = _block_diag(rng)
    plan = ChunkPlan("knl", (0, 64), (0, 32, 64), 0.0, 0.0)
    env = instance_envelope(A, B, plan, block_size=8)
    assert env.bsr_caps and env.bsr_caps[0] == 8
    models = backend_fast_models(plan, env)
    best = models["bsr"].fast_bytes_needed
    assert all(best < m.fast_bytes_needed
               for name, m in models.items() if name != "bsr"), \
        {n: m.fast_bytes_needed for n, m in models.items()}
    assert select_accumulator_backend(plan, env) == "bsr"
    # uncapped envelope: bsr excluded from the resolve entirely
    assert select_accumulator_backend(
        plan, instance_envelope(A, B, plan)) != "bsr"
    # end to end through the dispatcher, with the trace witness that the
    # bsr core (not merely the bsr price) is what auto ran
    before = TRACE_COUNTS["knl_bsr"]
    C, _ = chunked_spgemm(A, B, plan, backend="auto", block_size=8)
    assert TRACE_COUNTS["knl_bsr"] == before + 1
    assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-4,
                 msg="auto->bsr block-diagonal")


def test_bsr_plan_caps_dominate_instances():
    """The envelope-level caps (bsr_plan_caps) must dominate every realized
    per-(strip, chunk) structure — the property the executor relies on when
    it passes envelope floors to ``bsr_spgemm_symbolic``. Witnessed by the
    executor completing under caps built from the same instances."""
    rng = np.random.default_rng(637)
    A = random_csr(rng, 20, 18, 0.35)
    B = random_csr(rng, 18, 14, 0.3)
    for algorithm in ALGORITHMS:
        plan = _plan(algorithm, A, B)
        caps = bsr_plan_caps(A, B, plan, 8)
        assert caps.as_tuple()[0] == 8
        C, _ = chunked_spgemm(A, B, plan, backend="bsr")
        assert_close(csr_to_dense(C), spgemm_dense_oracle(A, B), atol=1e-4,
                     msg=f"caps-dominate/{algorithm}")
