"""Training machinery: microbatch invariance, compression bounds, schedules,
loss actually falls."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as tf
from repro.train.optim import TrainConfig, lr_schedule, adamw_init, adamw_update
from repro.train.compress import compress_grads, decompress_grads, ef_init, roundtrip
from repro.train.step import make_train_step, init_opt_state
from repro.data.pipeline import SyntheticLM

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64, d_ff=128,
                  vocab_size=128, n_heads=8, n_kv_heads=2, q_chunk=16,
                  attn_chunk=16, compute_dtype="float32")


def _batch(b=4, s=32, seed=0):
    return jax.tree.map(jnp.asarray, SyntheticLM(CFG, b, s, seed=seed).batch(0))


def test_microbatch_gradient_invariance():
    """n_micro=1 and n_micro=4 must produce the same update (up to fp tolerance):
    gradient accumulation is exact for mean losses over equal microbatches."""
    params = tf.init_params(KEY, CFG)
    batch = _batch(b=8)
    outs = []
    for n in (1, 4):
        tcfg = TrainConfig(microbatches=n, total_steps=10, warmup_steps=0)
        step = make_train_step(CFG, tcfg)
        opt = init_opt_state(CFG, tcfg, params)
        p2, _, m = step(params, opt, batch)
        outs.append((p2, float(m["loss"])))
    (pa, la), (pb, lb) = outs
    assert abs(la - lb) < 1e-4
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), pa, pb)
    assert jax.tree.reduce(max, diffs, 0.0) < 1e-4


def test_loss_decreases_over_steps():
    params = tf.init_params(KEY, CFG)
    tcfg = TrainConfig(learning_rate=1e-3, microbatches=1, total_steps=30,
                       warmup_steps=2)
    step = jax.jit(make_train_step(CFG, tcfg))
    opt = init_opt_state(CFG, tcfg, params)
    losses = []
    for i in range(15):
        batch = jax.tree.map(jnp.asarray, SyntheticLM(CFG, 4, 32, seed=0).batch(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_grad_compression_error_bound():
    """int8 quantization error per tensor <= scale/2 elementwise; error feedback
    carries the residual."""
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)),
         "b": jnp.asarray(rng.standard_normal(17).astype(np.float32) * 10)}
    ef = ef_init(g)
    q, ef2 = compress_grads(g, ef)
    deq = decompress_grads(q)
    for k in g:
        amax = float(jnp.max(jnp.abs(g[k])))
        err = np.abs(np.asarray(deq[k]) - np.asarray(g[k]))
        assert err.max() <= amax / 127.0 * 0.5 + 1e-6
        # ef carries exactly the residual
        np.testing.assert_allclose(np.asarray(ef2[k]),
                                   np.asarray(g[k]) - np.asarray(deq[k]),
                                   atol=1e-6)


def test_error_feedback_reinjects():
    """Constant gradient + EF: the long-run mean of dequantized grads converges
    to the true gradient (bias-free compression)."""
    g = {"w": jnp.full((8, 8), 0.001, jnp.float32) +
         jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)) * 1.0,
                     jnp.float32)}
    ef = ef_init(g)
    acc = np.zeros((8, 8))
    n = 50
    for _ in range(n):
        deq, ef = roundtrip(g, ef)
        acc += np.asarray(deq["w"])
    np.testing.assert_allclose(acc / n, np.asarray(g["w"]), atol=1e-3)


def test_compressed_training_still_learns():
    params = tf.init_params(KEY, CFG)
    tcfg = TrainConfig(learning_rate=1e-3, microbatches=1, total_steps=30,
                       warmup_steps=2, grad_compression="int8")
    step = jax.jit(make_train_step(CFG, tcfg))
    opt = init_opt_state(CFG, tcfg, params)
    assert "ef" in opt
    losses = []
    for i in range(15):
        batch = jax.tree.map(jnp.asarray, SyntheticLM(CFG, 4, 32, seed=0).batch(i))
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                       min_lr_fraction=0.1)
    assert float(lr_schedule(tcfg, 0)) == 0.0
    assert float(lr_schedule(tcfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_schedule(tcfg, 100)) == pytest.approx(1e-4, rel=1e-2)
    mid = float(lr_schedule(tcfg, 55))
    assert 1e-4 < mid < 1e-3


def test_clipping_engages():
    tcfg = TrainConfig(clip_norm=0.001)
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}
    opt = adamw_init(p)
    p2, _, m = adamw_update(tcfg, p, g, opt)
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_data_pipeline_deterministic():
    a = SyntheticLM(CFG, 4, 32, seed=7).batch(3)
    b = SyntheticLM(CFG, 4, 32, seed=7).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(CFG, 4, 32, seed=8).batch(3)
    assert not np.array_equal(a["tokens"], c["tokens"])
