"""Static backend auditor: positive corpus runs, negative fixtures proving
each analysis catches its bug class, registry validation, and the
same-envelope retrace pin.

Everything here is abstract tracing (``jax.make_jaxpr``) plus host
arithmetic — no kernel executes, so the whole file stays in the fast lane.
Corpus geometries use their own dims/seeds (211+), disjoint from the
conformance cases whose first-trace deltas are pinned exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (
    audit_all, audit_vmem, check_dma_structure, check_retrace,
    simulate_schedule,
)
from repro.analysis import corpus
from repro.core import backend_registry
from repro.core.backend_registry import BackendSpec, TraceTarget
from repro.core.chunking import instance_envelope
from repro.kernels._compat import ANY as _ANY
from repro.kernels.dma_schedule import SlotSchedule, TWO_SLOT
from repro.kernels.hash_accum_spgemm import probe_step_bound


# ---------------------------------------------------------------------------
# positive: the shipped backends pass every analysis
# ---------------------------------------------------------------------------


def test_audit_clean_on_fast_corpus():
    """Every auditable backend x algorithm passes all analyses on the fast
    corpus subset (the CLI / static-audit CI job runs the full corpus)."""
    rep = audit_all(cases=["skewed_rows"])
    assert rep["ok"], rep["violations"]
    # every accumulator backend's byte model was actually domination-checked
    checked = {r["backend"] for r in rep["records"]
               if r["dominated"] is True}
    assert {"pallas", "sparse", "hash", "bsr"} <= checked
    # the host-loop oracle is the only non-auditable backend
    assert [s["backend"] for s in rep["skipped"]] == ["loop"]


def test_schedule_simulation_race_free():
    for total in (0, 1, 2, 3, 7, 12):
        assert simulate_schedule(total) == []


def test_retrace_identical_across_backends():
    """Same envelope, different instance data => byte-identical jaxprs, for
    every registered backend with a jitted core (the compile-key pin)."""
    backend_registry.ensure_registered()
    A, B = corpus.build_case("dense_row")
    A2, B2 = corpus.retrace_pair(A, B)
    for spec in backend_registry.specs():
        if not spec.supports_audit:
            continue
        for algorithm in ("knl", "chunk2"):
            plan = corpus.make_plan(algorithm, A, B)
            block = spec.block_size if spec.needs_block_caps else None
            env = instance_envelope(A, B, plan, block_size=block).union(
                instance_envelope(A2, B2, plan, block_size=block))
            t1 = spec.audit_trace(A, B, plan, env.c_pad, env)
            t2 = spec.audit_trace(A2, B2, plan, env.c_pad, env)
            assert check_retrace(t1, t2) == [], (spec.name, algorithm)


# ---------------------------------------------------------------------------
# negative fixtures: each analysis demonstrably catches its bug class
# ---------------------------------------------------------------------------


def test_undercounting_byte_model_is_flagged():
    """A model claiming fewer bytes than the trace stages must fail the
    domination check — the planner-undercount bug class."""
    spec = backend_registry.get("sparse")
    A, B = corpus.build_case("skewed_rows")
    plan = corpus.make_plan("chunk1", A, B)
    env = instance_envelope(A, B, plan)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    honest = spec.byte_model(plan, env)
    assert audit_vmem(traced, honest).dominated is True
    lying = dataclasses.replace(honest, fast_bytes_needed=64.0)
    assert audit_vmem(traced, lying).dominated is False


class _SlotAliasingSchedule(SlotSchedule):
    """Broken schedule: the prefetch targets the slot being read."""

    def prefetch_slot(self, lin):
        return self.read_slot(lin)


class _OneSlotSchedule(SlotSchedule):
    """Broken schedule: single-slot 'double' buffer (every copy collides)."""

    n_slots = 1


def test_slot_aliasing_schedule_is_flagged():
    violations = simulate_schedule(6, _SlotAliasingSchedule())
    assert any("write-after-read race" in v for v in violations)
    assert simulate_schedule(6, TWO_SLOT) == []


def test_one_slot_schedule_is_flagged():
    assert simulate_schedule(4, _OneSlotSchedule())


def _toy_missing_wait_core():
    """A two-slot-shaped kernel that starts a DMA and reads the buffer
    without ever waiting — the unsynchronized-read bug class."""

    def kernel(x_hbm, o_ref, buf, sem):
        pltpu.make_async_copy(x_hbm, buf.at[0], sem.at[0]).start()
        o_ref[...] = buf[0]

    @jax.jit
    def core(x):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=0,
                grid=(1,),
                in_specs=[pl.BlockSpec(memory_space=_ANY)],
                out_specs=pl.BlockSpec(x.shape, lambda i: (0, 0)),
                scratch_shapes=[
                    pltpu.VMEM((2,) + x.shape, jnp.float32),
                    pltpu.SemaphoreType.DMA((2,)),
                ],
            ),
            out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            interpret=True,
        )(x)

    return core


def test_missing_dma_wait_is_flagged():
    core = _toy_missing_wait_core()
    traced = jax.make_jaxpr(core)(jnp.ones((4, 8), jnp.float32))
    violations = check_dma_structure(traced)
    assert any("no dma_wait" in v for v in violations)
    assert any("read before any dma_wait" in v
               or "before any dma_wait" in v for v in violations)


def test_leaked_python_scalar_is_flagged():
    """A core that bakes a value from the instance *data* into the trace
    diverges between same-envelope instances — the silent-retrace bug."""
    A, _ = corpus.build_case("skewed_rows")
    A2, _ = corpus.retrace_pair(A, A)
    cap = max(np.asarray(A.data).size, np.asarray(A2.data).size)

    def make_target(M):
        leak = float(np.asarray(M.data)[0])   # Python scalar from the data
        staged = np.zeros(cap, np.float32)    # envelope-shaped staging
        staged[: np.asarray(M.data).size] = np.asarray(M.data)

        def core(data):
            return data * leak

        return TraceTarget(fn=jax.jit(core), args=(jnp.asarray(staged),))

    violations = check_retrace(make_target(A), make_target(A2))
    assert violations and "leaked" in violations[0]


def test_staging_aval_mismatch_is_flagged():
    a = TraceTarget(fn=jax.jit(lambda x: x), args=(jnp.ones((3,)),))
    b = TraceTarget(fn=jax.jit(lambda x: x), args=(jnp.ones((4,)),))
    violations = check_retrace(a, b)
    assert violations and "staging is broken" in violations[0]


def test_hash_probe_bound_matches_planner():
    """The hash kernel's while-loop bound is the planner's table size; an
    audit expecting a different bound must flag it."""
    from repro.analysis.dma import check_while_bounds

    spec = backend_registry.get("hash")
    A, B = corpus.build_case("duplicate_heavy")
    plan = corpus.make_plan("chunk1", A, B)
    env = instance_envelope(A, B, plan)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    bound = probe_step_bound(target.meta["table_size"])
    assert check_while_bounds(traced, expected_bound=bound) == []
    assert check_while_bounds(traced, expected_bound=bound + 1)


# ---------------------------------------------------------------------------
# registry validation (import-time spec contracts)
# ---------------------------------------------------------------------------


def _spec_kwargs(**overrides):
    base = dict(
        name="_audit_test_backend",
        executors=dict.fromkeys(backend_registry.ALGORITHMS, lambda: None),
    )
    base.update(overrides)
    return base


def _expect_register_error(match, **overrides):
    spec = BackendSpec(**_spec_kwargs(**overrides))
    with pytest.raises(ValueError, match=match):
        backend_registry.register(spec)
    assert spec.name not in backend_registry._REGISTRY


def test_register_rejects_trace_key_without_alg_placeholder():
    _expect_register_error("'{alg}' placeholder",
                           trace_key="static_key_no_placeholder")


def test_register_rejects_batched_trace_key_without_alg_placeholder():
    _expect_register_error("'{alg}' placeholder",
                           trace_key="{alg}_ok",
                           trace_key_batched="batched_no_placeholder")


def test_register_rejects_block_caps_without_block_size():
    _expect_register_error("registers no\\s+block_size",
                           needs_block_caps=True)


def test_register_rejects_missing_executor():
    spec = BackendSpec(name="_audit_test_backend",
                       executors={"knl": lambda: None})
    with pytest.raises(ValueError, match="missing executors"):
        backend_registry.register(spec)


def test_registered_specs_satisfy_the_validated_contracts():
    """The shipped roster passes the new import-time validations (they ran
    at registration; re-assert the invariants directly)."""
    for spec in backend_registry.specs():
        for template in (spec.trace_key, spec.trace_key_batched):
            assert template is None or "{alg}" in template, spec.name
        if spec.needs_block_caps:
            assert spec.block_size is not None, spec.name
