"""Static backend auditor: positive corpus runs, negative fixtures proving
each analysis catches its bug class, registry validation, and the
same-envelope retrace pin.

Everything here is abstract tracing (``jax.make_jaxpr``) plus host
arithmetic — no kernel executes, so the whole file stays in the fast lane.
Corpus geometries use their own dims/seeds (211+), disjoint from the
conformance cases whose first-trace deltas are pinned exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (
    audit_all, audit_vmem, build_program, check_dma_structure,
    check_interleave, check_lint, check_retrace, check_traffic, explore,
    lint_traced, normalize_analyses, simulate_schedule,
)
from repro.analysis import corpus
from repro.core import backend_registry
from repro.core.backend_registry import BackendSpec, TraceTarget
from repro.core.chunking import instance_envelope
from repro.kernels._compat import ANY as _ANY
from repro.kernels.dma_schedule import SlotSchedule, TWO_SLOT
from repro.kernels.hash_accum_spgemm import probe_step_bound


# ---------------------------------------------------------------------------
# positive: the shipped backends pass every analysis
# ---------------------------------------------------------------------------


def test_audit_clean_on_fast_corpus():
    """Every auditable backend x algorithm passes all analyses on the fast
    corpus subset (the CLI / static-audit CI job runs the full corpus)."""
    rep = audit_all(cases=["skewed_rows"])
    assert rep["ok"], rep["violations"]
    # every accumulator backend's byte model was actually domination-checked
    checked = {r["backend"] for r in rep["records"]
               if r["dominated"] is True}
    assert {"pallas", "sparse", "hash", "bsr"} <= checked
    # the host-loop oracle is the only non-auditable backend
    assert [s["backend"] for s in rep["skipped"]] == ["loop"]
    # flow equality actually ran on every backend with a traffic model, and
    # the scan backend's exemption is recorded, not silently skipped
    for r in rep["records"]:
        if r["backend"] in ("pallas", "sparse", "hash", "bsr"):
            assert r["traffic"]["checked"], r
            assert r["traffic"]["in_events"] > 0, r
        elif r["backend"] == "scan":
            assert r["traffic"]["checked"] is False
            assert "reason" in r["traffic"]
        # zero lint errors on the shipped kernels (warnings are the on-TPU
        # validation worklist and do not fail the audit)
        assert r["lint"]["counts"]["error"] == 0, r
    # the streaming backends' two-slot schedules were model-checked
    streamed = {r["backend"] for r in rep["records"]
                if any(s["ok"] for s in r["interleave"]["streams"])}
    assert {"pallas", "sparse", "hash"} <= streamed


def test_audit_analyses_subset():
    """The --analyses subset machinery: only the requested passes run and
    their record fields appear."""
    rep = audit_all(cases=["skewed_rows"], backends=["pallas"],
                    algorithms=["knl"], analyses=["lint"])
    assert rep["ok"]
    assert rep["analyses"] == ["lint"]
    (record,) = rep["records"]
    assert "lint" in record and "vmem" not in record and "traffic" not in record
    with pytest.raises(ValueError, match="unknown analyses"):
        normalize_analyses(["lint", "nonsense"])


def test_schedule_simulation_race_free():
    for total in (0, 1, 2, 3, 7, 12):
        assert simulate_schedule(total) == []


def test_retrace_identical_across_backends():
    """Same envelope, different instance data => byte-identical jaxprs, for
    every registered backend with a jitted core (the compile-key pin)."""
    backend_registry.ensure_registered()
    A, B = corpus.build_case("dense_row")
    A2, B2 = corpus.retrace_pair(A, B)
    for spec in backend_registry.specs():
        if not spec.supports_audit:
            continue
        for algorithm in ("knl", "chunk2"):
            plan = corpus.make_plan(algorithm, A, B)
            block = spec.block_size if spec.needs_block_caps else None
            env = instance_envelope(A, B, plan, block_size=block).union(
                instance_envelope(A2, B2, plan, block_size=block))
            t1 = spec.audit_trace(A, B, plan, env.c_pad, env)
            t2 = spec.audit_trace(A2, B2, plan, env.c_pad, env)
            assert check_retrace(t1, t2) == [], (spec.name, algorithm)


# ---------------------------------------------------------------------------
# negative fixtures: each analysis demonstrably catches its bug class
# ---------------------------------------------------------------------------


def test_undercounting_byte_model_is_flagged():
    """A model claiming fewer bytes than the trace stages must fail the
    domination check — the planner-undercount bug class."""
    spec = backend_registry.get("sparse")
    A, B = corpus.build_case("skewed_rows")
    plan = corpus.make_plan("chunk1", A, B)
    env = instance_envelope(A, B, plan)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    honest = spec.byte_model(plan, env)
    assert audit_vmem(traced, honest).dominated is True
    lying = dataclasses.replace(honest, fast_bytes_needed=64.0)
    assert audit_vmem(traced, lying).dominated is False


def _pipeline_fixture(frac=0.25):
    from repro.core.memory_model import P100
    from repro.core.planner import plan_pipeline
    from repro.core.symbolic import pipeline_output_caps
    from repro.sparse import multigrid

    A, R, P = multigrid.problem("laplace3d", 4)
    limit = float(A.nbytes() + P.nbytes() + R.nbytes()) * frac
    plan = plan_pipeline(A, P, R, P100, fast_limit_bytes=limit)
    caps = pipeline_output_caps(A, P, R, plan.plan1.p_ac, plan.plan2.p_ac)
    return A, P, R, plan, caps


def test_pipeline_audit_clean_on_chunked_hops():
    """Both hops of the two-hop pipeline trace and pass the vmem domination
    check plus the composed-model checks, for the sparse and hash backends."""
    from repro.core.pipeline_spgemm import audit_pipeline

    A, P, R, plan, caps = _pipeline_fixture()
    assert "whole_fast" not in (plan.plan1.algorithm, plan.plan2.algorithm)
    for backend in ("sparse", "hash"):
        record, violations = audit_pipeline(A, P, R, plan, backend=backend,
                                            caps=caps)
        assert violations == [], (backend, violations)
        assert set(record["hops"]) == {"hop1", "hop2"}
        for hop in record["hops"].values():
            assert hop["model_bytes"] >= hop["traced_bytes"]


def test_pipeline_double_counted_intermediate_is_flagged():
    """The negative fixture for the composed byte model: a model that adds
    the resident intermediate's bytes *twice* (once per hop) still dominates
    every trace — domination alone cannot catch it — but must fail the
    once-counted consistency invariant."""
    from repro.core.pipeline_spgemm import (
        check_pipeline_model, pipeline_envelope, pipeline_fast_model,
    )

    A, P, R, plan, caps = _pipeline_fixture()
    penv = pipeline_envelope(A, P, R, plan, caps)
    honest = pipeline_fast_model(plan, penv, "sparse")
    assert honest.t_bytes > 0
    assert check_pipeline_model(honest) == []
    double_counted = dataclasses.replace(
        honest, fast_bytes_needed=honest.fast_bytes_needed + honest.t_bytes)
    violations = check_pipeline_model(double_counted)
    assert violations and "counted exactly once" in violations[0]


class _SlotAliasingSchedule(SlotSchedule):
    """Broken schedule: the prefetch targets the slot being read."""

    def prefetch_slot(self, lin):
        return self.read_slot(lin)


class _OneSlotSchedule(SlotSchedule):
    """Broken schedule: single-slot 'double' buffer (every copy collides)."""

    n_slots = 1


def test_slot_aliasing_schedule_is_flagged():
    violations = simulate_schedule(6, _SlotAliasingSchedule())
    assert any("write-after-read race" in v for v in violations)
    assert simulate_schedule(6, TWO_SLOT) == []


def test_one_slot_schedule_is_rejected_at_construction():
    """Below two slots, every prefetch collides with the read by
    construction — the schedule class refuses to exist."""
    with pytest.raises(ValueError, match="n_slots >= 2"):
        _OneSlotSchedule()


def test_schedule_replay_edge_cases():
    """Host-replay boundary conditions: an empty stream has nothing to
    race, a single-chunk stream is prime-only (no prefetch), and wider
    double buffers replay clean too."""
    assert simulate_schedule(0) == []
    assert simulate_schedule(1) == []

    class _ThreeSlot(SlotSchedule):
        n_slots = 3

    for total in (0, 1, 2, 5, 9):
        assert simulate_schedule(total, _ThreeSlot()) == []


def _toy_missing_wait_core():
    """A two-slot-shaped kernel that starts a DMA and reads the buffer
    without ever waiting — the unsynchronized-read bug class."""

    def kernel(x_hbm, o_ref, buf, sem):
        pltpu.make_async_copy(x_hbm, buf.at[0], sem.at[0]).start()
        o_ref[...] = buf[0]

    @jax.jit
    def core(x):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=0,
                grid=(1,),
                in_specs=[pl.BlockSpec(memory_space=_ANY)],
                out_specs=pl.BlockSpec(x.shape, lambda i: (0, 0)),
                scratch_shapes=[
                    pltpu.VMEM((2,) + x.shape, jnp.float32),
                    pltpu.SemaphoreType.DMA((2,)),
                ],
            ),
            out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
            interpret=True,
        )(x)

    return core


def test_missing_dma_wait_is_flagged():
    core = _toy_missing_wait_core()
    traced = jax.make_jaxpr(core)(jnp.ones((4, 8), jnp.float32))
    violations = check_dma_structure(traced)
    assert any("no dma_wait" in v for v in violations)
    assert any("read before any dma_wait" in v
               or "before any dma_wait" in v for v in violations)


def test_leaked_python_scalar_is_flagged():
    """A core that bakes a value from the instance *data* into the trace
    diverges between same-envelope instances — the silent-retrace bug."""
    A, _ = corpus.build_case("skewed_rows")
    A2, _ = corpus.retrace_pair(A, A)
    cap = max(np.asarray(A.data).size, np.asarray(A2.data).size)

    def make_target(M):
        leak = float(np.asarray(M.data)[0])   # Python scalar from the data
        staged = np.zeros(cap, np.float32)    # envelope-shaped staging
        staged[: np.asarray(M.data).size] = np.asarray(M.data)

        def core(data):
            return data * leak

        return TraceTarget(fn=jax.jit(core), args=(jnp.asarray(staged),))

    violations = check_retrace(make_target(A), make_target(A2))
    assert violations and "leaked" in violations[0]


def test_staging_aval_mismatch_is_flagged():
    a = TraceTarget(fn=jax.jit(lambda x: x), args=(jnp.ones((3,)),))
    b = TraceTarget(fn=jax.jit(lambda x: x), args=(jnp.ones((4,)),))
    violations = check_retrace(a, b)
    assert violations and "staging is broken" in violations[0]


def test_hash_probe_bound_matches_planner():
    """The hash kernel's while-loop bound is the planner's table size; an
    audit expecting a different bound must flag it."""
    from repro.analysis.dma import check_while_bounds

    spec = backend_registry.get("hash")
    A, B = corpus.build_case("duplicate_heavy")
    plan = corpus.make_plan("chunk1", A, B)
    env = instance_envelope(A, B, plan)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    bound = probe_step_bound(target.meta["table_size"])
    assert check_while_bounds(traced, expected_bound=bound) == []
    assert check_while_bounds(traced, expected_bound=bound + 1)


def _traced_and_expected(backend="pallas", algorithm="chunk1",
                         case="skewed_rows"):
    spec = backend_registry.get(backend)
    A, B = corpus.build_case(case)
    plan = corpus.make_plan(algorithm, A, B)
    block = spec.block_size if spec.needs_block_caps else None
    env = instance_envelope(A, B, plan, block_size=block)
    target = spec.audit_trace(A, B, plan, env.c_pad, env)
    traced = jax.make_jaxpr(target.fn)(*target.args)
    expected = spec.traffic_model(A, B, plan, env.c_pad, env, target.meta)
    return traced, expected, target.meta.get("scalar_args", ())


def test_traffic_flow_divergence_is_flagged():
    """A traffic model missing one copy event diverges from the trace —
    flow equality is per-event, so the diff names the extra traced copy."""
    traced, expected, scalars = _traced_and_expected()
    clean, _ = check_traffic(traced, expected, scalar_args=scalars)
    assert clean == []
    short = dataclasses.replace(
        expected.in_ops[1], events=expected.in_ops[1].events[:-1])
    tampered = dataclasses.replace(
        expected, in_ops=(expected.in_ops[0], short, expected.in_ops[2]))
    violations, _ = check_traffic(traced, tampered, scalar_args=scalars)
    assert any("copy events" in v and "slow->fast" in v for v in violations)


def test_traffic_stats_undercount_is_flagged():
    """A kernel moving more bytes than its ChunkStats report breaks the
    stats tie: the merged model flow no longer matches the logged events."""
    traced, expected, scalars = _traced_and_expected(backend="sparse")
    undercounted = dataclasses.replace(
        expected, stats_in=expected.stats_in[:-1])
    violations, _ = check_traffic(traced, undercounted, scalar_args=scalars)
    assert any("stats tie broken" in v for v in violations)
    assert any("absent from the stats" in v for v in violations)


def test_traffic_wrong_event_size_diff_names_the_event():
    """Per-event diff: a single wrong byte size is located by index."""
    traced, expected, scalars = _traced_and_expected()
    events = list(expected.in_ops[0].events)
    events[1] = events[1] + 4.0
    bad = dataclasses.replace(expected.in_ops[0], events=tuple(events))
    tampered = dataclasses.replace(
        expected, in_ops=(bad,) + expected.in_ops[1:])
    violations, _ = check_traffic(traced, tampered, scalar_args=scalars)
    assert any("first divergence at event 1" in v for v in violations)


def test_interleave_counterexample_on_aliasing_schedule():
    """The model checker proves the aliasing schedule unsafe with a
    *minimal* counterexample: two starts into the same slot, nothing else."""
    cex = explore(build_program(4, _SlotAliasingSchedule()), n_slots=2)
    assert cex is not None
    assert "still in flight" in cex.hazard
    assert len(cex.trace) == 2          # shortest possible witness
    text = cex.describe()
    assert "shortest interleaving" in text
    assert simulate_schedule(4, _SlotAliasingSchedule()) != []


def test_interleave_clean_on_two_slot_schedule():
    for total in (0, 1, 2, 6):
        for n_fields in (1, 3):
            ops = build_program(total, TWO_SLOT, n_fields)
            assert explore(ops, n_slots=2, n_fields=n_fields) is None


def test_interleave_deadlock_is_flagged():
    """A schedule that never primes slot 0 leaves step 0's wait forever
    unsatisfiable — reported as a deadlock, not an infinite search."""

    class _NoPrime(SlotSchedule):
        def is_prime_step(self, lin):
            return False

    cex = explore(build_program(3, _NoPrime()), n_slots=2)
    assert cex is not None and "deadlock" in cex.hazard


def test_interleave_checks_real_streaming_backends():
    traced, _, _ = _traced_and_expected(backend="sparse")
    violations, info = check_interleave(traced)
    assert violations == []
    assert info["streams"] and info["streams"][0]["n_fields"] == 3


def _toy_lintable_core(bound_ref: bool):
    """A kernel with a deliberately misaligned block shape and, when
    ``bound_ref`` is set, a while loop whose trip bound is read from a ref
    (statically unbounded — the lint's error class)."""

    def kernel(n_ref, o_ref):
        if bound_ref:
            def cond(c):
                return c < n_ref[0]
        else:
            def cond(c):
                return c < 7
        jax.lax.while_loop(cond, lambda c: c + 1, 0)
        o_ref[...] = jnp.zeros_like(o_ref)

    @jax.jit
    def core(n):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(1,),
                in_specs=[],
                out_specs=pl.BlockSpec((4, 40), lambda i, n: (0, 0)),
            ),
            out_shape=jax.ShapeDtypeStruct((4, 40), jnp.float32),
            interpret=True,
        )(n)

    return core


def test_lint_flags_nonstatic_while_bound():
    traced = jax.make_jaxpr(_toy_lintable_core(bound_ref=True))(
        jnp.arange(1, dtype=jnp.int32))
    violations, info = check_lint(traced)
    assert any("no statically evident trip bound" in v for v in violations)
    assert info["counts"]["error"] >= 1
    # the literal-bounded variant of the same kernel lints clean of errors
    clean = jax.make_jaxpr(_toy_lintable_core(bound_ref=False))(
        jnp.arange(1, dtype=jnp.int32))
    assert check_lint(clean)[0] == []


def test_lint_flags_misaligned_block_shape():
    traced = jax.make_jaxpr(_toy_lintable_core(bound_ref=False))(
        jnp.arange(1, dtype=jnp.int32))
    diags = lint_traced(traced)
    lane = [d for d in diags if d.check == "tile-alignment"
            and "lane dim 40" in d.message]
    assert lane and all(d.severity == "warning" for d in lane)
    sub = [d for d in diags if d.check == "tile-alignment"
           and "sublane dim 4" in d.message]
    assert sub


def test_lint_flags_untrusted_esc_and_hash_lanes():
    """The ROADMAP's untrusted primitives surface as warnings on the real
    sparse/hash kernels (the on-TPU validation worklist), never errors."""
    for backend in ("sparse", "hash"):
        traced, _, _ = _traced_and_expected(backend=backend)
        violations, info = check_lint(traced)
        assert violations == [], (backend, violations)
        suspects = [d for d in info["diagnostics"]
                    if d["check"] == "primitive-allowlist"
                    and d["severity"] == "warning"]
        assert suspects, backend
    assert any("sort" in d["where"] or "scatter" in d["where"]
               for d in suspects)


# ---------------------------------------------------------------------------
# registry validation (import-time spec contracts)
# ---------------------------------------------------------------------------


def _spec_kwargs(**overrides):
    base = {
        "name": "_audit_test_backend",
        "executors": dict.fromkeys(backend_registry.ALGORITHMS, lambda: None),
    }
    base.update(overrides)
    return base


def _expect_register_error(match, **overrides):
    spec = BackendSpec(**_spec_kwargs(**overrides))
    with pytest.raises(ValueError, match=match):
        backend_registry.register(spec)
    assert spec.name not in backend_registry._REGISTRY


def test_register_rejects_trace_key_without_alg_placeholder():
    _expect_register_error("'{alg}' placeholder",
                           trace_key="static_key_no_placeholder")


def test_register_rejects_batched_trace_key_without_alg_placeholder():
    _expect_register_error("'{alg}' placeholder",
                           trace_key="{alg}_ok",
                           trace_key_batched="batched_no_placeholder")


def test_register_rejects_block_caps_without_block_size():
    _expect_register_error("registers no\\s+block_size",
                           needs_block_caps=True)


def test_register_rejects_traffic_model_without_audit_trace():
    _expect_register_error(
        "traffic_model without an\\s+audit_trace",
        traffic_model=lambda *a: None)


def test_register_rejects_missing_executor():
    spec = BackendSpec(name="_audit_test_backend",
                       executors={"knl": lambda: None})
    with pytest.raises(ValueError, match="missing executors"):
        backend_registry.register(spec)


def test_registered_specs_satisfy_the_validated_contracts():
    """The shipped roster passes the new import-time validations (they ran
    at registration; re-assert the invariants directly)."""
    for spec in backend_registry.specs():
        for template in (spec.trace_key, spec.trace_key_batched):
            assert template is None or "{alg}" in template, spec.name
        if spec.needs_block_caps:
            assert spec.block_size is not None, spec.name
