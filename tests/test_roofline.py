"""Roofline extraction: HLO collective parser + analytic flops + report math."""

import pytest

from repro.launch.roofline import (
    collective_bytes, analytic_model_flops, RooflineReport, _shape_bytes,
)
from repro.configs import get_config
from repro.configs.shapes import SHAPES


HLO_SAMPLE = """
HloModule jit_f

%add.clone {
  ROOT %x = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main {
  %p0 = f32[64,32]{1,0} parameter(0)
  %all-reduce = f32[16,16]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[4,2]<=[8]
  %ag = bf16[128,256]{1,0} all-gather(%p0), channel_id=2, dimensions={0}
  %rs = f32[8,8]{1,0} reduce-scatter(%ag2), channel_id=3
  %a2a = s32[4,4]{1,0} all-to-all(%x1), channel_id=4
  %cp = f32[32]{0} collective-permute(%y), channel_id=5
  %ars = (f32[10]{0}, f32[20]{0}) all-reduce-start(%z1, %z2), channel_id=6
  ROOT %out = f32[] add(%c1, %c2)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32", "16,16") == 1024
    assert _shape_bytes("bf16", "128,256") == 65536
    assert _shape_bytes("f32", "") == 4          # scalar
    assert _shape_bytes("pred", "8") == 8


def test_collective_parser_kinds_and_bytes():
    out = collective_bytes(HLO_SAMPLE)
    c = out["counts"]
    assert c["all-reduce"] == 2          # all-reduce + all-reduce-start
    assert c["all-gather"] == 1
    assert c["reduce-scatter"] == 1
    assert c["all-to-all"] == 1
    assert c["collective-permute"] == 1
    b = out["bytes"]
    assert b["all-gather"] == 128 * 256 * 2          # result bytes, mult 1.0
    assert b["all-reduce"] == (16 * 16 * 4 + (10 + 20) * 4) * 2.0  # ring 2x
    assert b["reduce-scatter"] == 8 * 8 * 4
    assert out["total_bytes"] == sum(b.values())


def test_collective_parser_empty():
    out = collective_bytes("ENTRY %main { ROOT %x = f32[] add(%a, %b) }")
    assert out["total_bytes"] == 0


def test_analytic_flops_train_vs_decode():
    cfg = get_config("llama3_2_1b")
    train = analytic_model_flops(cfg, SHAPES["train_4k"])
    # 6 N D lower bound
    assert train >= 6 * cfg.active_param_count() * 256 * 4096
    decode = analytic_model_flops(cfg, SHAPES["decode_32k"])
    assert decode < train / 1000
    # MoE active < total
    moe = get_config("olmoe_1b_7b")
    t_moe = analytic_model_flops(moe, SHAPES["train_4k"])
    assert t_moe < 6 * moe.param_count() * 256 * 4096 * 1.2


def test_swa_caps_attention_flops():
    mix = get_config("mixtral_8x22b")
    full = analytic_model_flops(
        type(mix)(**{**mix.__dict__, "sliding_window": 0}), SHAPES["prefill_32k"])
    swa = analytic_model_flops(mix, SHAPES["prefill_32k"])
    assert swa < full


def test_attention_free_has_no_attn_term():
    rwkv = get_config("rwkv6_3b")
    f = analytic_model_flops(rwkv, SHAPES["prefill_32k"])
    assert f == 2.0 * rwkv.active_param_count() * 32 * 32768


def test_report_math():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="m", n_devices=256,
        hlo_flops=1e12, hlo_bytes=1e11, coll_bytes_raw=1e9, coll_detail={},
        analytic_flops_global=256e12 * 2,    # 2e12 per device -> rho = 2
        temp_bytes=8e9, arg_bytes=1e9,
    ).finalize()
    assert rep.rho == pytest.approx(2.0)
    assert rep.t_compute == pytest.approx(2e12 / 197e12)
    assert rep.t_memory == pytest.approx(1e11 * 2 / 819e9)
    assert rep.t_collective == pytest.approx(1e9 * 2 / 50e9)
    assert rep.bottleneck == "memory"
    assert rep.fits_hbm
    assert 0 < rep.roofline_fraction() <= 1.0


def test_rho_floors_at_one():
    rep = RooflineReport(
        arch="x", shape="s", mesh="m", n_devices=1,
        hlo_flops=1e12, hlo_bytes=1e9, coll_bytes_raw=0, coll_detail={},
        analytic_flops_global=1e11,   # hlo counts MORE than model flops
    ).finalize()
    assert rep.rho == 1.0
