"""Pipeline parallelism (GPipe over shard_map/ppermute): forward, identity
padding, and AD-derived backward all match the sequential stack.

Runs in a subprocess because it needs >1 placeholder device (same pattern as
the dry-run tests); in-process tests must keep seeing 1 CPU device."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_pipeline_matches_sequential_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "pipeline_check.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    for marker in ("PIPELINE_FWD_OK", "PIPELINE_PAD_OK", "PIPELINE_GRAD_OK"):
        assert marker in r.stdout, r.stdout[-2000:]
