"""Memory cost model + placement: the paper's qualitative claims hold."""

import pytest

from repro.core.memory_model import KNL, P100, TPU_V5E, spgemm_cost
from repro.core.placement import (
    Placement, ALL_FAST, ALL_SLOW, DP, dp_recommendation, placement_cost,
)
from repro.core.locality import analyze, miss_table
from repro.sparse import multigrid, generators


@pytest.fixture(scope="module")
def rxa_axp():
    A, R, P = multigrid.problem("laplace3d", 8)
    return A, R, P


def test_dp_policy_matches_paper():
    cap = P100.fast.capacity_bytes
    assert dp_recommendation(P100, cap / 4, cap / 4, cap / 4) == ALL_FAST
    assert dp_recommendation(P100, cap, cap / 2, cap / 2) == DP
    assert dp_recommendation(P100, cap, 2 * cap, cap) == ALL_SLOW


def test_b_pin_collapses_on_gpu(rxa_axp):
    """Paper Table 3: placing B in host-pinned memory costs 7x-29x; placing the
    (small) A is nearly free."""
    A, R, P = rxa_axp
    from repro.core.kkmem import spgemm_symbolic_host
    ws = spgemm_symbolic_host(R, A)   # R x A: A is the big irregular operand
    st = analyze(R, A)
    base = placement_cost(P100, ALL_FAST, R, A, ws.c_nnz * 12.0, ws.flops, st)
    b_pin = placement_cost(P100, Placement("fast", "slow", "fast"), R, A,
                           ws.c_nnz * 12.0, ws.flops, st)
    a_pin = placement_cost(P100, Placement("slow", "fast", "fast"), R, A,
                           ws.c_nnz * 12.0, ws.flops, st)
    assert b_pin.total / base.total > 3.0         # B_pin collapses
    assert a_pin.total / base.total < 2.0         # A_pin mild


def test_knl_gap_smaller_than_gpu_gap(rxa_axp):
    """Paper conclusion: bandwidth-only asymmetry (KNL) hurts far less than
    bandwidth+latency asymmetry (GPU pinned)."""
    A, R, P = rxa_axp
    from repro.core.kkmem import spgemm_symbolic_host
    ws = spgemm_symbolic_host(R, A)
    st = analyze(R, A)
    knl_fast = placement_cost(KNL, ALL_FAST, R, A, ws.c_nnz * 12.0, ws.flops, st)
    knl_slow = placement_cost(KNL, ALL_SLOW, R, A, ws.c_nnz * 12.0, ws.flops, st)
    gpu_fast = placement_cost(P100, ALL_FAST, R, A, ws.c_nnz * 12.0, ws.flops, st)
    gpu_slow = placement_cost(P100, ALL_SLOW, R, A, ws.c_nnz * 12.0, ws.flops, st)
    knl_gap = knl_slow.total / knl_fast.total
    gpu_gap = gpu_slow.total / gpu_fast.total
    assert gpu_gap > knl_gap
    assert knl_gap < 6.0          # paper: DDR as low as ~half of HBM perf
    assert gpu_gap > 5.0          # paper: pinned collapses by 7x-29x


def test_delta_sweep_direction():
    """Paper Table 2: increasing RHS density (delta) shrinks the DDR/HBM gap."""
    A, R, P = multigrid.problem("elasticity", 4)
    gaps = []
    for delta in (1, 4, 16, 64):
        B = generators.random_uniform_degree(R.n_cols, R.n_cols, delta, seed=1)
        from repro.core.kkmem import spgemm_symbolic_host
        ws = spgemm_symbolic_host(R, B)
        st = analyze(R, B)
        fast = placement_cost(KNL, ALL_FAST, R, B, ws.c_nnz * 12.0, ws.flops, st)
        slow = placement_cost(KNL, ALL_SLOW, R, B, ws.c_nnz * 12.0, ws.flops, st)
        gaps.append(slow.total / fast.total)
    assert gaps[-1] < gaps[0]


def test_rxa_worse_locality_than_axp(rxa_axp):
    A, R, P = rxa_axp
    axp = miss_table(A, P)
    rxa = miss_table(R, A)
    assert rxa["L2"] >= axp["L2"]


def test_latency_vs_bandwidth_terms():
    """Tiny rows on the P100 slow level are latency-dominated; fat rows are
    bandwidth-dominated — the prefetch-amortization effect (paper §3.1)."""
    thin = spgemm_cost(P100, bytes_A=1e6, bytes_B=1e8, bytes_C=1e6, flops=1e9,
                       b_row_reads=1e6, b_row_bytes=12, b_miss_fraction=0.5,
                       place_B="slow")
    fat = spgemm_cost(P100, bytes_A=1e6, bytes_B=1e8, bytes_C=1e6, flops=1e9,
                      b_row_reads=1e4, b_row_bytes=1200, b_miss_fraction=0.5,
                      place_B="slow")
    assert thin.t_B > fat.t_B


def test_tpu_preset_constants():
    assert TPU_V5E.flops_peak == 197e12
    assert abs(TPU_V5E.slow.bandwidth_Bps - 819e9) < 1e6
    assert TPU_V5E.fast.capacity_bytes == 128 * (1 << 20)
