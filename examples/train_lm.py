"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on CPU.

A scaled-down llama-family config (~100M params) on the synthetic pipeline, with
checkpointing, microbatch accumulation, and the straggler watchdog — the full
training path of the framework, for real.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 512]
"""

import argparse

from repro.launch.train import train_loop
from repro.models.config import ModelConfig
from repro.train.optim import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--grad-compression", choices=("none", "int8"),
                    default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lm100m", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 256, 1),
        d_ff=args.d_model * 4, vocab_size=32768,
        q_chunk=128, attn_chunk=128,
    )
    from repro.models import transformer as tf
    import jax
    n = sum(int(x.size) for x in jax.tree.leaves(tf.abstract_params(cfg)))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch_size}x{args.seq_len}")

    tcfg = TrainConfig(
        learning_rate=6e-4, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps, microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    stats = train_loop(
        cfg, tcfg, batch_size=args.batch_size, seq_len=args.seq_len,
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    print(f"[train_lm] finished: {stats}")


if __name__ == "__main__":
    main()
