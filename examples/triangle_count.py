"""Graph-analytics example: linear-algebra triangle counting (paper §4.1.2).

The fused path: triangles = sum((L @ L) o L) with the L-mask applied inside
the chunked backend's merge (``BackendSpec.run_masked``), so the unmasked
product is never materialized. Every mask-capable registered backend runs
and is checked against the unfused kkmem sort-merge baseline and (at small
scale) the dense oracle.

  PYTHONPATH=src python examples/triangle_count.py --scale 12
"""

import argparse
import time

from repro.core import backend_registry
from repro.core.triangle import (
    count_triangles, count_triangles_dense, count_triangles_kkmem,
)
from repro.core.placement import dp_recommendation
from repro.core.memory_model import KNL
from repro.sparse import graphs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11,
                    help="RMAT scale (2^scale vertices)")
    ap.add_argument("--edge-factor", type=int, default=8)
    args = ap.parse_args()

    G = graphs.rmat(args.scale, args.edge_factor, seed=7)
    L = graphs.lower_triangular_degree_sorted(G)
    print(f"[tc] graph: {G.shape[0]} vertices, {int(G.nnz())//2} edges; "
          f"L nnz={int(L.nnz())}")
    tri = None
    for backend in backend_registry.masked_backends():
        t0 = time.time()
        tri = float(count_triangles(L, backend=backend))
        dt = time.time() - t0
        print(f"[tc] fused/{backend:6s}: triangles = {tri:.0f} in "
              f"{dt*1e3:.0f} ms (mask inside the kernel, no unmasked C)")
    t0 = time.time()
    base = float(count_triangles_kkmem(L))
    dt = time.time() - t0
    print(f"[tc] kkmem baseline: {base:.0f} in {dt*1e3:.0f} ms "
          f"(unfused, C at full symbolic capacity); agrees: {base == tri}")
    if args.scale <= 11:
        want = float(count_triangles_dense(L))
        print(f"[tc] dense oracle agrees: {abs(tri - want) < 1e-3}")
    rec = dp_recommendation(KNL, 0.0, L.nbytes(), 0.0)
    print(f"[tc] DP (paper: place compressed L fast): L -> {rec.B}")


if __name__ == "__main__":
    main()
