"""Graph-analytics example: linear-algebra triangle counting (paper §4.1.2).

  PYTHONPATH=src python examples/triangle_count.py --scale 12
"""

import argparse
import time

from repro.core.triangle import count_triangles, count_triangles_dense
from repro.core.placement import dp_recommendation
from repro.core.memory_model import KNL
from repro.sparse import graphs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=11,
                    help="RMAT scale (2^scale vertices)")
    ap.add_argument("--edge-factor", type=int, default=8)
    args = ap.parse_args()

    G = graphs.rmat(args.scale, args.edge_factor, seed=7)
    L = graphs.lower_triangular_degree_sorted(G)
    print(f"[tc] graph: {G.shape[0]} vertices, {int(G.nnz())//2} edges; "
          f"L nnz={int(L.nnz())}")
    t0 = time.time()
    tri = float(count_triangles(L))
    dt = time.time() - t0
    print(f"[tc] triangles = {tri:.0f} in {dt*1e3:.0f} ms (masked L.L SpGEMM)")
    if args.scale <= 11:
        want = float(count_triangles_dense(L))
        print(f"[tc] dense oracle agrees: {abs(tri - want) < 1e-3}")
    rec = dp_recommendation(KNL, 0.0, L.nbytes(), 0.0)
    print(f"[tc] DP (paper: place compressed L fast): L -> {rec.B}")


if __name__ == "__main__":
    main()
