"""Paper-reproduction driver: the full multigrid SpGEMM study at CPU scale.

Runs the paper's experiment grid — 4 problems x {A x P, R x A} x memory modes x
placements x chunked variants — and prints the same comparisons the paper plots
(Figs 3/4/6/7, Table 3, Figs 12/13), using the calibrated memory model for the
machine-dependent numbers and real execution for all algorithmic results.

The chunked section runs through the ``chunked_spgemm`` backend dispatch:
every backend in ``--backends`` (comma-separated; ``all`` = every registered
backend plus ``auto``) executes the same plan and is checked against the
dense oracle, so the example doubles as an end-to-end demo of the executor
stack — host loop oracle, device-resident lax.scan, double-buffered Pallas,
the CSR-native ESC sparse-output accumulator, its hash-probe variant, the
BSR/MXU-blocked backend, and the planner-driven ``auto`` dispatch over the
registered accumulators. The roster comes from
``repro.core.backend_registry``: a newly registered backend appears here
(and in the example's test) without editing this file.

  PYTHONPATH=src python examples/multigrid_spgemm.py [--problem brick3d]
      [--size 6] [--backends scan,hash]
"""

import argparse

import numpy as np

from repro.core import backend_registry
from repro.core.chunking import chunked_spgemm
from repro.core.kkmem import spgemm, spgemm_symbolic_host, spgemm_dense_oracle
from repro.core.pipeline_spgemm import pipeline_spgemm
from repro.core.locality import analyze, miss_table
from repro.core.memory_model import KNL, P100
from repro.core.placement import (
    ALL_FAST, ALL_SLOW, DP, placement_cost, dp_recommendation,
)
from repro.core.planner import plan_chunks, row_bytes_csr
from repro.sparse import multigrid
from repro.sparse.csr import csr_to_dense

ALL_BACKENDS = (*backend_registry.all_backends(), "auto")


def study(problem: str, n: int, backends=("scan",)):
    A, R, P = multigrid.problem(problem, n)
    print(f"\n=== {problem} (n={n}) — A {A.shape} nnz={int(A.nnz())} ===")
    for tag, (L, Rt) in {"AxP": (A, P), "RxA": (R, A)}.items():
        ws = spgemm_symbolic_host(L, Rt)
        st = analyze(L, Rt)
        C = spgemm(L, Rt, ws.c_pad)
        ok = np.allclose(np.asarray(csr_to_dense(C)),
                         np.asarray(spgemm_dense_oracle(L, Rt)), atol=1e-4)
        locality = miss_table(L, Rt)
        print(f"\n-- {tag}: correct={ok} flops={ws.flops} "
              f"L2miss~{locality['L2']:.2f} reuse={locality['mean_reuse_rows']:.0f}")
        print(f"   {'mode':22s} {'GFLOP/s':>9s}")
        for sys_name, system in (("KNL", KNL), ("P100", P100)):
            for mode, pl in (("all-fast(HBM)", ALL_FAST), ("all-slow", ALL_SLOW),
                             ("DP(B fast)", DP)):
                c = placement_cost(system, pl, L, Rt, ws.c_nnz * 12.0, ws.flops,
                                   st)
                print(f"   {sys_name}/{mode:17s} {c.gflops(ws.flops):9.3f}")
        rec = dp_recommendation(P100, L.nbytes(), Rt.nbytes(), ws.c_nnz * 12.0)
        print(f"   DP recommendation: B -> {rec.B}")
        # chunked under half/quarter fast budgets, through every backend
        crb = np.full(L.n_rows, max(ws.c_nnz / L.n_rows, 1) * 12.0)
        total = float(row_bytes_csr(L).sum() + row_bytes_csr(Rt).sum()
                      + crb.sum())
        ref = np.asarray(spgemm_dense_oracle(L, Rt))
        for frac in (0.5, 0.25):
            plan = plan_chunks(L, Rt, crb, P100, fast_limit_bytes=total * frac)
            for backend in backends:
                C2, stats = chunked_spgemm(L, Rt, plan, backend=backend)
                ok2 = np.allclose(np.asarray(csr_to_dense(C2)), ref, atol=1e-4)
                print(f"   chunked@{frac:.2f}/{backend:6s}: {plan.algorithm} "
                      f"[{plan.n_ac}x{plan.n_b}] correct={ok2} "
                      f"staged={stats.copy_bytes/1e3:.0f}KB")
    # the fused two-hop Galerkin product C = R x (A x P) through the pipeline
    # executor: the intermediate T = A x P stays resident in fast memory when
    # the planner's budget allows, spills to slow otherwise
    rap = np.asarray(csr_to_dense(R)) @ np.asarray(spgemm_dense_oracle(A, P))
    total = float(row_bytes_csr(A).sum() + row_bytes_csr(P).sum()
                  + row_bytes_csr(R).sum())
    print("\n-- RAP: fused two-hop pipeline (T = AxP resident when it fits)")
    for frac in (1.0, 0.25):
        for backend in ("sparse", "hash"):
            C3, pstats = pipeline_spgemm(A, P, R, system=P100,
                                         fast_limit_bytes=total * frac,
                                         backend=backend)
            ok3 = np.allclose(np.asarray(csr_to_dense(C3)), rap, atol=1e-4)
            pp = pstats.plan
            print(f"   pipeline@{frac:.2f}/{backend:6s}: "
                  f"{pp.plan1.algorithm}+{pp.plan2.algorithm} "
                  f"resident={pp.t_resident} correct={ok3} "
                  f"copied={pstats.copy_bytes/1e3:.0f}KB")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=list(multigrid.PROBLEMS) + ["all"],
                    default="all")
    ap.add_argument("--size", type=int, default=None,
                    help="override the per-problem default size")
    ap.add_argument("--backends", default="scan",
                    help="comma-separated chunked_spgemm backends, or 'all'")
    args = ap.parse_args(argv)
    backends = (ALL_BACKENDS if args.backends == "all"
                else tuple(args.backends.split(",")))
    unknown = set(backends) - set(ALL_BACKENDS)
    if unknown:
        ap.error(f"unknown backends {sorted(unknown)}; have {ALL_BACKENDS}")
    sizes = {"laplace3d": 12, "bigstar2d": 40, "brick3d": 10, "elasticity": 6}
    probs = multigrid.PROBLEMS if args.problem == "all" else [args.problem]
    for p in probs:
        study(p, args.size or sizes[p], backends=backends)


if __name__ == "__main__":
    main()
