"""Serving example: batched prefill + greedy decode with uneven prompts.

  PYTHONPATH=src python examples/serve_lm.py --arch mixtral_8x22b --smoke
"""

import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.serve import serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size, int(rng.integers(5, 40))).tolist()
        for _ in range(args.batch)
    ]
    print(f"[serve_lm] {cfg.name}: {len(prompts)} prompts, lens "
          f"{[len(p) for p in prompts]}")
    outs, stats = serve_batch(cfg, prompts,
                              max_new_tokens=args.max_new_tokens,
                              cache_len=128)
    for i, o in enumerate(outs):
        print(f"[serve_lm] seq {i}: generated {len(o)} tokens: {o[:10]}...")
    print(f"[serve_lm] prefill {stats.prefill_s*1e3:.0f} ms, decode "
          f"{stats.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
