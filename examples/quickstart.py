"""Quickstart: the paper's technique in 60 lines.

Builds a multigrid triple-product problem, plans a two-level-memory chunked
SpGEMM with the paper's Algorithm-4 heuristic, executes it, and verifies the
chunk-invariance against the dense oracle.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.sparse import multigrid
from repro.sparse.csr import csr_to_dense
from repro.core.kkmem import spgemm_full, spgemm_symbolic_host, spgemm_dense_oracle
from repro.core.planner import plan_chunks, plan_knl, row_bytes_csr
from repro.core.chunking import chunked_spgemm
from repro.core.placement import dp_recommendation
from repro.core.memory_model import P100


def main():
    # 1. a Brick3D multigrid problem: A_c = R x A_f x P, P = R^T
    A, R, P = multigrid.problem("brick3d", 8)
    print(f"A: {A.shape} nnz={int(A.nnz())}, R: {R.shape} nnz={int(R.nnz())}")

    # 2. one-level baseline (KKMEM numeric phase)
    C = spgemm_full(A, P)
    ref = np.asarray(spgemm_dense_oracle(A, P))
    assert np.allclose(np.asarray(csr_to_dense(C)), ref, atol=1e-4)
    print(f"baseline A x P ok: C nnz={int(C.nnz())}")

    # 3. what would the paper place where? (selective data placement, §3.2.1)
    ws = spgemm_symbolic_host(A, P)
    rec = dp_recommendation(P100, A.nbytes(), P.nbytes(), ws.c_nnz * 12.0)
    print(f"DP recommendation on P100-like memory: A={rec.A} B={rec.B} C={rec.C}")

    # 4. chunked execution under a tight fast memory (Algorithm 4 plans it)
    crb = np.full(A.n_rows, max(ws.c_nnz / A.n_rows, 1) * 12.0)
    budget = (float(row_bytes_csr(A).sum() + row_bytes_csr(P).sum())
              + float(crb.sum())) / 4
    plan = plan_chunks(A, P, crb, P100, fast_limit_bytes=budget)
    print(f"plan: {plan.algorithm} with {plan.n_ac} A/C strips x {plan.n_b} B "
          f"chunks, modeled copy = {plan.copy_bytes/1e3:.1f} KB")
    C2, stats = chunked_spgemm(A, P, plan)
    assert np.allclose(np.asarray(csr_to_dense(C2)), ref, atol=1e-4)
    print(f"chunked == unchunked == oracle; actual staged bytes = "
          f"{stats.copy_bytes/1e3:.1f} KB in {stats.kernel_calls} kernel calls")

    # 5. KNL-style single-level-B chunking (Algorithm 1)
    plan_k = plan_knl(A, P, fast_limit_bytes=float(row_bytes_csr(P).sum()) / 3)
    C3, stats_k = chunked_spgemm(A, P, plan_k)
    assert np.allclose(np.asarray(csr_to_dense(C3)), ref, atol=1e-4)
    print(f"Alg-1 chunking ok: {plan_k.n_b} B chunks, "
          f"{stats_k.kernel_calls} fused multiply-add calls")


if __name__ == "__main__":
    main()
